//! `repro` — CLI for the ADC/DAC-free frequency-domain accelerator stack.
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!
//! ```text
//! repro transform [--dim D] [--bits B] [--backend digital|noisy|analog]
//!                 [--tile N] [--vdd V] [--sigma-ant S] [--seed K]
//! repro infer     [--weights PATH] [--artifacts DIR] [--backend ...]
//!                 [--shards N] [--workers W] [--batch B]
//! repro train     [--artifacts DIR] [--steps N] [--log-every K]
//! repro serve     [--requests N] [--workers W] [--tile N] [--bits B]
//!                 [--listen ADDR] [--shards N] [--backend digital|noisy|analog]
//!                 [--weights PATH] [--max-infer-batch N] [--no-respawn]
//!                 [--max-batch N] [--max-wait-us U] [--keepalive-requests N]
//!                 [--max-inflight N] [--rate R] [--burst B] [--duration-s S]
//!                 [--trace-sample K] [--slow-ms MS]
//!                 [--fidelity-sample K] [--drift-threshold X]
//!                 [--reactor-threads N] [--first-byte-timeout-ms MS]
//!                 [--default-deadline-ms MS] [--max-deadline-ms MS]
//!                 [--drain-timeout-ms MS] [--chaos-spec SPEC]
//! repro report    [--vdd V] [--avg-cycles C]
//! ```
//!
//! `serve --listen ADDR` starts the HTTP serving subsystem (dynamic
//! micro-batching + admission control + /metrics); without `--listen` it
//! runs the original offline batch benchmark.
//!
//! `train` is the end-to-end driver: it loads the AOT `train_step`
//! artifact via PJRT and trains the BWHT classifier from rust — python
//! never runs.  See examples/ for library-level versions of each flow.

use std::collections::HashMap;
use std::time::Instant;

#[cfg(feature = "pjrt")]
use anyhow::anyhow;
use anyhow::{bail, Result};

use repro::analog::crossbar::CrossbarConfig;
use repro::bitplane::QuantBwht;
use repro::coordinator::{required_tile, Coordinator, CoordinatorConfig, TileKind, TransformRequest};
use repro::energy::{table1, EnergyModel};
use repro::exec::Sharded;
use repro::nn::{loader::Weights, Backend, Mlp};
use repro::npy;
#[cfg(feature = "pjrt")]
use repro::runtime::{HostTensor, Runtime};
use repro::server::{AdmissionConfig, Server, ServerConfig};
use repro::shard::{ShardSet, ShardSetConfig};
use repro::util::rng::Rng;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), val);
        }
        i += 1;
    }
    flags
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags
        .get(key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Parse and validate `--tile N` (the crossbar macro geometry).  The
/// library asserts deep inside `wht::bwht_blocks` and worker threads
/// (`Tile::new`) that the tile is a power of two `>= MIN_BLOCK`; validate
/// up front so a bad flag is a clean CLI error, not a thread panic.
fn tile_flag(flags: &HashMap<String, String>) -> Result<usize> {
    let raw = flags.get("tile").map(String::as_str);
    let tile: usize = match raw {
        None => 16,
        Some(s) => s
            .parse()
            .map_err(|_| anyhow::anyhow!("--tile must be an integer, got {s:?}"))?,
    };
    if !tile.is_power_of_two() || tile < repro::wht::MIN_BLOCK {
        bail!(
            "--tile must be a power of two >= {} (16 or 32 in the paper), got {tile}",
            repro::wht::MIN_BLOCK
        );
    }
    Ok(tile)
}

/// Parse and validate `--bits B` (input magnitude bitplanes).  The
/// sign-magnitude quantizer supports 1..=16 planes; validate up front so
/// `--bits 0` (or an absurd 64) is a clean CLI error, mirroring the
/// `--tile` validation, instead of a submission-time failure.
fn bits_flag(flags: &HashMap<String, String>) -> Result<u32> {
    let raw = flags.get("bits").map(String::as_str);
    let bits: u32 = match raw {
        None => 8,
        Some(s) => s
            .parse()
            .map_err(|_| anyhow::anyhow!("--bits must be an integer, got {s:?}"))?,
    };
    if !(1..=16).contains(&bits) {
        bail!("--bits must be in 1..=16 magnitude bitplanes (8 in the paper), got {bits}");
    }
    Ok(bits)
}

/// Parse and validate `--fidelity-sample K` (shadow-verify 1 slice in
/// every K served by a noisy/analog shard; 0 disables the monitor).
/// Mirrors the `--tile`/`--bits` pattern: a malformed flag is a clean
/// CLI error instead of silently falling back to the default.
fn fidelity_sample_flag(flags: &HashMap<String, String>) -> Result<u32> {
    match flags.get("fidelity-sample").map(String::as_str) {
        None => Ok(16),
        Some(s) => s.parse().map_err(|_| {
            anyhow::anyhow!(
                "--fidelity-sample must be a non-negative integer (0 disables), got {s:?}"
            )
        }),
    }
}

/// Parse and validate `--drift-threshold X` (quantizer LSBs of mean
/// divergence a shard slot's EWMA may reach before it is recycled).
fn drift_threshold_flag(flags: &HashMap<String, String>) -> Result<f64> {
    let threshold: f64 = match flags.get("drift-threshold").map(String::as_str) {
        None => 1.0,
        Some(s) => s
            .parse()
            .map_err(|_| anyhow::anyhow!("--drift-threshold must be a number, got {s:?}"))?,
    };
    if !(threshold.is_finite() && threshold > 0.0) {
        bail!(
            "--drift-threshold must be a positive, finite number of quantizer LSBs, got {threshold}"
        );
    }
    Ok(threshold)
}

/// SIGTERM/SIGINT → graceful drain.  Hand-rolled `signal(2)` binding
/// (the build box is offline: no signal-hook crate); the handler only
/// stores to an atomic, which is async-signal-safe.
mod signals {
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static SHUTDOWN: AtomicBool = AtomicBool::new(false);

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" fn handle(_sig: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }

    pub fn install() {
        unsafe {
            signal(SIGINT, handle as extern "C" fn(i32) as usize);
            signal(SIGTERM, handle as extern "C" fn(i32) as usize);
        }
    }
}

/// `--chaos-spec SPEC` (or the `REPRO_CHAOS_SPEC` env var):
/// `point=rate[,seed][;point=rate...]` deterministic fault injection.
/// Parsing fails loudly when the binary was built without
/// `--features chaos`, so a requested fault plan is never silently
/// ignored.
fn chaos_flag(flags: &HashMap<String, String>) -> Result<repro::chaos::ChaosPlan> {
    let spec = flags
        .get("chaos-spec")
        .cloned()
        .or_else(|| std::env::var("REPRO_CHAOS_SPEC").ok())
        .unwrap_or_default();
    repro::chaos::ChaosPlan::parse(&spec)
}

fn backend_from_flags(flags: &HashMap<String, String>) -> Backend {
    match flags.get("backend").map(|s| s.as_str()).unwrap_or("quantized") {
        "float" => Backend::Float,
        "noisy" => Backend::Noisy {
            bits: flag(flags, "bits", 8u32),
            sigma_ant: flag(flags, "sigma-ant", 2e-3f64),
        },
        _ => Backend::Quantized {
            bits: flag(flags, "bits", 8u32),
        },
    }
}

/// `--backend digital|noisy|analog` → the tile execution backend
/// (shared by `transform` and `serve`; per-shard/per-worker variability
/// seeds are derived downstream from `--seed`).
fn tile_kind_from_flags(flags: &HashMap<String, String>, tile: usize, vdd: f64) -> TileKind {
    match flags.get("backend").map(|s| s.as_str()).unwrap_or("digital") {
        "noisy" => TileKind::Noisy {
            sigma_ant: flag(flags, "sigma-ant", 2e-3f64),
        },
        "analog" => TileKind::Analog {
            config: CrossbarConfig::new(tile, vdd),
        },
        _ => TileKind::Digital,
    }
}

fn cmd_transform(flags: &HashMap<String, String>) -> Result<()> {
    let dim: usize = flag(flags, "dim", 64);
    let bits = bits_flag(flags)?;
    let tile = tile_flag(flags)?;
    let seed: u64 = flag(flags, "seed", 0);
    let vdd: f64 = flag(flags, "vdd", 0.8);
    let kind = tile_kind_from_flags(flags, tile, vdd);
    let mut rng = Rng::seed_from_u64(seed);
    let x: Vec<f32> = (0..dim).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: tile,
        bits,
        kind,
        seed,
        ..Default::default()
    });
    let t0 = Instant::now();
    let out = coord.transform(&TransformRequest {
        x: x.clone(),
        thresholds_units: vec![0.0; dim],
        scale: None,
        deadline: None,
    })?;
    let dt = t0.elapsed();
    let exact = {
        let padded = repro::wht::bwht_padded_dim(dim, tile);
        let mut xp = x.clone();
        xp.resize(padded, 0.0);
        QuantBwht::new(dim, tile, bits).transform_exact(&xp)
    };
    let cos = {
        let dot: f32 = out.iter().zip(&exact).map(|(a, b)| a * b).sum();
        let na: f32 = out.iter().map(|v| v * v).sum::<f32>().sqrt();
        let nb: f32 = exact.iter().map(|v| v * v).sum::<f32>().sqrt();
        dot / (na * nb).max(1e-12)
    };
    let m = coord.metrics();
    let model = EnergyModel::new(tile, vdd);
    println!("transform dim={dim} bits={bits} tile={tile} ({dt:?})");
    println!("  cosine vs exact float transform: {cos:.4}");
    println!(
        "  planes issued: {}  row-cycles: {}",
        m.planes_issued, m.row_cycles
    );
    println!("  modelled energy: {:.1} fJ", m.energy_fj(&model));
    coord.shutdown();
    Ok(())
}

fn cmd_infer(flags: &HashMap<String, String>) -> Result<()> {
    let weights_path = flags
        .get("weights")
        .cloned()
        .unwrap_or_else(|| "artifacts/mlp_qat.json".into());
    let dir = flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".into());
    let w = Weights::load(&weights_path)?;
    let mlp = Mlp::from_weights(&w)?;
    let x = npy::load_f32(format!("{dir}/test_x.npy"))?;
    let y = npy::load_i32(format!("{dir}/test_y.npy"))?;
    let batch: usize = flag(flags, "batch", 256);
    let shards: usize = flag(flags, "shards", 0);
    let t0 = Instant::now();
    if shards > 0 {
        // Crossbar-pool path: the model's BWHT transforms scatter–gather
        // across N coordinator pools through the same executor seam the
        // server uses.  `--backend digital|noisy|analog` picks the tile
        // model; digital is bit-identical to the quantized software
        // path.  Tiles are sized to the widest block of the model's
        // partition; narrower blocks run under sub-tile masking, so any
        // hidden width works.
        let tile = required_tile(mlp.bwht.transform_blocks())?;
        let vdd: f64 = flag(flags, "vdd", 0.8);
        let mut set = ShardSet::new(ShardSetConfig {
            shards,
            coordinator: CoordinatorConfig {
                tile_n: tile,
                bits: bits_flag(flags)?,
                workers: flag(flags, "workers", 4),
                seed: flag(flags, "seed", 0),
                kind: tile_kind_from_flags(flags, tile, vdd),
                ..Default::default()
            },
            ..Default::default()
        })?;
        let acc = {
            let mut executor = Sharded::new(&mut set);
            mlp.evaluate_with(&mut executor, &x.data, &y.data, batch)?
        };
        let m = set.metrics();
        println!(
            "infer {} on {} samples [{} shard(s), {}x{} tiles, {} backend]: accuracy {:.2}% ({:?})",
            weights_path,
            y.len(),
            shards,
            tile,
            tile,
            flags.get("backend").map(|s| s.as_str()).unwrap_or("digital"),
            acc * 100.0,
            t0.elapsed()
        );
        println!(
            "  crossbar slices {} | avg bitplane cycles/elem {:.2} | row-cycles {}",
            m.requests,
            m.average_cycles(),
            m.row_cycles
        );
        set.shutdown();
    } else {
        let backend = backend_from_flags(flags);
        let mut rng = Rng::seed_from_u64(flag(flags, "seed", 0u64));
        let acc = mlp.evaluate(&x.data, &y.data, backend, &mut rng, batch);
        println!(
            "infer {} on {} samples [{:?}]: accuracy {:.2}% ({:?})",
            weights_path,
            y.len(),
            backend,
            acc * 100.0,
            t0.elapsed()
        );
    }
    Ok(())
}

/// Stub when built without the XLA/PJRT toolchain.
#[cfg(not(feature = "pjrt"))]
fn cmd_train(_flags: &HashMap<String, String>) -> Result<()> {
    bail!(
        "`repro train` needs the PJRT runtime; rebuild with `--features pjrt` \
         (requires the XLA toolchain)"
    )
}

/// The E2E driver: PJRT-load train_step, train from rust, report.
#[cfg(feature = "pjrt")]
fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let dir = flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".into());
    let steps: usize = flag(flags, "steps", 300);
    let log_every: usize = flag(flags, "log-every", 25);
    let batch = 64usize;

    let mut rt = Runtime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());

    // Initial parameters + dataset, exported by `make artifacts`.
    let mut params: Vec<HostTensor> = ["fc1_w", "fc1_b", "bwht_t", "fc2_w", "fc2_b"]
        .iter()
        .map(|name| {
            let arr = npy::load_f32(format!("{dir}/init_{name}.npy"))?;
            Ok(HostTensor::f32(&arr.shape, arr.data))
        })
        .collect::<Result<Vec<_>>>()?;
    let xtr = npy::load_f32(format!("{dir}/train_x.npy"))?;
    let ytr = npy::load_i32(format!("{dir}/train_y.npy"))?;
    let xte = npy::load_f32(format!("{dir}/test_x.npy"))?;
    let yte = npy::load_i32(format!("{dir}/test_y.npy"))?;
    let din = xtr.shape[1];
    let ntrain = xtr.shape[0];

    let mut rng = Rng::seed_from_u64(flag(flags, "seed", 0u64));
    let t0 = Instant::now();
    println!("step,loss");
    for step in 0..steps {
        let mut bx = Vec::with_capacity(batch * din);
        let mut by = Vec::with_capacity(batch);
        for _ in 0..batch {
            let i = rng.int_range(0, ntrain as i64 - 1) as usize;
            bx.extend_from_slice(xtr.row(i));
            by.push(ytr.data[i]);
        }
        let mut inputs = params.clone();
        inputs.push(HostTensor::f32(&[batch, din], bx));
        inputs.push(HostTensor::i32(&[batch], by));
        let mut outputs = rt.run("train_step", &inputs)?;
        let loss = outputs.pop().ok_or_else(|| anyhow!("missing loss"))?;
        params = outputs;
        if step % log_every == 0 || step == steps - 1 {
            println!("{step},{:.4}", loss.scalar_f32()?);
        }
    }
    let train_time = t0.elapsed();

    // Evaluate the trained weights through the rust inference engine on
    // (a) the exact float path, (b) the digital ADC-free quantized path.
    let flat: Vec<Vec<f32>> = params
        .iter()
        .map(|t| t.as_f32().map(|d| d.to_vec()))
        .collect::<Result<_>>()?;
    let hidden = 64;
    let mlp = Mlp::from_flat(
        din,
        hidden,
        10,
        flat[0].clone(),
        flat[1].clone(),
        flat[2].clone(),
        flat[3].clone(),
        flat[4].clone(),
    );
    let mut r2 = Rng::seed_from_u64(1);
    let acc_q = mlp.evaluate(
        &xte.data,
        &yte.data,
        Backend::Quantized { bits: 8 },
        &mut r2,
        256,
    );
    let acc_f = mlp.evaluate(&xte.data, &yte.data, Backend::Float, &mut r2, 256);
    println!("trained {steps} steps in {train_time:?}");
    println!(
        "test accuracy: float backend {:.2}%  quantized(8b) backend {:.2}%",
        acc_f * 100.0,
        acc_q * 100.0
    );
    Ok(())
}

/// Network mode: a long-running HTTP service over the sharded
/// coordinator pools.
fn cmd_serve_network(listen: &str, flags: &HashMap<String, String>) -> Result<()> {
    let tile = tile_flag(flags)?;
    let vdd: f64 = flag(flags, "vdd", 0.8);
    let shards: usize = flag(flags, "shards", 1);
    let backend = flags
        .get("backend")
        .cloned()
        .unwrap_or_else(|| "digital".to_string());
    let model = match flags.get("weights") {
        Some(path) => {
            let w = Weights::load(path)?;
            Some(Mlp::from_weights(&w)?)
        }
        None => None,
    };
    // A hosted model bounds the tile width from below: the tile must fit
    // the model's widest BWHT block (narrower blocks of a mixed
    // partition run under sub-tile masking, so any hidden width serves).
    // The tile backend (analog crossbar geometry in particular) must be
    // built for the effective width, not the raw --tile flag.
    let effective_tile = match &model {
        Some(m) => required_tile(m.bwht.transform_blocks())?.max(tile),
        None => tile,
    };
    let chaos = chaos_flag(flags)?;
    let config = ServerConfig {
        listen: listen.to_string(),
        coordinator: CoordinatorConfig {
            tile_n: effective_tile,
            bits: bits_flag(flags)?,
            workers: flag(flags, "workers", 4),
            seed: flag(flags, "seed", 0),
            kind: tile_kind_from_flags(flags, effective_tile, vdd),
            chaos: chaos.clone(),
            ..Default::default()
        },
        shards: shards.max(1),
        admission: AdmissionConfig {
            max_inflight: flag(flags, "max-inflight", 256),
            rate_per_sec: flag(flags, "rate", 0.0),
            burst: flag(flags, "burst", 32.0),
        },
        max_batch: flag(flags, "max-batch", 32),
        max_wait_us: flag(flags, "max-wait-us", 200),
        max_connections: flag(flags, "max-connections", 512),
        vdd,
        keepalive_max_requests: flag(flags, "keepalive-requests", 64),
        reactor_threads: flag(flags, "reactor-threads", 2usize),
        first_byte_timeout: std::time::Duration::from_millis(flag(
            flags,
            "first-byte-timeout-ms",
            10_000u64,
        )),
        model,
        max_infer_batch: flag(flags, "max-infer-batch", 64),
        auto_respawn: !flags.contains_key("no-respawn"),
        trace_sample: flag(flags, "trace-sample", 1u32),
        slow_ms: flag(flags, "slow-ms", 0u64),
        fidelity_sample: fidelity_sample_flag(flags)?,
        drift_threshold: drift_threshold_flag(flags)?,
        default_deadline_ms: flags.get("default-deadline-ms").and_then(|v| v.parse().ok()),
        max_deadline_ms: flag(flags, "max-deadline-ms", 60_000u64),
        drain_timeout_ms: flag(flags, "drain-timeout-ms", 5_000u64),
        ..Default::default()
    };
    let has_model = config.model.is_some();
    let duration_s: u64 = flag(flags, "duration-s", 0);
    let drain_timeout = std::time::Duration::from_millis(config.drain_timeout_ms.max(1));
    signals::install();
    let server = Server::start(config)?;
    println!("repro serve listening on http://{}", server.addr);
    println!(
        "  {} shard(s) x {} worker(s), {} backend, tile {}x{}",
        shards.max(1),
        flag::<usize>(flags, "workers", 4),
        backend,
        effective_tile,
        effective_tile
    );
    println!("  POST /v1/transform  {{\"x\": [...], \"thresholds\": [...]}}");
    if has_model {
        println!("  POST /v1/infer      {{\"x\": [...]}} or {{\"x\": [[...], ...]}} -> logits");
    }
    println!("  GET  /metrics       Prometheus text format (merged + per-shard + per-stage)");
    println!("  GET  /healthz       liveness probe");
    println!("  GET  /readyz        readiness probe (503 + per-shard JSON when degraded)");
    println!("  GET  /debug/traces  recent request traces (?n=K, ?format=chrome)");
    println!("  GET  /debug/fidelity  shadow-verification snapshot (?n=K recent checks)");
    if chaos.is_enabled() {
        println!("  CHAOS: deterministic fault injection armed ({})", chaos.describe());
    }
    // Serve until SIGTERM/SIGINT (or --duration-s elapses), then drain
    // gracefully: stop accepting, fail /readyz, let in-flight requests
    // finish (bounded by --drain-timeout-ms) and exit 0.
    let until = (duration_s > 0)
        .then(|| Instant::now() + std::time::Duration::from_secs(duration_s));
    while !signals::SHUTDOWN.load(std::sync::atomic::Ordering::SeqCst) {
        if until.is_some_and(|t| Instant::now() >= t) {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    eprintln!("repro serve: draining (up to {drain_timeout:?})...");
    let m = server.drain(drain_timeout);
    println!(
        "served {} transform slices | avg bitplane cycles {:.2} | worker p50 {:.0} us",
        m.requests,
        m.average_cycles(),
        m.latency.quantile_us(0.5)
    );
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    if let Some(listen) = flags.get("listen") {
        return cmd_serve_network(listen, flags);
    }
    let requests: usize = flag(flags, "requests", 1000);
    let workers: usize = flag(flags, "workers", 4);
    let tile = tile_flag(flags)?;
    let bits = bits_flag(flags)?;
    let dim: usize = flag(flags, "dim", 64);
    let vdd: f64 = flag(flags, "vdd", 0.8);
    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: tile,
        bits,
        workers,
        kind: tile_kind_from_flags(flags, tile, vdd),
        seed: flag(flags, "seed", 0),
        ..Default::default()
    });
    let mut rng = Rng::seed_from_u64(7);
    let reqs: Vec<TransformRequest> = (0..requests)
        .map(|_| {
            let x: Vec<f32> = (0..dim)
                .map(|_| rng.uniform_range(-1.0, 1.0) as f32)
                .collect();
            let th: Vec<f64> = (0..dim)
                .map(|_| {
                    repro::bitplane::early_term::sample_threshold(
                        &mut rng,
                        repro::bitplane::early_term::ThresholdDist::Wald,
                        1.0,
                    )
                    .abs()
                        * 255.0
                })
                .collect();
            TransformRequest {
                x,
                thresholds_units: th,
                scale: None,
                deadline: None,
            }
        })
        .collect();
    let t0 = Instant::now();
    coord.transform_batch(&reqs)?;
    let dt = t0.elapsed();
    let m = coord.metrics();
    let model = EnergyModel::new(tile, vdd);
    println!("served {requests} transform requests (dim {dim}) in {dt:?}");
    println!(
        "  throughput: {:.0} req/s | avg cycles/elem {:.2} | early-terminated {:.1}%",
        requests as f64 / dt.as_secs_f64(),
        m.average_cycles(),
        100.0 * m.cycles.terminated_early as f64 / m.cycles.total_elements as f64
    );
    println!(
        "  modelled energy {:.2} nJ | effective {:.0} TOPS/W",
        m.energy_fj(&model) / 1e6,
        m.tops_per_watt(&model)
    );
    coord.shutdown();
    Ok(())
}

fn cmd_report(flags: &HashMap<String, String>) -> Result<()> {
    let vdd: f64 = flag(flags, "vdd", 0.8);
    let avg_cycles: f64 = flag(flags, "avg-cycles", 1.34);
    let model = EnergyModel::new(16, vdd);
    let no_et = model.tops_per_watt(8);
    let et = model.tops_per_watt_et(8, avg_cycles);
    println!("Energy model @ VDD={vdd} V, 16x16, 8-bit inputs");
    println!("  1-bit MAC energy: {:.0} aJ/op", model.mac_energy_aj());
    println!("  TOPS/W no ET: {no_et:.0} | with ET (avg {avg_cycles} cycles): {et:.0}");
    println!("\nTable I comparison:");
    println!(
        "{:<16} {:>6} {:>14} {:>6} {:>6} {:>12} {:>9} {:>16}",
        "design", "tech", "mode", "ADC", "DAC", "network", "accuracy", "TOPS/W"
    );
    for row in table1(no_et, et, 91.04) {
        println!(
            "{:<16} {:>6} {:>14} {:>6} {:>6} {:>12} {:>9} {:>16}",
            row.label,
            row.technology,
            row.computing_mode,
            row.adc,
            row.dac,
            row.network,
            row.accuracy,
            row.tops_per_watt
        );
    }
    println!("\nPower breakdown (Fig. 12):");
    for (name, fj, share) in model.bitplane_breakdown().rows() {
        println!("  {name:<26} {fj:>8.2} fJ  {:>5.1}%", share * 100.0);
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(if args.is_empty() { &[] } else { &args[1..] });
    match cmd {
        "transform" => cmd_transform(&flags),
        "infer" => cmd_infer(&flags),
        "train" => cmd_train(&flags),
        "serve" => cmd_serve(&flags),
        "report" => cmd_report(&flags),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => bail!("unknown subcommand {other}; see `repro help`"),
    }
}

const HELP: &str = "repro — ADC/DAC-free analog frequency-domain DNN accelerator (reproduction)

USAGE: repro <SUBCOMMAND> [flags]

SUBCOMMANDS:
  transform   run one BWHT transform through the coordinator
  infer       evaluate exported MLP weights on the test set; --shards N
              runs the model's BWHT transforms on N crossbar pools via
              the sharded executor (--backend digital|noisy|analog;
              digital is bit-identical to the quantized software path)
  train       E2E: train via the PJRT train_step artifact (no python;
              needs a build with --features pjrt)
  serve       --listen ADDR: HTTP service with dynamic batching,
              admission control, keep-alive connections and a Prometheus
              /metrics endpoint; --shards N scatter-gathers wide requests
              across N coordinator pools; --backend digital|noisy|analog
              picks the per-shard tile backend (per-worker variability
              seeds derive from --seed); --weights PATH hosts the MLP on
              POST /v1/infer (any hidden width: tiles are sized to the
              model's widest BWHT block and narrower blocks run under
              sub-tile masking; transforms run through the shard set;
              poisoned shards respawn on a health tick unless
              --no-respawn); request tracing samples 1-in-K requests
              (--trace-sample K, 0 disables) into /debug/traces and the
              per-stage /metrics histograms, and --slow-ms MS logs any
              traced request slower than MS to stderr as structured
              JSON; with a noisy/analog backend, --fidelity-sample K
              shadow-verifies 1-in-K served slices against the digital
              golden path (0 disables) and --drift-threshold X recycles
              any shard whose divergence EWMA exceeds X quantizer LSBs
              (see GET /debug/fidelity and repro_fidelity_* metrics);
              the front end is an epoll event loop (--reactor-threads N
              parallel reactors; --first-byte-timeout-ms MS bounds how
              long a fresh connection may sit without a request);
              requests carry end-to-end deadlines (X-Deadline-Ms header,
              clamped by --max-deadline-ms, defaulted by
              --default-deadline-ms); expired work is cancelled before
              it executes and answered 504; per-shard circuit breakers
              shed routing away from failing slots (see /readyz and
              repro_shard_breaker_state); SIGTERM/SIGINT drain
              gracefully (--drain-timeout-ms bounds the wait, exit 0);
              --chaos-spec point=rate[,seed];... arms deterministic
              fault injection (REPRO_CHAOS_SPEC env works too; needs a
              build with --features chaos);
              without --listen: offline batch benchmark
  report      energy model: Table I, Fig. 12 power breakdown
  help        this text
";

//! Deterministic fault injection for chaos-engineered serving.
//!
//! The paper targets always-on edge deployment where analog CiM
//! hardware degrades, stalls and dies in the field; this module is the
//! test harness for that reality.  A [`ChaosPlan`] names *injection
//! points* — stable string keys compiled into the serving vertical
//! (pool workers, the shard router, the shard set, the batcher, the
//! connection event loop) — and arms each with a firing rate and a
//! seed.  Every decision is a pure function of `(seed, call index)`,
//! so a chaos run is exactly reproducible: the same spec produces the
//! same kills, stalls and drops in the same order on every run.
//!
//! Compiled out by default.  Without the `chaos` cargo feature
//! (mirroring `trace-off` / `monitor-off`, but opt-*in* rather than
//! opt-out) [`ChaosPoint::fire`] is a constant `false` the optimizer
//! deletes, [`ChaosPlan`] is a zero-sized token, and a non-empty
//! `--chaos-spec` is rejected at startup with a clear error instead of
//! being silently ignored.
//!
//! Spec grammar (CLI `--chaos-spec` or env `REPRO_CHAOS_SPEC`):
//!
//! ```text
//! point=rate[,seed][;point=rate[,seed]]...
//! ```
//!
//! e.g. `pool.worker.panic=0.02,7;shard.kill=0.005`.  `rate` is the
//! per-call firing probability in `[0, 1]`; `seed` defaults to a hash
//! of the point name so two points with the same rate still fire on
//! different calls.  Unknown point names are rejected — the registry
//! in [`POINTS`] is the single source of truth.

#[cfg(feature = "chaos")]
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;
#[cfg(feature = "chaos")]
use anyhow::{bail, Context};

/// Registry of every injection point compiled into the serving
/// vertical, in dependency order (deepest seam first).  `--chaos-spec`
/// validates against this list so a typo fails startup instead of
/// silently injecting nothing.
pub const POINTS: &[&str] = &[
    // coordinator/pool.rs — worker thread, around `schedule_batch`.
    "pool.worker.panic",
    "pool.worker.stall",
    "pool.worker.slow",
    // shard/router.rs — the drain side of the scatter–gather loop.
    "router.drain.drop",
    "router.drain.delay",
    // shard/set.rs — whole-shard lifecycle faults.
    "shard.kill",
    "shard.flap",
    // server/batcher.rs — the micro-batching loop.
    "batcher.stall",
    "batcher.reply.drop",
    // server/event_loop.rs — the connection state machine.
    "conn.reset",
    "conn.short_read",
    "conn.short_write",
];

/// How long an injected `pool.worker.stall` / `batcher.stall` sleeps.
pub const STALL: std::time::Duration = std::time::Duration::from_millis(50);
/// How long an injected `pool.worker.slow` / `router.drain.delay`
/// sleeps (a degraded-but-alive component, not a dead one).
pub const SLOWDOWN: std::time::Duration = std::time::Duration::from_millis(2);

/// SplitMix64 — the same finalizer the analog simulator's RNG family
/// uses; full-period, passes BigCrush, and two calls with different
/// inputs are statistically independent.
#[cfg_attr(not(feature = "chaos"), allow(dead_code))]
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Map a hash to a uniform double in `[0, 1)` (53 mantissa bits).
#[cfg_attr(not(feature = "chaos"), allow(dead_code))]
fn unit(z: u64) -> f64 {
    (z >> 11) as f64 / (1u64 << 53) as f64
}

/// FNV-1a over the point name — the default per-point seed, so
/// distinct points never share a decision stream by accident.
#[cfg(feature = "chaos")]
fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A parsed, validated chaos plan: which injection points are armed,
/// at what rate, under which seed.  Cloning a plan is cheap and the
/// clones stay in agreement — a plan is pure configuration; the
/// per-point call counters live in the [`ChaosPoint`] handles resolved
/// from it, one per consumer, so each consumer's decision stream is
/// independently deterministic regardless of thread interleaving.
#[derive(Clone, Debug, Default)]
pub struct ChaosPlan {
    #[cfg(feature = "chaos")]
    points: Vec<ArmedPoint>,
}

#[cfg(feature = "chaos")]
#[derive(Clone, Debug)]
struct ArmedPoint {
    name: String,
    rate: f64,
    seed: u64,
}

impl ChaosPlan {
    /// The no-faults plan (also what `Default` gives you).
    pub fn disabled() -> ChaosPlan {
        ChaosPlan::default()
    }

    /// Whether any injection point is armed.  Always `false` when the
    /// `chaos` feature is compiled out.
    pub fn is_enabled(&self) -> bool {
        #[cfg(feature = "chaos")]
        {
            !self.points.is_empty()
        }
        #[cfg(not(feature = "chaos"))]
        {
            false
        }
    }

    /// Human-readable summary of the armed points, for startup banners
    /// and logs ("pool.worker.panic=0.01@seed=7; shard.kill=0.001@seed=9").
    pub fn describe(&self) -> String {
        #[cfg(feature = "chaos")]
        {
            if self.points.is_empty() {
                "no points armed".to_string()
            } else {
                self.points
                    .iter()
                    .map(|p| format!("{}={}@seed={}", p.name, p.rate, p.seed))
                    .collect::<Vec<_>>()
                    .join("; ")
            }
        }
        #[cfg(not(feature = "chaos"))]
        {
            "compiled out".to_string()
        }
    }

    /// Parse a `point=rate[,seed];...` spec.  An empty (or
    /// all-whitespace) spec is the disabled plan.  With the `chaos`
    /// feature compiled out, a non-empty spec is an error — silently
    /// ignoring a requested fault plan would make a chaos run report
    /// a falsely green result.
    pub fn parse(spec: &str) -> Result<ChaosPlan> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Ok(ChaosPlan::default());
        }
        #[cfg(not(feature = "chaos"))]
        {
            anyhow::bail!(
                "chaos spec {spec:?} given but fault injection is compiled out; \
                 rebuild with `--features chaos`"
            );
        }
        #[cfg(feature = "chaos")]
        {
            let mut points: Vec<ArmedPoint> = Vec::new();
            for entry in spec.split(';') {
                let entry = entry.trim();
                if entry.is_empty() {
                    continue;
                }
                let (name, rest) = entry.split_once('=').with_context(|| {
                    format!("chaos spec entry {entry:?}: expected point=rate[,seed]")
                })?;
                let name = name.trim();
                if !POINTS.contains(&name) {
                    bail!(
                        "chaos spec names unknown injection point {name:?}; known points: {}",
                        POINTS.join(", ")
                    );
                }
                let (rate_s, seed_s) = match rest.split_once(',') {
                    Some((r, s)) => (r.trim(), Some(s.trim())),
                    None => (rest.trim(), None),
                };
                let rate: f64 = rate_s
                    .parse()
                    .with_context(|| format!("chaos point {name}: bad rate {rate_s:?}"))?;
                if !(0.0..=1.0).contains(&rate) {
                    bail!("chaos point {name}: rate {rate} outside [0, 1]");
                }
                let seed: u64 = match seed_s {
                    Some(s) => s
                        .parse()
                        .with_context(|| format!("chaos point {name}: bad seed {s:?}"))?,
                    None => fnv1a(name),
                };
                if points.iter().any(|p| p.name == name) {
                    bail!("chaos point {name} armed twice in one spec");
                }
                points.push(ArmedPoint {
                    name: name.to_string(),
                    rate,
                    seed,
                });
            }
            Ok(ChaosPlan { points })
        }
    }

    /// Resolve an injection point by name.  Done once at setup — the
    /// hot path holds the returned handle and never hashes or scans.
    /// Unarmed (or unknown) names resolve to the inactive point whose
    /// `fire()` is always `false`.
    pub fn point(&self, name: &str) -> ChaosPoint {
        self.point_indexed(name, 0)
    }

    /// Resolve an injection point for one lane of a parallel consumer
    /// (e.g. pool worker `w` of `N`): same rate, lane-mixed seed, own
    /// call counter — so each lane's fault sequence is deterministic
    /// on its own, independent of how the lanes interleave.
    pub fn point_indexed(&self, name: &str, lane: u64) -> ChaosPoint {
        #[cfg(feature = "chaos")]
        {
            for p in &self.points {
                if p.name == name {
                    return ChaosPoint {
                        inner: Some(PointInner {
                            rate: p.rate,
                            seed: p.seed ^ splitmix64(0xC0FF_EE00 ^ lane),
                            calls: AtomicU64::new(0),
                        }),
                    };
                }
            }
            ChaosPoint::default()
        }
        #[cfg(not(feature = "chaos"))]
        {
            let _ = (name, lane);
            ChaosPoint::default()
        }
    }
}

/// A resolved injection point: one consumer's handle on one armed
/// fault.  `fire()` advances the point's private call counter and
/// returns whether this call is a fault — a pure, reproducible
/// function of `(seed, call index)`.
#[derive(Debug, Default)]
pub struct ChaosPoint {
    #[cfg(feature = "chaos")]
    inner: Option<PointInner>,
}

#[cfg(feature = "chaos")]
#[derive(Debug)]
struct PointInner {
    rate: f64,
    seed: u64,
    calls: AtomicU64,
}

impl ChaosPoint {
    /// The never-fires point (also what `Default` gives you).
    pub fn inactive() -> ChaosPoint {
        ChaosPoint::default()
    }

    /// Whether this handle is armed at all — lets a consumer skip
    /// setup work (victim selection, clock reads) on the common path.
    #[inline]
    pub fn is_active(&self) -> bool {
        #[cfg(feature = "chaos")]
        {
            self.inner.is_some()
        }
        #[cfg(not(feature = "chaos"))]
        {
            false
        }
    }

    /// Should this call inject the fault?  Deterministic per handle:
    /// call `i` fires iff `unit(mix(seed, i)) < rate`.  Compiles to a
    /// constant `false` without the `chaos` feature.
    #[inline]
    pub fn fire(&self) -> bool {
        #[cfg(feature = "chaos")]
        {
            if let Some(inner) = &self.inner {
                let i = inner.calls.fetch_add(1, Ordering::Relaxed);
                return unit(splitmix64(inner.seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15)))
                    < inner.rate;
            }
            false
        }
        #[cfg(not(feature = "chaos"))]
        {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_is_disabled_everywhere() {
        let plan = ChaosPlan::parse("").unwrap();
        assert!(!plan.is_enabled());
        assert!(!plan.point("shard.kill").fire());
        let plan = ChaosPlan::parse("   ").unwrap();
        assert!(!plan.is_enabled());
    }

    #[test]
    fn inactive_point_never_fires() {
        let p = ChaosPoint::inactive();
        assert!(!p.is_active());
        for _ in 0..64 {
            assert!(!p.fire());
        }
    }

    #[cfg(not(feature = "chaos"))]
    #[test]
    fn non_empty_spec_errors_when_compiled_out() {
        let err = ChaosPlan::parse("shard.kill=0.5").unwrap_err();
        assert!(err.to_string().contains("--features chaos"), "{err}");
    }

    #[cfg(feature = "chaos")]
    mod armed {
        use crate::chaos::{ChaosPlan, POINTS};

        #[test]
        fn parses_rates_and_seeds() {
            let plan = ChaosPlan::parse("pool.worker.panic=0.25,42; shard.kill=1.0").unwrap();
            assert!(plan.is_enabled());
            assert!(plan.point("shard.kill").is_active());
            assert!(plan.point("shard.kill").fire(), "rate 1.0 always fires");
            assert!(!plan.point("batcher.stall").is_active(), "unarmed point");
        }

        #[test]
        fn rejects_malformed_specs() {
            for bad in [
                "no.such.point=0.5",
                "shard.kill",
                "shard.kill=1.5",
                "shard.kill=-0.1",
                "shard.kill=x",
                "shard.kill=0.5,notaseed",
                "shard.kill=0.1;shard.kill=0.2",
            ] {
                assert!(ChaosPlan::parse(bad).is_err(), "{bad:?} should not parse");
            }
        }

        #[test]
        fn every_registered_point_parses() {
            let spec = POINTS
                .iter()
                .map(|p| format!("{p}=0.5"))
                .collect::<Vec<_>>()
                .join(";");
            let plan = ChaosPlan::parse(&spec).unwrap();
            for p in POINTS {
                assert!(plan.point(p).is_active(), "{p} should be armed");
            }
        }

        #[test]
        fn decision_stream_is_reproducible() {
            let plan = ChaosPlan::parse("conn.reset=0.3,7").unwrap();
            let a = plan.point("conn.reset");
            let b = plan.point("conn.reset");
            let seq_a: Vec<bool> = (0..256).map(|_| a.fire()).collect();
            let seq_b: Vec<bool> = (0..256).map(|_| b.fire()).collect();
            assert_eq!(seq_a, seq_b, "same point, same seed, same stream");
            assert!(seq_a.iter().any(|&f| f), "rate 0.3 fires somewhere in 256");
            assert!(!seq_a.iter().all(|&f| f), "rate 0.3 must not always fire");
        }

        #[test]
        fn lanes_decorrelate_but_stay_deterministic() {
            let plan = ChaosPlan::parse("pool.worker.panic=0.5,9").unwrap();
            let lane0: Vec<bool> = {
                let p = plan.point_indexed("pool.worker.panic", 0);
                (0..128).map(|_| p.fire()).collect()
            };
            let lane1: Vec<bool> = {
                let p = plan.point_indexed("pool.worker.panic", 1);
                (0..128).map(|_| p.fire()).collect()
            };
            assert_ne!(lane0, lane1, "lanes must not share a stream");
            let lane0_again: Vec<bool> = {
                let p = plan.point_indexed("pool.worker.panic", 0);
                (0..128).map(|_| p.fire()).collect()
            };
            assert_eq!(lane0, lane0_again);
        }

        #[test]
        fn default_seed_comes_from_the_point_name() {
            let plan = ChaosPlan::parse("conn.reset=0.5;conn.short_read=0.5").unwrap();
            let a = plan.point("conn.reset");
            let b = plan.point("conn.short_read");
            let seq_a: Vec<bool> = (0..128).map(|_| a.fire()).collect();
            let seq_b: Vec<bool> = (0..128).map(|_| b.fire()).collect();
            assert_ne!(seq_a, seq_b, "same rate, different name, different stream");
        }

        #[test]
        fn empirical_rate_tracks_the_spec() {
            let plan = ChaosPlan::parse("batcher.reply.drop=0.2,1234").unwrap();
            let p = plan.point("batcher.reply.drop");
            let n = 20_000;
            let fired = (0..n).filter(|_| p.fire()).count();
            let rate = fired as f64 / n as f64;
            assert!((rate - 0.2).abs() < 0.02, "empirical rate {rate} vs 0.2");
        }

        #[test]
        fn rate_zero_never_fires() {
            let plan = ChaosPlan::parse("shard.flap=0.0").unwrap();
            let p = plan.point("shard.flap");
            assert!(p.is_active());
            for _ in 0..256 {
                assert!(!p.fire());
            }
        }
    }
}

//! Sign-magnitude quantization and bitplane encoding (Fig. 6 input path).
//!
//! The crossbar is DAC-free: a multi-bit input vector is streamed as
//! sign-magnitude *bitplanes* — the sign selects CL vs CLB, the magnitude
//! bit gates the selected column line.  This module is the digital
//! front-end that performs that encoding, bit-identical to
//! `python/compile/kernels/ref.py::quantize_ref`/`bitplanes_ref`.

/// Symmetric sign-magnitude quantizer with `bits` magnitude bitplanes.
///
/// Integer range is `±(2^bits - 1)`; `bits = 1` is the extreme ternary
/// case (`{-1, 0, +1}`) of Fig. 8's sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Quantizer {
    pub bits: u32,
}

/// A quantized vector: integers plus the scale such that `x ≈ q * scale`.
#[derive(Debug, Clone)]
pub struct Quantized {
    pub q: Vec<i32>,
    pub scale: f32,
    pub bits: u32,
}

impl Quantizer {
    pub fn new(bits: u32) -> Self {
        assert!((1..=16).contains(&bits), "bits must be in 1..=16");
        Quantizer { bits }
    }

    pub fn qmax(&self) -> i32 {
        (1i32 << self.bits) - 1
    }

    /// The scale per-tensor symmetric quantization of `x` would use
    /// (`amax / qmax`, with the same `1e-8` floor as [`Self::quantize`]).
    ///
    /// Exposed so a caller splitting one logical tensor across tiles can
    /// compute the global scale once and pin it on every slice via
    /// [`Self::quantize_with_scale`] — the slices then reproduce the
    /// whole-tensor quantization bit-for-bit.
    pub fn scale_for(&self, x: &[f32]) -> f32 {
        let amax = x.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-8);
        amax / self.qmax() as f32
    }

    /// Per-tensor symmetric quantization (matches `quantize_ref`).
    pub fn quantize(&self, x: &[f32]) -> Quantized {
        self.quantize_with_scale(x, self.scale_for(x))
    }

    /// Quantize with an externally pinned (positive) scale.  Identical
    /// arithmetic to [`Self::quantize`] given the same scale, so slices
    /// of a tensor quantized under its global scale match the
    /// whole-tensor quantization elementwise.
    pub fn quantize_with_scale(&self, x: &[f32], scale: f32) -> Quantized {
        let qmax = self.qmax() as f32;
        let q = x
            .iter()
            .map(|&v| (v / scale).round().clamp(-qmax, qmax) as i32)
            .collect();
        Quantized {
            q,
            scale,
            bits: self.bits,
        }
    }
}

impl Quantized {
    /// Dequantize back to floats.
    pub fn dequantize(&self) -> Vec<f32> {
        self.q.iter().map(|&q| q as f32 * self.scale).collect()
    }

    /// Sign-magnitude bitplane `b` (0 = LSB): values in `{-1, 0, +1}`.
    ///
    /// `plane_b[j] = sign(q_j) * bit_b(|q_j|)` — exactly the CL/CLB drive
    /// pattern for one 2-clock crossbar operation.
    pub fn bitplane(&self, b: u32) -> Vec<i8> {
        assert!(b < self.bits);
        self.q
            .iter()
            .map(|&q| {
                let bit = ((q.unsigned_abs() >> b) & 1) as i8;
                if q < 0 {
                    -bit
                } else {
                    bit
                }
            })
            .collect()
    }

    /// All bitplanes, MSB first (the early-termination processing order).
    pub fn bitplanes_msb_first(&self) -> Vec<Vec<i8>> {
        (0..self.bits).rev().map(|b| self.bitplane(b)).collect()
    }

    /// Reconstruct the integers from the bitplanes (sanity identity).
    pub fn reconstruct_from_planes(&self) -> Vec<i32> {
        let mut acc = vec![0i32; self.q.len()];
        for b in 0..self.bits {
            let plane = self.bitplane(b);
            for (a, &p) in acc.iter_mut().zip(&plane) {
                *a += (p as i32) << b;
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        // deterministic pseudo-random floats in [-3, 3]
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s % 6000) as f32 / 1000.0) - 3.0
            })
            .collect()
    }

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        for bits in [1, 2, 4, 8] {
            let x = sample(100, bits as u64);
            let q = Quantizer::new(bits).quantize(&x);
            for (orig, deq) in x.iter().zip(q.dequantize()) {
                assert!(
                    (orig - deq).abs() <= q.scale / 2.0 + 1e-6,
                    "bits={bits}: {orig} vs {deq} (scale {})",
                    q.scale
                );
            }
        }
    }

    #[test]
    fn range_respects_qmax() {
        let x = sample(256, 7);
        let q = Quantizer::new(8).quantize(&x);
        assert!(q.q.iter().all(|&v| v.abs() <= 255));
        assert!(q.q.iter().any(|&v| v.abs() == 255), "max must hit qmax");
    }

    #[test]
    fn one_bit_is_ternary() {
        let x = sample(64, 3);
        let q = Quantizer::new(1).quantize(&x);
        assert!(q.q.iter().all(|&v| (-1..=1).contains(&v)));
    }

    #[test]
    fn bitplane_values_are_sign_magnitude() {
        let q = Quantized {
            q: vec![-5, 3, 0, -1],
            scale: 1.0,
            bits: 4,
        };
        // |-5| = 0b0101
        assert_eq!(q.bitplane(0), vec![-1, 1, 0, -1]);
        assert_eq!(q.bitplane(1), vec![0, 1, 0, 0]);
        assert_eq!(q.bitplane(2), vec![-1, 0, 0, 0]);
        assert_eq!(q.bitplane(3), vec![0, 0, 0, 0]);
    }

    #[test]
    fn planes_reconstruct_integers() {
        let x = sample(128, 11);
        for bits in [1, 3, 8] {
            let q = Quantizer::new(bits).quantize(&x);
            assert_eq!(q.reconstruct_from_planes(), q.q, "bits={bits}");
        }
    }

    #[test]
    fn msb_first_ordering() {
        let q = Quantized {
            q: vec![4],
            scale: 1.0,
            bits: 3,
        };
        let planes = q.bitplanes_msb_first();
        assert_eq!(planes, vec![vec![1], vec![0], vec![0]]);
    }

    #[test]
    #[should_panic(expected = "bits")]
    fn zero_bits_panics() {
        Quantizer::new(0);
    }

    #[test]
    fn pinned_scale_slices_match_global_quantization() {
        let x = sample(64, 21);
        let q = Quantizer::new(8);
        let global = q.quantize(&x);
        let scale = q.scale_for(&x);
        assert_eq!(scale, global.scale);
        for chunk in 0..4 {
            let slice = &x[chunk * 16..(chunk + 1) * 16];
            let local = q.quantize_with_scale(slice, scale);
            assert_eq!(local.q, global.q[chunk * 16..(chunk + 1) * 16].to_vec());
            assert_eq!(local.scale, scale);
        }
    }

    #[test]
    fn zero_vector_stable() {
        let q = Quantizer::new(8).quantize(&[0.0; 16]);
        assert!(q.q.iter().all(|&v| v == 0));
        assert!(q.scale > 0.0);
    }
}

//! Sign-magnitude quantization and bitplane encoding (Fig. 6 input path).
//!
//! The crossbar is DAC-free: a multi-bit input vector is streamed as
//! sign-magnitude *bitplanes* — the sign selects CL vs CLB, the magnitude
//! bit gates the selected column line.  This module is the digital
//! front-end that performs that encoding, bit-identical to
//! `python/compile/kernels/ref.py::quantize_ref`/`bitplanes_ref`.

/// Symmetric sign-magnitude quantizer with `bits` magnitude bitplanes.
///
/// Integer range is `±(2^bits - 1)`; `bits = 1` is the extreme ternary
/// case (`{-1, 0, +1}`) of Fig. 8's sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Quantizer {
    pub bits: u32,
}

/// A quantized vector: integers plus the scale such that `x ≈ q * scale`.
#[derive(Debug, Clone)]
pub struct Quantized {
    pub q: Vec<i32>,
    pub scale: f32,
    pub bits: u32,
}

impl Quantizer {
    pub fn new(bits: u32) -> Self {
        assert!((1..=16).contains(&bits), "bits must be in 1..=16");
        Quantizer { bits }
    }

    pub fn qmax(&self) -> i32 {
        (1i32 << self.bits) - 1
    }

    /// The scale per-tensor symmetric quantization of `x` would use
    /// (`amax / qmax`, with the same `1e-8` floor as [`Self::quantize`]).
    ///
    /// Exposed so a caller splitting one logical tensor across tiles can
    /// compute the global scale once and pin it on every slice via
    /// [`Self::quantize_with_scale`] — the slices then reproduce the
    /// whole-tensor quantization bit-for-bit.
    pub fn scale_for(&self, x: &[f32]) -> f32 {
        let amax = x.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-8);
        amax / self.qmax() as f32
    }

    /// Per-tensor symmetric quantization (matches `quantize_ref`).
    pub fn quantize(&self, x: &[f32]) -> Quantized {
        self.quantize_with_scale(x, self.scale_for(x))
    }

    /// Quantize with an externally pinned (positive) scale.  Identical
    /// arithmetic to [`Self::quantize`] given the same scale, so slices
    /// of a tensor quantized under its global scale match the
    /// whole-tensor quantization elementwise.
    pub fn quantize_with_scale(&self, x: &[f32], scale: f32) -> Quantized {
        let mut q = Vec::with_capacity(x.len());
        self.quantize_with_scale_into(x, scale, &mut q);
        Quantized {
            q,
            scale,
            bits: self.bits,
        }
    }

    /// [`Self::quantize_with_scale`] appending into a caller buffer — the
    /// allocation-free seam the scheduler's [`ScratchArena`] quantizes
    /// through (the buffer's capacity is retained across jobs).
    ///
    /// [`ScratchArena`]: crate::coordinator::scheduler::ScratchArena
    pub fn quantize_with_scale_into(&self, x: &[f32], scale: f32, out: &mut Vec<i32>) {
        let qmax = self.qmax() as f32;
        out.reserve(x.len());
        for &v in x {
            out.push((v / scale).round().clamp(-qmax, qmax) as i32);
        }
    }
}

/// Write sign-magnitude plane `b` of the quantized integers `q` into
/// `out` — the zero-allocation core shared by [`Quantized::bitplane`]
/// and [`PlaneIter`].  `out[j] = sign(q_j) * bit_b(|q_j|)`.
pub fn plane_into(q: &[i32], b: u32, out: &mut [i8]) {
    assert_eq!(q.len(), out.len(), "plane buffer must match the block");
    for (o, &v) in out.iter_mut().zip(q) {
        let bit = ((v.unsigned_abs() >> b) & 1) as i8;
        *o = if v < 0 { -bit } else { bit };
    }
}

/// Streaming MSB-first bitplane extractor: each plane is written into a
/// caller-owned scratch slice instead of materializing the whole
/// `Vec<Vec<i8>>` plane stack up front — the hot-path encoding of the
/// DAC-free input stream (one 2-clock crossbar op per extracted plane).
#[derive(Debug)]
pub struct PlaneIter<'a> {
    q: &'a [i32],
    bits: u32,
    done: u32,
}

impl PlaneIter<'_> {
    /// Extract the next plane (MSB first) into `out` and return its bit
    /// position `b` (recombination weight `2^b`), or `None` once all
    /// `bits` planes have been streamed.
    pub fn next_into(&mut self, out: &mut [i8]) -> Option<u32> {
        if self.done == self.bits {
            return None;
        }
        let b = self.bits - 1 - self.done;
        self.done += 1;
        plane_into(self.q, b, out);
        Some(b)
    }

    /// Planes not yet streamed.
    pub fn remaining(&self) -> u32 {
        self.bits - self.done
    }
}

impl Quantized {
    /// Dequantize back to floats.
    pub fn dequantize(&self) -> Vec<f32> {
        self.q.iter().map(|&q| q as f32 * self.scale).collect()
    }

    /// Sign-magnitude bitplane `b` (0 = LSB): values in `{-1, 0, +1}`.
    ///
    /// `plane_b[j] = sign(q_j) * bit_b(|q_j|)` — exactly the CL/CLB drive
    /// pattern for one 2-clock crossbar operation.
    pub fn bitplane(&self, b: u32) -> Vec<i8> {
        let mut out = vec![0i8; self.q.len()];
        self.bitplane_into(b, &mut out);
        out
    }

    /// [`Self::bitplane`] into a caller scratch slice (no allocation).
    pub fn bitplane_into(&self, b: u32, out: &mut [i8]) {
        assert!(b < self.bits);
        plane_into(&self.q, b, out);
    }

    /// Stream all bitplanes MSB first (the early-termination processing
    /// order) through a caller scratch slice — see [`PlaneIter`].
    pub fn planes_msb_first(&self) -> PlaneIter<'_> {
        PlaneIter {
            q: &self.q,
            bits: self.bits,
            done: 0,
        }
    }

    /// Reconstruct the integers from the bitplanes (sanity identity).
    pub fn reconstruct_from_planes(&self) -> Vec<i32> {
        let mut acc = vec![0i32; self.q.len()];
        for b in 0..self.bits {
            let plane = self.bitplane(b);
            for (a, &p) in acc.iter_mut().zip(&plane) {
                *a += (p as i32) << b;
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        // deterministic pseudo-random floats in [-3, 3]
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s % 6000) as f32 / 1000.0) - 3.0
            })
            .collect()
    }

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        for bits in [1, 2, 4, 8] {
            let x = sample(100, bits as u64);
            let q = Quantizer::new(bits).quantize(&x);
            for (orig, deq) in x.iter().zip(q.dequantize()) {
                assert!(
                    (orig - deq).abs() <= q.scale / 2.0 + 1e-6,
                    "bits={bits}: {orig} vs {deq} (scale {})",
                    q.scale
                );
            }
        }
    }

    #[test]
    fn range_respects_qmax() {
        let x = sample(256, 7);
        let q = Quantizer::new(8).quantize(&x);
        assert!(q.q.iter().all(|&v| v.abs() <= 255));
        assert!(q.q.iter().any(|&v| v.abs() == 255), "max must hit qmax");
    }

    #[test]
    fn one_bit_is_ternary() {
        let x = sample(64, 3);
        let q = Quantizer::new(1).quantize(&x);
        assert!(q.q.iter().all(|&v| (-1..=1).contains(&v)));
    }

    #[test]
    fn bitplane_values_are_sign_magnitude() {
        let q = Quantized {
            q: vec![-5, 3, 0, -1],
            scale: 1.0,
            bits: 4,
        };
        // |-5| = 0b0101
        assert_eq!(q.bitplane(0), vec![-1, 1, 0, -1]);
        assert_eq!(q.bitplane(1), vec![0, 1, 0, 0]);
        assert_eq!(q.bitplane(2), vec![-1, 0, 0, 0]);
        assert_eq!(q.bitplane(3), vec![0, 0, 0, 0]);
    }

    #[test]
    fn planes_reconstruct_integers() {
        let x = sample(128, 11);
        for bits in [1, 3, 8] {
            let q = Quantizer::new(bits).quantize(&x);
            assert_eq!(q.reconstruct_from_planes(), q.q, "bits={bits}");
        }
    }

    #[test]
    fn msb_first_ordering() {
        let q = Quantized {
            q: vec![4],
            scale: 1.0,
            bits: 3,
        };
        let mut scratch = [0i8; 1];
        let mut planes = q.planes_msb_first();
        assert_eq!(planes.remaining(), 3);
        assert_eq!(planes.next_into(&mut scratch), Some(2));
        assert_eq!(scratch, [1]);
        assert_eq!(planes.next_into(&mut scratch), Some(1));
        assert_eq!(scratch, [0]);
        assert_eq!(planes.next_into(&mut scratch), Some(0));
        assert_eq!(scratch, [0]);
        assert_eq!(planes.next_into(&mut scratch), None);
        assert_eq!(planes.remaining(), 0);
    }

    #[test]
    fn plane_iter_matches_materialized_planes() {
        let x = sample(64, 17);
        for bits in [1u32, 4, 8] {
            let q = Quantizer::new(bits).quantize(&x);
            let mut scratch = vec![0i8; 64];
            let mut planes = q.planes_msb_first();
            let mut seen = 0u32;
            while let Some(b) = planes.next_into(&mut scratch) {
                assert_eq!(b, bits - 1 - seen, "MSB-first bit order");
                assert_eq!(scratch, q.bitplane(b), "bits={bits} plane {b}");
                seen += 1;
            }
            assert_eq!(seen, bits);
        }
    }

    #[test]
    fn quantize_into_matches_quantize() {
        let x = sample(48, 23);
        let qz = Quantizer::new(8);
        let scale = qz.scale_for(&x);
        let mut buf = Vec::new();
        qz.quantize_with_scale_into(&x, scale, &mut buf);
        assert_eq!(buf, qz.quantize_with_scale(&x, scale).q);
        // appending semantics: a second block lands after the first
        qz.quantize_with_scale_into(&x[..8], scale, &mut buf);
        assert_eq!(buf.len(), 56);
        assert_eq!(&buf[48..], &qz.quantize_with_scale(&x[..8], scale).q[..]);
    }

    #[test]
    #[should_panic(expected = "bits")]
    fn zero_bits_panics() {
        Quantizer::new(0);
    }

    #[test]
    fn pinned_scale_slices_match_global_quantization() {
        let x = sample(64, 21);
        let q = Quantizer::new(8);
        let global = q.quantize(&x);
        let scale = q.scale_for(&x);
        assert_eq!(scale, global.scale);
        for chunk in 0..4 {
            let slice = &x[chunk * 16..(chunk + 1) * 16];
            let local = q.quantize_with_scale(slice, scale);
            assert_eq!(local.q, global.q[chunk * 16..(chunk + 1) * 16].to_vec());
            assert_eq!(local.scale, scale);
        }
    }

    #[test]
    fn zero_vector_stable() {
        let q = Quantizer::new(8).quantize(&[0.0; 16]);
        assert!(q.q.iter().all(|&v| v == 0));
        assert!(q.scale > 0.0);
    }
}

//! Primitive float layers (dense, activations, metrics) for the inference
//! engine.  Row-major matrices, batch-major activations `(batch, dim)`.

/// Dense layer: `y = x W + b`, `w` is `(din, dout)` row-major.
#[derive(Debug, Clone)]
pub struct Dense {
    pub din: usize,
    pub dout: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl Dense {
    pub fn new(din: usize, dout: usize, w: Vec<f32>, b: Vec<f32>) -> Self {
        assert_eq!(w.len(), din * dout, "weight size mismatch");
        assert_eq!(b.len(), dout, "bias size mismatch");
        Dense { din, dout, w, b }
    }

    /// Forward one batch: `x` is `(batch, din)` flat; returns `(batch, dout)`.
    pub fn forward(&self, x: &[f32], batch: usize) -> Vec<f32> {
        assert_eq!(x.len(), batch * self.din);
        let mut out = vec![0f32; batch * self.dout];
        for bi in 0..batch {
            let xi = &x[bi * self.din..(bi + 1) * self.din];
            let oi = &mut out[bi * self.dout..(bi + 1) * self.dout];
            oi.copy_from_slice(&self.b);
            for (k, &xv) in xi.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &self.w[k * self.dout..(k + 1) * self.dout];
                for (o, &wv) in oi.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
        out
    }
}

/// In-place ReLU.
pub fn relu(x: &mut [f32]) {
    for v in x {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Soft threshold S_T (Eq. 3), per-channel t over a `(batch, dim)` buffer.
pub fn soft_threshold(x: &mut [f32], t: &[f32]) {
    let dim = t.len();
    for (i, v) in x.iter_mut().enumerate() {
        let th = t[i % dim].abs();
        let a = v.abs() - th;
        *v = if a > 0.0 { v.signum() * a } else { 0.0 };
    }
}

/// Row-wise argmax of a `(batch, classes)` buffer.
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<usize> {
    logits
        .chunks_exact(classes)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect()
}

/// Classification accuracy against integer labels.
pub fn accuracy(logits: &[f32], labels: &[i32], classes: usize) -> f64 {
    let preds = argmax_rows(logits, classes);
    assert_eq!(preds.len(), labels.len());
    let correct = preds
        .iter()
        .zip(labels)
        .filter(|&(&p, &l)| p as i32 == l)
        .count();
    correct as f64 / labels.len() as f64
}

/// Fraction of exactly-zero activations (the paper's output sparsity).
pub fn sparsity(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().filter(|v| **v == 0.0).count() as f64 / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_matches_manual() {
        // 2x3 weight, batch 2
        let d = Dense::new(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![0.1, 0.2, 0.3]);
        let out = d.forward(&[1.0, 1.0, 2.0, 0.0], 2);
        assert_eq!(out.len(), 6);
        // row0: [1+4, 2+5, 3+6] + b
        assert!((out[0] - 5.1).abs() < 1e-6);
        assert!((out[1] - 7.2).abs() < 1e-6);
        assert!((out[2] - 9.3).abs() < 1e-6);
        // row1: 2*[1,2,3] + b
        assert!((out[3] - 2.1).abs() < 1e-6);
    }

    #[test]
    fn relu_clamps() {
        let mut x = vec![-1.0, 0.0, 2.0];
        relu(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 2.0]);
    }

    #[test]
    fn soft_threshold_dead_zone() {
        let mut x = vec![-0.5, -0.1, 0.0, 0.1, 0.5];
        soft_threshold(&mut x, &[0.2, 0.2, 0.2, 0.2, 0.2]);
        let want = [-0.3, 0.0, 0.0, 0.0, 0.3];
        for (a, b) in x.iter().zip(want) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn soft_threshold_broadcasts_over_batch() {
        let mut x = vec![1.0, 1.0, 1.0, 1.0]; // batch 2, dim 2
        soft_threshold(&mut x, &[0.5, 2.0]);
        assert_eq!(x, vec![0.5, 0.0, 0.5, 0.0]);
    }

    #[test]
    fn accuracy_and_argmax() {
        let logits = vec![0.1, 0.9, 0.8, 0.2]; // batch 2, classes 2
        assert_eq!(argmax_rows(&logits, 2), vec![1, 0]);
        assert_eq!(accuracy(&logits, &[1, 1], 2), 0.5);
    }

    #[test]
    fn sparsity_counts_zeros() {
        assert_eq!(sparsity(&[0.0, 1.0, 0.0, 2.0]), 0.5);
        assert_eq!(sparsity(&[]), 0.0);
    }
}

//! Parameter and MAC accounting for the real ResNet20 / MobileNetV2
//! architectures (Figs. 1(b) compression and 1(c) MAC increase).
//!
//! Counting conventions:
//! * a conventional 1×1 mixing conv costs `H·W·Cin·Cout` MACs and
//!   `Cin·Cout` parameters;
//! * its BWHT replacement is executed as *blockwise dense ±1 matvecs on
//!   crossbar tiles* (that is literally what the hardware does), so it
//!   costs `H·W·2·Σ_blocks b²` MAC-equivalents (forward + inverse
//!   transform) and only `P` threshold parameters (`P` = padded width).
//!
//! With 32-wide tiles this reproduces the paper's ≈3× MAC increase for a
//! fully frequency-processed MobileNetV2 while cutting parameters by
//! ~50-60% (Fig. 1(b): −55.6% for ResNet20).

use crate::wht;

/// One layer of an architecture description.
#[derive(Debug, Clone)]
pub enum Layer {
    /// Spatial conv: `k×k`, `cin→cout`, over `h×w` outputs, `groups`.
    Conv {
        h: usize,
        w: usize,
        k: usize,
        cin: usize,
        cout: usize,
        groups: usize,
    },
    /// Channel-mixing 1×1 conv that frequency processing can replace.
    Mix1x1 {
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
    },
    /// Dense head.
    Dense { din: usize, dout: usize },
}

impl Layer {
    /// (MACs, params) in conventional form.
    pub fn conventional(&self) -> (u64, u64) {
        match *self {
            Layer::Conv {
                h,
                w,
                k,
                cin,
                cout,
                groups,
            } => {
                let macs = (h * w * k * k * cin * cout / groups) as u64;
                let params = (k * k * cin * cout / groups) as u64;
                (macs, params)
            }
            Layer::Mix1x1 { h, w, cin, cout } => {
                ((h * w * cin * cout) as u64, (cin * cout) as u64)
            }
            Layer::Dense { din, dout } => ((din * dout) as u64, (din * dout + dout) as u64),
        }
    }

    /// (MACs, params) with the mixing layer in the frequency domain,
    /// tiled on `tile`-wide crossbars.  Non-mixing layers are unchanged.
    pub fn frequency(&self, tile: usize) -> (u64, u64) {
        match *self {
            Layer::Mix1x1 { h, w, cin, cout } => {
                let width = cin.max(cout);
                let blocks = wht::bwht_blocks(width, tile);
                let padded: usize = blocks.iter().sum();
                let per_pos: u64 = blocks.iter().map(|&b| (b * b) as u64).sum();
                // forward + inverse transform, plus the thresholding pass
                let macs = (h * w) as u64 * (2 * per_pos + padded as u64);
                (macs, padded as u64)
            }
            _ => self.conventional(),
        }
    }

    pub fn is_mixing(&self) -> bool {
        matches!(self, Layer::Mix1x1 { .. })
    }
}

/// A whole architecture: ordered layers.
#[derive(Debug, Clone)]
pub struct Arch {
    pub name: &'static str,
    pub layers: Vec<Layer>,
}

impl Arch {
    pub fn num_mixing(&self) -> usize {
        self.layers.iter().filter(|l| l.is_mixing()).count()
    }

    /// Totals with the first `freq_layers` mixing layers frequency-
    /// processed: returns (macs, params).
    pub fn count(&self, freq_layers: usize, tile: usize) -> (u64, u64) {
        let mut converted = 0usize;
        let mut macs = 0u64;
        let mut params = 0u64;
        for l in &self.layers {
            let (m, p) = if l.is_mixing() && converted < freq_layers {
                converted += 1;
                l.frequency(tile)
            } else {
                l.conventional()
            };
            macs += m;
            params += p;
        }
        (macs, params)
    }

    /// Fig. 1(b) metric: params(freq)/params(conventional).
    pub fn compression(&self, freq_layers: usize, tile: usize) -> f64 {
        let (_, p0) = self.count(0, tile);
        let (_, pf) = self.count(freq_layers, tile);
        pf as f64 / p0 as f64
    }

    /// Fig. 1(c) metric: macs(freq)/macs(conventional).
    pub fn mac_increase(&self, freq_layers: usize, tile: usize) -> f64 {
        let (m0, _) = self.count(0, tile);
        let (mf, _) = self.count(freq_layers, tile);
        mf as f64 / m0 as f64
    }
}

/// The paper's ResNet20 variant (Fig. 3(a)): bottleneck residual blocks
/// `1×1 reduce → 3×3 → 1×1 expand`, where both 1×1 convs are replaceable
/// by 1D-BWHT layers; CIFAR-10 geometry.  The bottleneck width `c/4` puts
/// the parameter mass in the mixing layers, which is the regime where the
/// paper's −55.6% full-frequency compression arises.
pub fn resnet20() -> Arch {
    let mut layers = vec![Layer::Conv {
        h: 32,
        w: 32,
        k: 3,
        cin: 3,
        cout: 16,
        groups: 1,
    }];
    let stages: [(usize, usize, usize); 3] = [(16, 32, 3), (32, 16, 3), (64, 8, 3)];
    for (cout, hw, blocks) in stages {
        for _ in 0..blocks {
            let mid = (cout / 4).max(4);
            layers.push(Layer::Mix1x1 {
                h: hw,
                w: hw,
                cin: cout,
                cout: mid,
            });
            layers.push(Layer::Conv {
                h: hw,
                w: hw,
                k: 3,
                cin: mid,
                cout: mid,
                groups: 1,
            });
            layers.push(Layer::Mix1x1 {
                h: hw,
                w: hw,
                cin: mid,
                cout,
            });
        }
    }
    layers.push(Layer::Dense { din: 64, dout: 10 });
    Arch {
        name: "ResNet20",
        layers,
    }
}

/// MobileNetV2 (CIFAR-10 geometry, width 1.0): inverted bottlenecks with
/// replaceable expand/project 1×1 convs (Fig. 3(b)).
pub fn mobilenet_v2() -> Arch {
    let mut layers = vec![Layer::Conv {
        h: 32,
        w: 32,
        k: 3,
        cin: 3,
        cout: 32,
        groups: 1,
    }];
    // (expansion t, cout, repeats, stride) — standard MobileNetV2 table.
    let cfg: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 1),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut cin = 32;
    let mut hw = 32usize;
    for (t, cout, n, s) in cfg {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            hw = if stride == 2 { hw / 2 } else { hw };
            let mid = cin * t;
            if t != 1 {
                layers.push(Layer::Mix1x1 {
                    h: hw,
                    w: hw,
                    cin,
                    cout: mid,
                });
            }
            layers.push(Layer::Conv {
                h: hw,
                w: hw,
                k: 3,
                cin: mid,
                cout: mid,
                groups: mid,
            });
            layers.push(Layer::Mix1x1 {
                h: hw,
                w: hw,
                cin: mid,
                cout,
            });
            cin = cout;
        }
    }
    layers.push(Layer::Mix1x1 {
        h: hw,
        w: hw,
        cin: 320,
        cout: 1280,
    });
    layers.push(Layer::Dense {
        din: 1280,
        dout: 10,
    });
    Arch {
        name: "MobileNetV2",
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Crossbar tile width used for the paper-band checks (the Fig. 1
    /// curves are regenerated at several tiles by `exp_fig1`).
    const TILE: usize = 128;

    #[test]
    fn resnet20_structure() {
        let a = resnet20();
        assert_eq!(a.num_mixing(), 18); // two 1×1s per bottleneck block
    }

    #[test]
    fn mobilenet_structure() {
        let a = mobilenet_v2();
        // 17 bottlenecks: 16 with expand+project, 1 (t=1) project-only,
        // plus the 1280 head = 16*2 + 1 + 1 = 34.
        assert_eq!(a.num_mixing(), 34);
    }

    #[test]
    fn compression_improves_with_more_freq_layers() {
        for arch in [resnet20(), mobilenet_v2()] {
            let n = arch.num_mixing();
            let half = arch.compression(n / 2, TILE);
            let full = arch.compression(n, TILE);
            assert!(full < half, "{}: {full} vs {half}", arch.name);
            assert!(full < 1.0);
        }
    }

    #[test]
    fn compression_is_monotone_in_freq_layers() {
        // Every converted mixing layer strictly drops parameters
        // (thresholds P << Cin·Cout), so the Fig. 1(b) curve is monotone.
        let a = resnet20();
        let mut prev = f64::INFINITY;
        for k in 0..=a.num_mixing() {
            let r = a.compression(k, TILE);
            assert!(r <= prev, "k={k}: {r} > {prev}");
            prev = r;
        }
    }

    #[test]
    fn resnet20_full_compression_matches_paper_band() {
        // Paper: −55.6% parameters (ratio ≈ 0.444) for their variant; our
        // Fig. 3(a) bottleneck descriptor lands in the same band.
        let a = resnet20();
        let ratio = a.compression(a.num_mixing(), TILE);
        assert!(
            (0.30..0.65).contains(&ratio),
            "ResNet20 full-frequency compression ratio {ratio:.3}"
        );
    }

    #[test]
    fn mobilenet_mac_increase_matches_paper_band() {
        // Paper Fig. 1(c): ≈3× average MAC increase when all layers are
        // frequency-processed on MobileNetV2.
        let a = mobilenet_v2();
        let ratio = a.mac_increase(a.num_mixing(), TILE);
        assert!(
            (2.5..4.5).contains(&ratio),
            "MobileNetV2 full-frequency MAC increase {ratio:.2}"
        );
    }

    #[test]
    fn both_archs_pay_macs_for_compression() {
        // Fig. 1(c)'s qualitative claim: frequency processing *increases*
        // MACs on both networks (the compute cost the crossbar absorbs).
        // Exact per-arch factors depend on the authors' bottleneck widths,
        // which the paper does not specify; EXPERIMENTS.md reports ours.
        for arch in [resnet20(), mobilenet_v2()] {
            let r = arch.mac_increase(arch.num_mixing(), TILE);
            assert!(
                (1.5..5.0).contains(&r),
                "{}: MAC increase {r:.2} outside the paper's regime",
                arch.name
            );
        }
    }

    #[test]
    fn zero_freq_layers_is_identity() {
        let a = resnet20();
        assert_eq!(a.compression(0, TILE), 1.0);
        assert_eq!(a.mac_increase(0, TILE), 1.0);
    }
}

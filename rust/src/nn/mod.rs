//! Pure-rust inference substrate for BWHT-compressed networks.
//!
//! Mirrors the python L2 models (`python/compile/model.py`) closely enough
//! that weights trained there (exported as JSON by `make weights`) run
//! here comparably, with the BWHT layers executable on three backends:
//!
//! * [`Backend::Float`] — exact float transform (the algorithmic baseline),
//! * [`Backend::Quantized`] — the digital golden model of the ADC-free
//!   crossbar arithmetic (Eq. 4),
//! * [`Backend::Noisy`] — Eq. 4 with ANT noise injection (Fig. 11(a)),
//!
//! plus the full tile-pool paths when driven through a
//! [`crate::exec::TransformExecutor`]: `BwhtLayer::forward_with` /
//! `Mlp::forward_with` batch every transform through one executor seam,
//! so the same model runs on the in-process loops, one
//! [`crate::coordinator::Coordinator`] pool, or a sharded
//! [`crate::shard::ShardSet`] — bit-identically on the digital path.
//!
//! [`counter`] reproduces the Fig. 1(b)/(c) parameter and MAC accounting
//! for the *real* ResNet20 / MobileNetV2 architectures.

pub mod bwht_layer;
pub mod counter;
pub mod layers;
pub mod loader;
pub mod model;

pub use bwht_layer::{Backend, BwhtLayer};
pub use model::Mlp;

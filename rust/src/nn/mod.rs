//! Pure-rust inference substrate for BWHT-compressed networks.
//!
//! Mirrors the python L2 models (`python/compile/model.py`) closely enough
//! that weights trained there (exported as JSON by `make weights`) run
//! here comparably, with the BWHT layers executable on three backends:
//!
//! * [`Backend::Float`] — exact float transform (the algorithmic baseline),
//! * [`Backend::Quantized`] — the digital golden model of the ADC-free
//!   crossbar arithmetic (Eq. 4),
//! * [`Backend::Noisy`] — Eq. 4 with ANT noise injection (Fig. 11(a)),
//!
//! plus the full analog path when driven through
//! [`crate::coordinator`]'s tile pool.
//!
//! [`counter`] reproduces the Fig. 1(b)/(c) parameter and MAC accounting
//! for the *real* ResNet20 / MobileNetV2 architectures.

pub mod bwht_layer;
pub mod counter;
pub mod layers;
pub mod loader;
pub mod model;

pub use bwht_layer::{Backend, BwhtLayer};
pub use model::Mlp;

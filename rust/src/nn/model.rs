//! The MLP classifier matching `python/compile/model.py::init_mlp`:
//! dense → ReLU → BWHT layer → dense.  This is the model the AOT
//! artifacts embed and the E2E driver trains; the rust engine runs the
//! same weights for inference on any [`Backend`].

use anyhow::Result;

use crate::util::rng::Rng;

use super::bwht_layer::{Backend, BwhtLayer};
use super::layers::{accuracy, relu, Dense};
use super::loader::Weights;

/// dense(din→hidden) → ReLU → BWHT(hidden) → dense(hidden→classes).
#[derive(Debug, Clone)]
pub struct Mlp {
    pub fc1: Dense,
    pub bwht: BwhtLayer,
    pub fc2: Dense,
    pub hidden: usize,
    pub classes: usize,
}

impl Mlp {
    /// Build from a python-exported weight file (`mlp_*.json`).
    pub fn from_weights(w: &Weights) -> Result<Mlp> {
        let fc1w = w.get("fc1.w")?;
        let fc1b = w.get("fc1.b")?;
        let t = w.get("bwht.t")?;
        let fc2w = w.get("fc2.w")?;
        let fc2b = w.get("fc2.b")?;
        let (din, hidden) = (fc1w.shape[0], fc1w.shape[1]);
        let classes = fc2w.shape[1];
        Ok(Mlp {
            fc1: Dense::new(din, hidden, fc1w.data.clone(), fc1b.data.clone()),
            bwht: BwhtLayer::new(hidden, hidden, t.data.clone(), 128),
            fc2: Dense::new(hidden, classes, fc2w.data.clone(), fc2b.data.clone()),
            hidden,
            classes,
        })
    }

    /// Build from flat parameter vectors (e.g. PJRT training output).
    #[allow(clippy::too_many_arguments)]
    pub fn from_flat(
        din: usize,
        hidden: usize,
        classes: usize,
        fc1_w: Vec<f32>,
        fc1_b: Vec<f32>,
        t: Vec<f32>,
        fc2_w: Vec<f32>,
        fc2_b: Vec<f32>,
    ) -> Mlp {
        Mlp {
            fc1: Dense::new(din, hidden, fc1_w, fc1_b),
            bwht: BwhtLayer::new(hidden, hidden, t, 128),
            fc2: Dense::new(hidden, classes, fc2_w, fc2_b),
            hidden,
            classes,
        }
    }

    /// Logits for a `(batch, din)` input.
    pub fn forward(&self, x: &[f32], batch: usize, backend: Backend, rng: &mut Rng) -> Vec<f32> {
        let mut h = self.fc1.forward(x, batch);
        relu(&mut h);
        let h = self
            .bwht
            .forward(&h, batch, self.hidden, self.hidden, backend, rng);
        self.fc2.forward(&h, batch)
    }

    /// Batched accuracy evaluation.
    pub fn evaluate(
        &self,
        x: &[f32],
        labels: &[i32],
        backend: Backend,
        rng: &mut Rng,
        batch: usize,
    ) -> f64 {
        let din = self.fc1.din;
        let n = labels.len();
        assert_eq!(x.len(), n * din);
        let mut correct_weighted = 0.0;
        let mut i = 0;
        while i < n {
            let b = batch.min(n - i);
            let logits = self.forward(&x[i * din..(i + b) * din], b, backend, rng);
            correct_weighted += accuracy(&logits, &labels[i..i + b], self.classes) * b as f64;
            i += b;
        }
        correct_weighted / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_mlp() -> Mlp {
        let mut r = Rng::seed_from_u64(1);
        let din = 8;
        let hidden = 8;
        let classes = 3;
        Mlp::from_flat(
            din,
            hidden,
            classes,
            r.normal_vec_f32(din * hidden, 0.0, 0.5),
            vec![0.0; hidden],
            vec![0.05; hidden],
            r.normal_vec_f32(hidden * classes, 0.0, 0.5),
            vec![0.0; classes],
        )
    }

    #[test]
    fn forward_shape() {
        let m = tiny_mlp();
        let mut r = Rng::seed_from_u64(2);
        let x: Vec<f32> = (0..4 * 8).map(|i| (i as f32 * 0.3).sin()).collect();
        let y = m.forward(&x, 4, Backend::Float, &mut r);
        assert_eq!(y.len(), 4 * 3);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn evaluate_in_unit_interval() {
        let m = tiny_mlp();
        let mut r = Rng::seed_from_u64(3);
        let x: Vec<f32> = (0..10 * 8).map(|i| (i as f32 * 0.7).cos()).collect();
        let labels = vec![0, 1, 2, 0, 1, 2, 0, 1, 2, 0];
        let acc = m.evaluate(&x, &labels, Backend::Float, &mut r, 4);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn from_weights_roundtrip() {
        let json = r#"{
            "fc1.w": {"shape": [4, 8], "data": [0.1,0.1,0.1,0.1,0.1,0.1,0.1,0.1,
                                                 0.1,0.1,0.1,0.1,0.1,0.1,0.1,0.1,
                                                 0.1,0.1,0.1,0.1,0.1,0.1,0.1,0.1,
                                                 0.1,0.1,0.1,0.1,0.1,0.1,0.1,0.1]},
            "fc1.b": {"shape": [8], "data": [0,0,0,0,0,0,0,0]},
            "bwht.t": {"shape": [8], "data": [0.1,0.1,0.1,0.1,0.1,0.1,0.1,0.1]},
            "fc2.w": {"shape": [8, 2], "data": [1,0, 0,1, 1,0, 0,1, 1,0, 0,1, 1,0, 0,1]},
            "fc2.b": {"shape": [2], "data": [0, 0]}
        }"#;
        let w = Weights::parse(json).unwrap();
        let m = Mlp::from_weights(&w).unwrap();
        assert_eq!(m.fc1.din, 4);
        assert_eq!(m.classes, 2);
        let mut r = Rng::seed_from_u64(4);
        let y = m.forward(&[1.0, 2.0, 3.0, 4.0], 1, Backend::Float, &mut r);
        assert_eq!(y.len(), 2);
    }
}

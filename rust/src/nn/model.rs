//! The MLP classifier matching `python/compile/model.py::init_mlp`:
//! dense → ReLU → BWHT layer → dense.  This is the model the AOT
//! artifacts embed and the E2E driver trains; the rust engine runs the
//! same weights for inference on any [`Backend`] — or, through
//! [`Mlp::forward_with`], on any [`TransformExecutor`] (coordinator
//! pool, shard set), with the BWHT transforms batched across the tiles.

use anyhow::Result;

use crate::exec::{InProcess, TransformExecutor};
use crate::util::rng::Rng;

use super::bwht_layer::{Backend, BwhtLayer};
use super::layers::{accuracy, relu, Dense};
use super::loader::Weights;

/// dense(din→hidden) → ReLU → BWHT(hidden) → dense(hidden→classes).
#[derive(Debug, Clone)]
pub struct Mlp {
    pub fc1: Dense,
    pub bwht: BwhtLayer,
    pub fc2: Dense,
    pub hidden: usize,
    pub classes: usize,
}

impl Mlp {
    /// Build from a python-exported weight file (`mlp_*.json`).
    pub fn from_weights(w: &Weights) -> Result<Mlp> {
        let fc1w = w.get("fc1.w")?;
        let fc1b = w.get("fc1.b")?;
        let t = w.get("bwht.t")?;
        let fc2w = w.get("fc2.w")?;
        let fc2b = w.get("fc2.b")?;
        let (din, hidden) = (fc1w.shape[0], fc1w.shape[1]);
        let classes = fc2w.shape[1];
        Ok(Mlp {
            fc1: Dense::new(din, hidden, fc1w.data.clone(), fc1b.data.clone()),
            bwht: BwhtLayer::new(hidden, hidden, t.data.clone(), 128),
            fc2: Dense::new(hidden, classes, fc2w.data.clone(), fc2b.data.clone()),
            hidden,
            classes,
        })
    }

    /// Build from flat parameter vectors (e.g. PJRT training output).
    #[allow(clippy::too_many_arguments)]
    pub fn from_flat(
        din: usize,
        hidden: usize,
        classes: usize,
        fc1_w: Vec<f32>,
        fc1_b: Vec<f32>,
        t: Vec<f32>,
        fc2_w: Vec<f32>,
        fc2_b: Vec<f32>,
    ) -> Mlp {
        Mlp {
            fc1: Dense::new(din, hidden, fc1_w, fc1_b),
            bwht: BwhtLayer::new(hidden, hidden, t, 128),
            fc2: Dense::new(hidden, classes, fc2_w, fc2_b),
            hidden,
            classes,
        }
    }

    /// Input feature count.
    pub fn din(&self) -> usize {
        self.fc1.din
    }

    /// Logits for a `(batch, din)` input, with the BWHT transforms
    /// delegated to `exec` as one batched call per pass.  `sample_offset`
    /// is the global index of the first sample (per-sample noise
    /// streams; irrelevant on deterministic executors).
    pub fn forward_with(
        &self,
        exec: &mut dyn TransformExecutor,
        x: &[f32],
        batch: usize,
        sample_offset: u64,
    ) -> Result<Vec<f32>> {
        let mut h = self.fc1.forward(x, batch);
        relu(&mut h);
        let h = self
            .bwht
            .forward_with(exec, &h, batch, self.hidden, self.hidden, sample_offset)?;
        Ok(self.fc2.forward(&h, batch))
    }

    /// Logits for a `(batch, din)` input on an in-process software
    /// backend (legacy signature; delegates through the executor seam).
    pub fn forward(&self, x: &[f32], batch: usize, backend: Backend, rng: &mut Rng) -> Vec<f32> {
        let mut exec = InProcess::new(backend, rng.next_u64());
        self.forward_with(&mut exec, x, batch, 0)
            .expect("in-process execution cannot fail")
    }

    /// Batched accuracy evaluation through an executor.  Chunks carry a
    /// running sample offset, so stochastic backends assign noise by
    /// *sample index* and the result is invariant to `batch`.
    pub fn evaluate_with(
        &self,
        exec: &mut dyn TransformExecutor,
        x: &[f32],
        labels: &[i32],
        batch: usize,
    ) -> Result<f64> {
        let din = self.fc1.din;
        let n = labels.len();
        assert_eq!(x.len(), n * din);
        let mut correct_weighted = 0.0;
        let mut i = 0;
        while i < n {
            let b = batch.min(n - i);
            let logits = self.forward_with(exec, &x[i * din..(i + b) * din], b, i as u64)?;
            correct_weighted += accuracy(&logits, &labels[i..i + b], self.classes) * b as f64;
            i += b;
        }
        Ok(correct_weighted / n as f64)
    }

    /// Batched accuracy evaluation on an in-process backend (legacy
    /// signature).  One RNG draw seeds the whole run, and noise streams
    /// are derived per sample index — so for a fixed starting `rng` the
    /// accuracy is deterministic regardless of `batch`.
    pub fn evaluate(
        &self,
        x: &[f32],
        labels: &[i32],
        backend: Backend,
        rng: &mut Rng,
        batch: usize,
    ) -> f64 {
        let mut exec = InProcess::new(backend, rng.next_u64());
        self.evaluate_with(&mut exec, x, labels, batch)
            .expect("in-process execution cannot fail")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_mlp() -> Mlp {
        let mut r = Rng::seed_from_u64(1);
        let din = 8;
        let hidden = 8;
        let classes = 3;
        Mlp::from_flat(
            din,
            hidden,
            classes,
            r.normal_vec_f32(din * hidden, 0.0, 0.5),
            vec![0.0; hidden],
            vec![0.05; hidden],
            r.normal_vec_f32(hidden * classes, 0.0, 0.5),
            vec![0.0; classes],
        )
    }

    #[test]
    fn forward_shape() {
        let m = tiny_mlp();
        let mut r = Rng::seed_from_u64(2);
        let x: Vec<f32> = (0..4 * 8).map(|i| (i as f32 * 0.3).sin()).collect();
        let y = m.forward(&x, 4, Backend::Float, &mut r);
        assert_eq!(y.len(), 4 * 3);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn evaluate_in_unit_interval() {
        let m = tiny_mlp();
        let mut r = Rng::seed_from_u64(3);
        let x: Vec<f32> = (0..10 * 8).map(|i| (i as f32 * 0.7).cos()).collect();
        let labels = vec![0, 1, 2, 0, 1, 2, 0, 1, 2, 0];
        let acc = m.evaluate(&x, &labels, Backend::Float, &mut r, 4);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn noisy_accuracy_is_batch_size_invariant() {
        // Satellite of the executor refactor: evaluation noise is keyed
        // by sample index, so chunking must not change the result.
        let m = tiny_mlp();
        let x: Vec<f32> = (0..24 * 8).map(|i| (i as f32 * 0.37).sin()).collect();
        let labels: Vec<i32> = (0..24).map(|i| (i % 3) as i32).collect();
        let backend = Backend::Noisy {
            bits: 4,
            sigma_ant: 0.8,
        };
        let acc_for = |batch: usize| {
            let mut r = Rng::seed_from_u64(11);
            m.evaluate(&x, &labels, backend, &mut r, batch)
        };
        let a1 = acc_for(1);
        assert_eq!(a1, acc_for(5));
        assert_eq!(a1, acc_for(24));
    }

    #[test]
    fn from_weights_roundtrip() {
        let json = r#"{
            "fc1.w": {"shape": [4, 8], "data": [0.1,0.1,0.1,0.1,0.1,0.1,0.1,0.1,
                                                 0.1,0.1,0.1,0.1,0.1,0.1,0.1,0.1,
                                                 0.1,0.1,0.1,0.1,0.1,0.1,0.1,0.1,
                                                 0.1,0.1,0.1,0.1,0.1,0.1,0.1,0.1]},
            "fc1.b": {"shape": [8], "data": [0,0,0,0,0,0,0,0]},
            "bwht.t": {"shape": [8], "data": [0.1,0.1,0.1,0.1,0.1,0.1,0.1,0.1]},
            "fc2.w": {"shape": [8, 2], "data": [1,0, 0,1, 1,0, 0,1, 1,0, 0,1, 1,0, 0,1]},
            "fc2.b": {"shape": [2], "data": [0, 0]}
        }"#;
        let w = Weights::parse(json).unwrap();
        let m = Mlp::from_weights(&w).unwrap();
        assert_eq!(m.fc1.din, 4);
        assert_eq!(m.classes, 2);
        let mut r = Rng::seed_from_u64(4);
        let y = m.forward(&[1.0, 2.0, 3.0, 4.0], 1, Backend::Float, &mut r);
        assert_eq!(y.len(), 2);
    }
}

//! Weight loading from the JSON exports of `python/compile/train.py`
//! (`make weights`): flat `{name: {shape, data}}` maps.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// A named weight tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Weight {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// All weights from one JSON export.
#[derive(Debug, Clone, Default)]
pub struct Weights {
    map: HashMap<String, Weight>,
}

impl Weights {
    pub fn load(path: impl AsRef<Path>) -> Result<Weights> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Weights> {
        let root = json::parse(text)?;
        let obj = root.as_obj().ok_or_else(|| anyhow!("root must be object"))?;
        let mut map = HashMap::new();
        for (name, entry) in obj {
            if entry.get("static").is_some() {
                continue; // non-array config leaf
            }
            let shape: Vec<usize> = entry
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing shape"))?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
                .collect::<Result<_>>()?;
            let data: Vec<f32> = entry
                .get("data")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing data"))?
                .iter()
                .map(|v| v.as_f64().map(|f| f as f32).ok_or_else(|| anyhow!("bad datum")))
                .collect::<Result<_>>()?;
            if shape.iter().product::<usize>() != data.len() {
                bail!("{name}: shape/data mismatch");
            }
            map.insert(name.clone(), Weight { shape, data });
        }
        Ok(Weights { map })
    }

    pub fn get(&self, name: &str) -> Result<&Weight> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("missing weight {name}"))
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.map.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn insert(&mut self, name: &str, shape: Vec<usize>, data: Vec<f32>) {
        self.map.insert(name.to_string(), Weight { shape, data });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "fc1.w": {"shape": [2, 3], "data": [1, 2, 3, 4, 5, 6]},
        "bwht.t": {"shape": [4], "data": [0.1, 0.2, 0.3, 0.4]},
        "flag": {"static": true}
    }"#;

    #[test]
    fn parses_sample() {
        let w = Weights::parse(SAMPLE).unwrap();
        assert_eq!(w.get("fc1.w").unwrap().shape, vec![2, 3]);
        assert_eq!(w.get("bwht.t").unwrap().data.len(), 4);
        assert!(w.get("flag").is_err(), "static leaves are skipped");
    }

    #[test]
    fn rejects_shape_mismatch() {
        let bad = r#"{"x": {"shape": [3], "data": [1, 2]}}"#;
        assert!(Weights::parse(bad).is_err());
    }

    #[test]
    fn names_sorted() {
        let w = Weights::parse(SAMPLE).unwrap();
        assert_eq!(w.names(), vec!["bwht.t", "fc1.w"]);
    }
}

//! The BWHT layer (Fig. 2): transform → soft-threshold → inverse, with
//! channel expansion/projection, executable on multiple backends.
//!
//! Matches `python/compile/model.py::bwht_layer` numerically in Float mode
//! and `ref.quant_bwht_ref` bit-for-bit in Quantized mode.

use crate::analog::noise::NoiseModel;
use crate::bitplane::QuantBwht;
use crate::util::rng::Rng;
use crate::wht;

use super::layers::soft_threshold;

/// Execution backend for the two transforms inside a BWHT layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Backend {
    /// Exact float transform — the "with ADC" algorithmic baseline.
    Float,
    /// Digital golden model of the ADC-free crossbar (Eq. 4).
    Quantized { bits: u32 },
    /// Eq. 4 with ANT noise on every PSUM before the comparator
    /// (Fig. 11(a) emulation of analog non-idealities).
    Noisy { bits: u32, sigma_ant: f64 },
}

/// A BWHT channel-mixing layer with per-channel thresholds `t`.
#[derive(Debug, Clone)]
pub struct BwhtLayer {
    /// Transform width (padded); `t.len() == width`.
    pub width: usize,
    pub max_block: usize,
    /// Trainable soft thresholds (the layer's ONLY parameters).
    pub t: Vec<f32>,
    /// Orthonormal scaling 1/sqrt(block) per channel.
    norm: Vec<f32>,
}

impl BwhtLayer {
    /// Build for mixing `cin -> cout` channels; `t` must cover the padded
    /// width of `max(cin, cout)`.
    pub fn new(cin: usize, cout: usize, t: Vec<f32>, max_block: usize) -> Self {
        let width = wht::bwht_padded_dim(cin.max(cout), max_block);
        assert_eq!(t.len(), width, "t must have padded width {width}");
        let blocks = wht::bwht_blocks(cin.max(cout), max_block);
        let mut norm = Vec::with_capacity(width);
        for &b in &blocks {
            norm.extend(std::iter::repeat(1.0 / (b as f32).sqrt()).take(b));
        }
        BwhtLayer {
            width,
            max_block,
            t,
            norm,
        }
    }

    fn transform(&self, x: &[f32], backend: Backend, rng: &mut Rng) -> Vec<f32> {
        match backend {
            Backend::Float => wht::bwht_apply(x, self.width, self.max_block),
            Backend::Quantized { bits } => {
                QuantBwht::new(self.width, self.max_block, bits).transform(x)
            }
            Backend::Noisy { bits, sigma_ant } => {
                let eng = QuantBwht::new(self.width, self.max_block, bits);
                let q = eng.quantizer.quantize(x);
                let nm = NoiseModel::new(sigma_ant, self.width);
                let mut acc = vec![0f32; self.width];
                for (p, plane) in q.bitplanes_msb_first().iter().enumerate() {
                    let psums = eng.plane_psums(plane);
                    let obits = nm.perturb_and_compare(&psums, rng);
                    let w = (1i64 << (bits as usize - 1 - p)) as f32;
                    for (a, &o) in acc.iter_mut().zip(&obits) {
                        *a += o as f32 * w;
                    }
                }
                acc.iter().map(|v| v * q.scale).collect()
            }
        }
    }

    /// Forward one `(batch, cin)` activation to `(batch, cout)`.
    ///
    /// Expansion (`cout > cin`) zero-pads channels before the transform;
    /// projection truncates after the inverse (low-sequency channels carry
    /// the energy).  Thresholding happens in the frequency domain between
    /// the two transforms, exactly the Fig. 2 flow.
    pub fn forward(
        &self,
        x: &[f32],
        batch: usize,
        cin: usize,
        cout: usize,
        backend: Backend,
        rng: &mut Rng,
    ) -> Vec<f32> {
        assert_eq!(x.len(), batch * cin);
        assert!(cin <= self.width && cout <= self.width);
        let mut out = vec![0f32; batch * cout];
        let mut padded = vec![0f32; self.width];
        for bi in 0..batch {
            padded.fill(0.0);
            padded[..cin].copy_from_slice(&x[bi * cin..(bi + 1) * cin]);
            // forward transform + orthonormal scale
            let mut freq = self.transform(&padded, backend, rng);
            for (f, &n) in freq.iter_mut().zip(&self.norm) {
                *f *= n;
            }
            soft_threshold(&mut freq, &self.t);
            // inverse transform (+ scale): W/sqrt(n) is its own inverse
            let mut spatial = self.transform(&freq, backend, rng);
            for (s, &n) in spatial.iter_mut().zip(&self.norm) {
                *s *= n;
            }
            out[bi * cout..(bi + 1) * cout].copy_from_slice(&spatial[..cout]);
        }
        out
    }

    /// Thresholds in comparator units for the early-termination scheduler:
    /// `T_units[i] = |t_i| / (norm_i * scale)`.
    pub fn thresholds_units(&self, scale: f32) -> Vec<f64> {
        self.t
            .iter()
            .zip(&self.norm)
            .map(|(&t, &n)| (t.abs() / (n * scale).max(1e-12)) as f64)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(width_src: usize, t_val: f32) -> BwhtLayer {
        let width = wht::bwht_padded_dim(width_src, 128);
        BwhtLayer::new(width_src, width_src, vec![t_val; width], 128)
    }

    fn rng() -> Rng {
        Rng::seed_from_u64(3)
    }

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::seed_from_u64(seed);
        (0..n).map(|_| r.uniform_range(-2.0, 2.0) as f32).collect()
    }

    #[test]
    fn zero_threshold_float_is_identity() {
        let l = layer(32, 0.0);
        let x = sample(2 * 32, 1);
        let y = l.forward(&x, 2, 32, 32, Backend::Float, &mut rng());
        for (a, b) in y.iter().zip(&x) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn huge_threshold_zeroes_output() {
        let l = layer(16, 1e6);
        let x = sample(16, 2);
        let y = l.forward(&x, 1, 16, 16, Backend::Float, &mut rng());
        assert!(y.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn expansion_and_projection_shapes() {
        let width = wht::bwht_padded_dim(32, 128);
        let l = BwhtLayer::new(16, 32, vec![0.1; width], 128);
        let x = sample(3 * 16, 3);
        let y = l.forward(&x, 3, 16, 32, Backend::Float, &mut rng());
        assert_eq!(y.len(), 3 * 32);
        let l2 = BwhtLayer::new(32, 8, vec![0.1; width], 128);
        let y2 = l2.forward(&sample(2 * 32, 4), 2, 32, 8, Backend::Float, &mut rng());
        assert_eq!(y2.len(), 2 * 8);
    }

    #[test]
    fn quantized_backend_approximates_float() {
        let l = layer(64, 0.05);
        let x = sample(64, 5);
        let yf = l.forward(&x, 1, 64, 64, Backend::Float, &mut rng());
        let yq = l.forward(&x, 1, 64, 64, Backend::Quantized { bits: 8 }, &mut rng());
        // crude approximation: require correlation, not fidelity
        let dot: f32 = yf.iter().zip(&yq).map(|(a, b)| a * b).sum();
        let na: f32 = yf.iter().map(|v| v * v).sum::<f32>().sqrt();
        let nb: f32 = yq.iter().map(|v| v * v).sum::<f32>().sqrt();
        if na > 1e-6 && nb > 1e-6 {
            assert!(dot / (na * nb) > 0.2, "cosine {}", dot / (na * nb));
        }
    }

    #[test]
    fn noisy_backend_zero_sigma_equals_quantized() {
        let l = layer(16, 0.1);
        let x = sample(16, 6);
        let yq = l.forward(&x, 1, 16, 16, Backend::Quantized { bits: 4 }, &mut rng());
        let yn = l.forward(
            &x,
            1,
            16,
            16,
            Backend::Noisy {
                bits: 4,
                sigma_ant: 0.0,
            },
            &mut rng(),
        );
        assert_eq!(yq, yn);
    }

    #[test]
    fn noisy_backend_perturbs() {
        let l = layer(16, 0.0);
        let x = sample(16, 7);
        let yq = l.forward(&x, 1, 16, 16, Backend::Quantized { bits: 8 }, &mut rng());
        let yn = l.forward(
            &x,
            1,
            16,
            16,
            Backend::Noisy {
                bits: 8,
                sigma_ant: 0.3,
            },
            &mut rng(),
        );
        assert_ne!(yq, yn);
    }

    #[test]
    fn threshold_units_scaling() {
        let l = layer(16, 0.5);
        let units = l.thresholds_units(0.25);
        // norm = 1/4 for a 16-block; units = 0.5 / (0.25 * 0.25) = 8
        assert!((units[0] - 8.0).abs() < 1e-6);
    }
}

//! The BWHT layer (Fig. 2): transform → soft-threshold → inverse, with
//! channel expansion/projection, executable on any
//! [`crate::exec::TransformExecutor`].
//!
//! Matches `python/compile/model.py::bwht_layer` numerically in Float mode
//! and `ref.quant_bwht_ref` bit-for-bit in Quantized mode.  The legacy
//! per-sample [`BwhtLayer::forward`] signature survives as a thin wrapper
//! that builds an [`crate::exec::InProcess`] executor, so both transforms
//! of every sample — wherever they execute — flow through one seam.

use anyhow::Result;

use crate::coordinator::TransformRequest;
use crate::exec::{InProcess, TransformExecutor};
use crate::quant::Quantizer;
use crate::util::rng::Rng;
use crate::wht;

use super::layers::soft_threshold;

/// Execution backend for the two transforms inside a BWHT layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Backend {
    /// Exact float transform — the "with ADC" algorithmic baseline.
    Float,
    /// Digital golden model of the ADC-free crossbar (Eq. 4).
    Quantized { bits: u32 },
    /// Eq. 4 with ANT noise on every PSUM before the comparator
    /// (Fig. 11(a) emulation of analog non-idealities).
    Noisy { bits: u32, sigma_ant: f64 },
}

/// A BWHT channel-mixing layer with per-channel thresholds `t`.
#[derive(Debug, Clone)]
pub struct BwhtLayer {
    /// Transform width (padded); `t.len() == width`.
    pub width: usize,
    pub max_block: usize,
    /// Trainable soft thresholds (the layer's ONLY parameters).
    pub t: Vec<f32>,
    /// Orthonormal scaling 1/sqrt(block) per channel.
    norm: Vec<f32>,
    /// Block partition both transforms run on (`bwht_blocks(width,
    /// max_block)` — the structure the legacy backends always used).
    /// Mixed partitions like `[128, 64, 16, 4]` are emitted as-is: every
    /// executor maps sub-tile blocks onto the crossbar via
    /// [`crate::coordinator::plan::TilePlan`] masking, so any width is
    /// servable.
    tblocks: Vec<usize>,
}

impl BwhtLayer {
    /// Build for mixing `cin -> cout` channels; `t` must cover the padded
    /// width of `max(cin, cout)`.
    pub fn new(cin: usize, cout: usize, t: Vec<f32>, max_block: usize) -> Self {
        let width = wht::bwht_padded_dim(cin.max(cout), max_block);
        assert_eq!(t.len(), width, "t must have padded width {width}");
        let blocks = wht::bwht_blocks(cin.max(cout), max_block);
        let mut norm = Vec::with_capacity(width);
        for &b in &blocks {
            norm.extend(std::iter::repeat(1.0 / (b as f32).sqrt()).take(b));
        }
        let tblocks = wht::bwht_blocks(width, max_block);
        BwhtLayer {
            width,
            max_block,
            t,
            norm,
            tblocks,
        }
    }

    /// Block partition of this layer's transforms (what an executor must
    /// be able to map onto tiles).
    pub fn transform_blocks(&self) -> &[usize] {
        &self.tblocks
    }

    /// Forward one `(batch, cin)` activation to `(batch, cout)` through
    /// an executor: one batched transform call per pass instead of a
    /// per-sample loop.
    ///
    /// Expansion (`cout > cin`) zero-pads channels before the transform;
    /// projection truncates after the inverse (low-sequency channels carry
    /// the energy).  Thresholding happens in the frequency domain between
    /// the two transforms, exactly the Fig. 2 flow.  On quantized
    /// executors the per-sample global quantization scale is pinned on
    /// every request (so tiled execution matches the whole-width golden
    /// model bit-for-bit) and the soft-threshold dead zone is mapped into
    /// comparator units so it fuses into the crossbar early-termination
    /// path — crossbar backends skip the cycles, and the survivors are
    /// shrunk in the frequency domain exactly as in software.
    ///
    /// `sample_offset` is the global index of the first sample; noisy
    /// backends derive one RNG stream per (sample index, pass), making
    /// results invariant to how a dataset is chunked into batches.
    pub fn forward_with(
        &self,
        exec: &mut dyn TransformExecutor,
        x: &[f32],
        batch: usize,
        cin: usize,
        cout: usize,
        sample_offset: u64,
    ) -> Result<Vec<f32>> {
        assert_eq!(x.len(), batch * cin);
        assert!(cin <= self.width && cout <= self.width);
        let qbits = exec.quant_bits();

        // Forward transform: pad each sample, pin its quantization scale
        // and fuse the soft-threshold dead zone into ET thresholds.
        let mut reqs = Vec::with_capacity(batch);
        let mut streams = Vec::with_capacity(batch);
        for bi in 0..batch {
            let mut padded = vec![0f32; self.width];
            padded[..cin].copy_from_slice(&x[bi * cin..(bi + 1) * cin]);
            let (scale, thresholds_units) = match qbits {
                Some(bits) => {
                    let quantizer = Quantizer::new(bits);
                    let s = quantizer.scale_for(&padded);
                    let th = self.fused_thresholds_units(s, quantizer.qmax() as i64);
                    (Some(s), th)
                }
                None => (None, vec![0.0; self.width]),
            };
            reqs.push(TransformRequest {
                x: padded,
                thresholds_units,
                scale,
                deadline: None,
            });
            streams.push((sample_offset + bi as u64) * 2);
        }
        let freqs = exec.transform_batch(&self.tblocks, &reqs, &streams)?;

        // Frequency domain: orthonormal scale + soft threshold, then the
        // inverse transform (W/sqrt(n) is its own inverse).  ET-zeroed
        // elements arrive as 0 and stay 0; survivors carry their full
        // value and are shrunk here, bit-identically to the software path.
        let mut reqs2 = Vec::with_capacity(batch);
        let mut streams2 = Vec::with_capacity(batch);
        for (bi, mut freq) in freqs.into_iter().enumerate() {
            debug_assert_eq!(freq.len(), self.width);
            for (f, &n) in freq.iter_mut().zip(&self.norm) {
                *f *= n;
            }
            soft_threshold(&mut freq, &self.t);
            let scale = qbits.map(|bits| Quantizer::new(bits).scale_for(&freq));
            reqs2.push(TransformRequest {
                x: freq,
                thresholds_units: vec![0.0; self.width],
                scale,
                deadline: None,
            });
            streams2.push((sample_offset + bi as u64) * 2 + 1);
        }
        let spatials = exec.transform_batch(&self.tblocks, &reqs2, &streams2)?;

        let mut out = vec![0f32; batch * cout];
        for (bi, mut spatial) in spatials.into_iter().enumerate() {
            for (s, &n) in spatial.iter_mut().zip(&self.norm) {
                *s *= n;
            }
            out[bi * cout..(bi + 1) * cout].copy_from_slice(&spatial[..cout]);
        }
        Ok(out)
    }

    /// Forward one `(batch, cin)` activation to `(batch, cout)` on an
    /// in-process software backend (legacy signature; delegates to
    /// [`BwhtLayer::forward_with`] over an [`InProcess`] executor).
    pub fn forward(
        &self,
        x: &[f32],
        batch: usize,
        cin: usize,
        cout: usize,
        backend: Backend,
        rng: &mut Rng,
    ) -> Vec<f32> {
        let mut exec = InProcess::new(backend, rng.next_u64());
        self.forward_with(&mut exec, x, batch, cin, cout, 0)
            .expect("in-process execution cannot fail")
    }

    /// Thresholds in comparator units for the early-termination scheduler:
    /// `T_units[i] = |t_i| / (norm_i * scale)`.
    pub fn thresholds_units(&self, scale: f32) -> Vec<f64> {
        self.t
            .iter()
            .zip(&self.norm)
            .map(|(&t, &n)| (t.abs() / (n * scale).max(1e-12)) as f64)
            .collect()
    }

    /// Early-termination thresholds that fuse the soft-threshold dead
    /// zone *exactly* into the comparator path.
    ///
    /// `T_units[i]` is the largest integer `u` in `[0, qmax]` whose
    /// dequantized frequency value lands inside the dead zone under f32
    /// arithmetic — i.e. `(u as f32 * scale) * norm_i <= |t_i|`, the very
    /// comparison [`soft_threshold`] makes.  The naive ratio
    /// `|t| / (norm * scale)` can straddle an integer boundary after f32
    /// rounding, silently zeroing an element software would have kept (or
    /// vice versa); searching the integer lattice with the f32 predicate
    /// makes the ET zero-set identical to the software dead zone, which
    /// is what keeps pooled execution bit-identical to
    /// [`Backend::Quantized`].  The predicate is monotone in `u` (product
    /// of non-negative f32 factors), so a binary search suffices.
    pub fn fused_thresholds_units(&self, scale: f32, qmax: i64) -> Vec<f64> {
        self.t
            .iter()
            .zip(&self.norm)
            .map(|(&t, &n)| {
                let t_abs = t.abs();
                let inside = |u: i64| (u as f32 * scale) * n <= t_abs;
                let mut lo = 0i64; // inside(0) always holds: 0 <= |t|
                let mut hi = qmax;
                while lo < hi {
                    let mid = (lo + hi + 1) / 2;
                    if inside(mid) {
                        lo = mid;
                    } else {
                        hi = mid - 1;
                    }
                }
                lo as f64
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(width_src: usize, t_val: f32) -> BwhtLayer {
        let width = wht::bwht_padded_dim(width_src, 128);
        BwhtLayer::new(width_src, width_src, vec![t_val; width], 128)
    }

    fn rng() -> Rng {
        Rng::seed_from_u64(3)
    }

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::seed_from_u64(seed);
        (0..n).map(|_| r.uniform_range(-2.0, 2.0) as f32).collect()
    }

    #[test]
    fn zero_threshold_float_is_identity() {
        let l = layer(32, 0.0);
        let x = sample(2 * 32, 1);
        let y = l.forward(&x, 2, 32, 32, Backend::Float, &mut rng());
        for (a, b) in y.iter().zip(&x) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn huge_threshold_zeroes_output() {
        let l = layer(16, 1e6);
        let x = sample(16, 2);
        let y = l.forward(&x, 1, 16, 16, Backend::Float, &mut rng());
        assert!(y.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn expansion_and_projection_shapes() {
        let width = wht::bwht_padded_dim(32, 128);
        let l = BwhtLayer::new(16, 32, vec![0.1; width], 128);
        let x = sample(3 * 16, 3);
        let y = l.forward(&x, 3, 16, 32, Backend::Float, &mut rng());
        assert_eq!(y.len(), 3 * 32);
        let l2 = BwhtLayer::new(32, 8, vec![0.1; width], 128);
        let y2 = l2.forward(&sample(2 * 32, 4), 2, 32, 8, Backend::Float, &mut rng());
        assert_eq!(y2.len(), 2 * 8);
    }

    #[test]
    fn quantized_backend_approximates_float() {
        let l = layer(64, 0.05);
        let x = sample(64, 5);
        let yf = l.forward(&x, 1, 64, 64, Backend::Float, &mut rng());
        let yq = l.forward(&x, 1, 64, 64, Backend::Quantized { bits: 8 }, &mut rng());
        // crude approximation: require correlation, not fidelity
        let dot: f32 = yf.iter().zip(&yq).map(|(a, b)| a * b).sum();
        let na: f32 = yf.iter().map(|v| v * v).sum::<f32>().sqrt();
        let nb: f32 = yq.iter().map(|v| v * v).sum::<f32>().sqrt();
        if na > 1e-6 && nb > 1e-6 {
            assert!(dot / (na * nb) > 0.2, "cosine {}", dot / (na * nb));
        }
    }

    #[test]
    fn noisy_backend_zero_sigma_equals_quantized() {
        let l = layer(16, 0.1);
        let x = sample(16, 6);
        let yq = l.forward(&x, 1, 16, 16, Backend::Quantized { bits: 4 }, &mut rng());
        let yn = l.forward(
            &x,
            1,
            16,
            16,
            Backend::Noisy {
                bits: 4,
                sigma_ant: 0.0,
            },
            &mut rng(),
        );
        assert_eq!(yq, yn);
    }

    #[test]
    fn noisy_backend_perturbs() {
        let l = layer(16, 0.0);
        let x = sample(16, 7);
        let yq = l.forward(&x, 1, 16, 16, Backend::Quantized { bits: 8 }, &mut rng());
        let yn = l.forward(
            &x,
            1,
            16,
            16,
            Backend::Noisy {
                bits: 8,
                sigma_ant: 0.3,
            },
            &mut rng(),
        );
        assert_ne!(yq, yn);
    }

    #[test]
    fn threshold_units_scaling() {
        let l = layer(16, 0.5);
        let units = l.thresholds_units(0.25);
        // norm = 1/4 for a 16-block; units = 0.5 / (0.25 * 0.25) = 8
        assert!((units[0] - 8.0).abs() < 1e-6);
    }

    #[test]
    fn fused_thresholds_match_the_f32_dead_zone_exactly() {
        let l = layer(16, 0.37);
        let scale = 0.013f32;
        let units = l.fused_thresholds_units(scale, 255);
        let norm = 0.25f32; // 1/sqrt(16)
        for (i, &u) in units.iter().enumerate() {
            let u = u as i64;
            // u is inside the dead zone; u+1 (if representable) is not.
            assert!((u as f32 * scale) * norm <= 0.37, "channel {i}: u inside");
            if u < 255 {
                assert!(
                    ((u + 1) as f32 * scale) * norm > 0.37,
                    "channel {i}: u+1 outside"
                );
            }
        }
    }

    #[test]
    fn fused_thresholds_zero_t_terminates_nothing() {
        let l = layer(16, 0.0);
        let units = l.fused_thresholds_units(0.01, 255);
        assert!(units.iter().all(|&u| u == 0.0), "{units:?}");
    }

    #[test]
    fn transform_blocks_partition_covers_width() {
        let l = layer(20, 0.1);
        assert_eq!(l.transform_blocks().iter().sum::<usize>(), l.width);
    }
}

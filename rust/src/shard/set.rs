//! Shard-set lifecycle: N independent [`Coordinator`] pools presented as
//! one logical accelerator.
//!
//! Each shard owns its own worker threads, tiles and RNG stream — shards
//! never share mutable state, so they scale like the paper's stitched
//! crossbar arrays (PAPER.md §IV).  Per-shard seeds are derived from the
//! base seed with a large odd stride, and each shard's coordinator then
//! derives per-*worker* variability seeds from its shard seed, so every
//! simulated macro in the whole set samples independent process
//! variability.
//!
//! Failure isolation: a shard whose pool dies is *poisoned* — taken out
//! of the healthy set and retired — rather than failing requests.  The
//! [`crate::shard::router`] re-routes a poisoned shard's slices to the
//! surviving shards.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::coordinator::{Coordinator, CoordinatorConfig, Metrics, TileKind};

use super::metrics_agg::MetricsAggregator;

/// Per-shard seed stride (large odd constant, well clear of the
/// coordinator's per-worker stride of `0x9E37`).
pub const SHARD_SEED_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

/// Shard-set configuration.
#[derive(Debug, Clone)]
pub struct ShardSetConfig {
    /// Number of independent coordinator pools.
    pub shards: usize,
    /// Base pool configuration; shard `s` runs it with
    /// `seed + s * seed_stride` (and `kinds[s]` when given).
    pub coordinator: CoordinatorConfig,
    /// Per-shard seed stride.
    pub seed_stride: u64,
    /// Optional per-shard backend override (length must equal `shards`);
    /// `None` runs every shard on `coordinator.kind`.
    pub kinds: Option<Vec<TileKind>>,
}

impl Default for ShardSetConfig {
    fn default() -> Self {
        ShardSetConfig {
            shards: 1,
            coordinator: CoordinatorConfig::default(),
            seed_stride: SHARD_SEED_STRIDE,
            kinds: None,
        }
    }
}

/// N coordinator pools plus health tracking and retired-shard metrics.
pub struct ShardSet {
    /// `None` marks a poisoned slot.  Indices are stable for the set's
    /// lifetime so metrics labels and plans stay meaningful.
    slots: Vec<Option<Coordinator>>,
    /// Live metrics handles, one per slot — kept even after poisoning so
    /// the aggregator can still report what a dead shard served.
    handles: Vec<Arc<Mutex<Metrics>>>,
    /// Worker-side metrics folded out of poisoned shards at poison time.
    retired: Metrics,
    /// Healthy-shard count, shared with the serving front-end's
    /// `/metrics` exporter.
    healthy_gauge: Arc<AtomicUsize>,
    config: ShardSetConfig,
}

impl ShardSet {
    pub fn new(config: ShardSetConfig) -> Result<ShardSet> {
        if config.shards == 0 {
            bail!("shard set needs at least one shard");
        }
        if let Some(kinds) = &config.kinds {
            if kinds.len() != config.shards {
                bail!(
                    "per-shard kinds length {} does not match shards {}",
                    kinds.len(),
                    config.shards
                );
            }
        }
        let mut slots = Vec::with_capacity(config.shards);
        let mut handles = Vec::with_capacity(config.shards);
        for s in 0..config.shards {
            let mut cc = config.coordinator.clone();
            cc.seed = cc.seed.wrapping_add((s as u64).wrapping_mul(config.seed_stride));
            if let Some(kinds) = &config.kinds {
                cc.kind = kinds[s].clone();
            }
            let coord = Coordinator::new(cc);
            handles.push(coord.metrics_handle());
            slots.push(Some(coord));
        }
        let retired = Metrics::new(config.coordinator.bits);
        let healthy_gauge = Arc::new(AtomicUsize::new(config.shards));
        Ok(ShardSet {
            slots,
            handles,
            retired,
            healthy_gauge,
            config,
        })
    }

    pub fn config(&self) -> &ShardSetConfig {
        &self.config
    }

    /// Total slots, poisoned included.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Tile width every shard runs (shards share the base geometry).
    pub fn tile_n(&self) -> usize {
        self.config.coordinator.tile_n
    }

    pub fn bits(&self) -> u32 {
        self.config.coordinator.bits
    }

    /// Worker threads per shard (the router splits a shard's blocks this
    /// many ways for intra-shard parallelism).
    pub fn workers_per_shard(&self) -> usize {
        self.config.coordinator.workers
    }

    pub fn is_healthy(&self, shard: usize) -> bool {
        self.slots.get(shard).is_some_and(Option::is_some)
    }

    /// Slot indices of the currently healthy shards, ascending.
    pub fn healthy(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&s| self.is_healthy(s)).collect()
    }

    pub fn healthy_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Shared healthy-count gauge for metrics exporters.
    pub fn health_handle(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.healthy_gauge)
    }

    /// Mutable access to one shard's coordinator (`None` if poisoned or
    /// out of range).
    pub fn coordinator_mut(&mut self, shard: usize) -> Option<&mut Coordinator> {
        self.slots.get_mut(shard).and_then(Option::as_mut)
    }

    /// Retire a shard: take it out of the healthy set, shut its pool
    /// down (joining whatever workers are still alive) and fold its
    /// worker metrics into the retired accumulator.  Idempotent.
    pub fn poison(&mut self, shard: usize) {
        if let Some(coord) = self.slots.get_mut(shard).and_then(Option::take) {
            self.retired.merge(&coord.shutdown());
            self.healthy_gauge.fetch_sub(1, Ordering::AcqRel);
        }
    }

    /// Aggregator over every shard's live metrics handle (poisoned
    /// shards keep reporting what they served before dying).
    pub fn aggregator(&self) -> MetricsAggregator {
        MetricsAggregator::new(self.handles.clone(), self.config.coordinator.bits)
    }

    /// Merged snapshot of drained work across all shards.
    pub fn metrics(&self) -> Metrics {
        self.aggregator().merged()
    }

    /// Shut every surviving pool down and return the merged per-worker
    /// metrics, poisoned shards included.
    pub fn shutdown(self) -> Metrics {
        let mut total = self.retired;
        for slot in self.slots.into_iter().flatten() {
            total.merge(&slot.shutdown());
        }
        self.healthy_gauge.store(0, Ordering::Release);
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TransformRequest;

    #[test]
    fn spins_up_and_shuts_down_n_shards() {
        let set = ShardSet::new(ShardSetConfig {
            shards: 3,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(set.len(), 3);
        assert_eq!(set.healthy(), vec![0, 1, 2]);
        assert_eq!(set.healthy_count(), 3);
        let m = set.shutdown();
        assert_eq!(m.requests, 0);
    }

    #[test]
    fn rejects_zero_shards_and_mismatched_kinds() {
        assert!(ShardSet::new(ShardSetConfig {
            shards: 0,
            ..Default::default()
        })
        .is_err());
        assert!(ShardSet::new(ShardSetConfig {
            shards: 2,
            kinds: Some(vec![TileKind::Digital]),
            ..Default::default()
        })
        .is_err());
    }

    #[test]
    fn poison_removes_a_shard_and_keeps_its_metrics() {
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.17).cos()).collect();
        let req = TransformRequest {
            x,
            thresholds_units: vec![0.0; 16],
        };
        let id = set.coordinator_mut(0).unwrap().submit(&req).unwrap();
        let done = set.coordinator_mut(0).unwrap().drain_one().unwrap();
        assert_eq!(done.request_id, id);

        let gauge = set.health_handle();
        set.poison(0);
        set.poison(0); // idempotent
        assert_eq!(set.healthy(), vec![1]);
        assert_eq!(gauge.load(Ordering::Acquire), 1);
        assert!(set.coordinator_mut(0).is_none());
        // The poisoned shard's served work survives in both views.
        assert_eq!(set.metrics().requests, 1);
        let m = set.shutdown();
        assert_eq!(m.requests, 1);
        assert_eq!(gauge.load(Ordering::Acquire), 0);
    }

    #[test]
    fn per_shard_seeds_differ() {
        let set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        // Derivation happens in new(); spot-check the stride arithmetic.
        let base = set.config().coordinator.seed;
        assert_ne!(
            base.wrapping_add(SHARD_SEED_STRIDE),
            base,
            "stride must move the seed"
        );
        set.shutdown();
    }
}

//! Shard-set lifecycle: N independent [`Coordinator`] pools presented as
//! one logical accelerator.
//!
//! Each shard owns its own worker threads, tiles and RNG stream — shards
//! never share mutable state, so they scale like the paper's stitched
//! crossbar arrays (PAPER.md §IV).  Per-shard seeds are derived from the
//! base seed with a large odd stride, and each shard's coordinator then
//! derives per-*worker* variability seeds from its shard seed, so every
//! simulated macro in the whole set samples independent process
//! variability.
//!
//! Failure isolation: a shard whose pool dies is *poisoned* — taken out
//! of the healthy set and retired — rather than failing requests.  The
//! [`crate::shard::router`] re-routes a poisoned shard's slices to the
//! surviving shards.  A poisoned slot can later be healed in place with
//! [`ShardSet::respawn`]: a fresh pool (new seed, so fresh process
//! variability) is spun up and folded back into the healthy set — the
//! serving loop calls this on a health tick so a transient pool death
//! does not permanently shrink capacity.
//!
//! Every slot carries a circuit breaker ([`super::breaker`]): poisoning
//! forces it open, a respawn puts it on half-open probation, and the
//! serving health tick heals through [`ShardSet::respawn_backed_off`]
//! so a permanently sick slot backs off exponentially instead of
//! respawn-storming.  Under the `chaos` feature the `shard.kill` and
//! `shard.flap` injection points ([`ShardSet::chaos_disrupt`]) drive
//! exactly those paths deterministically.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::chaos::ChaosPoint;
use crate::coordinator::{Coordinator, CoordinatorConfig, Metrics, TileKind};
use crate::monitor::MonitorHandle;
use crate::trace::TraceHandle;

use super::breaker::BreakerSet;
use super::metrics_agg::{HandleSlots, MetricsAggregator};

/// Per-shard seed stride (large odd constant, well clear of the
/// coordinator's per-worker stride of `0x9E37`).
pub const SHARD_SEED_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

/// Per-generation seed stride applied on [`ShardSet::respawn`], so a
/// respawned pool samples fresh process variability instead of
/// resurrecting the dead pool's exact tiles.
pub const RESPAWN_SEED_STRIDE: u64 = 0x517C_C1B7_2722_0A95;

/// Shard-set configuration.
#[derive(Debug, Clone)]
pub struct ShardSetConfig {
    /// Number of independent coordinator pools.
    pub shards: usize,
    /// Base pool configuration; shard `s` runs it with
    /// `seed + s * seed_stride` (and `kinds[s]` when given).
    pub coordinator: CoordinatorConfig,
    /// Per-shard seed stride.
    pub seed_stride: u64,
    /// Optional per-shard backend override (length must equal `shards`);
    /// `None` runs every shard on `coordinator.kind`.
    pub kinds: Option<Vec<TileKind>>,
}

impl Default for ShardSetConfig {
    fn default() -> Self {
        ShardSetConfig {
            shards: 1,
            coordinator: CoordinatorConfig::default(),
            seed_stride: SHARD_SEED_STRIDE,
            kinds: None,
        }
    }
}

/// N coordinator pools plus health tracking and retired-shard metrics.
pub struct ShardSet {
    /// `None` marks a poisoned slot.  Indices are stable for the set's
    /// lifetime so metrics labels and plans stay meaningful.
    slots: Vec<Option<Coordinator>>,
    /// Live metrics handles, one list per slot (one entry per pool
    /// generation) — kept even after poisoning so the aggregator can
    /// still report what a dead shard served.  Shared with every
    /// [`MetricsAggregator`] handed out, so respawns are visible to
    /// aggregators created earlier.
    handles: HandleSlots,
    /// Pool generation per slot (0 = the original pool).
    generations: Vec<u64>,
    /// Worker-side metrics folded out of poisoned shards at poison time.
    retired: Metrics,
    /// Healthy-shard count, shared with the serving front-end's
    /// `/metrics` exporter.
    healthy_gauge: Arc<AtomicUsize>,
    /// Respawns performed over the set's lifetime (shared counter for
    /// the `/metrics` exporter).
    respawns: Arc<AtomicU64>,
    /// Per-slot health flags, shared with the serving front-end's
    /// `/readyz` probe (slot-granular, unlike the aggregate
    /// `healthy_gauge`).
    slot_health: Arc<Vec<AtomicBool>>,
    /// Trace handles for the requests of the batch currently being
    /// routed (one per planned request, in request order).  Set by the
    /// batcher around each dispatch so the router can attribute
    /// plan/scatter/execute/drain spans without widening the
    /// [`crate::exec::TransformExecutor`] seam.  Empty (the common
    /// case) or all-inactive means no tracing work happens.
    trace_scope: Vec<TraceHandle>,
    /// Fidelity-monitor capture handle.  Inactive (the default) unless
    /// the serving front-end attached a live monitor; the router checks
    /// it once per drained slice and enqueues sampled slices for shadow
    /// verification.
    monitor: MonitorHandle,
    /// Per-slot circuit breakers: routing consults them, drains and
    /// lifecycle events (poison/respawn) feed them.  Shared so the
    /// serving front-end can export breaker state without holding the
    /// set.
    breakers: Arc<BreakerSet>,
    /// Injection points owned by the set so their decision counters
    /// persist across router invocations (a fresh counter per batch
    /// would replay the same prefix of the decision stream forever).
    chaos_drain_drop: ChaosPoint,
    chaos_drain_delay: ChaosPoint,
    chaos_kill: ChaosPoint,
    chaos_flap: ChaosPoint,
    /// Rotating victim cursor for [`ShardSet::chaos_disrupt`].
    chaos_cursor: usize,
    config: ShardSetConfig,
}

impl ShardSet {
    /// Seed for slot `shard` at pool generation `generation`.
    fn slot_seed(config: &ShardSetConfig, shard: usize, generation: u64) -> u64 {
        config
            .coordinator
            .seed
            .wrapping_add((shard as u64).wrapping_mul(config.seed_stride))
            .wrapping_add(generation.wrapping_mul(RESPAWN_SEED_STRIDE))
    }

    fn spawn_coordinator(config: &ShardSetConfig, shard: usize, generation: u64) -> Coordinator {
        let mut cc = config.coordinator.clone();
        cc.seed = Self::slot_seed(config, shard, generation);
        if let Some(kinds) = &config.kinds {
            cc.kind = kinds[shard].clone();
        }
        Coordinator::new(cc)
    }

    pub fn new(config: ShardSetConfig) -> Result<ShardSet> {
        if config.shards == 0 {
            bail!("shard set needs at least one shard");
        }
        let bits = config.coordinator.bits;
        if !(1..=16).contains(&bits) {
            // Mirror the pool's submission-boundary check: without it a
            // bad `bits` only dies when the router's first submit fails,
            // which reads as "every shard is poisoned".
            bail!(
                "shard set is configured with bits = {bits}; the sign-magnitude \
                 quantizer supports 1..=16 magnitude bitplanes"
            );
        }
        if let Some(kinds) = &config.kinds {
            if kinds.len() != config.shards {
                bail!(
                    "per-shard kinds length {} does not match shards {}",
                    kinds.len(),
                    config.shards
                );
            }
        }
        let mut slots = Vec::with_capacity(config.shards);
        let mut handle_slots = Vec::with_capacity(config.shards);
        for s in 0..config.shards {
            let coord = Self::spawn_coordinator(&config, s, 0);
            handle_slots.push(vec![coord.metrics_handle()]);
            slots.push(Some(coord));
        }
        let retired = Metrics::new(config.coordinator.bits);
        let healthy_gauge = Arc::new(AtomicUsize::new(config.shards));
        let slot_health =
            Arc::new((0..config.shards).map(|_| AtomicBool::new(true)).collect::<Vec<_>>());
        let chaos = &config.coordinator.chaos;
        Ok(ShardSet {
            slots,
            handles: Arc::new(Mutex::new(handle_slots)),
            generations: vec![0; config.shards],
            retired,
            healthy_gauge,
            respawns: Arc::new(AtomicU64::new(0)),
            slot_health,
            trace_scope: Vec::new(),
            monitor: MonitorHandle::inactive(),
            breakers: Arc::new(BreakerSet::new(config.shards, config.coordinator.seed)),
            chaos_drain_drop: chaos.point("router.drain.drop"),
            chaos_drain_delay: chaos.point("router.drain.delay"),
            chaos_kill: chaos.point("shard.kill"),
            chaos_flap: chaos.point("shard.flap"),
            chaos_cursor: 0,
            config,
        })
    }

    pub fn config(&self) -> &ShardSetConfig {
        &self.config
    }

    /// Total slots, poisoned included.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Tile width every shard runs (shards share the base geometry).
    pub fn tile_n(&self) -> usize {
        self.config.coordinator.tile_n
    }

    pub fn bits(&self) -> u32 {
        self.config.coordinator.bits
    }

    /// Worker threads per shard (the router splits a shard's blocks this
    /// many ways for intra-shard parallelism).
    pub fn workers_per_shard(&self) -> usize {
        self.config.coordinator.workers
    }

    pub fn is_healthy(&self, shard: usize) -> bool {
        self.slots.get(shard).is_some_and(Option::is_some)
    }

    /// Slot indices of the currently healthy shards, ascending.
    pub fn healthy(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&s| self.is_healthy(s)).collect()
    }

    /// Slot indices of the currently poisoned shards, ascending
    /// (respawn candidates).
    pub fn poisoned(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&s| !self.is_healthy(s)).collect()
    }

    pub fn healthy_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Shared healthy-count gauge for metrics exporters.
    pub fn health_handle(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.healthy_gauge)
    }

    /// Shared lifetime-respawns counter for metrics exporters.
    pub fn respawns_handle(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.respawns)
    }

    /// Shared per-slot health flags for the `/readyz` readiness probe.
    pub fn slot_health_handle(&self) -> Arc<Vec<AtomicBool>> {
        Arc::clone(&self.slot_health)
    }

    /// Attach trace handles for the batch about to be routed (one per
    /// planned request, in request order).  Pair with
    /// [`ShardSet::clear_trace_scope`] after the dispatch returns.
    pub fn set_trace_scope(&mut self, scope: Vec<TraceHandle>) {
        self.trace_scope = scope;
    }

    pub fn clear_trace_scope(&mut self) {
        self.trace_scope.clear();
    }

    /// The trace handles attached to the in-flight batch (empty when
    /// untraced).
    pub fn trace_scope(&self) -> &[TraceHandle] {
        &self.trace_scope
    }

    /// Attach a fidelity-monitor capture handle (set once by the
    /// serving front-end; persists for the set's lifetime, unlike the
    /// per-batch trace scope).
    pub fn set_monitor(&mut self, monitor: MonitorHandle) {
        self.monitor = monitor;
    }

    /// The fidelity-monitor capture handle (inactive by default).
    pub fn monitor(&self) -> &MonitorHandle {
        &self.monitor
    }

    /// Which slots run a non-digital backend — the slots worth shadow
    /// verifying (a digital slot is bit-identical to the golden path by
    /// construction).
    pub fn non_digital_slots(&self) -> Vec<bool> {
        (0..self.config.shards)
            .map(|s| {
                let kind = match &self.config.kinds {
                    Some(kinds) => &kinds[s],
                    None => &self.config.coordinator.kind,
                };
                !matches!(kind, TileKind::Digital)
            })
            .collect()
    }

    /// Mutable access to one shard's coordinator (`None` if poisoned or
    /// out of range).
    pub fn coordinator_mut(&mut self, shard: usize) -> Option<&mut Coordinator> {
        self.slots.get_mut(shard).and_then(Option::as_mut)
    }

    /// Retire a shard: take it out of the healthy set, shut its pool
    /// down (joining whatever workers are still alive) and fold its
    /// worker metrics into the retired accumulator.  Idempotent.
    pub fn poison(&mut self, shard: usize) {
        if let Some(coord) = self.slots.get_mut(shard).and_then(Option::take) {
            self.retired.merge(&coord.shutdown());
            self.healthy_gauge.fetch_sub(1, Ordering::AcqRel);
            self.slot_health[shard].store(false, Ordering::Release);
            // A dead pool is the definition of a tripped breaker: force
            // it open so routing (and `/readyz`) reflect the loss even
            // before the health tick notices.
            self.breakers.force_open(shard, Instant::now());
        }
    }

    /// Heal a poisoned slot in place: spin up a fresh pool under a new
    /// seed (next generation of this slot) and fold it back into the
    /// healthy set.  The dead generation's metrics keep being reported;
    /// the fresh pool's handle is appended to the slot so labeled series
    /// carry across the replacement.
    ///
    /// Errors if the slot is out of range or still healthy — respawning
    /// a live pool would silently drop its in-flight work.
    pub fn respawn(&mut self, shard: usize) -> Result<()> {
        if shard >= self.slots.len() {
            bail!("shard {shard} out of range (set has {})", self.slots.len());
        }
        if self.slots[shard].is_some() {
            bail!("shard {shard} is still healthy; poison it before respawning");
        }
        self.generations[shard] += 1;
        let coord = Self::spawn_coordinator(&self.config, shard, self.generations[shard]);
        self.handles
            .lock()
            .expect("shard metrics poisoned")
            .get_mut(shard)
            .expect("slot index checked above")
            .push(coord.metrics_handle());
        self.slots[shard] = Some(coord);
        self.healthy_gauge.fetch_add(1, Ordering::AcqRel);
        self.respawns.fetch_add(1, Ordering::AcqRel);
        self.slot_health[shard].store(true, Ordering::Release);
        // The fresh pool starts on probation, not at full traffic: the
        // breaker goes half-open and closes only after clean probes.
        self.breakers.on_respawn(shard);
        Ok(())
    }

    /// Respawn every poisoned slot (serve-loop health tick).  Returns
    /// how many shards were brought back.
    pub fn respawn_poisoned(&mut self) -> usize {
        let dead = self.poisoned();
        let mut brought_back = 0;
        for s in dead {
            if self.respawn(s).is_ok() {
                brought_back += 1;
            }
        }
        brought_back
    }

    /// Backoff-aware heal pass: respawn the poisoned slots whose
    /// per-slot respawn backoff has elapsed.  The first respawn of a
    /// slot is free; each one after that (without intervening served
    /// traffic) doubles the wait, so a permanently sick shard converges
    /// to open-breaker shedding instead of a respawn storm.  Returns
    /// how many shards were brought back.
    pub fn respawn_backed_off(&mut self, now: Instant) -> usize {
        let mut brought_back = 0;
        for s in self.poisoned() {
            if !self.breakers.respawn_allowed(s, now) {
                continue;
            }
            if self.respawn(s).is_ok() {
                self.breakers.note_respawn(s, now);
                brought_back += 1;
            }
        }
        brought_back
    }

    /// Per-slot circuit breakers (shared with the router and the
    /// serving front-end's exporter).
    pub fn breakers(&self) -> &Arc<BreakerSet> {
        &self.breakers
    }

    /// The `router.drain.drop` injection point (lost completions).
    pub fn chaos_drain_drop(&self) -> &ChaosPoint {
        &self.chaos_drain_drop
    }

    /// The `router.drain.delay` injection point (slow drains).
    pub fn chaos_drain_delay(&self) -> &ChaosPoint {
        &self.chaos_drain_delay
    }

    /// Fire the `shard.kill` / `shard.flap` injection points (called by
    /// the serving health tick, before healing).  A kill aborts and
    /// poisons a rotating healthy victim — recovery then flows through
    /// the normal breaker + respawn-backoff machinery.  A flap kills
    /// and *immediately* respawns, bypassing the heal tick, so the
    /// breaker sees a bouncing pool.  The last healthy shard is never
    /// targeted (chaos degrades the set; emptying it would just turn
    /// every request into an error).  Returns the slots disturbed.
    pub fn chaos_disrupt(&mut self) -> usize {
        let mut hits = 0;
        if self.chaos_kill.fire() {
            if let Some(victim) = self.next_chaos_victim() {
                if let Some(c) = self.coordinator_mut(victim) {
                    c.abort();
                }
                self.poison(victim);
                hits += 1;
            }
        }
        if self.chaos_flap.fire() {
            if let Some(victim) = self.next_chaos_victim() {
                if let Some(c) = self.coordinator_mut(victim) {
                    c.abort();
                }
                self.poison(victim);
                let _ = self.respawn(victim);
                hits += 1;
            }
        }
        hits
    }

    /// Rotating healthy victim for [`ShardSet::chaos_disrupt`]; `None`
    /// when only one healthy shard remains.
    fn next_chaos_victim(&mut self) -> Option<usize> {
        let healthy = self.healthy();
        if healthy.len() <= 1 {
            return None;
        }
        let victim = healthy[self.chaos_cursor % healthy.len()];
        self.chaos_cursor = self.chaos_cursor.wrapping_add(1);
        Some(victim)
    }

    /// Aggregator over every slot's live metrics handles (poisoned
    /// shards keep reporting what they served before dying; respawned
    /// generations accumulate onto their slot).
    pub fn aggregator(&self) -> MetricsAggregator {
        MetricsAggregator::shared(Arc::clone(&self.handles), self.config.coordinator.bits)
    }

    /// Merged snapshot of drained work across all shards.
    pub fn metrics(&self) -> Metrics {
        self.aggregator().merged()
    }

    /// Shut every surviving pool down and return the merged per-worker
    /// metrics, poisoned shards included.
    pub fn shutdown(self) -> Metrics {
        let mut total = self.retired;
        for slot in self.slots.into_iter().flatten() {
            total.merge(&slot.shutdown());
        }
        self.healthy_gauge.store(0, Ordering::Release);
        for flag in self.slot_health.iter() {
            flag.store(false, Ordering::Release);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TransformRequest;

    #[test]
    fn rejects_out_of_range_bits_up_front() {
        for bits in [0u32, 64] {
            let err = ShardSet::new(ShardSetConfig {
                coordinator: crate::coordinator::CoordinatorConfig {
                    bits,
                    ..Default::default()
                },
                ..Default::default()
            })
            .unwrap_err();
            assert!(err.to_string().contains("1..=16"), "bits={bits}: {err}");
        }
    }

    #[test]
    fn spins_up_and_shuts_down_n_shards() {
        let set = ShardSet::new(ShardSetConfig {
            shards: 3,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(set.len(), 3);
        assert_eq!(set.healthy(), vec![0, 1, 2]);
        assert_eq!(set.healthy_count(), 3);
        assert!(set.poisoned().is_empty());
        let m = set.shutdown();
        assert_eq!(m.requests, 0);
    }

    #[test]
    fn rejects_zero_shards_and_mismatched_kinds() {
        assert!(ShardSet::new(ShardSetConfig {
            shards: 0,
            ..Default::default()
        })
        .is_err());
        assert!(ShardSet::new(ShardSetConfig {
            shards: 2,
            kinds: Some(vec![TileKind::Digital]),
            ..Default::default()
        })
        .is_err());
    }

    #[test]
    fn poison_removes_a_shard_and_keeps_its_metrics() {
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.17).cos()).collect();
        let req = TransformRequest {
            x,
            thresholds_units: vec![0.0; 16],
            scale: None,
            deadline: None,
        };
        let id = set.coordinator_mut(0).unwrap().submit(&req).unwrap();
        let done = set.coordinator_mut(0).unwrap().drain_one().unwrap();
        assert_eq!(done.request_id, id);

        let gauge = set.health_handle();
        set.poison(0);
        set.poison(0); // idempotent
        assert_eq!(set.healthy(), vec![1]);
        assert_eq!(set.poisoned(), vec![0]);
        assert_eq!(gauge.load(Ordering::Acquire), 1);
        assert!(set.coordinator_mut(0).is_none());
        // The poisoned shard's served work survives in both views.
        assert_eq!(set.metrics().requests, 1);
        let m = set.shutdown();
        assert_eq!(m.requests, 1);
        assert_eq!(gauge.load(Ordering::Acquire), 0);
    }

    #[test]
    fn respawn_heals_a_poisoned_slot_and_keeps_old_metrics() {
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        let agg = set.aggregator();
        let mk_req = || TransformRequest {
            x: (0..16).map(|i| (i as f32 * 0.23).sin()).collect(),
            thresholds_units: vec![0.0; 16],
            scale: None,
            deadline: None,
        };
        // Serve one request on shard 0, then kill and respawn it.
        set.coordinator_mut(0).unwrap().submit(&mk_req()).unwrap();
        set.coordinator_mut(0).unwrap().drain_one().unwrap();
        set.coordinator_mut(0).unwrap().abort();
        set.poison(0);
        assert_eq!(set.healthy(), vec![1]);

        assert!(set.respawn(5).is_err(), "out of range");
        assert!(set.respawn(1).is_err(), "still healthy");
        set.respawn(0).unwrap();
        assert_eq!(set.healthy(), vec![0, 1]);
        assert_eq!(set.health_handle().load(Ordering::Acquire), 2);
        assert_eq!(set.respawns_handle().load(Ordering::Acquire), 1);

        // The fresh pool serves; the dead generation's request is still
        // reported through aggregators created before the respawn.
        set.coordinator_mut(0).unwrap().submit(&mk_req()).unwrap();
        set.coordinator_mut(0).unwrap().drain_one().unwrap();
        assert_eq!(agg.per_shard()[0].requests, 2);
        assert_eq!(set.metrics().requests, 2);
        set.shutdown();
    }

    #[test]
    fn respawn_poisoned_sweeps_every_dead_slot() {
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 3,
            ..Default::default()
        })
        .unwrap();
        set.coordinator_mut(0).unwrap().abort();
        set.coordinator_mut(2).unwrap().abort();
        set.poison(0);
        set.poison(2);
        assert_eq!(set.healthy(), vec![1]);
        assert_eq!(set.respawn_poisoned(), 2);
        assert_eq!(set.healthy(), vec![0, 1, 2]);
        assert_eq!(set.respawn_poisoned(), 0, "nothing left to heal");
        set.shutdown();
    }

    #[test]
    fn slot_health_flags_track_poison_and_respawn() {
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        let flags = set.slot_health_handle();
        assert!(flags.iter().all(|f| f.load(Ordering::Acquire)));
        set.coordinator_mut(1).unwrap().abort();
        set.poison(1);
        assert!(flags[0].load(Ordering::Acquire));
        assert!(!flags[1].load(Ordering::Acquire), "poisoned slot reads unhealthy");
        set.respawn(1).unwrap();
        assert!(flags[1].load(Ordering::Acquire), "respawn heals the flag");
        set.shutdown();
        assert!(
            flags.iter().all(|f| !f.load(Ordering::Acquire)),
            "shutdown marks every slot unhealthy"
        );
    }

    #[test]
    fn trace_scope_is_settable_and_clearable() {
        let mut set = ShardSet::new(ShardSetConfig::default()).unwrap();
        assert!(set.trace_scope().is_empty());
        set.set_trace_scope(vec![crate::trace::TraceHandle::inactive(); 3]);
        assert_eq!(set.trace_scope().len(), 3);
        assert!(!set.trace_scope()[0].is_active());
        set.clear_trace_scope();
        assert!(set.trace_scope().is_empty());
        set.shutdown();
    }

    #[test]
    fn non_digital_slots_follow_per_shard_kinds() {
        let set = ShardSet::new(ShardSetConfig {
            shards: 3,
            kinds: Some(vec![
                TileKind::Digital,
                TileKind::Noisy { sigma_ant: 2e-3 },
                TileKind::Digital,
            ]),
            ..Default::default()
        })
        .unwrap();
        assert_eq!(set.non_digital_slots(), vec![false, true, false]);
        assert!(!set.monitor().is_active(), "monitor defaults to inactive");
        set.shutdown();

        let noisy = ShardSet::new(ShardSetConfig {
            shards: 2,
            coordinator: CoordinatorConfig {
                kind: TileKind::Noisy { sigma_ant: 2e-3 },
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        assert_eq!(noisy.non_digital_slots(), vec![true, true]);
        noisy.shutdown();
    }

    #[test]
    fn poison_trips_the_breaker_and_respawn_probates() {
        use crate::shard::breaker::BreakerState;
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(set.breakers().state(0), BreakerState::Closed);
        set.coordinator_mut(0).unwrap().abort();
        set.poison(0);
        assert_eq!(set.breakers().state(0), BreakerState::Open, "poison forces open");
        set.respawn(0).unwrap();
        assert_eq!(
            set.breakers().state(0),
            BreakerState::HalfOpen,
            "a respawned slot starts on probation"
        );
        set.shutdown();
    }

    #[test]
    fn permanently_sick_slot_backs_off_exponentially_and_sheds() {
        use crate::shard::breaker::{BreakerState, RESPAWN_BACKOFF_BASE};
        use std::time::Duration;
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        let mut now = Instant::now();
        // A slot that dies after every heal: the recorded backoff must
        // double each round (250ms, 500ms, 1s, 2s), converging toward
        // open-breaker shedding instead of a respawn storm.
        for round in 0..4u32 {
            set.coordinator_mut(0).unwrap().abort();
            set.poison(0);
            now += Duration::from_secs(30); // past any earlier backoff
            assert_eq!(set.respawn_backed_off(now), 1, "round {round} heals");
            assert_eq!(
                set.breakers().snapshot()[0].respawn_backoff,
                RESPAWN_BACKOFF_BASE * (1u32 << round),
                "round {round} backoff"
            );
        }
        // Mid-backoff the slot sheds: the heal pass declines, the slot
        // stays poisoned, its breaker stays open.
        set.coordinator_mut(0).unwrap().abort();
        set.poison(0);
        assert_eq!(set.respawn_backed_off(now), 0, "backoff not elapsed");
        assert_eq!(set.healthy(), vec![1]);
        assert_eq!(set.breakers().state(0), BreakerState::Open);
        // Once the window passes, the heal goes through again.
        now += RESPAWN_BACKOFF_BASE * 16;
        assert_eq!(set.respawn_backed_off(now), 1);
        assert_eq!(set.healthy(), vec![0, 1]);
        set.shutdown();
    }

    #[cfg(feature = "chaos")]
    #[test]
    fn chaos_kill_rotates_victims_but_spares_the_last_shard() {
        use crate::chaos::ChaosPlan;
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 3,
            coordinator: CoordinatorConfig {
                chaos: ChaosPlan::parse("shard.kill=1.0,9").unwrap(),
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        assert_eq!(set.chaos_disrupt(), 1);
        assert_eq!(set.healthy_count(), 2);
        assert_eq!(set.chaos_disrupt(), 1);
        assert_eq!(set.healthy_count(), 1);
        assert_eq!(set.chaos_disrupt(), 0, "never kills the last shard");
        assert_eq!(set.healthy_count(), 1);
        set.shutdown();
    }

    #[cfg(feature = "chaos")]
    #[test]
    fn chaos_flap_bounces_a_slot_through_the_breaker() {
        use crate::chaos::ChaosPlan;
        use crate::shard::breaker::BreakerState;
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            coordinator: CoordinatorConfig {
                chaos: ChaosPlan::parse("shard.flap=1.0,4").unwrap(),
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        assert_eq!(set.chaos_disrupt(), 1);
        assert_eq!(set.healthy_count(), 2, "a flap comes straight back");
        assert_eq!(set.respawns_handle().load(Ordering::Acquire), 1);
        let flapped = set
            .breakers()
            .snapshot()
            .iter()
            .filter(|b| b.state == BreakerState::HalfOpen)
            .count();
        assert_eq!(flapped, 1, "the flapped slot sits on probation");
        set.shutdown();
    }

    #[test]
    fn respawned_generation_gets_a_fresh_seed() {
        let config = ShardSetConfig::default();
        let g0 = ShardSet::slot_seed(&config, 0, 0);
        let g1 = ShardSet::slot_seed(&config, 0, 1);
        let other_shard = ShardSet::slot_seed(&config, 1, 0);
        assert_ne!(g0, g1, "generation must move the seed");
        assert_ne!(
            g1, other_shard,
            "generation stride must not collide with shard stride"
        );
    }
}

//! L3.5 sharding subsystem: scatter–gather execution of wide transforms
//! across multiple crossbar coordinator pools.
//!
//! The paper stitches 16×16 crossbar cells column- and row-wise for
//! "perfect parallelism" (§IV); a single [`crate::coordinator::Coordinator`]
//! reproduces one such tile chain, but walks every block of a wide
//! request on one worker.  This module turns N independent pools into
//! one logical accelerator:
//!
//! ```text
//!   batch of requests (width W, same partition)
//!        │ planner: split padded block list, balance estimated
//!        ▼          row-cycles summed over the batch (LPT)
//!   ┌─────────┬─────────┬─────────┐
//!   │ shard 0 │ shard 1 │ shard 2 │   each its own Coordinator pool
//!   │  fused  │  fused  │  fused  │   (tiles, workers, RNG stream);
//!   │  jobs   │  jobs   │  jobs   │   N samples per submitted job
//!   └────┬────┴────┬────┴────┬────┘
//!        ▼ router: drain_batch per shard, scatter samples back
//!   reassembled outputs (bit-identical to a single pool, digital)
//! ```
//!
//! * [`planner`] — per-block row-cycle estimation + deterministic LPT
//!   placement balancing load across healthy shards (block widths may be
//!   heterogeneous: planned requests carry mixed BWHT partitions);
//! * [`router`] — the scatter–gather executor over the coordinator's
//!   batched `try_submit_batch_planned`/`drain_batch` API: same-partition
//!   requests fuse into multi-sample jobs per shard lane, failover stays
//!   per-slice under poisoned-shard load shedding; sub-tile blocks
//!   execute under [`crate::coordinator::plan::TilePlan`] masking;
//! * [`set`] — shard lifecycle: per-shard seed/backend config, health
//!   tracking, retirement of dead pools;
//! * [`breaker`] — per-shard circuit breakers (closed/open/half-open,
//!   failure-rate + drift EWMAs, exponential open windows) and the
//!   heal pass's per-slot respawn backoff;
//! * [`metrics_agg`] — merged + per-shard [`crate::coordinator::Metrics`]
//!   snapshots for the serving `/metrics` exporter.

pub mod breaker;
pub mod metrics_agg;
pub mod planner;
pub mod router;
pub mod set;

pub use breaker::{BreakerSet, BreakerSnapshot, BreakerState};
pub use metrics_agg::MetricsAggregator;
pub use planner::{estimate_block_cost, plan_blocks, BlockPlan, ShardAssignment};
pub use set::{ShardSet, ShardSetConfig, RESPAWN_SEED_STRIDE, SHARD_SEED_STRIDE};

//! Placement planning: partition a request's block list across shards so
//! each shard carries a similar estimated row-cycle load.
//!
//! The coordinator walks a request as the blocks of its partition —
//! uniform `tile_n`-wide slices for raw requests, or a mixed partition
//! such as `[128, 64, 16, 4]` for planned NN transforms — each block
//! quantized and scheduled independently (so any placement of whole
//! blocks reproduces the single-pool output bit-for-bit on the digital
//! backend).  The planner's job is purely load balance: estimate the
//! row-cycles each block will execute — both block width and early
//! termination make blocks heterogeneous — and spread them with a
//! deterministic longest-processing-time greedy.
//!
//! The router plans fusion-aware: a batch's same-partition requests form
//! one *group* whose per-block costs are summed across members before
//! the LPT pass (one placement serves every member, so same-shard slices
//! can fuse into multi-sample jobs), and shard loads carry over between
//! groups of a mixed batch so later groups balance around earlier
//! placements.

/// Blocks placed on one shard (slot index into the
/// [`crate::shard::ShardSet`]).  `blocks` holds ascending block indices
/// of the padded request; the router concatenates them in this order and
/// scatters the shard's output back by the same indices.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardAssignment {
    pub shard: usize,
    pub blocks: Vec<usize>,
}

/// One request's placement across the healthy shards.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockPlan {
    /// Only shards that received at least one block appear.
    pub assignments: Vec<ShardAssignment>,
}

impl BlockPlan {
    /// Total blocks placed (equals the planned request's block count).
    pub fn total_blocks(&self) -> usize {
        self.assignments.iter().map(|a| a.blocks.len()).sum()
    }
}

/// Estimated row-cycles one block will execute (any block width: a
/// sub-tile block bills only its logical rows, which is exactly
/// `x.len()` here).
///
/// Mirrors the scheduler's cost structure without running it:
///
/// * an exactly-zero block retires after a single plane (the digital
///   zero-input fast path) — one row-cycle per row;
/// * a row with early-termination threshold `T` skips roughly the
///   trailing planes whose remaining contribution fits under `T`
///   (`~log2(1 + T)` of them), floored at one executed plane.
///
/// This is a heuristic for balance, not an exact count: over- or
/// under-estimation only skews placement, never correctness.
pub fn estimate_block_cost(x: &[f32], thresholds_units: &[f64], bits: u32) -> u64 {
    debug_assert_eq!(x.len(), thresholds_units.len());
    if x.iter().all(|&v| v == 0.0) {
        return x.len() as u64;
    }
    let bits = u64::from(bits.max(1));
    let mut cost = 0u64;
    for &t in thresholds_units {
        let skip = if t <= 0.0 {
            0
        } else {
            ((t + 1.0).log2().floor() as u64).min(bits - 1)
        };
        cost += bits - skip;
    }
    cost
}

/// Partition blocks `0..costs.len()` across `shard_ids`, balancing
/// cumulative cost.
///
/// `loads` carries the running per-shard load (aligned with
/// `shard_ids`); it is updated in place so a batch of requests planned
/// one after another balances globally, not just per request.
///
/// Deterministic: blocks are placed heaviest-first onto the least-loaded
/// shard, ties broken by lowest block index / lowest shard position.
///
/// # Panics
/// If `shard_ids` is empty or `loads.len() != shard_ids.len()`.
pub fn plan_blocks(costs: &[u64], shard_ids: &[usize], loads: &mut [u64]) -> BlockPlan {
    assert!(!shard_ids.is_empty(), "cannot plan onto zero shards");
    assert_eq!(shard_ids.len(), loads.len(), "loads must align with shard_ids");
    let mut order: Vec<usize> = (0..costs.len()).collect();
    order.sort_by_key(|&b| (std::cmp::Reverse(costs[b]), b));
    let mut placed: Vec<Vec<usize>> = vec![Vec::new(); shard_ids.len()];
    for &b in &order {
        let k = (0..loads.len())
            .min_by_key(|&k| (loads[k], k))
            .expect("at least one shard");
        loads[k] += costs[b];
        placed[k].push(b);
    }
    let assignments = placed
        .into_iter()
        .enumerate()
        .filter(|(_, blocks)| !blocks.is_empty())
        .map(|(k, mut blocks)| {
            blocks.sort_unstable();
            ShardAssignment {
                shard: shard_ids[k],
                blocks,
            }
        })
        .collect();
    BlockPlan { assignments }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_block_exactly_once() {
        let costs = vec![8, 1, 5, 5, 3, 7, 2, 4];
        let mut loads = vec![0u64; 3];
        let plan = plan_blocks(&costs, &[0, 1, 2], &mut loads);
        let mut seen: Vec<usize> = plan
            .assignments
            .iter()
            .flat_map(|a| a.blocks.iter().copied())
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..8).collect::<Vec<_>>());
        assert_eq!(plan.total_blocks(), 8);
        assert_eq!(loads.iter().sum::<u64>(), 35);
    }

    #[test]
    fn balances_uniform_costs_evenly() {
        let costs = vec![10u64; 8];
        let mut loads = vec![0u64; 4];
        plan_blocks(&costs, &[0, 1, 2, 3], &mut loads);
        assert_eq!(loads, vec![20, 20, 20, 20]);
    }

    #[test]
    fn heaviest_block_lands_alone_when_it_dominates() {
        // One block as heavy as all others combined: LPT gives it its own
        // shard and spreads the rest over the other.
        let costs = vec![12, 3, 3, 3, 3];
        let mut loads = vec![0u64; 2];
        let plan = plan_blocks(&costs, &[5, 9], &mut loads);
        let heavy = plan
            .assignments
            .iter()
            .find(|a| a.blocks.contains(&0))
            .unwrap();
        assert_eq!(heavy.blocks, vec![0]);
        assert_eq!(loads, vec![12, 12]);
    }

    #[test]
    fn deterministic_and_blocks_ascending() {
        let costs = vec![4, 4, 4, 4, 4, 4, 4];
        let mut l1 = vec![0u64; 3];
        let mut l2 = vec![0u64; 3];
        let p1 = plan_blocks(&costs, &[0, 1, 2], &mut l1);
        let p2 = plan_blocks(&costs, &[0, 1, 2], &mut l2);
        assert_eq!(p1, p2);
        for a in &p1.assignments {
            assert!(a.blocks.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn respects_carried_over_loads() {
        // Shard 0 starts heavily loaded, so a one-block plan avoids it.
        let mut loads = vec![100u64, 0];
        let plan = plan_blocks(&[5], &[0, 1], &mut loads);
        assert_eq!(plan.assignments, vec![ShardAssignment { shard: 1, blocks: vec![0] }]);
    }

    #[test]
    fn single_shard_takes_everything() {
        let mut loads = vec![0u64];
        let plan = plan_blocks(&[1, 2, 3], &[7], &mut loads);
        assert_eq!(plan.assignments.len(), 1);
        assert_eq!(plan.assignments[0].shard, 7);
        assert_eq!(plan.assignments[0].blocks, vec![0, 1, 2]);
    }

    #[test]
    fn cost_estimates_track_the_scheduler_shape() {
        let zeros = vec![0.0f32; 16];
        let live: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
        let t0 = vec![0.0f64; 16];
        let t_huge = vec![1e9f64; 16];
        // Zero block: one row-cycle per row.
        assert_eq!(estimate_block_cost(&zeros, &t0, 8), 16);
        // Full-precision block: bits cycles per row.
        assert_eq!(estimate_block_cost(&live, &t0, 8), 16 * 8);
        // Saturating thresholds: floored at one cycle per row.
        assert_eq!(estimate_block_cost(&live, &t_huge, 8), 16);
    }

    #[test]
    fn cost_estimates_scale_with_block_width() {
        // Mixed partitions: a 4-wide block costs a quarter of a 16-wide
        // one under the same regime, so LPT balances row-cycles, not
        // block counts.
        let wide: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin() + 0.1).collect();
        let narrow = &wide[..4];
        let t0_wide = vec![0.0f64; 16];
        let t0_narrow = vec![0.0f64; 4];
        assert_eq!(estimate_block_cost(&wide, &t0_wide, 8), 16 * 8);
        assert_eq!(estimate_block_cost(narrow, &t0_narrow, 8), 4 * 8);
    }
}

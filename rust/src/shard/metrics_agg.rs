//! Merging per-shard [`Metrics`] into one logical-accelerator snapshot.
//!
//! Every shard's coordinator already aggregates its own workers into a
//! shared `Arc<Mutex<Metrics>>`; this module folds those N handles into
//! a single [`Metrics`] (row-cycles, planes, ET savings and latency
//! histograms all merge additively) for the Prometheus exporter, while
//! keeping the per-shard views available for labeled series.

use std::sync::{Arc, Mutex};

use crate::coordinator::Metrics;

/// Cheap cloneable view over the shard set's metrics handles.
///
/// Handles outlive their coordinators, so snapshots keep working after
/// shards are poisoned or the set is shut down — the serving front-end
/// can hold an aggregator while the batcher thread owns the set itself.
#[derive(Clone)]
pub struct MetricsAggregator {
    handles: Vec<Arc<Mutex<Metrics>>>,
    bits: u32,
}

impl MetricsAggregator {
    pub fn new(handles: Vec<Arc<Mutex<Metrics>>>, bits: u32) -> MetricsAggregator {
        MetricsAggregator { handles, bits }
    }

    /// Number of shards aggregated (poisoned slots included).
    pub fn shards(&self) -> usize {
        self.handles.len()
    }

    /// Snapshot of each shard's metrics, by slot index.
    pub fn per_shard(&self) -> Vec<Metrics> {
        self.handles
            .iter()
            .map(|h| h.lock().expect("shard metrics poisoned").clone())
            .collect()
    }

    /// One merged snapshot across every shard.
    pub fn merged(&self) -> Metrics {
        let mut total = Metrics::new(self.bits);
        for h in &self.handles {
            total.merge(&h.lock().expect("shard metrics poisoned"));
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn with_requests(bits: u32, requests: u64, row_cycles: u64) -> Arc<Mutex<Metrics>> {
        let mut m = Metrics::new(bits);
        m.requests = requests;
        m.row_cycles = row_cycles;
        m.busy = Duration::from_micros(10 * requests);
        m.latency.record(Duration::from_micros(50));
        Arc::new(Mutex::new(m))
    }

    #[test]
    fn merged_is_the_sum_of_shards() {
        let agg = MetricsAggregator::new(
            vec![with_requests(8, 3, 100), with_requests(8, 5, 200)],
            8,
        );
        assert_eq!(agg.shards(), 2);
        let merged = agg.merged();
        assert_eq!(merged.requests, 8);
        assert_eq!(merged.row_cycles, 300);
        assert_eq!(merged.latency.count(), 2);
        assert_eq!(merged.busy, Duration::from_micros(80));
        let per = agg.per_shard();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].requests, 3);
        assert_eq!(per[1].requests, 5);
    }

    #[test]
    fn empty_aggregator_merges_to_zero() {
        let agg = MetricsAggregator::new(Vec::new(), 8);
        let merged = agg.merged();
        assert_eq!(merged.requests, 0);
        assert_eq!(merged.bits(), 8);
    }
}

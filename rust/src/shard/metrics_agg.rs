//! Merging per-shard [`Metrics`] into one logical-accelerator snapshot.
//!
//! Every shard's coordinator already aggregates its own workers into a
//! shared `Arc<Mutex<Metrics>>`; this module folds those handles into a
//! single [`Metrics`] (row-cycles, planes, ET savings and latency
//! histograms all merge additively) for the Prometheus exporter, while
//! keeping the per-shard views available for labeled series.
//!
//! A slot may accumulate *several* handles over its lifetime: when a
//! poisoned shard is respawned ([`crate::shard::ShardSet::respawn`]) the
//! fresh pool's handle is appended to the slot, so the labeled series
//! keep counting what the dead generation served.  The slot list itself
//! is shared (`Arc`) with the owning shard set, so aggregators handed to
//! a serving front-end observe respawns that happen after they were
//! created.

use std::sync::{Arc, Mutex};

use crate::coordinator::Metrics;

/// One coordinator pool's live metrics handle.
pub(crate) type Handle = Arc<Mutex<Metrics>>;
/// Shared per-slot handle lists (one inner `Vec` per shard slot; one
/// entry per pool generation of that slot).
pub(crate) type HandleSlots = Arc<Mutex<Vec<Vec<Handle>>>>;

/// Cheap cloneable view over the shard set's metrics handles.
///
/// Handles outlive their coordinators, so snapshots keep working after
/// shards are poisoned or the set is shut down — the serving front-end
/// can hold an aggregator while the batcher thread owns the set itself.
#[derive(Clone)]
pub struct MetricsAggregator {
    slots: HandleSlots,
    bits: u32,
}

impl MetricsAggregator {
    /// Aggregator over a flat list of handles, one slot each (the
    /// single-generation case; tests and ad-hoc callers).
    pub fn new(handles: Vec<Handle>, bits: u32) -> MetricsAggregator {
        let slots: Vec<Vec<Handle>> = handles.into_iter().map(|h| vec![h]).collect();
        MetricsAggregator {
            slots: Arc::new(Mutex::new(slots)),
            bits,
        }
    }

    /// Aggregator sharing a shard set's live slot list (respawns append
    /// new generations that this aggregator then reports).
    pub(crate) fn shared(slots: HandleSlots, bits: u32) -> MetricsAggregator {
        MetricsAggregator { slots, bits }
    }

    /// Number of shard slots aggregated (poisoned slots included).
    pub fn shards(&self) -> usize {
        self.slots.lock().expect("shard metrics poisoned").len()
    }

    /// Snapshot of each slot's metrics (all generations merged), by slot
    /// index.
    pub fn per_shard(&self) -> Vec<Metrics> {
        let slots = self.slots.lock().expect("shard metrics poisoned");
        slots
            .iter()
            .map(|gens| {
                let mut m = Metrics::new(self.bits);
                for h in gens {
                    m.merge(&h.lock().expect("shard metrics poisoned"));
                }
                m
            })
            .collect()
    }

    /// One merged snapshot across every slot and generation.
    pub fn merged(&self) -> Metrics {
        let mut total = Metrics::new(self.bits);
        for m in self.per_shard() {
            total.merge(&m);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn with_requests(bits: u32, requests: u64, row_cycles: u64) -> Arc<Mutex<Metrics>> {
        let mut m = Metrics::new(bits);
        m.requests = requests;
        m.row_cycles = row_cycles;
        m.busy = Duration::from_micros(10 * requests);
        m.latency.record(Duration::from_micros(50));
        Arc::new(Mutex::new(m))
    }

    #[test]
    fn merged_is_the_sum_of_shards() {
        let agg = MetricsAggregator::new(
            vec![with_requests(8, 3, 100), with_requests(8, 5, 200)],
            8,
        );
        assert_eq!(agg.shards(), 2);
        let merged = agg.merged();
        assert_eq!(merged.requests, 8);
        assert_eq!(merged.row_cycles, 300);
        assert_eq!(merged.latency.count(), 2);
        assert_eq!(merged.busy, Duration::from_micros(80));
        let per = agg.per_shard();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].requests, 3);
        assert_eq!(per[1].requests, 5);
    }

    #[test]
    fn empty_aggregator_merges_to_zero() {
        let agg = MetricsAggregator::new(Vec::new(), 8);
        let merged = agg.merged();
        assert_eq!(merged.requests, 0);
        assert_eq!(merged.bits(), 8);
    }

    #[test]
    fn respawned_generation_adds_to_its_slot() {
        let slots: HandleSlots = Arc::new(Mutex::new(vec![
            vec![with_requests(8, 2, 10)],
            vec![with_requests(8, 1, 5)],
        ]));
        let agg = MetricsAggregator::shared(Arc::clone(&slots), 8);
        assert_eq!(agg.per_shard()[0].requests, 2);
        // A respawn appends a fresh handle to slot 0; existing
        // aggregators see it immediately.
        slots.lock().unwrap()[0].push(with_requests(8, 7, 70));
        assert_eq!(agg.shards(), 2);
        assert_eq!(agg.per_shard()[0].requests, 9);
        assert_eq!(agg.merged().requests, 10);
        assert_eq!(agg.merged().row_cycles, 85);
    }
}

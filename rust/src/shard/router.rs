//! Scatter–gather execution of wide transforms across the shard set.
//!
//! A request carries a *block partition*: either the legacy uniform one
//! (padded to whole `tile_n` blocks — the raw `/v1/transform`
//! semantics) or an explicit, possibly mixed, partition such as
//! `[128, 64, 16, 4]` ([`transform_batch_planned`], the NN executor
//! path, where blocks narrower than the tile run under sub-tile
//! masking).
//!
//! Routing is *fusion-aware*: requests that share a partition (the same
//! plan `Arc` or an equal slot layout) are planned as one group — a
//! single LPT pass over the group's summed per-block costs puts block
//! `b` of every member on the same shard — and the group's work is cut
//! into multi-sample [`Slice`]s: a contiguous run of requests × a
//! contiguous run of blocks, submitted as ONE fused pool job through
//! the coordinator's `try_submit_batch_planned`/`drain_batch` API.  The
//! pool worker then runs its plane-major engine over N router samples
//! in one pass instead of being dispatched N times, so a batch of M
//! same-partition requests costs `~shards × workers` jobs instead of
//! `M × shards × lanes`.  Per-sample outputs are scattered back into
//! each request's output vector by block offset.
//!
//! Because every block is quantized and scheduled independently — and
//! the batch engine is bit-identical to per-sample jobs on the digital
//! backend, RNG-stream-identical on the noisy one — any placement *and
//! any fusion* reproduces the single-coordinator output bit-for-bit.
//! Placement and fusion are pure throughput decisions.
//!
//! Failure isolation stays per-slice: a shard whose pool errors on
//! submit or drain is poisoned and its in-flight fused jobs are
//! re-queued as their per-request constituent slices, re-routed to the
//! surviving shards.  Re-executed slices are harmless: a poisoned shard
//! is never drained again, so a duplicate result can never be observed.
//!
//! Rerouting is *budgeted*, not explode-and-pray: every requeue bumps
//! the slice's attempt count, a retried slice waits out a short
//! exponential backoff (deterministic jitter) before resubmitting, and
//! a slice that exhausts [`MAX_SLICE_ATTEMPTS`] fails the batch with a
//! clean error after the in-flight work drains.  Target selection
//! consults the per-shard circuit breakers ([`super::breaker`]): an
//! open breaker sheds the slice to a sibling, a half-open one admits
//! probe traffic, and only when every healthy shard is breaker-blocked
//! does the router serve degraded through the least-loaded one.

use std::borrow::Cow;
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{CompletedBatch, TilePlan, TransformRequest};
use crate::monitor::{MonitorHandle, ShadowSample};
use crate::trace::{self, ExecStats, Stage, TraceHandle};

use super::breaker::{self, BreakerSet};
use super::planner::{estimate_block_cost, plan_blocks};
use super::set::ShardSet;

/// A slice that has been re-queued this many times fails the whole
/// batch instead of bouncing between shards forever.  Derivation in
/// DESIGN.md: the only legitimate requeue causes are a shard death
/// (bounded by the shard count) and an injected drain drop, so three
/// strikes distinguishes "unlucky" from "systemically broken".
pub const MAX_SLICE_ATTEMPTS: u32 = 3;

/// Base/cap of the per-retry backoff.  The router runs on the batcher
/// thread, so the schedule stays in the sub-millisecond range: enough
/// to let a flapping pool settle, never enough to blow a deadline.
const RETRY_BACKOFF_BASE: Duration = Duration::from_micros(200);
const RETRY_BACKOFF_CAP: Duration = Duration::from_millis(5);

/// Exponential backoff with deterministic jitter (±25%) for a slice on
/// its `attempts`-th retry.  Jitter is keyed by the slice's first
/// request index so concurrent retried slices de-synchronise without
/// any wall-clock randomness.
fn retry_backoff(attempts: u32, key: u64) -> Duration {
    let base = breaker::backoff(RETRY_BACKOFF_BASE, RETRY_BACKOFF_CAP, attempts);
    let z = breaker::splitmix64(key ^ (u64::from(attempts) << 32));
    let jitter = ((z >> 11) as f64 / (1u64 << 53) as f64 - 0.5) * 0.5;
    base.mul_f64(1.0 + jitter)
}

/// One request resolved onto its block partition: the routing unit of
/// work is a *block*, identified by its index into the plan's slots.
/// The validated [`TilePlan`] already carries every block's offset and
/// width, so it is shared by reference — one `Arc` per batch, not a
/// re-derived partition clone per request.  Input and threshold data
/// are `Cow`s so the planned paths borrow straight from the caller's
/// requests (the executor seam submits thousands of rows per layer; a
/// copy per row was pure overhead) while the legacy uniform path can
/// still own its padded storage.
struct PlannedReq<'a> {
    x: Cow<'a, [f32]>,
    th: Cow<'a, [f64]>,
    scale: Option<f32>,
    /// End-to-end deadline inherited by every slice of the request, so
    /// the pool worker can cancel expired samples before scheduling.
    deadline: Option<Instant>,
    plan: Arc<TilePlan>,
}

impl PlannedReq<'_> {
    fn block_offset(&self, b: usize) -> usize {
        self.plan.slots()[b].offset
    }

    fn block_width(&self, b: usize) -> usize {
        self.plan.slots()[b].width
    }
}

/// One unit of scatter work: a contiguous run of same-partition batch
/// requests × a contiguous run of their shared blocks, bound for one
/// shard and submitted as a single fused multi-sample pool job.  The
/// failover path re-queues fused slices split back to one request each.
#[derive(Debug, Clone)]
struct Slice {
    /// Indices into the batch, ascending; every member shares the
    /// slice's block layout.
    reqs: Vec<usize>,
    /// Target shard slot (revised when the target is poisoned).
    shard: usize,
    /// Ascending block indices of the requests' shared partition.
    blocks: Vec<usize>,
    /// How many times this work has been re-queued; bounded by
    /// [`MAX_SLICE_ATTEMPTS`] and backed off exponentially.
    attempts: u32,
}

/// Concatenate `blocks` of the request into one sub-request plus the
/// matching sub-partition.  The parent's pinned quantization scale (if
/// any) is inherited by every slice, so a sliced request quantizes
/// exactly like the whole one.
fn sub_request(preq: &PlannedReq<'_>, blocks: &[usize]) -> (TransformRequest, Vec<usize>) {
    let total: usize = blocks.iter().map(|&b| preq.block_width(b)).sum();
    let mut sx = Vec::with_capacity(total);
    let mut sth = Vec::with_capacity(total);
    let mut widths = Vec::with_capacity(blocks.len());
    for &b in blocks {
        let lo = preq.block_offset(b);
        let hi = lo + preq.block_width(b);
        sx.extend_from_slice(&preq.x[lo..hi]);
        sth.extend_from_slice(&preq.th[lo..hi]);
        widths.push(preq.block_width(b));
    }
    (
        TransformRequest {
            x: sx,
            thresholds_units: sth,
            scale: preq.scale,
            deadline: preq.deadline,
        },
        widths,
    )
}

/// Scatter a slice's concatenated outputs back by block offset.
fn gather(out: &mut [f32], values: &[f32], preq: &PlannedReq<'_>, blocks: &[usize]) {
    let mut pos = 0usize;
    for &b in blocks {
        let lo = preq.block_offset(b);
        let w = preq.block_width(b);
        out[lo..lo + w].copy_from_slice(&values[pos..pos + w]);
        pos += w;
    }
    debug_assert_eq!(pos, values.len());
}

/// Split `items` into at most `parts` contiguous chunks of near-equal
/// length (at least one item each).  Used both for a shard's block list
/// (per-worker lanes) and for a group's request list (sample chunks).
fn split_lanes(items: &[usize], parts: usize) -> Vec<Vec<usize>> {
    let parts = parts.clamp(1, items.len().max(1));
    let base = items.len() / parts;
    let extra = items.len() % parts;
    let mut chunks = Vec::with_capacity(parts);
    let mut off = 0;
    for lane in 0..parts {
        let take = base + usize::from(lane < extra);
        if take == 0 {
            break;
        }
        chunks.push(items[off..off + take].to_vec());
        off += take;
    }
    chunks
}

/// True when request `ri` of the batch carries an active trace handle.
fn is_traced(scope: &[TraceHandle], ri: usize) -> bool {
    scope.get(ri).is_some_and(TraceHandle::is_active)
}

/// True when any member of a fused slice is traced (one clock read per
/// slice covers the whole fused job).
fn any_traced(scope: &[TraceHandle], reqs: &[usize]) -> bool {
    reqs.iter().any(|&ri| is_traced(scope, ri))
}

/// An in-flight fused job: what was submitted plus the submit timestamp
/// (µs on the trace epoch; 0 when the batch is untraced) that anchors
/// the pool-queue span at drain time.
type InFlight = (Slice, u64);

/// Pick a routing target among the healthy shards, least-loaded first,
/// honouring the circuit breakers: the first candidate whose breaker
/// admits traffic (closed, or half-open with probe budget) wins.  When
/// *every* healthy shard is breaker-blocked the router serves degraded
/// through the least-loaded one rather than failing the request — the
/// breakers shape load, the health map decides liveness.
fn reroute_target(
    set: &ShardSet,
    outstanding: &[HashMap<u64, InFlight>],
    breakers: &BreakerSet,
    now: Instant,
) -> Result<usize> {
    let mut order = set.healthy();
    order.sort_by_key(|&s| outstanding[s].len());
    for &s in &order {
        if breakers.allow(s, now) {
            return Ok(s);
        }
    }
    order
        .first()
        .copied()
        .ok_or_else(|| anyhow!("every shard is poisoned; request cannot be served"))
}

/// Retire a dead shard and push everything in flight on it back onto
/// the work queue (the re-queued slices keep their stale shard id; the
/// scatter loop re-routes them to a healthy target).
fn poison_and_requeue(
    set: &mut ShardSet,
    shard: usize,
    outstanding: &mut [HashMap<u64, InFlight>],
    queue: &mut VecDeque<Slice>,
) {
    set.poison(shard);
    for (_, (orphan, _)) in outstanding[shard].drain() {
        requeue_split(orphan, queue);
    }
}

/// Failover granularity is the *slice*, not the fused job: work lost to
/// a poisoned shard is re-queued as per-request slices so the survivors
/// can re-balance (and re-fail) each sample independently.  Every
/// requeue costs one attempt; the scatter loop enforces the budget and
/// the backoff.
fn requeue_split(slice: Slice, queue: &mut VecDeque<Slice>) {
    let attempts = slice.attempts + 1;
    if slice.reqs.len() <= 1 {
        queue.push_back(Slice { attempts, ..slice });
        return;
    }
    for &ri in &slice.reqs {
        queue.push_back(Slice {
            reqs: vec![ri],
            shard: slice.shard,
            blocks: slice.blocks.clone(),
            attempts,
        });
    }
}

/// Gather a drained fused job into its requests' outputs and, for every
/// traced member, reconstruct that slice's pool-queue / execute / drain
/// spans from the per-sample completion payloads: the job's execute
/// window ends at drain time and lasted the worker's reported busy
/// time; within it, sample windows are laid end to end, each sized by
/// its row-cycle share of the busy time (the pool's apportioning), so
/// per-slice spans tile the fused window without overlap.  Execute
/// spans carry each sample's own plane-count / row-cycle / ET-depth
/// payload.  The fidelity monitor keeps sampling individual slices: the
/// 1-in-K counter advances once per *sample*, not per job.
#[allow(clippy::too_many_arguments)]
fn finish_job(
    scope: &[TraceHandle],
    monitor: &MonitorHandle,
    outs: &mut [Vec<f32>],
    planned: &[PlannedReq<'_>],
    shard: usize,
    batch: CompletedBatch,
    in_flight: InFlight,
    drain_start_us: u64,
) {
    let (slice, submit_us) = in_flight;
    debug_assert_eq!(batch.samples.len(), slice.reqs.len());
    let job_traced = any_traced(scope, &slice.reqs);
    let (end_us, exec_start) = if job_traced {
        let end = trace::now_us();
        let busy = batch.busy.as_micros().min(u128::from(u64::MAX)) as u64;
        // Clamp the reconstructed execute window into [submit, drain-end].
        (end, end.saturating_sub(busy).max(submit_us))
    } else {
        (0, 0)
    };
    let mut cursor_us = exec_start;
    for (&ri, done) in slice.reqs.iter().zip(batch.samples) {
        // Fidelity capture: 1-in-K slices served by a monitored
        // (non-digital) shard are copied off to the shadow checker
        // before the gather.  An inactive monitor is one dead branch;
        // digital slots are filtered by the handle without touching the
        // sample counter.  Deadline-expired samples carry zeroed
        // placeholder values, not transform output — shadow-checking
        // them would report phantom drift.
        if !done.expired && monitor.wants_sample(shard) {
            let (sub, widths) = sub_request(&planned[ri], &slice.blocks);
            monitor.enqueue(ShadowSample {
                shard,
                request: sub,
                blocks: widths,
                observed: done.values.clone(),
            });
        }
        gather(&mut outs[ri], &done.values, &planned[ri], &slice.blocks);
        if !job_traced {
            continue;
        }
        let sample_busy = done.busy.as_micros().min(u128::from(u64::MAX)) as u64;
        let exec_end = (cursor_us + sample_busy).min(end_us).max(cursor_us);
        if is_traced(scope, ri) {
            let handle = &scope[ri];
            handle.record_shard(
                Stage::PoolQueue,
                submit_us,
                exec_start.saturating_sub(submit_us),
                shard,
            );
            handle.record_exec(
                cursor_us,
                exec_end - cursor_us,
                shard,
                ExecStats {
                    planes: done.planes_issued,
                    row_cycles: done.row_cycles,
                    elements: done.elements,
                    terminated_early: done.terminated_early,
                },
            );
            handle.record_shard(
                Stage::Drain,
                drain_start_us,
                end_us.saturating_sub(drain_start_us),
                shard,
            );
        }
        cursor_us = exec_end;
    }
}

/// Validate one request at the routing boundary (mirrors
/// `Coordinator::validate`).
fn validate_request(i: usize, req: &TransformRequest) -> Result<()> {
    if req.x.is_empty() {
        bail!("request {i} has an empty input vector");
    }
    if req.thresholds_units.len() != req.x.len() {
        bail!(
            "request {i}: thresholds_units length {} does not match input length {}",
            req.thresholds_units.len(),
            req.x.len()
        );
    }
    if let Some(s) = req.scale {
        if !(s.is_finite() && s > 0.0) {
            bail!("request {i}: pinned quantization scale must be positive and finite");
        }
    }
    Ok(())
}

/// Execute one transform request across the shard set.  Returns outputs
/// at padded width, bit-identical (digital backend) to a single
/// [`crate::coordinator::Coordinator`] serving the same request.
pub fn transform(set: &mut ShardSet, req: &TransformRequest) -> Result<Vec<f32>> {
    let mut outs = transform_batch(set, std::slice::from_ref(req))?;
    Ok(outs.pop().expect("one request, one output"))
}

/// Execute a batch of requests with the legacy uniform partition: each
/// request is padded to whole `tile_n` blocks and outputs come back at
/// padded width, in request order.
///
/// The router assumes exclusive use of the set's async API: every slice
/// it submits is drained before returning, and no caller-submitted
/// requests may be outstanding on any shard when it is invoked.
pub fn transform_batch(set: &mut ShardSet, reqs: &[TransformRequest]) -> Result<Vec<Vec<f32>>> {
    let tile_n = set.tile_n();
    // One uniform plan per distinct request width, shared across the
    // batch (serving batches are usually width-homogeneous).
    let mut plans: HashMap<usize, Arc<TilePlan>> = HashMap::new();
    let mut planned = Vec::with_capacity(reqs.len());
    for (i, req) in reqs.iter().enumerate() {
        validate_request(i, req)?;
        let plan = Arc::clone(
            plans
                .entry(req.x.len())
                .or_insert_with(|| Arc::new(TilePlan::uniform(tile_n, req.x.len()))),
        );
        // Already tile-aligned requests are borrowed as-is; only ragged
        // widths pay for padded owned storage.
        let (x, th) = if req.x.len() == plan.width() {
            (Cow::Borrowed(&req.x[..]), Cow::Borrowed(&req.thresholds_units[..]))
        } else {
            let mut x = req.x.clone();
            x.resize(plan.width(), 0.0);
            let mut th = req.thresholds_units.clone();
            th.resize(plan.width(), 0.0);
            (Cow::Owned(x), Cow::Owned(th))
        };
        planned.push(PlannedReq { x, th, scale: req.scale, deadline: req.deadline, plan });
    }
    run(set, planned)
}

/// Execute a batch of requests over an explicit block partition (shared
/// by the whole batch — the executor seam's contract).  Requests must be
/// exactly `blocks.iter().sum()` wide; outputs come back at that width,
/// unpadded.  Blocks narrower than the shard tile run under sub-tile
/// masking; blocks wider than the tile are a clean error.
pub fn transform_batch_planned(
    set: &mut ShardSet,
    blocks: &[usize],
    reqs: &[TransformRequest],
) -> Result<Vec<Vec<f32>>> {
    // Resolve the partition against the shard geometry once, up front;
    // every request in the batch shares the one validated plan and its
    // input/threshold storage is borrowed, not cloned.
    let plan = Arc::new(TilePlan::new(set.tile_n(), blocks)?);
    let width = plan.width();
    let mut planned = Vec::with_capacity(reqs.len());
    for (i, req) in reqs.iter().enumerate() {
        validate_request(i, req)?;
        if req.x.len() != width {
            bail!(
                "request {i} is {} wide, but the block partition {blocks:?} covers {width}",
                req.x.len()
            );
        }
        planned.push(PlannedReq {
            x: Cow::Borrowed(&req.x[..]),
            th: Cow::Borrowed(&req.thresholds_units[..]),
            scale: req.scale,
            deadline: req.deadline,
            plan: Arc::clone(&plan),
        });
    }
    run(set, planned)
}

/// The shared scatter–gather loop over pre-validated planned requests.
fn run(set: &mut ShardSet, planned: Vec<PlannedReq<'_>>) -> Result<Vec<Vec<f32>>> {
    let bits = set.bits();
    let tile_n = set.tile_n();
    // Trace handles for the batch, one per request (set by the batcher;
    // empty on untraced paths).  `traced` gates every clock read so an
    // unsampled batch pays a branch per stage and nothing more.
    let scope: Vec<TraceHandle> = set.trace_scope().to_vec();
    let traced = scope.iter().any(TraceHandle::is_active);
    // One clone per batch; the handle is a single `Option<Arc>`.
    let monitor = set.monitor().clone();
    // Shared breaker state: routing consults it, drains feed it.
    let breakers = Arc::clone(set.breakers());

    let healthy = set.healthy();
    if healthy.is_empty() {
        bail!("every shard is poisoned; request cannot be served");
    }

    // Fusion-aware grouping: requests sharing a block partition (the
    // same `Arc` or an equal slot layout — the key is the width vector,
    // which fully determines offsets and sub-tile masks for one
    // `tile_n`) are planned together.  Groups keep batch order.
    let mut group_of: HashMap<Vec<usize>, usize> = HashMap::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for (ri, preq) in planned.iter().enumerate() {
        let key: Vec<usize> = preq.plan.slots().iter().map(|s| s.width).collect();
        let g = *group_of.entry(key).or_insert_with(|| {
            groups.push(Vec::new());
            groups.len() - 1
        });
        groups[g].push(ri);
    }

    let workers = set.workers_per_shard().max(1);
    // Plan each group over the healthy shards with ONE LPT pass on the
    // group's summed per-block costs, carrying the load vector across
    // groups so the batch balances globally.  Sharing the block→shard
    // assignment across a group is what makes its slices fusable.
    let mut loads = vec![0u64; healthy.len()];
    let mut queue: VecDeque<Slice> = VecDeque::new();
    for members in &groups {
        let group_traced = traced && any_traced(&scope, members);
        let plan_start = if group_traced { trace::now_us() } else { 0 };
        let slots = planned[members[0]].plan.slots().len();
        let mut costs = vec![0u64; slots];
        for &ri in members {
            let preq = &planned[ri];
            for (b, s) in preq.plan.slots().iter().enumerate() {
                let lo = s.offset;
                let w = s.width;
                costs[b] += estimate_block_cost(&preq.x[lo..lo + w], &preq.th[lo..lo + w], bits);
            }
        }
        let plan = plan_blocks(&costs, &healthy, &mut loads);
        if group_traced {
            let now = trace::now_us();
            for &ri in members {
                if is_traced(&scope, ri) {
                    scope[ri].record(Stage::Plan, plan_start, now.saturating_sub(plan_start));
                }
            }
        }
        // Chunking keeps every worker of a shard busy with the fewest
        // jobs: a group with >= `workers` samples saturates the pool
        // with whole-block-run sample chunks; a smaller group also
        // splits its blocks into lanes (a 1-sample group reproduces the
        // pre-fusion dispatch shape exactly).
        let sample_chunks = members.len().min(workers);
        let lanes = workers.div_ceil(sample_chunks);
        for a in plan.assignments {
            for blocks in split_lanes(&a.blocks, lanes) {
                for chunk in split_lanes(members, sample_chunks) {
                    queue.push_back(Slice {
                        reqs: chunk,
                        shard: a.shard,
                        blocks: blocks.clone(),
                        attempts: 0,
                    });
                }
            }
        }
    }

    let mut outs: Vec<Vec<f32>> = planned.iter().map(|p| vec![0.0f32; p.x.len()]).collect();
    let mut outstanding: Vec<HashMap<u64, InFlight>> =
        (0..set.len()).map(|_| HashMap::new()).collect();
    // Sub-partition plans are resolved once per distinct lane shape and
    // shared by `Arc` across every fused job with that shape — an
    // N-sample job never re-derives its plan.
    let mut subplans: HashMap<Vec<usize>, Arc<TilePlan>> = HashMap::new();
    // Rotating gather start: blocking on the lowest-indexed shard with
    // work would let later shards' bounded result queues sit full (and
    // their pools idle) while shard 0 finishes; the cursor spreads the
    // blocking drain across shards round-robin.
    let mut gather_from = 0usize;
    // First retry-budget exhaustion; the loop keeps draining in-flight
    // work (the router contract: nothing outstanding on return) and the
    // error surfaces once the set is quiet.
    let mut fail: Option<anyhow::Error> = None;

    loop {
        // Scatter phase: submit everything queued, shedding poisoned
        // shards' slices to the survivors.  `try_submit_batch_planned`
        // (never the blocking `submit`) keeps a full bounded job queue
        // from deadlocking the scatter against the undrained result
        // queue: on backpressure we drain one finished job first.
        while let Some(mut slice) = queue.pop_front() {
            if fail.is_some() {
                continue; // draining only; queued work is moot
            }
            if slice.attempts > MAX_SLICE_ATTEMPTS {
                fail = Some(anyhow!(
                    "slice for requests {:?} exhausted its retry budget \
                     ({MAX_SLICE_ATTEMPTS} attempts); shards are systemically failing",
                    slice.reqs
                ));
                continue;
            }
            if slice.attempts > 0 {
                // Budgeted retry: wait out the backoff so a flapping
                // shard gets a beat to settle before the resubmit.
                std::thread::sleep(retry_backoff(slice.attempts, slice.reqs[0] as u64));
            }
            let now = Instant::now();
            if !set.is_healthy(slice.shard) || !breakers.allow(slice.shard, now) {
                slice.shard = reroute_target(set, &outstanding, &breakers, now)?;
            }
            let shard = slice.shard;
            let active = traced && any_traced(&scope, &slice.reqs);
            let scatter_start = if active { trace::now_us() } else { 0 };
            let subs: Vec<TransformRequest> = slice
                .reqs
                .iter()
                .map(|&ri| sub_request(&planned[ri], &slice.blocks).0)
                .collect();
            let widths: Vec<usize> = slice
                .blocks
                .iter()
                .map(|&b| planned[slice.reqs[0]].block_width(b))
                .collect();
            let subplan = Arc::clone(subplans.entry(widths).or_insert_with_key(|w| {
                Arc::new(TilePlan::new(tile_n, w).expect("sub-partition of a validated plan"))
            }));
            let coord = set.coordinator_mut(shard).expect("healthy shard has a pool");
            match coord.try_submit_batch_planned(&subs, &subplan) {
                Ok(Some(id)) => {
                    let submit_us = if active { trace::now_us() } else { 0 };
                    if active {
                        for &ri in &slice.reqs {
                            if is_traced(&scope, ri) {
                                scope[ri].record_shard(
                                    Stage::Scatter,
                                    scatter_start,
                                    submit_us.saturating_sub(scatter_start),
                                    shard,
                                );
                            }
                        }
                    }
                    outstanding[shard].insert(id, (slice, submit_us));
                }
                Ok(None) => {
                    // Bounded queue full: free a slot by collecting one
                    // finished job from this shard, then retry.
                    let drain_start = if traced { trace::now_us() } else { 0 };
                    match set
                        .coordinator_mut(shard)
                        .expect("healthy shard has a pool")
                        .drain_batch()
                    {
                        Ok(batch) => {
                            let finished = outstanding[shard]
                                .remove(&batch.request_id)
                                .expect("drained id was submitted by this router");
                            if set.chaos_drain_drop().fire() {
                                // Injected lost completion: the result
                                // is discarded and the slice recomputed
                                // (bit-identical), the breaker sees it
                                // as a shard failure.
                                breakers.record_failure(shard, Instant::now());
                                requeue_split(finished.0, &mut queue);
                            } else {
                                breakers.record_success(shard);
                                finish_job(
                                    &scope,
                                    &monitor,
                                    &mut outs,
                                    &planned,
                                    shard,
                                    batch,
                                    finished,
                                    drain_start,
                                );
                            }
                        }
                        Err(_) => poison_and_requeue(set, shard, &mut outstanding, &mut queue),
                    }
                    queue.push_front(slice);
                }
                Err(_) => {
                    // Pool is gone: poison the shard and re-route both
                    // this slice (split per request) and anything
                    // already in flight on it.
                    poison_and_requeue(set, shard, &mut outstanding, &mut queue);
                    requeue_split(slice, &mut queue);
                }
            }
        }

        // Gather phase: drain one job from a shard with work in flight,
        // starting from the rotating cursor; a drain failure re-queues
        // that shard's slices.
        let len = set.len();
        let next = (0..len)
            .map(|i| (gather_from + i) % len)
            .find(|&s| !outstanding[s].is_empty());
        let Some(shard) = next else {
            break;
        };
        gather_from = (shard + 1) % len;
        let drain_start = if traced { trace::now_us() } else { 0 };
        if set.chaos_drain_delay().fire() {
            // Injected slow drain: latency only, results untouched.
            std::thread::sleep(crate::chaos::SLOWDOWN);
        }
        match set.coordinator_mut(shard).expect("outstanding implies healthy").drain_batch() {
            Ok(batch) => {
                let in_flight = outstanding[shard]
                    .remove(&batch.request_id)
                    .expect("drained id was submitted by this router");
                if set.chaos_drain_drop().fire() {
                    breakers.record_failure(shard, Instant::now());
                    requeue_split(in_flight.0, &mut queue);
                } else {
                    breakers.record_success(shard);
                    finish_job(
                        &scope,
                        &monitor,
                        &mut outs,
                        &planned,
                        shard,
                        batch,
                        in_flight,
                        drain_start,
                    );
                }
            }
            Err(_) => poison_and_requeue(set, shard, &mut outstanding, &mut queue),
        }
    }

    if let Some(e) = fail {
        return Err(e);
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitplane::QuantBwht;
    use crate::coordinator::{Coordinator, CoordinatorConfig};
    use crate::quant::Quantizer;
    use crate::shard::set::ShardSetConfig;
    use crate::util::rng::Rng;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::seed_from_u64(seed);
        (0..n).map(|_| r.uniform_range(-1.0, 1.0) as f32).collect()
    }

    fn golden(req: &TransformRequest) -> Vec<f32> {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        let out = c.transform(req).unwrap();
        c.shutdown();
        out
    }

    #[test]
    fn split_lanes_covers_blocks_contiguously() {
        let blocks: Vec<usize> = (0..7).collect();
        let chunks = split_lanes(&blocks, 3);
        assert_eq!(chunks, vec![vec![0, 1, 2], vec![3, 4], vec![5, 6]]);
        assert_eq!(split_lanes(&blocks, 100).len(), 7);
        assert_eq!(split_lanes(&[5], 4), vec![vec![5]]);
    }

    fn planned(width: usize, blocks: &[usize]) -> PlannedReq<'static> {
        PlannedReq {
            x: Cow::Owned(vec![0.0; width]),
            th: Cow::Owned(vec![0.0; width]),
            scale: None,
            deadline: None,
            plan: Arc::new(TilePlan::new(16, blocks).unwrap()),
        }
    }

    #[test]
    fn gather_scatters_by_block_offset() {
        let preq = planned(12, &[4, 4, 4]);
        let mut out = vec![0.0f32; 12];
        let values = vec![1.0, 1.0, 1.0, 1.0, 3.0, 3.0, 3.0, 3.0];
        gather(&mut out, &values, &preq, &[0, 2]);
        assert_eq!(out, vec![1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 3.0, 3.0, 3.0, 3.0]);
    }

    #[test]
    fn gather_handles_mixed_widths() {
        let preq = planned(20, &[16, 4]);
        let mut out = vec![0.0f32; 20];
        let values = vec![7.0; 4];
        gather(&mut out, &values, &preq, &[1]);
        assert_eq!(&out[16..], &[7.0; 4]);
        assert!(out[..16].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn sharded_output_matches_single_coordinator() {
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 3,
            ..Default::default()
        })
        .unwrap();
        let req = TransformRequest {
            x: sample(96, 11),
            thresholds_units: vec![0.0; 96],
            scale: None,
            deadline: None,
        };
        let out = transform(&mut set, &req).unwrap();
        assert_eq!(out, golden(&req));
        set.shutdown();
    }

    #[test]
    fn planned_mixed_partition_matches_whole_width_golden_model() {
        // Width 20 as [16, 4] over 2 shards of 16-wide tiles: the
        // 4-block runs under sub-tile masking on whichever shard the
        // planner picks, and the pinned scale keeps the result
        // bit-identical to the 20-wide golden model.
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        let x = sample(20, 77);
        let req = TransformRequest {
            thresholds_units: vec![0.0; 20],
            scale: Some(Quantizer::new(8).scale_for(&x)),
            deadline: None,
            x,
        };
        let outs = transform_batch_planned(&mut set, &[16, 4], std::slice::from_ref(&req)).unwrap();
        let want = QuantBwht::new(20, 128, 8).transform(&req.x);
        assert_eq!(outs[0], want);
        assert_eq!(outs[0].len(), 20, "planned outputs are unpadded");
        set.shutdown();
    }

    #[test]
    fn planned_partition_is_validated_at_the_boundary() {
        let mut set = ShardSet::new(ShardSetConfig::default()).unwrap();
        let req = TransformRequest::plain(vec![0.5; 20]);
        // Width mismatch.
        assert!(transform_batch_planned(&mut set, &[16], std::slice::from_ref(&req)).is_err());
        // Block wider than the tile.
        assert!(
            transform_batch_planned(&mut set, &[32], &[TransformRequest::plain(vec![0.5; 32])])
                .is_err()
        );
        set.shutdown();
    }

    #[test]
    fn batch_outputs_come_back_in_request_order() {
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        let reqs: Vec<TransformRequest> = (0..5)
            .map(|i| TransformRequest {
                x: sample(48, 20 + i),
                thresholds_units: vec![0.0; 48],
                scale: None,
                deadline: None,
            })
            .collect();
        let outs = transform_batch(&mut set, &reqs).unwrap();
        for (i, req) in reqs.iter().enumerate() {
            assert_eq!(outs[i], golden(req), "request {i}");
        }
        set.shutdown();
    }

    #[test]
    fn fused_batch_issues_fewer_pool_jobs_than_slices() {
        // 16 same-width requests over 2 shards × 4 workers: the group
        // fuses into sample chunks, so the whole batch costs at most
        // `shards × workers` jobs while still billing every sample —
        // pre-fusion dispatch paid one job per (request × shard).
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        let workers = set.workers_per_shard();
        let reqs: Vec<TransformRequest> = (0..16)
            .map(|i| TransformRequest {
                x: sample(96, 500 + i),
                thresholds_units: vec![0.0; 96],
                scale: None,
                deadline: None,
            })
            .collect();
        let outs = transform_batch(&mut set, &reqs).unwrap();
        for (i, req) in reqs.iter().enumerate() {
            assert_eq!(outs[i], golden(req), "request {i}");
        }
        let m = set.metrics();
        assert!(
            m.jobs < m.requests,
            "fusion must issue fewer jobs ({}) than sample-slices ({})",
            m.jobs,
            m.requests
        );
        assert!(
            m.jobs <= (2 * workers) as u64,
            "16 fused requests need at most shards*workers jobs, got {}",
            m.jobs
        );
        set.shutdown();
    }

    #[test]
    fn rejects_malformed_requests_at_the_boundary() {
        let mut set = ShardSet::new(ShardSetConfig::default()).unwrap();
        assert!(transform(
            &mut set,
            &TransformRequest {
                x: vec![],
                thresholds_units: vec![],
                scale: None,
                deadline: None,
            }
        )
        .is_err());
        assert!(transform(
            &mut set,
            &TransformRequest {
                x: vec![1.0; 8],
                thresholds_units: vec![0.0; 4],
                scale: None,
                deadline: None,
            }
        )
        .is_err());
        set.shutdown();
    }

    #[test]
    fn poisoned_shard_sheds_load_to_siblings() {
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 3,
            ..Default::default()
        })
        .unwrap();
        let req = TransformRequest {
            x: sample(128, 31),
            thresholds_units: vec![0.0; 128],
            scale: None,
            deadline: None,
        };
        // Kill shard 1's pool before routing: its submits fail, the
        // router poisons it and the survivors absorb the blocks.
        set.coordinator_mut(1).unwrap().abort();
        let out = transform(&mut set, &req).unwrap();
        assert_eq!(out, golden(&req));
        assert_eq!(set.healthy(), vec![0, 2]);
        set.shutdown();
    }

    #[test]
    fn poisoned_shard_requeues_fused_jobs_per_slice() {
        // A fused batch against a pre-killed shard: every sample of
        // every fused job routed there must come back whole from the
        // survivor — failover splits fused work per request.
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        set.coordinator_mut(0).unwrap().abort();
        let reqs: Vec<TransformRequest> = (0..8)
            .map(|i| TransformRequest {
                x: sample(64, 700 + i),
                thresholds_units: vec![0.0; 64],
                scale: None,
                deadline: None,
            })
            .collect();
        let outs = transform_batch(&mut set, &reqs).unwrap();
        for (i, req) in reqs.iter().enumerate() {
            assert_eq!(outs[i], golden(req), "request {i}");
        }
        assert_eq!(set.healthy(), vec![1]);
        set.shutdown();
    }

    #[cfg(not(feature = "trace-off"))]
    #[test]
    fn traced_scope_attributes_plan_scatter_execute_and_drain_spans() {
        use crate::trace::{Stage, TraceConfig, Tracer};
        let tracer = Tracer::new(TraceConfig::default());
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        let req = TransformRequest {
            x: sample(64, 90),
            thresholds_units: vec![0.0; 64],
            scale: None,
            deadline: None,
        };
        let handle = tracer.begin("/v1/transform");
        set.set_trace_scope(vec![handle.clone()]);
        let out = transform_batch(&mut set, std::slice::from_ref(&req)).unwrap();
        set.clear_trace_scope();
        tracer.finish(handle);
        assert_eq!(out[0], golden(&req));

        let trace = &tracer.recent(1)[0];
        let stages: Vec<Stage> = trace.spans.iter().map(|s| s.stage).collect();
        for want in [Stage::Plan, Stage::Scatter, Stage::PoolQueue, Stage::Execute, Stage::Drain] {
            assert!(stages.contains(&want), "missing {want:?} in {stages:?}");
        }
        let exec = trace
            .spans
            .iter()
            .find(|s| s.stage == Stage::Execute)
            .unwrap();
        let payload = exec.exec.expect("execute spans carry the engine payload");
        assert!(payload.planes > 0);
        assert!(payload.elements > 0);
        assert!(exec.shard.is_some(), "execute spans name their shard");
        // Span ordering is consistent on the shared timeline.
        for s in &trace.spans {
            assert!(s.start_us + s.dur_us <= trace.end_us);
            assert!(s.start_us >= trace.begin_us);
        }
        set.shutdown();
    }

    #[cfg(not(feature = "trace-off"))]
    #[test]
    fn fused_jobs_reconstruct_per_slice_execute_spans() {
        use crate::trace::{Stage, TraceConfig, Tracer};
        let tracer = Tracer::new(TraceConfig::default());
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 1,
            ..Default::default()
        })
        .unwrap();
        let workers = set.workers_per_shard();
        // More requests than workers on one shard forces multi-sample
        // fused jobs; every request is traced under one scope.
        let n = 2 * workers;
        let reqs: Vec<TransformRequest> = (0..n)
            .map(|i| TransformRequest {
                x: sample(32, 800 + i as u64),
                thresholds_units: vec![0.0; 32],
                scale: None,
                deadline: None,
            })
            .collect();
        let handle = tracer.begin("/v1/transform");
        set.set_trace_scope(vec![handle.clone(); n]);
        transform_batch(&mut set, &reqs).unwrap();
        set.clear_trace_scope();
        tracer.finish(handle);

        let trace = &tracer.recent(1)[0];
        let execs: Vec<_> = trace
            .spans
            .iter()
            .filter(|s| s.stage == Stage::Execute)
            .collect();
        // One execute span per sample-slice, even though the pool ran
        // fewer fused jobs than samples.
        assert_eq!(execs.len(), n, "per-slice execute spans from fused jobs");
        let jobs = set.metrics().jobs;
        assert!(jobs < n as u64, "{jobs} jobs must undercut {n} spans");
        for s in &execs {
            let payload = s.exec.expect("per-sample payload");
            assert!(payload.planes > 0);
            assert!(payload.elements > 0);
            assert!(s.start_us + s.dur_us <= trace.end_us);
        }
        set.shutdown();
    }

    #[test]
    fn untraced_scope_leaves_results_bit_identical() {
        // The one-branch fast path: an all-inactive scope must not
        // perturb routing or outputs.
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        let req = TransformRequest {
            x: sample(64, 91),
            thresholds_units: vec![0.0; 64],
            scale: None,
            deadline: None,
        };
        set.set_trace_scope(vec![crate::trace::TraceHandle::inactive()]);
        let out = transform_batch(&mut set, std::slice::from_ref(&req)).unwrap();
        set.clear_trace_scope();
        assert_eq!(out[0], golden(&req));
        set.shutdown();
    }

    #[cfg(not(feature = "monitor-off"))]
    #[test]
    fn active_monitor_captures_slices_from_non_digital_shards_only() {
        use crate::coordinator::TileKind;
        use crate::monitor::{Monitor, MonitorConfig};

        let coord = CoordinatorConfig::default();
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            kinds: Some(vec![
                TileKind::Digital,
                TileKind::Noisy { sigma_ant: 1e-6 },
            ]),
            coordinator: coord.clone(),
            ..Default::default()
        })
        .unwrap();
        let monitor = Monitor::start(
            MonitorConfig {
                sample_every: 1,
                ..Default::default()
            },
            coord,
            set.non_digital_slots(),
            set.slot_health_handle(),
        );
        assert!(monitor.is_enabled());
        set.set_monitor(monitor.handle());

        let reqs: Vec<TransformRequest> = (0..4)
            .map(|i| TransformRequest {
                x: sample(96, 400 + i),
                thresholds_units: vec![0.0; 96],
                scale: None,
                deadline: None,
            })
            .collect();
        transform_batch(&mut set, &reqs).unwrap();

        // The checker thread runs asynchronously; wait for at least one
        // shadow check to land (the planner spreads 4×6 blocks over both
        // shards, so the noisy slot always serves some slices).
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while monitor.checked_total() == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(monitor.checked_total() > 0, "no shadow check completed");
        assert_eq!(monitor.check_errors_total(), 0);
        // Only the noisy slot is eligible: every record names shard 1.
        for rec in monitor.recent(64) {
            assert_eq!(rec.shard, 1);
        }
        set.shutdown();
    }

    #[test]
    fn open_breaker_sheds_routing_to_siblings() {
        // Both shards healthy, shard 0's breaker forced open: every
        // slice re-routes to shard 1 and the output stays golden.
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        set.breakers().force_open(0, std::time::Instant::now());
        let req = TransformRequest {
            x: sample(96, 55),
            thresholds_units: vec![0.0; 96],
            scale: None,
            deadline: None,
        };
        let out = transform(&mut set, &req).unwrap();
        assert_eq!(out, golden(&req));
        assert_eq!(
            set.aggregator().per_shard()[0].requests,
            0,
            "an open breaker admits no traffic inside its window"
        );
        assert!(set.aggregator().per_shard()[1].requests > 0);
        set.shutdown();
    }

    #[test]
    fn all_breakers_open_still_serves_degraded() {
        // Breakers shape load; they must never turn a healthy set into
        // a hard outage.  With every breaker open the router serves
        // through the least-loaded healthy shard anyway.
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        let now = std::time::Instant::now();
        set.breakers().force_open(0, now);
        set.breakers().force_open(1, now);
        let req = TransformRequest {
            x: sample(64, 56),
            thresholds_units: vec![0.0; 64],
            scale: None,
            deadline: None,
        };
        let out = transform(&mut set, &req).unwrap();
        assert_eq!(out, golden(&req));
        set.shutdown();
    }

    #[cfg(feature = "chaos")]
    #[test]
    fn injected_drain_drop_exhausts_the_retry_budget_cleanly() {
        use crate::chaos::ChaosPlan;
        // Every completion dropped: the slice recomputes until its
        // budget runs out, then the batch fails with a clean error
        // instead of spinning forever.
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            coordinator: crate::coordinator::CoordinatorConfig {
                chaos: ChaosPlan::parse("router.drain.drop=1.0,3").unwrap(),
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        let req = TransformRequest {
            x: sample(64, 57),
            thresholds_units: vec![0.0; 64],
            scale: None,
            deadline: None,
        };
        let err = transform(&mut set, &req).unwrap_err();
        assert!(err.to_string().contains("retry budget"), "{err}");
        set.shutdown();
    }

    #[cfg(feature = "chaos")]
    #[test]
    fn injected_drain_delay_keeps_results_bit_identical() {
        use crate::chaos::ChaosPlan;
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            coordinator: crate::coordinator::CoordinatorConfig {
                chaos: ChaosPlan::parse("router.drain.delay=1.0,7").unwrap(),
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        let reqs: Vec<TransformRequest> = (0..4)
            .map(|i| TransformRequest {
                x: sample(96, 900 + i),
                thresholds_units: vec![0.0; 96],
                scale: None,
                deadline: None,
            })
            .collect();
        let outs = transform_batch(&mut set, &reqs).unwrap();
        for (i, req) in reqs.iter().enumerate() {
            assert_eq!(outs[i], golden(req), "request {i}");
        }
        set.shutdown();
    }

    #[test]
    fn all_shards_poisoned_is_a_clean_error() {
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        set.coordinator_mut(0).unwrap().abort();
        set.coordinator_mut(1).unwrap().abort();
        let req = TransformRequest {
            x: sample(32, 40),
            thresholds_units: vec![0.0; 32],
            scale: None,
            deadline: None,
        };
        let err = transform(&mut set, &req).unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        set.shutdown();
    }
}

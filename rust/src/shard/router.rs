//! Scatter–gather execution of wide transforms across the shard set.
//!
//! A width-W request is padded to whole `tile_n` blocks, the block list
//! is partitioned by the [`super::planner`] across the healthy shards
//! (balancing estimated row-cycles), each shard's portion is further
//! split into per-worker lanes and fanned out through the coordinator's
//! `submit`/`drain_one` async API, and the per-slice outputs are
//! scattered back into the request's output vector by block index.
//!
//! Because every block is quantized and scheduled independently, any
//! placement reproduces the single-coordinator output bit-for-bit on the
//! digital backend — placement is a pure throughput decision.
//!
//! Failure isolation: a shard whose pool errors on submit or drain is
//! poisoned and its slices (outstanding ones included) are re-routed to
//! the surviving shards.  A request only fails once *every* shard is
//! gone.  Re-executed slices are harmless: a poisoned shard is never
//! drained again, so a duplicate result can never be observed.

use std::collections::{HashMap, VecDeque};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::TransformRequest;

use super::planner::{estimate_block_cost, plan_blocks};
use super::set::ShardSet;

/// One unit of scatter work: a subset of one request's blocks bound for
/// one shard.
#[derive(Debug, Clone)]
struct Slice {
    /// Index into the batch.
    req: usize,
    /// Target shard slot (revised when the target is poisoned).
    shard: usize,
    /// Ascending block indices of the padded request.
    blocks: Vec<usize>,
}

/// Concatenate `blocks` of the padded request into one sub-request.
/// The parent's pinned quantization scale (if any) is inherited by every
/// slice, so a sliced request quantizes exactly like the whole one.
fn sub_request(
    x: &[f32],
    th: &[f64],
    scale: Option<f32>,
    blocks: &[usize],
    tile_n: usize,
) -> TransformRequest {
    let mut sx = Vec::with_capacity(blocks.len() * tile_n);
    let mut sth = Vec::with_capacity(blocks.len() * tile_n);
    for &b in blocks {
        sx.extend_from_slice(&x[b * tile_n..(b + 1) * tile_n]);
        sth.extend_from_slice(&th[b * tile_n..(b + 1) * tile_n]);
    }
    TransformRequest {
        x: sx,
        thresholds_units: sth,
        scale,
    }
}

/// Scatter a slice's concatenated outputs back by block index.
fn gather(out: &mut [f32], values: &[f32], blocks: &[usize], tile_n: usize) {
    debug_assert_eq!(values.len(), blocks.len() * tile_n);
    for (j, &b) in blocks.iter().enumerate() {
        out[b * tile_n..(b + 1) * tile_n].copy_from_slice(&values[j * tile_n..(j + 1) * tile_n]);
    }
}

/// Split `blocks` into at most `lanes` contiguous chunks of near-equal
/// length (at least one block each).
fn split_lanes(blocks: &[usize], lanes: usize) -> Vec<Vec<usize>> {
    let lanes = lanes.clamp(1, blocks.len().max(1));
    let base = blocks.len() / lanes;
    let extra = blocks.len() % lanes;
    let mut chunks = Vec::with_capacity(lanes);
    let mut off = 0;
    for lane in 0..lanes {
        let take = base + usize::from(lane < extra);
        if take == 0 {
            break;
        }
        chunks.push(blocks[off..off + take].to_vec());
        off += take;
    }
    chunks
}

/// Healthy shard with the fewest outstanding slices (re-route target).
fn reroute_target(set: &ShardSet, outstanding: &[HashMap<u64, Slice>]) -> Result<usize> {
    set.healthy()
        .into_iter()
        .min_by_key(|&s| outstanding[s].len())
        .ok_or_else(|| anyhow!("every shard is poisoned; request cannot be served"))
}

/// Retire a dead shard and push everything in flight on it back onto the
/// work queue (the re-queued slices keep their stale shard id; the
/// scatter loop re-routes them to a healthy target).
fn poison_and_requeue(
    set: &mut ShardSet,
    shard: usize,
    outstanding: &mut [HashMap<u64, Slice>],
    queue: &mut VecDeque<Slice>,
) {
    set.poison(shard);
    for (_, orphan) in outstanding[shard].drain() {
        queue.push_back(orphan);
    }
}

/// Execute one transform request across the shard set.  Returns outputs
/// at padded width, bit-identical (digital backend) to a single
/// [`crate::coordinator::Coordinator`] serving the same request.
pub fn transform(set: &mut ShardSet, req: &TransformRequest) -> Result<Vec<f32>> {
    let mut outs = transform_batch(set, std::slice::from_ref(req))?;
    Ok(outs.pop().expect("one request, one output"))
}

/// Execute a batch of requests, scatter–gathering every request's blocks
/// across the healthy shards.  Outputs are returned in request order at
/// padded width.
///
/// The router assumes exclusive use of the set's async API: every slice
/// it submits is drained before returning, and no caller-submitted
/// requests may be outstanding on any shard when it is invoked.
pub fn transform_batch(set: &mut ShardSet, reqs: &[TransformRequest]) -> Result<Vec<Vec<f32>>> {
    let tile_n = set.tile_n();
    let bits = set.bits();

    // Validate + pad up front so malformed input is a clean error at the
    // routing boundary (mirrors `Coordinator::validate`).
    let mut padded: Vec<(Vec<f32>, Vec<f64>, Option<f32>)> = Vec::with_capacity(reqs.len());
    for (i, req) in reqs.iter().enumerate() {
        if req.x.is_empty() {
            bail!("request {i} has an empty input vector");
        }
        if req.thresholds_units.len() != req.x.len() {
            bail!(
                "request {i}: thresholds_units length {} does not match input length {}",
                req.thresholds_units.len(),
                req.x.len()
            );
        }
        if let Some(s) = req.scale {
            if !(s.is_finite() && s > 0.0) {
                bail!("request {i}: pinned quantization scale must be positive and finite");
            }
        }
        let w = req.x.len().div_ceil(tile_n) * tile_n;
        let mut x = req.x.clone();
        x.resize(w, 0.0);
        let mut th = req.thresholds_units.clone();
        th.resize(w, 0.0);
        padded.push((x, th, req.scale));
    }

    // Plan the whole batch over the healthy shards, carrying the load
    // vector across requests so the batch balances globally.
    let healthy = set.healthy();
    if healthy.is_empty() {
        bail!("every shard is poisoned; request cannot be served");
    }
    // Intra-shard lane splitting trades dispatch overhead (one channel
    // send + allocation per slice — the cost pool.rs's one-job-per-
    // request design amortizes) for intra-request parallelism.  A batch
    // with at least `workers` requests already saturates each shard's
    // pool at request granularity, so only split when the batch is too
    // small to do that: 1 request on 4-worker shards → 4 lanes, 2 → 2,
    // ≥ workers → 1 (the PR-1 dispatch behavior).
    let lanes_per_shard = set
        .workers_per_shard()
        .max(1)
        .div_ceil(reqs.len().max(1));
    let mut loads = vec![0u64; healthy.len()];
    let mut queue: VecDeque<Slice> = VecDeque::new();
    for (ri, (x, th, _)) in padded.iter().enumerate() {
        let nblocks = x.len() / tile_n;
        let costs: Vec<u64> = (0..nblocks)
            .map(|b| {
                estimate_block_cost(
                    &x[b * tile_n..(b + 1) * tile_n],
                    &th[b * tile_n..(b + 1) * tile_n],
                    bits,
                )
            })
            .collect();
        let plan = plan_blocks(&costs, &healthy, &mut loads);
        for a in plan.assignments {
            // Split each shard's share into per-worker lanes so the
            // shard's whole pool works on the request, not one thread.
            for blocks in split_lanes(&a.blocks, lanes_per_shard) {
                queue.push_back(Slice {
                    req: ri,
                    shard: a.shard,
                    blocks,
                });
            }
        }
    }

    let mut outs: Vec<Vec<f32>> = padded.iter().map(|(x, ..)| vec![0.0f32; x.len()]).collect();
    let mut outstanding: Vec<HashMap<u64, Slice>> =
        (0..set.len()).map(|_| HashMap::new()).collect();

    loop {
        // Scatter phase: submit everything queued, shedding poisoned
        // shards' slices to the survivors.  `try_submit` (never the
        // blocking `submit`) keeps a full bounded job queue from
        // deadlocking the scatter against the undrained result queue:
        // on backpressure we drain one finished result first.
        while let Some(mut slice) = queue.pop_front() {
            if !set.is_healthy(slice.shard) {
                slice.shard = reroute_target(set, &outstanding)?;
            }
            let shard = slice.shard;
            let (x, th, scale) = &padded[slice.req];
            let sub = sub_request(x, th, *scale, &slice.blocks, tile_n);
            let coord = set.coordinator_mut(shard).expect("healthy shard has a pool");
            match coord.try_submit(&sub) {
                Ok(Some(id)) => {
                    outstanding[shard].insert(id, slice);
                }
                Ok(None) => {
                    // Bounded queue full: free a slot by collecting one
                    // finished result from this shard, then retry.
                    match set.coordinator_mut(shard).expect("healthy shard has a pool").drain_one()
                    {
                        Ok(done) => {
                            let finished = outstanding[shard]
                                .remove(&done.request_id)
                                .expect("drained id was submitted by this router");
                            gather(&mut outs[finished.req], &done.values, &finished.blocks, tile_n);
                        }
                        Err(_) => poison_and_requeue(set, shard, &mut outstanding, &mut queue),
                    }
                    queue.push_front(slice);
                }
                Err(_) => {
                    // Pool is gone: poison the shard and re-route both
                    // this slice and anything already in flight on it.
                    poison_and_requeue(set, shard, &mut outstanding, &mut queue);
                    queue.push_back(slice);
                }
            }
        }

        // Gather phase: drain one result from any shard with work in
        // flight; a drain failure re-queues that shard's slices.
        let Some(shard) = (0..set.len()).find(|&s| !outstanding[s].is_empty()) else {
            break;
        };
        match set.coordinator_mut(shard).expect("outstanding implies healthy").drain_one() {
            Ok(done) => {
                let slice = outstanding[shard]
                    .remove(&done.request_id)
                    .expect("drained id was submitted by this router");
                gather(&mut outs[slice.req], &done.values, &slice.blocks, tile_n);
            }
            Err(_) => poison_and_requeue(set, shard, &mut outstanding, &mut queue),
        }
    }

    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, CoordinatorConfig};
    use crate::shard::set::ShardSetConfig;
    use crate::util::rng::Rng;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::seed_from_u64(seed);
        (0..n).map(|_| r.uniform_range(-1.0, 1.0) as f32).collect()
    }

    fn golden(req: &TransformRequest) -> Vec<f32> {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        let out = c.transform(req).unwrap();
        c.shutdown();
        out
    }

    #[test]
    fn split_lanes_covers_blocks_contiguously() {
        let blocks: Vec<usize> = (0..7).collect();
        let chunks = split_lanes(&blocks, 3);
        assert_eq!(chunks, vec![vec![0, 1, 2], vec![3, 4], vec![5, 6]]);
        assert_eq!(split_lanes(&blocks, 100).len(), 7);
        assert_eq!(split_lanes(&[5], 4), vec![vec![5]]);
    }

    #[test]
    fn gather_scatters_by_block_index() {
        let mut out = vec![0.0f32; 12];
        let values = vec![1.0, 1.0, 1.0, 1.0, 3.0, 3.0, 3.0, 3.0];
        gather(&mut out, &values, &[0, 2], 4);
        assert_eq!(out, vec![1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 3.0, 3.0, 3.0, 3.0]);
    }

    #[test]
    fn sharded_output_matches_single_coordinator() {
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 3,
            ..Default::default()
        })
        .unwrap();
        let req = TransformRequest {
            x: sample(96, 11),
            thresholds_units: vec![0.0; 96],
            scale: None,
        };
        let out = transform(&mut set, &req).unwrap();
        assert_eq!(out, golden(&req));
        set.shutdown();
    }

    #[test]
    fn batch_outputs_come_back_in_request_order() {
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        let reqs: Vec<TransformRequest> = (0..5)
            .map(|i| TransformRequest {
                x: sample(48, 20 + i),
                thresholds_units: vec![0.0; 48],
                scale: None,
            })
            .collect();
        let outs = transform_batch(&mut set, &reqs).unwrap();
        for (i, req) in reqs.iter().enumerate() {
            assert_eq!(outs[i], golden(req), "request {i}");
        }
        set.shutdown();
    }

    #[test]
    fn rejects_malformed_requests_at_the_boundary() {
        let mut set = ShardSet::new(ShardSetConfig::default()).unwrap();
        assert!(transform(
            &mut set,
            &TransformRequest {
                x: vec![],
                thresholds_units: vec![],
                scale: None,
            }
        )
        .is_err());
        assert!(transform(
            &mut set,
            &TransformRequest {
                x: vec![1.0; 8],
                thresholds_units: vec![0.0; 4],
                scale: None,
            }
        )
        .is_err());
        set.shutdown();
    }

    #[test]
    fn poisoned_shard_sheds_load_to_siblings() {
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 3,
            ..Default::default()
        })
        .unwrap();
        let req = TransformRequest {
            x: sample(128, 31),
            thresholds_units: vec![0.0; 128],
            scale: None,
        };
        // Kill shard 1's pool before routing: its submits fail, the
        // router poisons it and the survivors absorb the blocks.
        set.coordinator_mut(1).unwrap().abort();
        let out = transform(&mut set, &req).unwrap();
        assert_eq!(out, golden(&req));
        assert_eq!(set.healthy(), vec![0, 2]);
        set.shutdown();
    }

    #[test]
    fn all_shards_poisoned_is_a_clean_error() {
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        set.coordinator_mut(0).unwrap().abort();
        set.coordinator_mut(1).unwrap().abort();
        let req = TransformRequest {
            x: sample(32, 40),
            thresholds_units: vec![0.0; 32],
            scale: None,
        };
        let err = transform(&mut set, &req).unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        set.shutdown();
    }
}

//! Per-shard circuit breakers and failover backoff.
//!
//! A shard that keeps failing should stop receiving traffic *before*
//! every request through it has paid the failure latency, and a shard
//! that keeps dying should stop being respawned on every health tick.
//! This module is the shared state machine for both decisions:
//!
//! ```text
//!             failure EWMA ≥ threshold, or
//!             OPEN_CONSECUTIVE_FAILURES in a row
//!   Closed ────────────────────────────────────▶ Open (until = now + d)
//!     ▲                                            │ d doubles per trip,
//!     │ probe succeeds                             │ ± deterministic jitter
//!     │                                            ▼ open window elapses
//!   HalfOpen ◀────────────────────────────────── (first allow() is the probe)
//!     │ probe fails → Open again, window doubled
//! ```
//!
//! The router consults [`BreakerSet::allow`] when picking a scatter or
//! reroute target, reports outcomes through `record_success` /
//! `record_failure`, and the batcher's heal pass gates pool respawns on
//! [`BreakerSet::respawn_allowed`] — exponential per-slot backoff so a
//! permanently sick shard converges to open-breaker shedding instead of
//! a respawn storm.  Drift detections from the fidelity monitor feed
//! the same failure EWMA, so a silently-diverging analog shard trips
//! the breaker just like a dying one.
//!
//! Every method takes `now: Instant` explicitly: the state machine is a
//! pure function of its inputs, which keeps chaos runs reproducible and
//! lets tests drive the clock instead of sleeping.  Thresholds and
//! backoff constants are derived in `DESIGN.md`.

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// EWMA smoothing factor for the per-shard failure rate.  With
/// `α = 0.25` the EWMA crosses [`OPEN_FAILURE_THRESHOLD`] after ~3
/// consecutive failures from a clean history (`1-(1-α)^3 ≈ 0.58`),
/// aligning the rate trigger with the streak trigger.
pub const FAILURE_EWMA_ALPHA: f64 = 0.25;

/// Failure-rate EWMA at or above which a closed breaker trips.
pub const OPEN_FAILURE_THRESHOLD: f64 = 0.5;

/// Consecutive-failure streak that trips a closed breaker regardless
/// of the EWMA (fast path for a shard that dies outright).
pub const OPEN_CONSECUTIVE_FAILURES: u32 = 3;

/// Open window after the first trip; doubles on every consecutive
/// trip.  One window covers a few health ticks (250 ms default), so a
/// respawned-and-healthy pool reopens for traffic within ~2 ticks.
pub const OPEN_BASE: Duration = Duration::from_millis(500);

/// Ceiling on the open window (a flapping shard is retried at least
/// this often).
pub const OPEN_CAP: Duration = Duration::from_secs(8);

/// Jitter fraction applied to each open window (deterministic, seeded
/// per slot + trip count) so shards tripped together do not re-probe
/// in lockstep.
pub const OPEN_JITTER: f64 = 0.10;

/// Probes admitted while half-open before the breaker decides.
pub const HALF_OPEN_PROBES: u32 = 2;

/// Backoff after the *second* respawn of the same slot (the first is
/// free so a one-off pool death heals on the next tick); doubles per
/// consecutive respawn.
pub const RESPAWN_BACKOFF_BASE: Duration = Duration::from_millis(250);

/// Ceiling on the per-slot respawn backoff.
pub const RESPAWN_BACKOFF_CAP: Duration = Duration::from_secs(10);

/// Breaker position, exported as `repro_shard_breaker_state`
/// (0 = closed, 1 = half-open, 2 = open).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    HalfOpen,
    Open,
}

impl BreakerState {
    /// Gauge encoding for `/metrics`.
    pub fn code(self) -> u8 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::HalfOpen => 1,
            BreakerState::Open => 2,
        }
    }

    /// Human label for `/readyz`.
    pub fn label(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::HalfOpen => "half-open",
            BreakerState::Open => "open",
        }
    }
}

#[derive(Debug)]
struct Slot {
    state: BreakerState,
    /// When an open breaker may admit its first half-open probe.
    open_until: Option<Instant>,
    /// Probes still admitted in the current half-open window.
    probes_left: u32,
    failure_ewma: f64,
    consecutive_failures: u32,
    /// Consecutive trips without an intervening close (drives the
    /// exponential open window).
    open_streak: u32,
    /// Consecutive respawns without the slot proving healthy (drives
    /// the exponential respawn backoff).
    respawn_streak: u32,
    /// Earliest instant the next respawn of this slot is allowed.
    respawn_not_before: Option<Instant>,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            state: BreakerState::Closed,
            open_until: None,
            probes_left: 0,
            failure_ewma: 0.0,
            consecutive_failures: 0,
            open_streak: 0,
            respawn_streak: 0,
            respawn_not_before: None,
        }
    }
}

/// Point-in-time view of one slot's breaker, for `/readyz` and the
/// `/metrics` exporter.
#[derive(Clone, Copy, Debug)]
pub struct BreakerSnapshot {
    pub state: BreakerState,
    /// Smoothed failure rate in `[0, 1]`.
    pub failure_ewma: f64,
    /// The backoff the *next* respawn of this slot must wait out,
    /// exported as `repro_shard_respawn_backoff_seconds`.
    pub respawn_backoff: Duration,
}

/// One breaker per shard slot, shared (`Arc`) between the router, the
/// batcher's heal pass, `/readyz` and the metrics exporter.  Slots are
/// independently locked; none of the operations are on the per-sample
/// hot path (they run per drained job, per failure, per health tick,
/// per scrape).
#[derive(Debug)]
pub struct BreakerSet {
    slots: Vec<Mutex<Slot>>,
    seed: u64,
}

pub(crate) fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Exponential-with-cap schedule shared by the open window and the
/// respawn backoff: `base * 2^(streak-1)`, saturating at `cap`.
pub(crate) fn backoff(base: Duration, cap: Duration, streak: u32) -> Duration {
    if streak == 0 {
        return Duration::ZERO;
    }
    let exp = streak.saturating_sub(1).min(30);
    base.checked_mul(1u32 << exp).map_or(cap, |d| d.min(cap))
}

impl BreakerSet {
    /// One closed breaker per slot.  `seed` drives the deterministic
    /// open-window jitter (the serving config seed, so a chaos run's
    /// breaker timing reproduces with the rest of the system).
    pub fn new(slots: usize, seed: u64) -> BreakerSet {
        BreakerSet {
            slots: (0..slots).map(|_| Mutex::new(Slot::new())).collect(),
            seed,
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    fn slot(&self, shard: usize) -> std::sync::MutexGuard<'_, Slot> {
        self.slots[shard].lock().expect("breaker state poisoned")
    }

    /// Deterministic jitter in `[-OPEN_JITTER, +OPEN_JITTER]` for slot
    /// `shard`'s `streak`-th trip.
    fn jitter(&self, shard: usize, streak: u32) -> f64 {
        let z = splitmix64(self.seed ^ ((shard as u64) << 32) ^ streak as u64);
        let u = (z >> 11) as f64 / (1u64 << 53) as f64;
        (2.0 * u - 1.0) * OPEN_JITTER
    }

    fn trip(&self, shard: usize, slot: &mut Slot, now: Instant) {
        slot.open_streak = slot.open_streak.saturating_add(1);
        let window = backoff(OPEN_BASE, OPEN_CAP, slot.open_streak);
        let jittered = window.mul_f64(1.0 + self.jitter(shard, slot.open_streak));
        slot.state = BreakerState::Open;
        slot.open_until = Some(now + jittered.min(OPEN_CAP));
        slot.probes_left = 0;
    }

    /// May traffic be routed to this shard right now?  Consults and
    /// *advances* the state machine: the call that finds an elapsed
    /// open window becomes the first half-open probe, and each
    /// half-open `true` spends one probe slot — so concurrent callers
    /// cannot all pile onto a recovering shard (the half-open probe
    /// race from the issue checklist).
    pub fn allow(&self, shard: usize, now: Instant) -> bool {
        let mut s = self.slot(shard);
        match s.state {
            BreakerState::Closed => true,
            BreakerState::Open => {
                if s.open_until.is_some_and(|t| now >= t) {
                    s.state = BreakerState::HalfOpen;
                    s.open_until = None;
                    s.probes_left = HALF_OPEN_PROBES.saturating_sub(1);
                    true
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => {
                if s.probes_left > 0 {
                    s.probes_left -= 1;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// A job on this shard completed cleanly.  Decays the failure
    /// EWMA; a half-open shard closes (and its open window resets) on
    /// its first success, and the slot's respawn streak is forgiven —
    /// it proved itself.
    pub fn record_success(&self, shard: usize) {
        let mut s = self.slot(shard);
        s.failure_ewma *= 1.0 - FAILURE_EWMA_ALPHA;
        s.consecutive_failures = 0;
        s.respawn_streak = 0;
        s.respawn_not_before = None;
        if s.state == BreakerState::HalfOpen {
            s.state = BreakerState::Closed;
            s.open_streak = 0;
            s.probes_left = 0;
        }
    }

    /// A job on this shard failed (pool submit/drain error, worker
    /// panic, or a drift detection from the fidelity monitor).  Trips
    /// the breaker when the EWMA or the streak crosses its threshold;
    /// a failed half-open probe reopens immediately with a doubled
    /// window.
    pub fn record_failure(&self, shard: usize, now: Instant) {
        let mut s = self.slot(shard);
        s.failure_ewma = s.failure_ewma * (1.0 - FAILURE_EWMA_ALPHA) + FAILURE_EWMA_ALPHA;
        s.consecutive_failures = s.consecutive_failures.saturating_add(1);
        match s.state {
            BreakerState::HalfOpen => self.trip(shard, &mut s, now),
            BreakerState::Closed
                if s.failure_ewma >= OPEN_FAILURE_THRESHOLD
                    || s.consecutive_failures >= OPEN_CONSECUTIVE_FAILURES =>
            {
                self.trip(shard, &mut s, now)
            }
            _ => {}
        }
    }

    /// Force the breaker open (shard poisoned: its pool is gone, no
    /// probabilistic judgement needed).
    pub fn force_open(&self, shard: usize, now: Instant) {
        let mut s = self.slot(shard);
        s.failure_ewma = 1.0;
        s.consecutive_failures = s.consecutive_failures.max(OPEN_CONSECUTIVE_FAILURES);
        self.trip(shard, &mut s, now);
    }

    /// The slot was respawned with a fresh pool: move to half-open
    /// probation — the new pool earns its way back to closed through
    /// successful probes rather than inheriting full traffic.
    pub fn on_respawn(&self, shard: usize) {
        let mut s = self.slot(shard);
        s.state = BreakerState::HalfOpen;
        s.open_until = None;
        s.probes_left = HALF_OPEN_PROBES;
        s.consecutive_failures = 0;
    }

    /// May the heal pass respawn this slot now?  The first respawn is
    /// always allowed; later ones wait out the exponential backoff
    /// recorded by [`BreakerSet::note_respawn`].
    pub fn respawn_allowed(&self, shard: usize, now: Instant) -> bool {
        self.slot(shard).respawn_not_before.is_none_or(|t| now >= t)
    }

    /// Record that the heal pass respawned this slot, pushing the next
    /// respawn out by the doubled backoff.
    pub fn note_respawn(&self, shard: usize, now: Instant) {
        let mut s = self.slot(shard);
        s.respawn_streak = s.respawn_streak.saturating_add(1);
        let delay = backoff(RESPAWN_BACKOFF_BASE, RESPAWN_BACKOFF_CAP, s.respawn_streak);
        s.respawn_not_before = Some(now + delay);
    }

    /// Current breaker position for one slot.
    pub fn state(&self, shard: usize) -> BreakerState {
        self.slot(shard).state
    }

    /// Point-in-time view of every slot, for `/readyz` and `/metrics`.
    pub fn snapshot(&self) -> Vec<BreakerSnapshot> {
        (0..self.slots.len())
            .map(|i| {
                let s = self.slot(i);
                BreakerSnapshot {
                    state: s.state,
                    failure_ewma: s.failure_ewma,
                    respawn_backoff: backoff(
                        RESPAWN_BACKOFF_BASE,
                        RESPAWN_BACKOFF_CAP,
                        s.respawn_streak,
                    ),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn closed_allows_and_single_failures_do_not_trip() {
        let b = BreakerSet::new(2, 1);
        let now = t0();
        assert!(b.allow(0, now));
        b.record_failure(0, now);
        b.record_success(0);
        b.record_failure(0, now);
        assert_eq!(b.state(0), BreakerState::Closed);
        assert!(b.allow(0, now), "isolated failures keep the breaker closed");
    }

    #[test]
    fn consecutive_failures_trip_then_recover_through_half_open() {
        let b = BreakerSet::new(1, 7);
        let now = t0();
        for _ in 0..OPEN_CONSECUTIVE_FAILURES {
            b.record_failure(0, now);
        }
        assert_eq!(b.state(0), BreakerState::Open);
        assert!(!b.allow(0, now), "open breaker sheds traffic");
        // The open window elapses: the next allow() is the probe.
        let later = now + 2 * OPEN_CAP;
        assert!(b.allow(0, later), "first post-window call is the probe");
        assert_eq!(b.state(0), BreakerState::HalfOpen);
        b.record_success(0);
        assert_eq!(b.state(0), BreakerState::Closed);
        assert!(b.allow(0, later));
    }

    #[test]
    fn half_open_probe_budget_bounds_the_race() {
        let b = BreakerSet::new(1, 7);
        let now = t0();
        b.force_open(0, now);
        let later = now + 2 * OPEN_CAP;
        let mut admitted = 0;
        for _ in 0..16 {
            if b.allow(0, later) {
                admitted += 1;
            }
        }
        assert_eq!(
            admitted, HALF_OPEN_PROBES as usize,
            "only the probe budget gets through while half-open"
        );
    }

    #[test]
    fn failed_probe_reopens_with_doubled_window() {
        let b = BreakerSet::new(1, 3);
        let mut now = t0();
        b.force_open(0, now);
        // First window: just past base (with jitter margin) is enough.
        now += OPEN_BASE.mul_f64(1.0 + OPEN_JITTER) + Duration::from_millis(1);
        assert!(b.allow(0, now), "window elapsed, probe admitted");
        b.record_failure(0, now);
        assert_eq!(b.state(0), BreakerState::Open);
        // Second window is doubled: base (even jittered) is not enough.
        let probe_at = now + OPEN_BASE.mul_f64(1.0 + OPEN_JITTER);
        assert!(!b.allow(0, probe_at), "doubled window still open");
        let probe_at = now + 2 * OPEN_CAP;
        assert!(b.allow(0, probe_at), "doubled window eventually elapses");
    }

    #[test]
    fn ewma_trip_threshold_matches_derivation() {
        // From a clean history, exactly OPEN_CONSECUTIVE_FAILURES
        // back-to-back failures cross OPEN_FAILURE_THRESHOLD.
        let mut ewma: f64 = 0.0;
        for _ in 0..OPEN_CONSECUTIVE_FAILURES {
            ewma = ewma * (1.0 - FAILURE_EWMA_ALPHA) + FAILURE_EWMA_ALPHA;
        }
        assert!(ewma > OPEN_FAILURE_THRESHOLD);
    }

    #[test]
    fn mixed_traffic_with_high_failure_rate_trips_via_ewma() {
        let b = BreakerSet::new(1, 11);
        let now = t0();
        // 2 failures : 1 success sustained — streak never reaches 3,
        // but the smoothed rate climbs past the threshold.
        for _ in 0..8 {
            b.record_failure(0, now);
            b.record_failure(0, now);
            b.record_success(0);
            if b.state(0) == BreakerState::Open {
                return;
            }
        }
        panic!("sustained 2/3 failure rate should trip the breaker");
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let a = BreakerSet::new(4, 99);
        let b = BreakerSet::new(4, 99);
        for shard in 0..4 {
            for streak in 1..8 {
                let ja = a.jitter(shard, streak);
                assert_eq!(ja, b.jitter(shard, streak), "same seed, same jitter");
                assert!(ja.abs() <= OPEN_JITTER, "jitter {ja} out of range");
            }
        }
        assert_ne!(a.jitter(0, 1), a.jitter(1, 1), "slots decorrelate");
    }

    #[test]
    fn open_window_is_monotone_in_the_streak_and_capped() {
        for streak in 1..32 {
            let w = backoff(OPEN_BASE, OPEN_CAP, streak);
            let w_next = backoff(OPEN_BASE, OPEN_CAP, streak + 1);
            assert!(w_next >= w);
            assert!(w <= OPEN_CAP);
        }
        assert_eq!(backoff(OPEN_BASE, OPEN_CAP, 31), OPEN_CAP);
        assert_eq!(backoff(OPEN_BASE, OPEN_CAP, 0), Duration::ZERO);
    }

    #[test]
    fn respawn_backoff_first_free_then_exponential_then_forgiven() {
        let b = BreakerSet::new(1, 5);
        let now = t0();
        assert!(b.respawn_allowed(0, now), "first respawn is free");
        b.note_respawn(0, now);
        assert!(
            !b.respawn_allowed(0, now + RESPAWN_BACKOFF_BASE / 2),
            "second respawn waits out the base backoff"
        );
        assert!(b.respawn_allowed(0, now + RESPAWN_BACKOFF_BASE));
        b.note_respawn(0, now);
        let snap = b.snapshot();
        assert_eq!(snap[0].respawn_backoff, 2 * RESPAWN_BACKOFF_BASE);
        assert!(!b.respawn_allowed(0, now + RESPAWN_BACKOFF_BASE));
        // A success forgives the streak entirely.
        b.record_success(0);
        assert!(b.respawn_allowed(0, now));
        assert_eq!(b.snapshot()[0].respawn_backoff, Duration::ZERO);
    }

    #[test]
    fn respawn_backoff_caps() {
        let b = BreakerSet::new(1, 5);
        let now = t0();
        for _ in 0..64 {
            b.note_respawn(0, now);
        }
        assert_eq!(b.snapshot()[0].respawn_backoff, RESPAWN_BACKOFF_CAP);
        assert!(b.respawn_allowed(0, now + RESPAWN_BACKOFF_CAP));
    }

    #[test]
    fn on_respawn_enters_probation_not_full_traffic() {
        let b = BreakerSet::new(1, 2);
        let now = t0();
        b.force_open(0, now);
        b.on_respawn(0);
        assert_eq!(b.state(0), BreakerState::HalfOpen);
        assert!(b.allow(0, now), "probation admits probes immediately");
        b.record_success(0);
        assert_eq!(b.state(0), BreakerState::Closed);
    }

    #[test]
    fn clock_never_runs_backwards_through_the_api() {
        // Callers pass `now` explicitly; feeding a *stale* now (e.g. a
        // scatter loop that cached the clock before a long drain) must
        // degrade gracefully: an open breaker just stays open.
        let b = BreakerSet::new(1, 13);
        let now = t0();
        let stale = now;
        b.force_open(0, now + Duration::from_secs(1));
        assert!(!b.allow(0, stale), "stale clock cannot reopen the breaker");
        assert_eq!(b.state(0), BreakerState::Open);
        b.record_failure(0, stale); // must not panic or reset the window
        assert_eq!(b.state(0), BreakerState::Open);
    }

    #[test]
    fn snapshot_and_codes_cover_every_state() {
        let b = BreakerSet::new(3, 1);
        let now = t0();
        b.force_open(1, now);
        b.force_open(2, now);
        b.on_respawn(2);
        let snap = b.snapshot();
        assert_eq!(snap.len(), 3);
        assert_eq!(snap[0].state.code(), 0);
        assert_eq!(snap[1].state.code(), 2);
        assert_eq!(snap[2].state.code(), 1);
        assert_eq!(snap[0].state.label(), "closed");
        assert_eq!(snap[1].state.label(), "open");
        assert_eq!(snap[2].state.label(), "half-open");
        assert!(snap[1].failure_ewma >= OPEN_FAILURE_THRESHOLD);
    }
}

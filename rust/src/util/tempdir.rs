//! Self-cleaning temporary directories for tests (tempfile is unavailable
//! offline).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temporary directory removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(prefix: &str) -> std::io::Result<TempDir> {
        let id = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "repro-{prefix}-{}-{}",
            std::process::id(),
            id
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn join(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans() {
        let p;
        {
            let d = TempDir::new("t").unwrap();
            p = d.path().to_path_buf();
            std::fs::write(d.join("f.txt"), "x").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new("u").unwrap();
        let b = TempDir::new("u").unwrap();
        assert_ne!(a.path(), b.path());
    }
}

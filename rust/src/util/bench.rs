//! Tiny criterion-style bench harness (criterion is unavailable offline).
//!
//! Warm-up, repeated timed batches, median/mean/min reporting, optional
//! throughput.  Used by every file under `benches/` (harness = false).

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.median),
            fmt_dur(self.min),
            self.iters,
        );
    }

    pub fn report_throughput(&self, elems_per_iter: f64, unit: &str) {
        let per_sec = elems_per_iter / self.mean.as_secs_f64();
        println!(
            "{:<44} {:>12} mean   {:>14.3e} {unit}/s",
            self.name,
            fmt_dur(self.mean),
            per_sec
        );
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Run `f` repeatedly: ~0.5 s warm-up then ~2 s of timed samples.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // Warm-up and batch-size estimation.
    let warm_start = Instant::now();
    let mut warm_iters = 0u64;
    while warm_start.elapsed() < Duration::from_millis(300) {
        f();
        warm_iters += 1;
    }
    let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
    let batch = ((0.05 / per_iter).ceil() as u64).max(1);
    let samples = 31usize;

    let mut times: Vec<Duration> = Vec::with_capacity(samples);
    let mut total_iters = 0u64;
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        times.push(t0.elapsed() / batch as u32);
        total_iters += batch;
    }
    times.sort();
    let mean = times.iter().sum::<Duration>() / samples as u32;
    BenchResult {
        name: name.to_string(),
        iters: total_iters,
        mean,
        median: times[samples / 2],
        min: times[0],
    }
}

/// Print the standard header for a bench binary.
pub fn header(group: &str) {
    println!("\n=== bench group: {group} ===");
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "name", "mean", "median", "min"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters > 0);
        assert!(r.min <= r.mean);
    }

    #[test]
    fn format_durations() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(500)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}

//! Tiny criterion-style bench harness (criterion is unavailable offline).
//!
//! Warm-up, repeated timed batches, median/mean/min reporting, optional
//! throughput.  Used by every file under `benches/` (harness = false).

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl BenchResult {
    /// JSON form for machine-readable baselines
    /// (`BENCH_<group>.json` emitted by bench binaries).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("name".to_string(), Json::Str(self.name.clone()));
        obj.insert("iters".to_string(), Json::Num(self.iters as f64));
        obj.insert("mean_ns".to_string(), Json::Num(self.mean.as_nanos() as f64));
        obj.insert(
            "median_ns".to_string(),
            Json::Num(self.median.as_nanos() as f64),
        );
        obj.insert("min_ns".to_string(), Json::Num(self.min.as_nanos() as f64));
        Json::Obj(obj)
    }

    pub fn report(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.median),
            fmt_dur(self.min),
            self.iters,
        );
    }

    pub fn report_throughput(&self, elems_per_iter: f64, unit: &str) {
        let per_sec = elems_per_iter / self.mean.as_secs_f64();
        println!(
            "{:<44} {:>12} mean   {:>14.3e} {unit}/s",
            self.name,
            fmt_dur(self.mean),
            per_sec
        );
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Run `f` repeatedly: ~0.5 s warm-up then ~2 s of timed samples.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // Warm-up and batch-size estimation.
    let warm_start = Instant::now();
    let mut warm_iters = 0u64;
    while warm_start.elapsed() < Duration::from_millis(300) {
        f();
        warm_iters += 1;
    }
    let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
    let batch = ((0.05 / per_iter).ceil() as u64).max(1);
    let samples = 31usize;

    let mut times: Vec<Duration> = Vec::with_capacity(samples);
    let mut total_iters = 0u64;
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        times.push(t0.elapsed() / batch as u32);
        total_iters += batch;
    }
    times.sort();
    let mean = times.iter().sum::<Duration>() / samples as u32;
    BenchResult {
        name: name.to_string(),
        iters: total_iters,
        mean,
        median: times[samples / 2],
        min: times[0],
    }
}

/// Write a machine-readable baseline for a bench group: the results
/// plus any derived scalar figures (speedups, throughput ratios).
pub fn write_json(
    path: &str,
    group: &str,
    results: &[BenchResult],
    derived: &[(&str, f64)],
) -> std::io::Result<()> {
    use crate::util::json::Json;
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("group".to_string(), Json::Str(group.to_string()));
    obj.insert(
        "results".to_string(),
        Json::Arr(results.iter().map(BenchResult::to_json).collect()),
    );
    for (name, value) in derived {
        obj.insert((*name).to_string(), Json::Num(*value));
    }
    std::fs::write(path, format!("{}\n", Json::Obj(obj)))
}

/// Print the standard header for a bench binary.
pub fn header(group: &str) {
    println!("\n=== bench group: {group} ===");
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "name", "mean", "median", "min"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters > 0);
        assert!(r.min <= r.mean);
    }

    #[test]
    fn bench_result_json_baseline_round_trips() {
        let r = BenchResult {
            name: "x".to_string(),
            iters: 10,
            mean: Duration::from_nanos(1500),
            median: Duration::from_nanos(1400),
            min: Duration::from_nanos(1000),
        };
        let dir = crate::util::tempdir::TempDir::new("bench-json").unwrap();
        let path = dir.path().join("BENCH_test.json");
        write_json(path.to_str().unwrap(), "test", &[r], &[("speedup", 2.5)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.get("group").and_then(|v| v.as_str()), Some("test"));
        assert_eq!(parsed.get("speedup").and_then(|v| v.as_f64()), Some(2.5));
        let results = parsed.get("results").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(results[0].get("mean_ns").and_then(|v| v.as_f64()), Some(1500.0));
    }

    #[test]
    fn format_durations() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(500)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}

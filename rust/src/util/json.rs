//! Minimal JSON parser/serializer (serde_json is unavailable offline).
//!
//! Supports the full JSON grammar we produce/consume: objects, arrays,
//! strings (with escapes), numbers, booleans, null.  Used for
//! `artifacts/manifest.json` and the trained-weight exports.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `obj["a"]["b"][2]`-style path access.
    pub fn path(&self, parts: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in parts {
            cur = cur.get(p)?;
        }
        Some(cur)
    }
}

pub fn parse(s: &str) -> Result<Json> {
    let bytes = s.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing garbage at byte {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    if b.len() - *pos >= lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(v)
    } else {
        bail!("invalid literal at byte {pos}");
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse::<f64>()?))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if *pos >= b.len() || b[*pos] != b'"' {
        bail!("expected string at byte {pos}");
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    bail!("dangling escape");
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    c => bail!("bad escape \\{}", c as char),
                }
                *pos += 1;
            }
            c => {
                // copy raw UTF-8 bytes
                let ch_len = utf8_len(c);
                out.push_str(std::str::from_utf8(&b[*pos..*pos + ch_len])?);
                *pos += ch_len;
            }
        }
    }
    bail!("unterminated string")
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => bail!("expected ',' or ']' at byte {pos}"),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            bail!("expected ':' at byte {pos}");
        }
        *pos += 1;
        map.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => bail!("expected ',' or '}}' at byte {pos}"),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"k":[1,2.5,"x",null,true]}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parses_real_manifest() {
        let manifest = std::fs::read_to_string(
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json"),
        );
        if let Ok(m) = manifest {
            let v = parse(&m).unwrap();
            assert!(v.path(&["artifacts", "train_step", "file"]).is_some());
        }
    }
}

//! In-tree utilities replacing crates unavailable on the offline build box:
//! [`rng`] (rand/rand_distr), [`json`] (serde_json), [`bench`] (criterion),
//! [`prop`] (proptest-style property loops), [`tempdir`] (tempfile).

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod tempdir;

//! Proptest-style property loops (proptest is unavailable offline).
//!
//! `forall(cases, seed, gen, check)` runs `check` on `cases` generated
//! inputs; on failure it reports the failing seed/iteration so the case
//! can be replayed deterministically.

use super::rng::Rng;

/// Run a property over `cases` generated inputs.  Panics (with the
/// reproducing iteration index) on the first violated property.
pub fn forall<T, G, C>(cases: usize, seed: u64, mut gen: G, mut check: C)
where
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for i in 0..cases {
        let mut rng = Rng::seed_from_u64(seed.wrapping_add(i as u64));
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed at case {i} (seed {seed}): {msg}\ninput: {input:?}"
            );
        }
    }
}

/// Generate a random f32 vector with entries in [-scale, scale].
pub fn vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len)
        .map(|_| rng.uniform_range(-scale as f64, scale as f64) as f32)
        .collect()
}

/// Generate a random ternary vector ({-1, 0, 1}).
pub fn vec_ternary(rng: &mut Rng, len: usize) -> Vec<i8> {
    (0..len).map(|_| rng.ternary()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(
            50,
            0,
            |r| vec_f32(r, 8, 2.0),
            |v| {
                if v.len() == 8 {
                    Ok(())
                } else {
                    Err("len".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        forall(10, 0, |r| r.int_range(0, 100), |&v| {
            if v < 1000 {
                Err("always fails".into())
            } else {
                Ok(())
            }
        });
    }
}

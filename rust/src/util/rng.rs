//! Deterministic PRNG (xoshiro256++) with the distributions the simulator
//! needs: uniform, Gaussian (Ziggurat-free Box–Muller), range sampling.
//!
//! Replaces `rand`/`rand_distr` (unavailable offline).  Seeded runs are
//! fully reproducible across platforms — important because EXPERIMENTS.md
//! records Monte-Carlo statistics.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically (SplitMix64-expanded).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            gauss_spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Fair coin.
    pub fn coin(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean/sigma.
    #[inline]
    pub fn normal(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.gaussian()
    }

    /// A ternary value in {-1, 0, +1} (uniform).
    pub fn ternary(&mut self) -> i8 {
        (self.next_u64() % 3) as i8 - 1
    }

    /// Fill with standard-normal f32s.
    pub fn normal_vec_f32(&mut self, n: usize, mean: f32, sigma: f32) -> Vec<f32> {
        (0..n)
            .map(|_| self.normal(mean as f64, sigma as f64) as f32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::seed_from_u64(4);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var {var}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seed_from_u64(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        // tails: ~0.27% outside 3 sigma
        let tails = xs.iter().filter(|x| x.abs() > 3.0).count() as f64 / n as f64;
        assert!((tails - 0.0027).abs() < 0.002, "tails {tails}");
    }

    #[test]
    fn int_range_inclusive_and_covering() {
        let mut r = Rng::seed_from_u64(6);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            let v = r.int_range(-2, 2);
            assert!((-2..=2).contains(&v));
            seen[(v + 2) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn ternary_covers_all() {
        let mut r = Rng::seed_from_u64(7);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[(r.ternary() + 1) as usize] += 1;
        }
        for c in counts {
            assert!(c > 800, "ternary imbalance: {counts:?}");
        }
    }
}

//! Bitplane scheduling with predictive early termination on one tile.
//!
//! Hardware model: each crossbar op processes one bitplane across all
//! rows in parallel (2 clock cycles).  Each row owns a Fig.-10 digital
//! terminator; a row that proves its output zero stops consuming cycles
//! (its comparator and recombination logic are gated off).  The *tile*
//! keeps issuing planes while any row is live — mirroring the per-element
//! cycle accounting of Fig. 9(c).
//!
//! # The zero-allocation batch-fused engine
//!
//! The original inner loop was allocation-bound: every request
//! materialized its full `Vec<Vec<i8>>` plane stack, every plane
//! `collect()`ed a fresh readout vector, and terminated rows still burned
//! a branch per plane.  The engine now runs out of a per-worker
//! [`ScratchArena`]: planes are streamed straight from the quantized
//! integers into a reusable scratch slice ([`crate::quant::plane_into`]),
//! readouts land in reusable buffers
//! ([`crate::coordinator::tile::Tile::execute_bitplane_rows_into`]), and
//! **live-row compaction** keeps a dense list of still-live logical rows
//! — on the digital model only those rows' comparators are evaluated, so
//! a terminated row costs zero work per plane instead of a branch.
//! Noisy/analog tiles keep full-width execution per plane (every
//! physical row exists electrically), so their RNG streams stay
//! plan- and termination-independent.
//!
//! [`schedule_batch`] additionally fuses a whole batch of same-partition
//! samples on one tile: quantizer construction, `subtile_rows` lookups
//! and the identity-row decision are hoisted out of the per-sample loop,
//! and on the digital path the batch runs *plane-major* (every sample's
//! plane `b` executes before any sample's plane `b-1`).  Noisy/analog
//! batches run sample-major so the tile's RNG stream is byte-identical
//! to submitting the same samples as individual jobs.

use crate::bitplane::early_term::{CycleStats, Decision, EarlyTerminator, ElementOutcome};
use crate::quant::{plane_into, Quantizer};

use super::plan::TilePlan;
use super::pool::TransformRequest;
use super::tile::Tile;

/// Result of one full vector transform on a tile.
#[derive(Debug, Clone)]
pub struct TransformOutcome {
    /// Post-threshold outputs, rescaled to input units.
    pub values: Vec<f32>,
    /// Per-element cycle statistics (merged into pool metrics).
    pub stats: CycleStats,
    /// Bitplane operations the tile actually issued (= max row cycles).
    pub planes_issued: u32,
    /// Sum over rows of executed row-cycles (the energy-relevant count).
    pub row_cycles: u64,
}

/// Result of one batched job: a whole batch of same-partition samples
/// executed on one tile via [`schedule_batch`].
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    /// Per-sample outputs at the plan's width, in request order.
    pub values: Vec<Vec<f32>>,
    /// Cycle statistics merged over every (sample, block) element.
    pub stats: CycleStats,
    /// Bitplane operations issued across the whole batch.
    pub planes_issued: u32,
    /// Row-cycles executed across the whole batch.
    pub row_cycles: u64,
    /// Per-sample engine counters, in request order.  The plane-major
    /// digital path interleaves samples, so these are *attributed*, not
    /// measured sequentially: each plane a sample's live rows execute is
    /// billed to that sample.  Sums equal the aggregate fields above —
    /// the invariant the drain path relies on to reconstruct per-slice
    /// trace spans out of a fused job.
    pub per_sample: Vec<SampleStats>,
}

/// Engine counters attributed to one sample of a batched job (the
/// per-slice execute payload the shard router reports at drain).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SampleStats {
    /// Bitplane operations issued for this sample's blocks.
    pub planes_issued: u32,
    /// Row-cycles this sample's live rows executed.
    pub row_cycles: u64,
    /// Output elements this sample produced.
    pub elements: u64,
    /// Elements that resolved before their final bitplane.
    pub terminated_early: u64,
}

/// Reusable per-worker scratch for the bitplane engine: every buffer the
/// plane loop touches, allocated once and recycled across jobs, so the
/// steady-state scheduling loop performs **no heap allocation** — `clear`
/// + `push`/`extend` retain capacity, and nothing inside the plane loop
/// constructs a `Vec`.
///
/// Per-element buffers are laid out flat with a stride of the block
/// width, so one arena serves a whole batch of samples at once (the
/// plane-major digital path of [`schedule_batch`]).
#[derive(Debug, Default)]
pub struct ScratchArena {
    /// Quantized integers, one block-width segment per sample.
    q: Vec<i32>,
    /// Quantization scale actually used, per sample.
    scales: Vec<f32>,
    /// Zero-padded plane streamed into the tile (tile width).
    plane: Vec<i8>,
    /// Readout bits of one plane's live rows.
    obits: Vec<i8>,
    /// Early-termination state per logical element.
    terminators: Vec<EarlyTerminator>,
    /// Dense live lists (physical tile row + logical element index),
    /// segmented per sample; compacted in place as rows terminate.
    live_rows: Vec<usize>,
    live_idx: Vec<usize>,
    /// Live-segment length per sample.
    live_len: Vec<usize>,
    /// Recombined value in comparator units, per element.
    done_value: Vec<i64>,
    /// Cycles consumed / terminated-early flag, per element.
    cycles: Vec<u32>,
    terminated: Vec<bool>,
}

impl ScratchArena {
    pub fn new() -> ScratchArena {
        ScratchArena::default()
    }

    /// Reset every per-element buffer, retaining capacity.
    fn reset(&mut self, tile_n: usize) {
        self.q.clear();
        self.scales.clear();
        self.terminators.clear();
        self.live_rows.clear();
        self.live_idx.clear();
        self.live_len.clear();
        self.done_value.clear();
        self.cycles.clear();
        self.terminated.clear();
        self.plane.clear();
        self.plane.resize(tile_n, 0);
        self.obits.clear();
        self.obits.resize(tile_n, 0);
    }

    /// Append one sample's per-element state for a `b`-wide block whose
    /// outputs live on `rows`.  Returns the element base index of the
    /// segment.  `fast_zero` marks the digital all-zero fast path: the
    /// segment starts with no live rows and its stats pre-recorded as
    /// one-cycle terminations.
    fn push_segment(
        &mut self,
        bits: u32,
        thresholds: &[f64],
        rows: &[usize],
        fast_zero: bool,
    ) -> usize {
        let b = rows.len();
        let base = self.done_value.len();
        for (i, &r) in rows.iter().enumerate() {
            self.live_rows.push(r);
            self.live_idx.push(i);
            self.done_value.push(0);
            if fast_zero {
                // Terminator state is never consulted for a retired
                // segment; push a placeholder to keep the flat stride.
                self.terminators.push(EarlyTerminator::new(bits, 0.0));
                self.cycles.push(1);
                self.terminated.push(true);
            } else {
                self.terminators.push(EarlyTerminator::new(bits, thresholds[i]));
                self.cycles.push(0);
                self.terminated.push(false);
            }
        }
        self.live_len.push(if fast_zero { 0 } else { b });
        base
    }
}

/// Quantize `x`, stream its bitplanes MSB-first through `tile`, apply
/// per-row early termination against `thresholds_units` (comparator
/// units), and recombine — for a *full-width* block (`x.len() ==
/// tile.n()`).  Thin wrapper over [`schedule_block`] with the identity
/// row map.
///
/// `thresholds_units[i]` is the |T| of output element `i` divided by the
/// input quantization scale and basis norm (see
/// [`crate::nn::BwhtLayer::thresholds_units`]).
///
/// `scale` pins the quantization scale; `None` quantizes against this
/// tile slice's own amax (the raw-transform serving default).  A caller
/// splitting one logical tensor across tiles passes the tensor's global
/// scale so every slice reproduces the whole-tensor quantization — the
/// seam that makes the pooled executors bit-identical to
/// [`crate::nn::Backend::Quantized`].
pub fn schedule_transform(
    tile: &mut Tile,
    x: &[f32],
    bits: u32,
    thresholds_units: &[f64],
    scale: Option<f32>,
) -> TransformOutcome {
    let n = tile.n();
    assert_eq!(x.len(), n);
    let rows = crate::coordinator::plan::subtile_rows(n, n);
    schedule_block(tile, x, bits, thresholds_units, scale, &rows)
}

/// Schedule one logical block of width `b = x.len() <= tile.n()` on the
/// tile, reading the `b` outputs off the physical rows listed in `rows`
/// (see [`crate::coordinator::plan::subtile_rows`]; identity when the
/// block fills the tile).
///
/// Sub-tile blocks stream zero-padded bitplanes — the tile's unused
/// columns carry 0 and contribute nothing to any PSUM, so by the
/// Sylvester structure the selected rows compute the exact `b`-point
/// sequency transform.  Masked rows have no early-termination counters:
/// `row_cycles`, per-element stats and the termination bookkeeping all
/// run over the `b` logical rows only, keeping cycle/energy accounting
/// honest about the work a stitched sub-array would actually do.
///
/// This is the compatibility entry (it builds a fresh [`ScratchArena`]
/// per call); the pool workers run [`schedule_batch`] with a long-lived
/// arena instead.
pub fn schedule_block(
    tile: &mut Tile,
    x: &[f32],
    bits: u32,
    thresholds_units: &[f64],
    scale: Option<f32>,
    rows: &[usize],
) -> TransformOutcome {
    let identity = x.len() == tile.n() && rows.iter().enumerate().all(|(i, &r)| i == r);
    let mut arena = ScratchArena::new();
    let mut values = vec![0.0f32; x.len()];
    let mut stats = CycleStats::new(bits);
    let (planes_issued, row_cycles) = run_block(
        tile,
        x,
        bits,
        thresholds_units,
        scale,
        rows,
        identity,
        &mut arena,
        &mut values,
        &mut stats,
    );
    TransformOutcome {
        values,
        stats,
        planes_issued,
        row_cycles,
    }
}

/// Execute a whole batch of same-partition samples on one tile, reusing
/// `arena` across samples and hoisting quantizer construction, row-map
/// lookups and the identity-row decision out of the per-sample loop.
///
/// * **Digital** tiles run each block *plane-major* across the batch
///   with live-row compaction — bit-identical to scheduling every sample
///   as its own job (each (sample, plane) execution is independent on
///   the golden model).
/// * **Noisy/analog** tiles run sample-major, block order within each
///   sample, exactly the order a sequence of per-sample jobs would
///   execute — so the tile's RNG stream is byte-identical to the
///   unbatched path (pinned by `tests/exec_equivalence.rs`).
///
/// Every request must be `plan.width()` wide with matching thresholds;
/// the pool validates at the submission boundary.
pub fn schedule_batch(
    tile: &mut Tile,
    plan: &TilePlan,
    reqs: &[TransformRequest],
    bits: u32,
    arena: &mut ScratchArena,
) -> BatchOutcome {
    let width = plan.width();
    assert_eq!(plan.tile_n(), tile.n(), "plan resolved for another tile");
    for req in reqs {
        assert_eq!(req.x.len(), width, "request width must match the plan");
        assert_eq!(req.thresholds_units.len(), width);
    }
    let mut values: Vec<Vec<f32>> = reqs.iter().map(|_| vec![0.0f32; width]).collect();
    let mut stats = CycleStats::new(bits);
    let mut per_sample = vec![SampleStats::default(); reqs.len()];

    if tile.is_digital() {
        for slot in plan.slots() {
            run_slot_plane_major(
                tile,
                slot,
                reqs,
                bits,
                arena,
                &mut values,
                &mut stats,
                &mut per_sample,
            );
        }
    } else {
        // Sample-major: the exact execution order of per-sample jobs,
        // so noise streams are independent of batching.
        for (s, req) in reqs.iter().enumerate() {
            let (elements0, terminated0) = (stats.total_elements, stats.terminated_early);
            for slot in plan.slots() {
                let lo = slot.offset;
                let hi = lo + slot.width;
                let (p, rc) = run_block(
                    tile,
                    &req.x[lo..hi],
                    bits,
                    &req.thresholds_units[lo..hi],
                    req.scale,
                    &slot.rows,
                    slot.identity,
                    arena,
                    &mut values[s][lo..hi],
                    &mut stats,
                );
                per_sample[s].planes_issued += p;
                per_sample[s].row_cycles += rc;
            }
            per_sample[s].elements = stats.total_elements - elements0;
            per_sample[s].terminated_early = stats.terminated_early - terminated0;
        }
    }

    let planes_issued = per_sample.iter().map(|s| s.planes_issued).sum();
    let row_cycles = per_sample.iter().map(|s| s.row_cycles).sum();
    BatchOutcome {
        values,
        stats,
        planes_issued,
        row_cycles,
        per_sample,
    }
}

/// One block of one sample through the zero-allocation engine.  Writes
/// the `b` outputs into `out`, records per-element stats, and returns
/// `(planes_issued, row_cycles)`.
#[allow(clippy::too_many_arguments)]
fn run_block(
    tile: &mut Tile,
    x: &[f32],
    bits: u32,
    thresholds_units: &[f64],
    scale: Option<f32>,
    rows: &[usize],
    identity: bool,
    arena: &mut ScratchArena,
    out: &mut [f32],
    stats: &mut CycleStats,
) -> (u32, u64) {
    let n = tile.n();
    let b = x.len();
    assert!(b <= n, "block of width {b} exceeds the {n}-wide tile");
    assert_eq!(thresholds_units.len(), b);
    assert_eq!(rows.len(), b, "one output row per logical element");
    assert_eq!(out.len(), b);
    let quantizer = Quantizer::new(bits);
    let scale = scale.unwrap_or_else(|| quantizer.scale_for(x));
    arena.reset(n);
    quantizer.quantize_with_scale_into(x, scale, &mut arena.q);

    // DAC-free input gating: a block that quantizes to all zeros has an
    // all-zero plane stream, so on the digital golden model every
    // comparator reads 0 forever and the output is exactly zero whatever
    // the thresholds.  The input encoder sees the full bit pattern up
    // front, so the block retires after a single plane instead of
    // streaming `bits` silent cycles — the zero-vector serving fast
    // path.  Digital tiles only: noisy/analog backends flip comparators
    // on zero PSUMs and must keep consuming their RNG stream.
    if tile.is_digital() && arena.q.iter().all(|&v| v == 0) {
        let outcome = ElementOutcome {
            cycles: 1,
            terminated: true,
            value_units: 0,
        };
        for _ in 0..b {
            stats.record(&outcome);
        }
        out.fill(0.0);
        return (1, b as u64);
    }

    arena.push_segment(bits, thresholds_units, rows, false);
    let mut planes_issued = 0u32;
    let mut row_cycles = 0u64;
    for bit in (0..bits).rev() {
        if arena.live_len[0] == 0 {
            break;
        }
        planes_issued += 1;
        row_cycles += step_plane(tile, 0, b, bit, thresholds_units, 0, identity, arena);
    }
    for i in 0..b {
        out[i] = arena.done_value[i] as f32 * scale;
        stats.record(&ElementOutcome {
            cycles: arena.cycles[i],
            terminated: arena.terminated[i],
            value_units: arena.done_value[i],
        });
    }
    (planes_issued, row_cycles)
}

/// Execute one plane for one sample's block segment (`seg = sample * b`)
/// and advance its terminators, compacting the live list in place.
/// Returns the row-cycles consumed (= live rows entering the plane).
#[allow(clippy::too_many_arguments)]
fn step_plane(
    tile: &mut Tile,
    sample: usize,
    b: usize,
    bit: u32,
    thresholds_units: &[f64],
    lo: usize,
    identity: bool,
    arena: &mut ScratchArena,
) -> u64 {
    let seg = sample * b;
    let live = arena.live_len[sample];
    debug_assert!(live > 0);
    plane_into(&arena.q[seg..seg + b], bit, &mut arena.plane[..b]);
    if identity && live == b {
        // Full-width block with the identity row map and nothing
        // terminated yet: direct readout, no row indirection.  The live
        // list is still in identity order, so obits[k] is live slot k.
        tile.execute_bitplane_into(&arena.plane, &mut arena.obits);
    } else {
        let rows_slice = &arena.live_rows[seg..seg + live];
        let obits_slice = &mut arena.obits[..live];
        tile.execute_bitplane_rows_into(&arena.plane, rows_slice, obits_slice);
    }
    let mut write = 0usize;
    for k in 0..live {
        let i = arena.live_idx[seg + k];
        let e = seg + i;
        arena.cycles[e] += 1;
        match arena.terminators[e].step(arena.obits[k]) {
            Decision::Continue => {
                arena.live_rows[seg + write] = arena.live_rows[seg + k];
                arena.live_idx[seg + write] = i;
                write += 1;
            }
            Decision::TerminateZero => {
                arena.terminated[e] = true;
            }
            Decision::Complete => {
                let v = arena.terminators[e].running();
                arena.done_value[e] = if (v.unsigned_abs() as f64) <= thresholds_units[lo + i] {
                    0
                } else {
                    v
                };
            }
        }
    }
    arena.live_len[sample] = write;
    live as u64
}

/// The digital plane-major engine for one block slot across the whole
/// batch: every sample's plane `bit` executes before any sample's next
/// plane.  Per-sample live lists are flat segments of the arena with a
/// stride of the block width, compacted in place as rows terminate.
/// Every plane/row-cycle is billed to the sample whose live rows
/// executed it (`per_sample`), so a fused job's counters decompose
/// exactly back into its constituent samples.
#[allow(clippy::too_many_arguments)]
fn run_slot_plane_major(
    tile: &mut Tile,
    slot: &crate::coordinator::plan::BlockSlot,
    reqs: &[TransformRequest],
    bits: u32,
    arena: &mut ScratchArena,
    values: &mut [Vec<f32>],
    stats: &mut CycleStats,
    per_sample: &mut [SampleStats],
) {
    let n = tile.n();
    let b = slot.width;
    let lo = slot.offset;
    let quantizer = Quantizer::new(bits);
    arena.reset(n);

    // Per-sample setup, hoisted quantizer + row map.
    for (s, req) in reqs.iter().enumerate() {
        let x = &req.x[lo..lo + b];
        let scale = req.scale.unwrap_or_else(|| quantizer.scale_for(x));
        arena.scales.push(scale);
        let qstart = arena.q.len();
        quantizer.quantize_with_scale_into(x, scale, &mut arena.q);
        let fast_zero = arena.q[qstart..].iter().all(|&v| v == 0);
        let thresholds = &req.thresholds_units[lo..lo + b];
        arena.push_segment(bits, thresholds, &slot.rows, fast_zero);
        if fast_zero {
            per_sample[s].planes_issued += 1;
            per_sample[s].row_cycles += b as u64;
        }
    }

    // Plane-major across the batch.
    for bit in (0..bits).rev() {
        let mut any_live = false;
        for (s, req) in reqs.iter().enumerate() {
            if arena.live_len[s] == 0 {
                continue;
            }
            any_live = true;
            per_sample[s].planes_issued += 1;
            per_sample[s].row_cycles += step_plane(
                tile,
                s,
                b,
                bit,
                &req.thresholds_units,
                lo,
                slot.identity,
                arena,
            );
        }
        if !any_live {
            break;
        }
    }

    // Recombine + record.
    for (s, sample_values) in values.iter_mut().enumerate() {
        let seg = s * b;
        let scale = arena.scales[s];
        let out = &mut sample_values[lo..lo + b];
        for i in 0..b {
            let e = seg + i;
            out[i] = arena.done_value[e] as f32 * scale;
            stats.record(&ElementOutcome {
                cycles: arena.cycles[e],
                terminated: arena.terminated[e],
                value_units: arena.done_value[e],
            });
            per_sample[s].elements += 1;
            per_sample[s].terminated_early += u64::from(arena.terminated[e]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitplane::QuantBwht;
    use crate::coordinator::tile::TileKind;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut r = crate::util::rng::Rng::seed_from_u64(seed);
        (0..n).map(|_| r.uniform_range(-1.5, 1.5) as f32).collect()
    }

    #[test]
    fn zero_thresholds_match_digital_golden_model() {
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let x = sample(16, 1);
        let out = schedule_transform(&mut tile, &x, 8, &vec![0.0; 16], None);
        let golden = QuantBwht::new(16, 128, 8).transform(&x);
        assert_eq!(out.values, golden, "ET with T=0 must be lossless");
        assert_eq!(out.planes_issued, 8);
    }

    #[test]
    fn high_thresholds_save_cycles_and_zero_outputs() {
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let x = sample(16, 2);
        let out = schedule_transform(&mut tile, &x, 8, &vec![1e9; 16], None);
        assert!(out.values.iter().all(|&v| v == 0.0));
        assert_eq!(out.planes_issued, 1, "everything terminates after MSB");
        assert!(out.stats.average_cycles() < 1.5);
    }

    #[test]
    fn termination_is_sound_vs_full_run() {
        // With ET at threshold T, outputs must equal the full (no-ET)
        // recombination passed through the same |y|<=T zeroing.
        let x = sample(16, 3);
        let t_units = 40.0;
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let et = schedule_transform(&mut tile, &x, 8, &vec![t_units; 16], None);
        let mut tile2 = Tile::new(16, &TileKind::Digital, 0);
        let full = schedule_transform(&mut tile2, &x, 8, &vec![0.0; 16], None);
        let q = Quantizer::new(8).quantize(&x);
        for i in 0..16 {
            let full_units = (full.values[i] / q.scale).round() as i64;
            let want = if (full_units.unsigned_abs() as f64) <= t_units {
                0.0
            } else {
                full.values[i]
            };
            assert_eq!(et.values[i], want, "element {i}");
        }
    }

    #[test]
    fn row_cycles_bounded_by_planes_times_rows() {
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let x = sample(16, 4);
        let out = schedule_transform(&mut tile, &x, 8, &vec![100.0; 16], None);
        assert!(out.row_cycles <= 8 * 16);
        assert!(out.row_cycles >= 16, "every row runs at least one cycle");
        assert_eq!(out.stats.total_elements, 16);
    }

    #[test]
    fn zero_block_retires_after_one_plane() {
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let out = schedule_transform(&mut tile, &[0.0; 16], 8, &[0.0; 16], None);
        assert!(out.values.iter().all(|&v| v == 0.0));
        assert_eq!(out.planes_issued, 1);
        assert_eq!(out.row_cycles, 16);
        assert_eq!(out.stats.terminated_early, 16);
        assert!((out.stats.average_cycles() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn one_bit_input_single_plane() {
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let x = sample(16, 5);
        let out = schedule_transform(&mut tile, &x, 1, &vec![0.0; 16], None);
        assert_eq!(out.planes_issued, 1);
    }

    #[test]
    fn sub_tile_block_matches_small_golden_model() {
        // A 4-point block on a 16-wide tile: bit-identical to the
        // 4-point golden model, accounted over 4 logical rows only.
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let x = sample(4, 8);
        let rows = crate::coordinator::plan::subtile_rows(16, 4);
        let out = schedule_block(&mut tile, &x, 8, &vec![0.0; 4], None, &rows);
        let golden = QuantBwht::new(4, 4, 8).transform(&x);
        assert_eq!(out.values, golden);
        assert_eq!(out.stats.total_elements, 4);
        assert_eq!(out.row_cycles, 4 * 8, "T=0: all planes on 4 rows");
        assert_eq!(out.planes_issued, 8);
    }

    #[test]
    fn sub_tile_early_termination_bills_logical_rows_only() {
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let x = sample(8, 9);
        let rows = crate::coordinator::plan::subtile_rows(16, 8);
        let out = schedule_block(&mut tile, &x, 8, &vec![1e9; 8], None, &rows);
        assert!(out.values.iter().all(|&v| v == 0.0));
        assert_eq!(out.planes_issued, 1, "everything terminates after MSB");
        assert_eq!(out.row_cycles, 8, "masked rows must not be billed");
        assert_eq!(out.stats.total_elements, 8);
        assert_eq!(out.stats.terminated_early, 8);
    }

    #[test]
    fn sub_tile_zero_block_fast_path() {
        let mut tile = Tile::new(32, &TileKind::Digital, 0);
        let rows = crate::coordinator::plan::subtile_rows(32, 4);
        let out = schedule_block(&mut tile, &[0.0; 4], 8, &[0.0; 4], None, &rows);
        assert_eq!(out.values, vec![0.0; 4]);
        assert_eq!(out.planes_issued, 1);
        assert_eq!(out.row_cycles, 4);
    }

    /// The per-sample reference for `schedule_batch`: every (sample,
    /// block) scheduled as its own `schedule_block` call.
    fn per_sample_reference(
        tile: &mut Tile,
        plan: &TilePlan,
        reqs: &[TransformRequest],
        bits: u32,
    ) -> BatchOutcome {
        let mut values = Vec::with_capacity(reqs.len());
        let mut stats = CycleStats::new(bits);
        let mut planes_issued = 0u32;
        let mut row_cycles = 0u64;
        let mut per_sample = Vec::with_capacity(reqs.len());
        for req in reqs {
            let mut v = vec![0.0f32; plan.width()];
            let mut sample = SampleStats::default();
            for slot in plan.slots() {
                let lo = slot.offset;
                let hi = lo + slot.width;
                let out = schedule_block(
                    tile,
                    &req.x[lo..hi],
                    bits,
                    &req.thresholds_units[lo..hi],
                    req.scale,
                    &slot.rows,
                );
                v[lo..hi].copy_from_slice(&out.values);
                stats.merge(&out.stats);
                planes_issued += out.planes_issued;
                row_cycles += out.row_cycles;
                sample.planes_issued += out.planes_issued;
                sample.row_cycles += out.row_cycles;
                sample.elements += out.stats.total_elements;
                sample.terminated_early += out.stats.terminated_early;
            }
            values.push(v);
            per_sample.push(sample);
        }
        BatchOutcome {
            values,
            stats,
            planes_issued,
            row_cycles,
            per_sample,
        }
    }

    fn batch_reqs(width: usize, samples: usize, seed: u64, thresh: f64) -> Vec<TransformRequest> {
        (0..samples)
            .map(|s| {
                let x = if s == 1 {
                    vec![0.0; width] // exercise the zero fast path mid-batch
                } else {
                    sample(width, seed + s as u64)
                };
                TransformRequest {
                    thresholds_units: vec![thresh; width],
                    scale: None,
                    deadline: None,
                    x,
                }
            })
            .collect()
    }

    #[test]
    fn batch_matches_per_sample_loop_on_digital() {
        for &(tile_n, blocks, bits, thresh) in &[
            (16usize, &[16usize][..], 8u32, 0.0f64),
            (16, &[16, 4][..], 8, 0.0),
            (32, &[32, 8, 4][..], 4, 20.0),
            (16, &[16][..], 1, 0.0),
        ] {
            let plan = TilePlan::new(tile_n, blocks).unwrap();
            let reqs = batch_reqs(plan.width(), 4, 77 + tile_n as u64, thresh);
            let mut t1 = Tile::new(tile_n, &TileKind::Digital, 0);
            let want = per_sample_reference(&mut t1, &plan, &reqs, bits);
            let mut t2 = Tile::new(tile_n, &TileKind::Digital, 0);
            let mut arena = ScratchArena::new();
            let got = schedule_batch(&mut t2, &plan, &reqs, bits, &mut arena);
            assert_eq!(got.values, want.values, "tile {tile_n} blocks {blocks:?}");
            assert_eq!(got.planes_issued, want.planes_issued);
            assert_eq!(got.row_cycles, want.row_cycles);
            assert_eq!(got.stats.total_elements, want.stats.total_elements);
            assert_eq!(got.stats.terminated_early, want.stats.terminated_early);
            assert_eq!(got.stats.histogram, want.stats.histogram);
            // Plane-major attribution decomposes exactly into the
            // counters each sample would report as its own job.
            assert_eq!(got.per_sample, want.per_sample, "tile {tile_n} {blocks:?}");
        }
    }

    #[test]
    fn per_sample_stats_sum_to_the_aggregates() {
        let plan = TilePlan::new(16, &[16, 4]).unwrap();
        let reqs = batch_reqs(plan.width(), 5, 1234, 15.0);
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let mut arena = ScratchArena::new();
        let out = schedule_batch(&mut tile, &plan, &reqs, 8, &mut arena);
        assert_eq!(out.per_sample.len(), reqs.len());
        assert_eq!(
            out.per_sample.iter().map(|s| s.planes_issued).sum::<u32>(),
            out.planes_issued
        );
        assert_eq!(
            out.per_sample.iter().map(|s| s.row_cycles).sum::<u64>(),
            out.row_cycles
        );
        assert_eq!(
            out.per_sample.iter().map(|s| s.elements).sum::<u64>(),
            out.stats.total_elements
        );
        assert_eq!(
            out.per_sample.iter().map(|s| s.terminated_early).sum::<u64>(),
            out.stats.terminated_early
        );
        for (s, sample) in out.per_sample.iter().enumerate() {
            assert_eq!(sample.elements, plan.width() as u64, "sample {s}");
        }
    }

    #[test]
    fn batch_arena_is_reusable_across_jobs() {
        let plan = TilePlan::new(16, &[16, 4]).unwrap();
        let mut arena = ScratchArena::new();
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        for round in 0..3u64 {
            let reqs = batch_reqs(plan.width(), 3, 500 + round, 10.0);
            let mut fresh = Tile::new(16, &TileKind::Digital, 0);
            let want = per_sample_reference(&mut fresh, &plan, &reqs, 8);
            let got = schedule_batch(&mut tile, &plan, &reqs, 8, &mut arena);
            assert_eq!(got.values, want.values, "round {round}");
        }
    }

    #[test]
    fn noisy_batch_keeps_rng_stream_alignment() {
        // A noisy tile that served a batched job must have consumed its
        // RNG stream byte-identically to one that served the same
        // samples as individual per-sample jobs: outputs agree AND the
        // tiles stay in lockstep afterwards.
        let kind = TileKind::Noisy { sigma_ant: 0.4 };
        let plan = TilePlan::new(16, &[16, 4]).unwrap();
        let reqs = batch_reqs(plan.width(), 3, 900, 5.0);
        let mut a = Tile::new(16, &kind, 9);
        let mut b = Tile::new(16, &kind, 9);
        let mut arena = ScratchArena::new();
        let batched = schedule_batch(&mut a, &plan, &reqs, 8, &mut arena);
        let unbatched = per_sample_reference(&mut b, &plan, &reqs, 8);
        assert_eq!(batched.values, unbatched.values, "noisy outputs");
        assert_eq!(batched.planes_issued, unbatched.planes_issued);
        assert_eq!(batched.per_sample, unbatched.per_sample);
        let probe = vec![1i8; 16];
        assert_eq!(
            a.execute_bitplane(&probe),
            b.execute_bitplane(&probe),
            "RNG streams diverged"
        );
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let plan = TilePlan::new(16, &[16]).unwrap();
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let mut arena = ScratchArena::new();
        let out = schedule_batch(&mut tile, &plan, &[], 8, &mut arena);
        assert!(out.values.is_empty());
        assert_eq!(out.planes_issued, 0);
        assert_eq!(out.stats.total_elements, 0);
    }
}

//! Bitplane scheduling with predictive early termination on one tile.
//!
//! Hardware model: each crossbar op processes one bitplane across all
//! rows in parallel (2 clock cycles).  Each row owns a Fig.-10 digital
//! terminator; a row that proves its output zero stops consuming cycles
//! (its comparator and recombination logic are gated off).  The *tile*
//! keeps issuing planes while any row is live — mirroring the per-element
//! cycle accounting of Fig. 9(c).

use crate::bitplane::early_term::{CycleStats, Decision, EarlyTerminator, ElementOutcome};
use crate::quant::Quantizer;

use super::tile::Tile;

/// Result of one full vector transform on a tile.
#[derive(Debug, Clone)]
pub struct TransformOutcome {
    /// Post-threshold outputs, rescaled to input units.
    pub values: Vec<f32>,
    /// Per-element cycle statistics (merged into pool metrics).
    pub stats: CycleStats,
    /// Bitplane operations the tile actually issued (= max row cycles).
    pub planes_issued: u32,
    /// Sum over rows of executed row-cycles (the energy-relevant count).
    pub row_cycles: u64,
}

/// Quantize `x`, stream its bitplanes MSB-first through `tile`, apply
/// per-row early termination against `thresholds_units` (comparator
/// units), and recombine — for a *full-width* block (`x.len() ==
/// tile.n()`).  Thin wrapper over [`schedule_block`] with the identity
/// row map.
///
/// `thresholds_units[i]` is the |T| of output element `i` divided by the
/// input quantization scale and basis norm (see
/// [`crate::nn::BwhtLayer::thresholds_units`]).
///
/// `scale` pins the quantization scale; `None` quantizes against this
/// tile slice's own amax (the raw-transform serving default).  A caller
/// splitting one logical tensor across tiles passes the tensor's global
/// scale so every slice reproduces the whole-tensor quantization — the
/// seam that makes the pooled executors bit-identical to
/// [`crate::nn::Backend::Quantized`].
pub fn schedule_transform(
    tile: &mut Tile,
    x: &[f32],
    bits: u32,
    thresholds_units: &[f64],
    scale: Option<f32>,
) -> TransformOutcome {
    let n = tile.n();
    assert_eq!(x.len(), n);
    let rows = crate::coordinator::plan::subtile_rows(n, n);
    schedule_block(tile, x, bits, thresholds_units, scale, &rows)
}

/// Schedule one logical block of width `b = x.len() <= tile.n()` on the
/// tile, reading the `b` outputs off the physical rows listed in `rows`
/// (see [`crate::coordinator::plan::subtile_rows`]; identity when the
/// block fills the tile).
///
/// Sub-tile blocks stream zero-padded bitplanes — the tile's unused
/// columns carry 0 and contribute nothing to any PSUM, so by the
/// Sylvester structure the selected rows compute the exact `b`-point
/// sequency transform.  Masked rows have no early-termination counters:
/// `row_cycles`, per-element stats and the termination bookkeeping all
/// run over the `b` logical rows only, keeping cycle/energy accounting
/// honest about the work a stitched sub-array would actually do.
pub fn schedule_block(
    tile: &mut Tile,
    x: &[f32],
    bits: u32,
    thresholds_units: &[f64],
    scale: Option<f32>,
    rows: &[usize],
) -> TransformOutcome {
    let n = tile.n();
    let b = x.len();
    assert!(b <= n, "block of width {b} exceeds the {n}-wide tile");
    assert_eq!(thresholds_units.len(), b);
    assert_eq!(rows.len(), b, "one output row per logical element");
    let quantizer = Quantizer::new(bits);
    let q = match scale {
        Some(s) => quantizer.quantize_with_scale(x, s),
        None => quantizer.quantize(x),
    };

    // DAC-free input gating: a block that quantizes to all zeros has an
    // all-zero plane stream, so on the digital golden model every
    // comparator reads 0 forever and the output is exactly zero whatever
    // the thresholds.  The input encoder sees the full bit pattern up
    // front, so the block retires after a single plane instead of
    // streaming `bits` silent cycles — the zero-vector serving fast
    // path.  Digital tiles only: noisy/analog backends flip comparators
    // on zero PSUMs and must keep consuming their RNG stream.
    if tile.is_digital() && q.q.iter().all(|&v| v == 0) {
        let mut stats = CycleStats::new(bits);
        let outcome = ElementOutcome {
            cycles: 1,
            terminated: true,
            value_units: 0,
        };
        for _ in 0..b {
            stats.record(&outcome);
        }
        return TransformOutcome {
            values: vec![0.0; b],
            stats,
            planes_issued: 1,
            row_cycles: b as u64,
        };
    }

    let planes = q.bitplanes_msb_first();

    let mut terminators: Vec<EarlyTerminator> = thresholds_units
        .iter()
        .map(|&t| EarlyTerminator::new(bits, t))
        .collect();
    let mut live: Vec<bool> = vec![true; b];
    let mut done_value: Vec<i64> = vec![0; b];
    let mut cycles: Vec<u32> = vec![0; b];
    let mut terminated: Vec<bool> = vec![false; b];
    let mut planes_issued = 0u32;
    let mut row_cycles = 0u64;
    // Zero-padded plane scratch for sub-tile blocks.
    let mut padded = vec![0i8; if b < n { n } else { 0 }];
    // Full-width blocks with the identity row map take the direct
    // readout (checked once, not per plane): the pre-plan hot path, with
    // no per-plane gather through the row indirection.
    let identity = b == n && rows.iter().enumerate().all(|(i, &r)| i == r);

    for plane in &planes {
        if !live.iter().any(|&l| l) {
            break;
        }
        planes_issued += 1;
        let obits = if identity {
            tile.execute_bitplane(plane)
        } else if b == n {
            tile.execute_bitplane_rows(plane, rows)
        } else {
            padded[..b].copy_from_slice(plane);
            tile.execute_bitplane_rows(&padded, rows)
        };
        for i in 0..b {
            if !live[i] {
                continue;
            }
            row_cycles += 1;
            cycles[i] += 1;
            match terminators[i].step(obits[i]) {
                Decision::Continue => {}
                Decision::TerminateZero => {
                    live[i] = false;
                    terminated[i] = true;
                    done_value[i] = 0;
                }
                Decision::Complete => {
                    live[i] = false;
                    let v = terminators[i].running();
                    done_value[i] = if (v.unsigned_abs() as f64) <= thresholds_units[i] {
                        0
                    } else {
                        v
                    };
                }
            }
        }
    }

    let mut stats = CycleStats::new(bits);
    for i in 0..b {
        stats.record(&crate::bitplane::early_term::ElementOutcome {
            cycles: cycles[i],
            terminated: terminated[i],
            value_units: done_value[i],
        });
    }
    let values = done_value
        .iter()
        .map(|&v| v as f32 * q.scale)
        .collect();
    TransformOutcome {
        values,
        stats,
        planes_issued,
        row_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitplane::QuantBwht;
    use crate::coordinator::tile::TileKind;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut r = crate::util::rng::Rng::seed_from_u64(seed);
        (0..n).map(|_| r.uniform_range(-1.5, 1.5) as f32).collect()
    }

    #[test]
    fn zero_thresholds_match_digital_golden_model() {
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let x = sample(16, 1);
        let out = schedule_transform(&mut tile, &x, 8, &vec![0.0; 16], None);
        let golden = QuantBwht::new(16, 128, 8).transform(&x);
        assert_eq!(out.values, golden, "ET with T=0 must be lossless");
        assert_eq!(out.planes_issued, 8);
    }

    #[test]
    fn high_thresholds_save_cycles_and_zero_outputs() {
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let x = sample(16, 2);
        let out = schedule_transform(&mut tile, &x, 8, &vec![1e9; 16], None);
        assert!(out.values.iter().all(|&v| v == 0.0));
        assert_eq!(out.planes_issued, 1, "everything terminates after MSB");
        assert!(out.stats.average_cycles() < 1.5);
    }

    #[test]
    fn termination_is_sound_vs_full_run() {
        // With ET at threshold T, outputs must equal the full (no-ET)
        // recombination passed through the same |y|<=T zeroing.
        let x = sample(16, 3);
        let t_units = 40.0;
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let et = schedule_transform(&mut tile, &x, 8, &vec![t_units; 16], None);
        let mut tile2 = Tile::new(16, &TileKind::Digital, 0);
        let full = schedule_transform(&mut tile2, &x, 8, &vec![0.0; 16], None);
        let q = Quantizer::new(8).quantize(&x);
        for i in 0..16 {
            let full_units = (full.values[i] / q.scale).round() as i64;
            let want = if (full_units.unsigned_abs() as f64) <= t_units {
                0.0
            } else {
                full.values[i]
            };
            assert_eq!(et.values[i], want, "element {i}");
        }
    }

    #[test]
    fn row_cycles_bounded_by_planes_times_rows() {
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let x = sample(16, 4);
        let out = schedule_transform(&mut tile, &x, 8, &vec![100.0; 16], None);
        assert!(out.row_cycles <= 8 * 16);
        assert!(out.row_cycles >= 16, "every row runs at least one cycle");
        assert_eq!(out.stats.total_elements, 16);
    }

    #[test]
    fn zero_block_retires_after_one_plane() {
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let out = schedule_transform(&mut tile, &[0.0; 16], 8, &[0.0; 16], None);
        assert!(out.values.iter().all(|&v| v == 0.0));
        assert_eq!(out.planes_issued, 1);
        assert_eq!(out.row_cycles, 16);
        assert_eq!(out.stats.terminated_early, 16);
        assert!((out.stats.average_cycles() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn one_bit_input_single_plane() {
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let x = sample(16, 5);
        let out = schedule_transform(&mut tile, &x, 1, &vec![0.0; 16], None);
        assert_eq!(out.planes_issued, 1);
    }

    #[test]
    fn sub_tile_block_matches_small_golden_model() {
        // A 4-point block on a 16-wide tile: bit-identical to the
        // 4-point golden model, accounted over 4 logical rows only.
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let x = sample(4, 8);
        let rows = crate::coordinator::plan::subtile_rows(16, 4);
        let out = schedule_block(&mut tile, &x, 8, &vec![0.0; 4], None, &rows);
        let golden = QuantBwht::new(4, 4, 8).transform(&x);
        assert_eq!(out.values, golden);
        assert_eq!(out.stats.total_elements, 4);
        assert_eq!(out.row_cycles, 4 * 8, "T=0: all planes on 4 rows");
        assert_eq!(out.planes_issued, 8);
    }

    #[test]
    fn sub_tile_early_termination_bills_logical_rows_only() {
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let x = sample(8, 9);
        let rows = crate::coordinator::plan::subtile_rows(16, 8);
        let out = schedule_block(&mut tile, &x, 8, &vec![1e9; 8], None, &rows);
        assert!(out.values.iter().all(|&v| v == 0.0));
        assert_eq!(out.planes_issued, 1, "everything terminates after MSB");
        assert_eq!(out.row_cycles, 8, "masked rows must not be billed");
        assert_eq!(out.stats.total_elements, 8);
        assert_eq!(out.stats.terminated_early, 8);
    }

    #[test]
    fn sub_tile_zero_block_fast_path() {
        let mut tile = Tile::new(32, &TileKind::Digital, 0);
        let rows = crate::coordinator::plan::subtile_rows(32, 4);
        let out = schedule_block(&mut tile, &[0.0; 4], 8, &[0.0; 4], None, &rows);
        assert_eq!(out.values, vec![0.0; 4]);
        assert_eq!(out.planes_issued, 1);
        assert_eq!(out.row_cycles, 4);
    }
}

//! Tile planning: mapping a logical BWHT block partition onto fixed-size
//! crossbar tiles — sub-tile blocks included.
//!
//! The paper's array micro-architecture stitches 16×16 cells to cover
//! arbitrary transform shapes; our simulated pools run one fixed tile
//! geometry per deployment, so a layer whose partition mixes block sizes
//! (`wht::bwht_blocks(300, 128)` = `[128, 128, 32, 8, 4]`) needs every
//! block mapped onto the *same* `tile_n`-wide tile.  A [`TilePlan`] does
//! that with zero-padding and an output row mask:
//!
//! * **input**: a `b`-point block (`b <= tile_n`) occupies the first `b`
//!   tile columns; the remaining columns stream zero bits, contributing
//!   nothing to any PSUM;
//! * **output**: only the `b` rows listed in [`BlockSlot::rows`] carry the
//!   block's outputs — the other rows are masked off, skipped by the
//!   bit-plane early-termination counters so cycle/energy accounting
//!   bills exactly `b` logical rows.
//!
//! Why this is *bit-identical* to the `b`-point golden model: the
//! Sylvester Hadamard matrix has `H_N[i][j] = (-1)^popcount(i & j)`, so
//! for `i, j < b` the top-left `b×b` of `H_N` **is** `H_b`.  With the
//! input zero-padded to `N`, natural-order tile row `r < b` therefore
//! computes natural-order row `r` of the `b`-point transform — the same
//! integer PSUM, hence the same comparator bit on every plane.  Both the
//! tile and the golden model emit *sequency* order, so logical sequency
//! output `i` (natural row `perm_b[i]`) lives at tile sequency row
//! `inv_perm_N(perm_b[i])` — the mapping [`subtile_rows`] caches.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Result};

use crate::wht::fast::sequency_perm;

/// Physical output rows (of a `tile_n`-wide sequency-ordered tile) that
/// carry the outputs of a `block`-point sequency transform computed on
/// zero-padded input, in logical output order.  Identity when
/// `block == tile_n`.  Cached per `(tile_n, block)` — the maps are
/// parameter-free and shared by every worker thread.
///
/// # Panics
/// If either argument is not a power of two, or `block > tile_n`.
pub fn subtile_rows(tile_n: usize, block: usize) -> Arc<Vec<usize>> {
    assert!(
        tile_n.is_power_of_two() && block.is_power_of_two() && block <= tile_n,
        "subtile_rows needs power-of-two block {block} <= tile {tile_n}"
    );
    static CACHE: OnceLock<Mutex<HashMap<(usize, usize), Arc<Vec<usize>>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = cache.lock().expect("subtile row cache poisoned");
    guard
        .entry((tile_n, block))
        .or_insert_with(|| {
            let perm_n = sequency_perm(tile_n.trailing_zeros() as usize);
            let mut inv = vec![0usize; tile_n];
            for (r, &h) in perm_n.iter().enumerate() {
                inv[h] = r;
            }
            let perm_b = sequency_perm(block.trailing_zeros() as usize);
            Arc::new(perm_b.iter().map(|&h| inv[h]).collect())
        })
        .clone()
}

/// One logical block of a request mapped onto a tile slice.
#[derive(Debug, Clone)]
pub struct BlockSlot {
    /// Start of the block within the request's logical vector.
    pub offset: usize,
    /// Logical width (`<= tile_n`; the tile's remaining rows are masked).
    pub width: usize,
    /// Tile output rows carrying this block's outputs, logical order.
    pub rows: Arc<Vec<usize>>,
    /// Whether this block fills the tile with the identity row map —
    /// cached at plan construction so the scheduler's per-block hot path
    /// takes the direct full-width readout without re-scanning `rows`
    /// on every call (PERF: the `enumerate().all()` re-derivation used
    /// to run inside every `schedule_block`).
    pub identity: bool,
}

/// Whether `rows` maps a full-width block onto the tile unchanged.
fn is_identity(tile_n: usize, width: usize, rows: &[usize]) -> bool {
    width == tile_n && rows.iter().enumerate().all(|(i, &r)| i == r)
}

/// A request's block partition resolved against a pool's tile geometry:
/// the contract between the submission APIs
/// ([`crate::coordinator::Coordinator::try_submit_planned`]) and the
/// worker's per-block scheduler
/// ([`crate::coordinator::scheduler::schedule_block`]).
#[derive(Debug, Clone)]
pub struct TilePlan {
    tile_n: usize,
    width: usize,
    slots: Vec<BlockSlot>,
}

impl TilePlan {
    /// Resolve an explicit block partition onto `tile_n`-wide tiles.
    /// Every block must be a power of two no wider than the tile.
    pub fn new(tile_n: usize, blocks: &[usize]) -> Result<TilePlan> {
        if !tile_n.is_power_of_two() {
            bail!("tile width must be a power of two, got {tile_n}");
        }
        if blocks.is_empty() {
            bail!("empty block partition");
        }
        let mut slots = Vec::with_capacity(blocks.len());
        let mut offset = 0usize;
        for &b in blocks {
            if b == 0 || !b.is_power_of_two() {
                bail!("block widths must be powers of two, got {b} in {blocks:?}");
            }
            if b > tile_n {
                bail!(
                    "block width {b} exceeds the {tile_n}x{tile_n} tile; configure the \
                     pool with tile_n >= {b} (partition {blocks:?})"
                );
            }
            let rows = subtile_rows(tile_n, b);
            let identity = is_identity(tile_n, b, &rows);
            slots.push(BlockSlot {
                offset,
                width: b,
                rows,
                identity,
            });
            offset += b;
        }
        Ok(TilePlan {
            tile_n,
            width: offset,
            slots,
        })
    }

    /// The legacy uniform mapping: `width` padded up to whole `tile_n`
    /// blocks, each one full tile (the raw `/v1/transform` semantics,
    /// where the padded dimension is part of the response contract).
    pub fn uniform(tile_n: usize, width: usize) -> TilePlan {
        assert!(tile_n.is_power_of_two(), "tile width must be a power of two");
        assert!(width > 0, "cannot plan a zero-width request");
        let nblocks = width.div_ceil(tile_n);
        let rows = subtile_rows(tile_n, tile_n);
        let identity = is_identity(tile_n, tile_n, &rows);
        let slots = (0..nblocks)
            .map(|i| BlockSlot {
                offset: i * tile_n,
                width: tile_n,
                rows: Arc::clone(&rows),
                identity,
            })
            .collect();
        TilePlan {
            tile_n,
            width: nblocks * tile_n,
            slots,
        }
    }

    /// Tile geometry the plan was resolved against.
    pub fn tile_n(&self) -> usize {
        self.tile_n
    }

    /// Total logical width the plan covers (the job's vector length;
    /// for [`TilePlan::uniform`] this is the padded width).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Per-block slots, in request order.
    pub fn slots(&self) -> &[BlockSlot] {
        &self.slots
    }

    /// The block widths, in order.
    pub fn block_widths(&self) -> Vec<usize> {
        self.slots.iter().map(|s| s.width).collect()
    }
}

/// Smallest tile geometry able to run every block of a partition: its
/// widest block.  Errors on empty or non-power-of-two partitions — the
/// check a serving front-end runs before sizing a pool for a model.
pub fn required_tile(blocks: &[usize]) -> Result<usize> {
    let Some(&max) = blocks.iter().max() else {
        bail!("empty block partition");
    };
    for &b in blocks {
        if b == 0 || !b.is_power_of_two() {
            bail!("block widths must be powers of two, got {b} in {blocks:?}");
        }
    }
    Ok(max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wht;

    #[test]
    fn identity_rows_for_full_width_blocks() {
        for &n in &[4usize, 16, 64, 128] {
            let rows = subtile_rows(n, n);
            assert_eq!(*rows, (0..n).collect::<Vec<_>>(), "tile {n}");
        }
    }

    #[test]
    fn subtile_rows_select_the_matching_walsh_rows() {
        // Row map correctness straight from the matrices: tile row
        // rows[i], restricted to the first b columns, must equal row i of
        // the b-point Walsh matrix.
        for &(n, b) in &[(16usize, 4usize), (16, 8), (32, 4), (128, 8), (64, 16)] {
            let rows = subtile_rows(n, b);
            assert_eq!(rows.len(), b);
            let wn = wht::walsh(n.trailing_zeros() as usize);
            let wb = wht::walsh(b.trailing_zeros() as usize);
            for i in 0..b {
                for j in 0..b {
                    assert_eq!(
                        wn.get(rows[i], j),
                        wb.get(i, j),
                        "tile {n} block {b} logical row {i} col {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn plan_resolves_mixed_partitions() {
        let plan = TilePlan::new(16, &[16, 4]).unwrap();
        assert_eq!(plan.width(), 20);
        assert_eq!(plan.tile_n(), 16);
        assert_eq!(plan.block_widths(), vec![16, 4]);
        assert_eq!(plan.slots()[0].offset, 0);
        assert_eq!(plan.slots()[1].offset, 16);
        assert_eq!(plan.slots()[1].rows.len(), 4);
    }

    #[test]
    fn identity_flag_is_cached_per_slot() {
        let plan = TilePlan::new(16, &[16, 4, 16]).unwrap();
        assert!(plan.slots()[0].identity, "full-width block is identity");
        assert!(!plan.slots()[1].identity, "sub-tile block is masked");
        assert!(plan.slots()[2].identity);
        let uniform = TilePlan::uniform(32, 64);
        assert!(uniform.slots().iter().all(|s| s.identity));
    }

    #[test]
    fn plan_rejects_bad_partitions() {
        assert!(TilePlan::new(16, &[]).is_err(), "empty");
        assert!(TilePlan::new(16, &[12]).is_err(), "non power of two");
        assert!(TilePlan::new(16, &[32]).is_err(), "wider than the tile");
        assert!(TilePlan::new(12, &[4]).is_err(), "non power-of-two tile");
    }

    #[test]
    fn uniform_plan_pads_to_whole_tiles() {
        let plan = TilePlan::uniform(16, 20);
        assert_eq!(plan.width(), 32);
        assert_eq!(plan.block_widths(), vec![16, 16]);
        let exact = TilePlan::uniform(16, 48);
        assert_eq!(exact.width(), 48);
        assert_eq!(exact.slots().len(), 3);
    }

    #[test]
    fn required_tile_is_the_widest_block() {
        assert_eq!(required_tile(&[128, 128, 32, 8, 4]).unwrap(), 128);
        assert_eq!(required_tile(&[16]).unwrap(), 16);
        assert!(required_tile(&[]).is_err());
        assert!(required_tile(&[16, 5]).is_err());
    }
}

//! Cycle / energy / latency accounting for the coordinator.

use std::time::Duration;

use crate::bitplane::early_term::CycleStats;
use crate::energy::EnergyModel;

/// Power-of-two octaves covered by the finite buckets: the last finite
/// upper bound is `2^(NUM_OCTAVES - 1)` µs ≈ 67 s.
const NUM_OCTAVES: usize = 27;

/// Linear sub-buckets per octave.  A value just past a sub-bucket's
/// lower edge is reported at the sub-bucket's upper bound, so quantiles
/// over-estimate by at most `1 + 1/SUBS_PER_OCTAVE` = 25% (the first
/// two octaves are exact: their bounds are consecutive integers).
const SUBS_PER_OCTAVE: u64 = 4;

/// Finite bucket count: octaves 0..=2 contribute one bound per integer
/// µs (1, 2, 3, 4); each wider octave contributes `SUBS_PER_OCTAVE`
/// linearly spaced bounds.
const NUM_FINITE_BUCKETS: usize = 4 + (NUM_OCTAVES - 3) * SUBS_PER_OCTAVE as usize;

/// Upper bounds (µs) of the finite buckets, ascending: within the
/// octave `(2^(i-1), 2^i]` the bounds are `2^(i-1) · (1 + k/4)` for
/// `k = 1..=4` — HDR-style log-linear bucketing.
const fn build_bounds() -> [u64; NUM_FINITE_BUCKETS] {
    let mut bounds = [0u64; NUM_FINITE_BUCKETS];
    let mut idx = 0;
    let mut octave = 0;
    while octave < NUM_OCTAVES {
        let hi = 1u64 << octave;
        let lo = hi / 2;
        let width = hi - lo;
        if width <= SUBS_PER_OCTAVE {
            let mut b = lo + 1;
            while b <= hi {
                bounds[idx] = b;
                idx += 1;
                b += 1;
            }
        } else {
            let step = width / SUBS_PER_OCTAVE;
            let mut k = 1;
            while k <= SUBS_PER_OCTAVE {
                bounds[idx] = lo + k * step;
                idx += 1;
                k += 1;
            }
        }
        octave += 1;
    }
    bounds
}

const BUCKET_BOUNDS_US: [u64; NUM_FINITE_BUCKETS] = build_bounds();

/// Log-linear-bucketed latency histogram with quantile estimation.
///
/// Fixed-size and allocation-free on the record path, mergeable across
/// workers — the p50/p95/p99 source for the serving `/metrics` endpoint.
/// Quantiles are reported as the upper bound of the covering bucket;
/// with `SUBS_PER_OCTAVE` linear sub-buckets per power-of-two octave
/// they over-estimate by at most 25% (was ≤2× when the buckets were
/// whole octaves).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: [u64; NUM_FINITE_BUCKETS + 1],
    count: u64,
    sum_us: u64,
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            counts: [0; NUM_FINITE_BUCKETS + 1],
            count: 0,
            sum_us: 0,
        }
    }

    /// Index of the smallest bucket whose upper bound covers `us`
    /// (`NUM_FINITE_BUCKETS` = the +Inf overflow bucket).
    fn bucket_index(us: u64) -> usize {
        BUCKET_BOUNDS_US.partition_point(|&b| b < us)
    }

    pub fn record(&mut self, latency: Duration) {
        let us = latency.as_micros().min(u128::from(u64::MAX)) as u64;
        self.counts[Self::bucket_index(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Upper bound (µs) of bucket `i`, or `None` for the +Inf bucket.
    pub fn bucket_upper_us(i: usize) -> Option<u64> {
        BUCKET_BOUNDS_US.get(i).copied()
    }

    /// `(upper_bound_us, cumulative_count)` pairs, Prometheus-style.
    pub fn cumulative_buckets(&self) -> Vec<(Option<u64>, u64)> {
        let mut acc = 0u64;
        self.counts
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                acc += c;
                (Self::bucket_upper_us(i), acc)
            })
            .collect()
    }

    /// Quantile estimate in µs (upper bound of the covering bucket);
    /// `f64::INFINITY` when the rank lands in the overflow bucket.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= rank {
                return match Self::bucket_upper_us(i) {
                    Some(us) => us as f64,
                    None => f64::INFINITY,
                };
            }
        }
        f64::INFINITY
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Aggregated service metrics.
#[derive(Debug, Clone)]
pub struct Metrics {
    /// Per-element bitplane cycle stats (Fig. 9(c)).
    pub cycles: CycleStats,
    /// Tile-level bitplane operations issued.
    pub planes_issued: u64,
    /// Row-cycles executed (energy-relevant granularity).
    pub row_cycles: u64,
    /// Requests served.
    pub requests: u64,
    /// Pool jobs executed.  A fused multi-sample job counts once here
    /// while counting each of its samples in `requests`, so
    /// `requests / jobs` is the average fusion factor — the router's
    /// batch-fusion win is directly observable as `jobs` falling below
    /// the slice count.
    pub jobs: u64,
    /// Total wall-clock busy time across workers.
    pub busy: Duration,
    /// Per-request worker busy-time distribution.
    pub latency: LatencyHistogram,
    bits: u32,
}

impl Metrics {
    pub fn new(bits: u32) -> Metrics {
        Metrics {
            cycles: CycleStats::new(bits),
            planes_issued: 0,
            row_cycles: 0,
            requests: 0,
            jobs: 0,
            busy: Duration::ZERO,
            latency: LatencyHistogram::new(),
            bits,
        }
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    pub fn merge_outcome(
        &mut self,
        outcome: &crate::coordinator::scheduler::TransformOutcome,
        elapsed: Duration,
    ) {
        self.cycles.merge(&outcome.stats);
        self.planes_issued += outcome.planes_issued as u64;
        self.row_cycles += outcome.row_cycles;
        self.requests += 1;
        self.jobs += 1;
        self.busy += elapsed;
        self.latency.record(elapsed);
    }

    /// Fold one executed job (a batch of `requests` same-partition
    /// samples that took `elapsed` of worker busy time) into the
    /// counters.  The latency histogram gets one sample per request, at
    /// the job's full busy time — every request in a chunk completes
    /// when the chunk does, so that IS the service latency each one
    /// observed — keeping histogram counts aligned with the request
    /// counter and quantiles request-meaningful.  `busy` accumulates
    /// the elapsed time once (worker utilization, not per-request
    /// waiting).  Shared by the worker-local and pool-shared accounting
    /// so the two cannot drift.
    pub fn record_job(
        &mut self,
        stats: &CycleStats,
        planes_issued: u32,
        row_cycles: u64,
        requests: usize,
        elapsed: Duration,
    ) {
        self.cycles.merge(stats);
        self.planes_issued += planes_issued as u64;
        self.row_cycles += row_cycles;
        self.requests += requests as u64;
        self.jobs += 1;
        self.busy += elapsed;
        for _ in 0..requests {
            self.latency.record(elapsed);
        }
    }

    pub fn merge(&mut self, other: &Metrics) {
        self.cycles.merge(&other.cycles);
        self.planes_issued += other.planes_issued;
        self.row_cycles += other.row_cycles;
        self.requests += other.requests;
        self.jobs += other.jobs;
        self.busy += other.busy;
        self.latency.merge(&other.latency);
    }

    /// Row-cycles *not* executed thanks to early termination, relative to
    /// the no-ET baseline of `bits` cycles per output element.
    pub fn row_cycles_saved(&self) -> u64 {
        (self.bits as u64 * self.cycles.total_elements).saturating_sub(self.row_cycles)
    }

    /// Modelled energy for the work done (fJ), with the ET digital
    /// overhead applied to every *executed* row-cycle.
    ///
    /// Energy granularity: one full-tile bitplane op costs
    /// `model.bitplane_energy_fj()`; a row that terminated early gates its
    /// share, so we bill `row_cycles / n` fractional ops (+ ET overhead).
    pub fn energy_fj(&self, model: &EnergyModel) -> f64 {
        let frac_ops = self.row_cycles as f64 / model.n as f64;
        frac_ops * model.bitplane_energy_fj() * (1.0 + crate::energy::ET_OVERHEAD)
    }

    /// Effective TOPS/W given the useful ops (bits × 2N² per request row).
    pub fn tops_per_watt(&self, model: &EnergyModel) -> f64 {
        let useful_ops =
            self.cycles.total_elements as f64 * self.bits as f64 * 2.0 * model.n as f64;
        let energy_j = self.energy_fj(model) * 1e-15;
        if energy_j == 0.0 {
            return 0.0;
        }
        useful_ops / energy_j / 1e12
    }

    /// Average executed bitplane cycles per output element.
    pub fn average_cycles(&self) -> f64 {
        self.cycles.average_cycles()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::schedule_transform;
    use crate::coordinator::tile::{Tile, TileKind};

    #[test]
    fn merge_outcome_accumulates() {
        let mut m = Metrics::new(8);
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let out = schedule_transform(&mut tile, &x, 8, &vec![0.0; 16], None);
        m.merge_outcome(&out, Duration::from_micros(5));
        assert_eq!(m.requests, 1);
        assert_eq!(m.jobs, 1);
        assert_eq!(m.cycles.total_elements, 16);
        assert!(m.row_cycles > 0);
        assert_eq!(m.latency.count(), 1);
    }

    #[test]
    fn fused_jobs_count_once_while_billing_every_request() {
        // A fused 4-sample job: one job, four requests, four latency
        // samples — the requests/jobs ratio is the fusion factor.
        let mut m = Metrics::new(8);
        let stats = crate::bitplane::early_term::CycleStats::new(8);
        m.record_job(&stats, 8, 128, 4, Duration::from_micros(10));
        assert_eq!(m.jobs, 1);
        assert_eq!(m.requests, 4);
        assert_eq!(m.latency.count(), 4);
        let mut other = Metrics::new(8);
        other.record_job(&stats, 8, 128, 1, Duration::from_micros(10));
        m.merge(&other);
        assert_eq!(m.jobs, 2);
        assert_eq!(m.requests, 5);
    }

    #[test]
    fn energy_scales_with_row_cycles() {
        let model = EnergyModel::new(16, 0.8);
        let mut a = Metrics::new(8);
        a.row_cycles = 16; // one full-tile op worth of rows
        let mut b = Metrics::new(8);
        b.row_cycles = 32;
        assert!((b.energy_fj(&model) - 2.0 * a.energy_fj(&model)).abs() < 1e-9);
    }

    #[test]
    fn tops_per_watt_matches_energy_model_at_full_cycles() {
        // With zero thresholds (no ET savings) every element runs all 8
        // planes: row_cycles = 8 * elements, and TOPS/W collapses to the
        // energy model's ET-overhead-corrected no-savings figure.
        let model = EnergyModel::new(16, 0.8);
        let mut m = Metrics::new(8);
        m.cycles = crate::bitplane::early_term::CycleStats::new(8);
        m.cycles.total_elements = 16;
        m.row_cycles = 8 * 16;
        let t = m.tops_per_watt(&model);
        let want = model.tops_per_watt(8) / (1.0 + crate::energy::ET_OVERHEAD);
        assert!((t - want).abs() / want < 1e-9, "{t} vs {want}");
    }

    #[test]
    fn row_cycles_saved_vs_baseline() {
        let mut m = Metrics::new(8);
        m.cycles.total_elements = 10;
        m.row_cycles = 30;
        assert_eq!(m.row_cycles_saved(), 80 - 30);
        m.row_cycles = 100; // more than baseline never underflows
        assert_eq!(m.row_cycles_saved(), 0);
    }

    #[test]
    fn latency_histogram_buckets_and_quantiles() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.quantile_us(0.5), 0.0, "empty histogram");
        for us in [1u64, 1, 1, 1, 100, 100, 100, 5000, 5000, 60_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.sum_us(), 4 + 300 + 10_000 + 60_000);
        // p50 covers the 5th sample (100 µs -> sub-bucket bound 112 µs,
        // a 12% over-estimate; the old whole-octave bound was 128 µs).
        assert_eq!(h.quantile_us(0.5), 112.0);
        // p99 covers the last sample (60 ms: octave (32768, 65536] has
        // sub-bounds 40960/49152/57344/65536, so 60000 -> 65536).
        assert_eq!(h.quantile_us(0.99), 65536.0);
        // cumulative buckets end at the total count with a +Inf bound.
        let buckets = h.cumulative_buckets();
        let (last_bound, last_cum) = buckets[buckets.len() - 1];
        assert_eq!(last_bound, None);
        assert_eq!(last_cum, 10);
    }

    #[test]
    fn latency_bucket_bounds_are_strictly_increasing() {
        for w in BUCKET_BOUNDS_US.windows(2) {
            assert!(w[0] < w[1], "{} !< {}", w[0], w[1]);
        }
        assert_eq!(BUCKET_BOUNDS_US[0], 1);
        assert_eq!(
            BUCKET_BOUNDS_US[NUM_FINITE_BUCKETS - 1],
            1u64 << (NUM_OCTAVES - 1),
            "coverage unchanged: last finite bound is still ~67 s"
        );
    }

    #[test]
    fn quantiles_over_estimate_by_at_most_25_percent() {
        // The ROADMAP SLO-precision item: for any single recorded value
        // the reported quantile (covering bucket's upper bound) is within
        // +25% of the true value.
        for us in [1u64, 3, 5, 9, 17, 100, 999, 4097, 65_000, 1_000_000, 33_333_333] {
            let mut h = LatencyHistogram::new();
            h.record(Duration::from_micros(us));
            let q = h.quantile_us(0.99);
            assert!(q >= us as f64, "{q} < {us}");
            assert!(q <= us as f64 * 1.25, "{q} > 1.25 * {us}");
        }
    }

    #[test]
    fn latency_histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(Duration::from_micros(10));
        b.record(Duration::from_micros(1000));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.sum_us(), 1010);
        assert!((a.mean_us() - 505.0).abs() < 1e-9);
    }
}

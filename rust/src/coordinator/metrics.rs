//! Cycle / energy / latency accounting for the coordinator.

use std::time::Duration;

use crate::bitplane::early_term::CycleStats;
use crate::energy::EnergyModel;

/// Aggregated service metrics.
#[derive(Debug, Clone)]
pub struct Metrics {
    /// Per-element bitplane cycle stats (Fig. 9(c)).
    pub cycles: CycleStats,
    /// Tile-level bitplane operations issued.
    pub planes_issued: u64,
    /// Row-cycles executed (energy-relevant granularity).
    pub row_cycles: u64,
    /// Requests served.
    pub requests: u64,
    /// Total wall-clock busy time across workers.
    pub busy: Duration,
    bits: u32,
}

impl Metrics {
    pub fn new(bits: u32) -> Metrics {
        Metrics {
            cycles: CycleStats::new(bits),
            planes_issued: 0,
            row_cycles: 0,
            requests: 0,
            busy: Duration::ZERO,
            bits,
        }
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    pub fn merge_outcome(
        &mut self,
        outcome: &crate::coordinator::scheduler::TransformOutcome,
        elapsed: Duration,
    ) {
        self.cycles.merge(&outcome.stats);
        self.planes_issued += outcome.planes_issued as u64;
        self.row_cycles += outcome.row_cycles;
        self.requests += 1;
        self.busy += elapsed;
    }

    pub fn merge(&mut self, other: &Metrics) {
        self.cycles.merge(&other.cycles);
        self.planes_issued += other.planes_issued;
        self.row_cycles += other.row_cycles;
        self.requests += other.requests;
        self.busy += other.busy;
    }

    /// Modelled energy for the work done (fJ), with the ET digital
    /// overhead applied to every *executed* row-cycle.
    ///
    /// Energy granularity: one full-tile bitplane op costs
    /// `model.bitplane_energy_fj()`; a row that terminated early gates its
    /// share, so we bill `row_cycles / n` fractional ops (+ ET overhead).
    pub fn energy_fj(&self, model: &EnergyModel) -> f64 {
        let frac_ops = self.row_cycles as f64 / model.n as f64;
        frac_ops * model.bitplane_energy_fj() * (1.0 + crate::energy::ET_OVERHEAD)
    }

    /// Effective TOPS/W given the useful ops (bits × 2N² per request row).
    pub fn tops_per_watt(&self, model: &EnergyModel) -> f64 {
        let useful_ops =
            self.cycles.total_elements as f64 * self.bits as f64 * 2.0 * model.n as f64;
        let energy_j = self.energy_fj(model) * 1e-15;
        if energy_j == 0.0 {
            return 0.0;
        }
        useful_ops / energy_j / 1e12
    }

    /// Average executed bitplane cycles per output element.
    pub fn average_cycles(&self) -> f64 {
        self.cycles.average_cycles()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::schedule_transform;
    use crate::coordinator::tile::{Tile, TileKind};

    #[test]
    fn merge_outcome_accumulates() {
        let mut m = Metrics::new(8);
        let mut tile = Tile::new(16, &TileKind::Digital, 0);
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let out = schedule_transform(&mut tile, &x, 8, &vec![0.0; 16]);
        m.merge_outcome(&out, Duration::from_micros(5));
        assert_eq!(m.requests, 1);
        assert_eq!(m.cycles.total_elements, 16);
        assert!(m.row_cycles > 0);
    }

    #[test]
    fn energy_scales_with_row_cycles() {
        let model = EnergyModel::new(16, 0.8);
        let mut a = Metrics::new(8);
        a.row_cycles = 16; // one full-tile op worth of rows
        let mut b = Metrics::new(8);
        b.row_cycles = 32;
        assert!((b.energy_fj(&model) - 2.0 * a.energy_fj(&model)).abs() < 1e-9);
    }

    #[test]
    fn tops_per_watt_matches_energy_model_at_full_cycles() {
        // With zero thresholds (no ET savings) every element runs all 8
        // planes: row_cycles = 8 * elements, and TOPS/W collapses to the
        // energy model's ET-overhead-corrected no-savings figure.
        let model = EnergyModel::new(16, 0.8);
        let mut m = Metrics::new(8);
        m.cycles = crate::bitplane::early_term::CycleStats::new(8);
        m.cycles.total_elements = 16;
        m.row_cycles = 8 * 16;
        let t = m.tops_per_watt(&model);
        let want = model.tops_per_watt(8) / (1.0 + crate::energy::ET_OVERHEAD);
        assert!((t - want).abs() / want < 1e-9, "{t} vs {want}");
    }
}

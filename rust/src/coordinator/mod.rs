//! L3 coordinator: mapping frequency transforms onto a crossbar tile pool.
//!
//! This is the serving layer a deployment would run: BWHT transform
//! requests are routed to fixed-size crossbar tiles (16×16/32×32 macros),
//! scheduled bitplane-by-bitplane with the paper's predictive early
//! termination (Fig. 10), accounted for cycles and energy, and executed in
//! parallel by a worker pool (one OS thread per simulated macro — the
//! tokio-free analog of a vLLM-style router on this offline box).
//!
//! * [`tile`] — the execution backends a tile can run on (digital golden
//!   model, ANT-noisy, full analog Monte-Carlo);
//! * [`plan`] — mapping logical block partitions onto tiles (sub-tile
//!   blocks run zero-padded with masked output rows);
//! * [`scheduler`] — per-tile bitplane scheduling + early termination;
//! * [`pool`] — the request router/batcher and worker threads;
//! * [`metrics`] — cycle/energy/latency accounting.

pub mod metrics;
pub mod plan;
pub mod pool;
pub mod scheduler;
pub mod tile;

pub use metrics::{LatencyHistogram, Metrics};
pub use plan::{required_tile, subtile_rows, BlockSlot, TilePlan};
pub use pool::{
    CompletedBatch, CompletedTransform, Coordinator, CoordinatorConfig, TransformRequest,
};
pub use scheduler::{
    schedule_batch, schedule_block, schedule_transform, BatchOutcome, SampleStats, ScratchArena,
    TransformOutcome,
};
pub use tile::{Tile, TileKind};

//! Crossbar tile execution backends.
//!
//! A tile is one N×N macro hardwired with the Walsh block `W_k`
//! (N = 2^k).  All backends implement the same single-bitplane contract:
//! ternary input column bits in, one comparator bit per row out.

use crate::analog::crossbar::{Crossbar, CrossbarConfig};
use crate::analog::noise::NoiseModel;
use crate::analog::variability;
use crate::bitplane::comparator;
use crate::util::rng::Rng;
use crate::wht;

/// Which physical model executes the tile.
#[derive(Debug, Clone)]
pub enum TileKind {
    /// Digital golden model: exact integer PSUM + ideal comparator.
    Digital,
    /// Digital PSUM with ANT noise before the comparator (Fig. 11(a)).
    Noisy { sigma_ant: f64 },
    /// Full analog behavioral model with sampled process variability.
    Analog { config: CrossbarConfig },
}

/// One instantiated N×N tile.
#[derive(Debug)]
pub struct Tile {
    n: usize,
    kind: TileKindInstance,
    rng: Rng,
    /// PERF: reusable PSUM scratch for the digital/noisy paths (the
    /// per-plane Vec<i64> allocation showed up in the scheduler profile).
    scratch: Vec<i64>,
    /// Full-width readout scratch for the noisy/analog masked paths
    /// (those backends execute every physical row per plane; only the
    /// gather is masked).
    scratch_obits: Vec<i8>,
    /// Per-row differential scratch for the analog backend.
    scratch_diffs: Vec<f64>,
}

#[derive(Debug)]
enum TileKindInstance {
    Digital,
    Noisy(NoiseModel),
    Analog(Box<Crossbar>),
}

impl Tile {
    /// Instantiate a tile (sampling process variability for analog tiles).
    pub fn new(n: usize, kind: &TileKind, seed: u64) -> Tile {
        assert!(n.is_power_of_two(), "tile dim must be a power of two");
        let mut rng = Rng::seed_from_u64(seed);
        let kind = match kind {
            TileKind::Digital => TileKindInstance::Digital,
            TileKind::Noisy { sigma_ant } => {
                TileKindInstance::Noisy(NoiseModel::new(*sigma_ant, n))
            }
            TileKind::Analog { config } => {
                assert_eq!(config.n, n, "analog config dim mismatch");
                TileKindInstance::Analog(Box::new(variability::sample_instance(
                    config.clone(),
                    &mut rng,
                )))
            }
        };
        Tile {
            n,
            kind,
            rng,
            scratch: vec![0; n],
            scratch_obits: vec![0; n],
            scratch_diffs: Vec::with_capacity(n),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Whether this tile runs the exact digital golden model (no noise
    /// sources, no per-plane RNG consumption).
    pub fn is_digital(&self) -> bool {
        matches!(self.kind, TileKindInstance::Digital)
    }

    /// Exact integer PSUMs of this tile's Walsh block into the scratch
    /// buffer (shared helper).
    fn psums_into_scratch(&mut self, input: &[i8]) {
        for (dst, &v) in self.scratch.iter_mut().zip(input) {
            *dst = v as i64;
        }
        wht::fast::wht_sequency_i64(&mut self.scratch);
    }

    /// Execute one bitplane: 2 clock cycles of the Fig. 5 schedule.
    pub fn execute_bitplane(&mut self, input: &[i8]) -> Vec<i8> {
        let mut out = vec![0i8; self.n];
        self.execute_bitplane_into(input, &mut out);
        out
    }

    /// [`Self::execute_bitplane`] writing into a caller buffer of width
    /// `n` — the zero-allocation hot path on every backend (PSUM,
    /// readout and differential scratch all live on the tile and are
    /// reused across planes).  RNG consumption is byte-identical to the
    /// allocating variant.
    pub fn execute_bitplane_into(&mut self, input: &[i8], out: &mut [i8]) {
        assert_eq!(input.len(), self.n, "input width must match tile");
        assert_eq!(out.len(), self.n, "readout must cover every row");
        match &self.kind {
            TileKindInstance::Digital => {
                self.psums_into_scratch(input);
                for (o, &p) in out.iter_mut().zip(&self.scratch) {
                    *o = comparator(p);
                }
            }
            TileKindInstance::Noisy(nm) => {
                let nm = *nm;
                self.psums_into_scratch(input);
                nm.perturb_and_compare_into(&self.scratch, &mut self.rng, out);
            }
            TileKindInstance::Analog(xb) => {
                xb.execute_bitplane_into(input, &mut self.rng, &mut self.scratch_diffs, out);
            }
        }
    }

    /// Execute one bitplane with an output row mask: only the listed
    /// `rows` are read out, in the given order — the sub-tile path of
    /// [`crate::coordinator::plan::TilePlan`], where a block narrower
    /// than the tile occupies a subset of the rows and the rest are
    /// gated off.
    ///
    /// On the digital golden model the masked rows' comparators are
    /// never evaluated.  Noisy/analog tiles still execute the full
    /// physical array (every row's PSUM exists electrically) and consume
    /// their RNG stream at full width — only the readout is masked — so
    /// a tile's noise stream does not depend on which plan runs on it.
    pub fn execute_bitplane_rows(&mut self, input: &[i8], rows: &[usize]) -> Vec<i8> {
        let mut out = vec![0i8; rows.len()];
        self.execute_bitplane_rows_into(input, rows, &mut out);
        out
    }

    /// [`Self::execute_bitplane_rows`] writing into a caller buffer of
    /// length `rows.len()` — the zero-allocation masked readout the
    /// scheduler's live-row compaction drives (the row list shrinks as
    /// elements terminate; on the digital model only the listed rows'
    /// comparators are ever evaluated, while noisy/analog execute full
    /// width so their RNG stream stays plan-independent).
    pub fn execute_bitplane_rows_into(&mut self, input: &[i8], rows: &[usize], out: &mut [i8]) {
        assert_eq!(input.len(), self.n, "input width must match tile");
        assert_eq!(rows.len(), out.len(), "one readout bit per listed row");
        match &self.kind {
            TileKindInstance::Digital => {
                self.psums_into_scratch(input);
                for (o, &r) in out.iter_mut().zip(rows) {
                    *o = comparator(self.scratch[r]);
                }
            }
            TileKindInstance::Noisy(nm) => {
                let nm = *nm;
                self.psums_into_scratch(input);
                nm.perturb_and_compare_into(&self.scratch, &mut self.rng, &mut self.scratch_obits);
                for (o, &r) in out.iter_mut().zip(rows) {
                    *o = self.scratch_obits[r];
                }
            }
            TileKindInstance::Analog(xb) => {
                xb.execute_bitplane_into(
                    input,
                    &mut self.rng,
                    &mut self.scratch_diffs,
                    &mut self.scratch_obits,
                );
                for (o, &r) in out.iter_mut().zip(rows) {
                    *o = self.scratch_obits[r];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digital_tile_matches_walsh_signs() {
        let mut t = Tile::new(16, &TileKind::Digital, 0);
        let input: Vec<i8> = (0..16).map(|i| ((i % 3) as i8) - 1).collect();
        let bits = t.execute_bitplane(&input);
        let w = wht::walsh(4);
        for i in 0..16 {
            let psum: i64 = (0..16)
                .map(|j| w.get(i, j) as i64 * input[j] as i64)
                .sum();
            assert_eq!(bits[i] as i64, psum.signum());
        }
    }

    #[test]
    fn noisy_tile_zero_sigma_equals_digital() {
        let mut d = Tile::new(16, &TileKind::Digital, 1);
        let mut n = Tile::new(16, &TileKind::Noisy { sigma_ant: 0.0 }, 1);
        let input = vec![1i8; 16];
        assert_eq!(d.execute_bitplane(&input), n.execute_bitplane(&input));
    }

    #[test]
    fn analog_tile_mostly_agrees_at_nominal() {
        let kind = TileKind::Analog {
            config: CrossbarConfig::new(16, 0.9),
        };
        let mut a = Tile::new(16, &kind, 2);
        let mut d = Tile::new(16, &TileKind::Digital, 2);
        let mut agree = 0;
        let mut total = 0;
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..50 {
            let input: Vec<i8> = (0..16).map(|_| rng.ternary()).collect();
            let ab = a.execute_bitplane(&input);
            let db = d.execute_bitplane(&input);
            for (x, y) in ab.iter().zip(&db) {
                if *y != 0 {
                    total += 1;
                    if x == y {
                        agree += 1;
                    }
                }
            }
        }
        assert!(
            agree as f64 / total as f64 > 0.95,
            "analog tile disagrees too much: {agree}/{total}"
        );
    }

    #[test]
    #[should_panic(expected = "width")]
    fn wrong_width_panics() {
        Tile::new(16, &TileKind::Digital, 0).execute_bitplane(&[0i8; 8]);
    }

    #[test]
    fn masked_readout_matches_full_readout_on_digital() {
        let mut full = Tile::new(16, &TileKind::Digital, 0);
        let mut masked = Tile::new(16, &TileKind::Digital, 0);
        let input: Vec<i8> = (0..16).map(|i| ((i % 3) as i8) - 1).collect();
        let all = full.execute_bitplane(&input);
        let rows = [0usize, 7, 8, 15];
        let got = masked.execute_bitplane_rows(&input, &rows);
        assert_eq!(got, rows.iter().map(|&r| all[r]).collect::<Vec<_>>());
    }

    #[test]
    fn masked_readout_keeps_noisy_rng_stream_alignment() {
        // Two noisy tiles with the same seed must stay in lockstep even
        // when one serves masked sub-tile planes between full planes.
        let kind = TileKind::Noisy { sigma_ant: 0.5 };
        let mut a = Tile::new(16, &kind, 9);
        let mut b = Tile::new(16, &kind, 9);
        let input = vec![1i8; 16];
        let rows: Vec<usize> = (0..4).collect();
        a.execute_bitplane(&input);
        b.execute_bitplane_rows(&input, &rows);
        assert_eq!(a.execute_bitplane(&input), b.execute_bitplane(&input));
    }
}

//! The request router / worker pool (leader-worker, std threads).
//!
//! Architecture (vLLM-router-like, scaled to a simulated device):
//!
//! ```text
//!   clients ──▶ bounded request queue (backpressure)
//!                    │ leader: splits width-W vectors into N-wide
//!                    ▼         tile jobs, round-robins across workers
//!              worker 0..P-1   each owns its own Tile instances
//!                    │         (process variability sampled per worker)
//!                    ▼
//!              response channel → recombined outputs + metrics
//! ```
//!
//! Every worker owns private tiles and a private RNG, so runs are
//! deterministic for a fixed (seed, worker count) and workers never
//! contend on shared state — the hot loop is allocation-light.

use std::collections::HashMap;
use std::panic::AssertUnwindSafe;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::metrics::Metrics;
use super::plan::TilePlan;
use super::scheduler::{schedule_batch, BatchOutcome, SampleStats, ScratchArena};
use super::tile::{Tile, TileKind};
use crate::bitplane::early_term::CycleStats;
use crate::chaos::ChaosPlan;
use crate::wht;

/// Pool configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Tile dimension (16 or 32 in the paper).
    pub tile_n: usize,
    /// Input magnitude bitplanes.
    pub bits: u32,
    /// Worker threads (each simulating one crossbar macro chain).
    pub workers: usize,
    /// Bounded queue depth (backpressure limit).
    pub queue_depth: usize,
    /// Tile execution backend.
    pub kind: TileKind,
    /// RNG seed (variability sampling + analog noise).
    pub seed: u64,
    /// Fault-injection plan for chaos testing (worker panic / stall /
    /// slow-down points).  Disabled by default, and a compile-time
    /// no-op without the `chaos` cargo feature.
    pub chaos: ChaosPlan,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            tile_n: 16,
            bits: 8,
            workers: 4,
            queue_depth: 256,
            kind: TileKind::Digital,
            seed: 0,
            chaos: ChaosPlan::disabled(),
        }
    }
}

/// One transform request: a width-W vector (padded to a multiple of the
/// tile width by the router) and per-output thresholds in comparator
/// units.
#[derive(Debug, Clone)]
pub struct TransformRequest {
    pub x: Vec<f32>,
    pub thresholds_units: Vec<f64>,
    /// Pinned quantization scale for every tile block of this request;
    /// `None` quantizes each block against its own amax (the raw
    /// `/v1/transform` default).  The NN executors pin the activation's
    /// global scale here so the tiled transform is bit-identical to the
    /// whole-width golden model (see [`crate::exec`]).
    pub scale: Option<f32>,
    /// Absolute end-to-end deadline, propagated from the serving layer
    /// (`X-Deadline-Ms`).  A worker cancels samples whose deadline has
    /// already passed *before* scheduling them — the reply slot at the
    /// connection has already 504'd, so executing would burn tile
    /// cycles on an answer nobody is waiting for.  `None` (the
    /// library/bench default) never expires.
    pub deadline: Option<Instant>,
}

impl TransformRequest {
    /// A request with per-block quantization, no early termination and
    /// no deadline.
    pub fn plain(x: Vec<f32>) -> TransformRequest {
        let thresholds_units = vec![0.0; x.len()];
        TransformRequest {
            x,
            thresholds_units,
            scale: None,
            deadline: None,
        }
    }
}

/// Internal job: one or more same-partition requests plus their resolved
/// [`TilePlan`].
///
/// PERF: jobs were originally one per tile-sized block; the per-job
/// channel + allocation overhead dominated at small tiles (≈14 µs per
/// dim-64 request vs ≈11 µs of useful tile work).  One job per request
/// amortizes the dispatch, and [`Coordinator::transform_batch_planned`]
/// goes further: one job per *worker chunk* of a whole batch, streamed
/// through the worker's tile by the batch-fused engine
/// ([`schedule_batch`]) with quantizer/row-map setup hoisted out of the
/// per-sample loop.
struct TileJob {
    request_id: u64,
    reqs: Vec<TransformRequest>,
    plan: Arc<TilePlan>,
}

struct TileResult {
    request_id: u64,
    /// One output vector per request in the job, in request order.
    values: Vec<Vec<f32>>,
    outcome_stats: CycleStats,
    planes_issued: u32,
    row_cycles: u64,
    /// Engine counters attributed per request of the job, in request
    /// order (aligned with `values`).
    per_sample: Vec<SampleStats>,
    /// Per-request deadline-cancellation flags, aligned with `values`:
    /// `true` samples were never scheduled (their deadline had passed
    /// when the worker picked the job up) and carry zeroed outputs.
    expired: Vec<bool>,
    elapsed: std::time::Duration,
}

/// Run one job on a worker's tile, cancelling samples whose deadline
/// has already passed.  The live subset streams through
/// [`schedule_batch`] — the engine's RNG streams are batching-invariant
/// (PR 5), so executing a subset of a fused job stays bit-identical to
/// the full run — and expired samples come back zero-filled with their
/// flag set, so the drain side reports the cancellation instead of
/// inventing data.
fn execute_job(
    tile: &mut Tile,
    job: &TileJob,
    bits: u32,
    arena: &mut ScratchArena,
) -> (BatchOutcome, Vec<bool>) {
    let now = Instant::now();
    let expired: Vec<bool> = job
        .reqs
        .iter()
        .map(|r| r.deadline.is_some_and(|d| now >= d))
        .collect();
    if !expired.iter().any(|&e| e) {
        let out = schedule_batch(tile, &job.plan, &job.reqs, bits, arena);
        return (out, expired);
    }
    let width = job.plan.width();
    let live: Vec<TransformRequest> = job
        .reqs
        .iter()
        .zip(&expired)
        .filter(|&(_, &e)| !e)
        .map(|(r, _)| r.clone())
        .collect();
    let mut out = if live.is_empty() {
        BatchOutcome {
            values: Vec::new(),
            stats: CycleStats::new(bits),
            planes_issued: 0,
            row_cycles: 0,
            per_sample: Vec::new(),
        }
    } else {
        schedule_batch(tile, &job.plan, &live, bits, arena)
    };
    // Scatter live outputs back into request order; expired slots get
    // zeroed outputs and default (all-zero) engine counters.
    let mut live_values = out.values.into_iter();
    let mut live_stats = out.per_sample.into_iter();
    out.values = Vec::with_capacity(job.reqs.len());
    out.per_sample = Vec::with_capacity(job.reqs.len());
    for &e in &expired {
        if e {
            out.values.push(vec![0.0; width]);
            out.per_sample.push(SampleStats::default());
        } else {
            out.values.push(live_values.next().expect("live output per live request"));
            out.per_sample.push(live_stats.next().expect("live stats per live request"));
        }
    }
    (out, expired)
}

/// One completed request from [`Coordinator::drain_one`] /
/// [`Coordinator::drain_batch`].
#[derive(Debug, Clone)]
pub struct CompletedTransform {
    pub request_id: u64,
    /// Outputs at padded width (raw submissions) or at the block
    /// partition's exact width (planned submissions).
    pub values: Vec<f32>,
    /// Worker busy time spent on this request.  For a fused multi-sample
    /// job this is the job's busy time apportioned by row-cycle share,
    /// so the samples of one job sum (up to rounding) to the job's
    /// elapsed time.
    pub busy: std::time::Duration,
    /// Bitplanes the engine actually issued for this request.
    pub planes_issued: u32,
    /// Row activation cycles executed (the energy proxy).
    pub row_cycles: u64,
    /// Output elements produced.
    pub elements: u64,
    /// Elements that resolved before their final bitplane (ET depth).
    pub terminated_early: u64,
    /// The sample's deadline had passed when the worker picked its job
    /// up: it was cancelled before scheduling and `values` is zeros.
    /// The serving layer has already 504'd the reply slot by the time
    /// this drains, so the router drops the payload instead of
    /// gathering it.
    pub expired: bool,
}

/// One completed *job* from [`Coordinator::drain_batch`]: the fused
/// job's identity and total busy time plus one per-sample
/// [`CompletedTransform`] payload per submitted request, in submission
/// order.  Single-sample jobs come back as one-element batches, so a
/// caller draining a mixed stream of fused and unfused submissions
/// handles both through this one envelope.
#[derive(Debug, Clone)]
pub struct CompletedBatch {
    pub request_id: u64,
    /// Worker busy time for the whole fused job.
    pub busy: std::time::Duration,
    /// Per-sample payloads, in submission order.
    pub samples: Vec<CompletedTransform>,
}

/// The leader + worker pool.
pub struct Coordinator {
    config: CoordinatorConfig,
    job_tx: SyncSender<TileJob>,
    /// Worker results; `Err` is a worker that died mid-job (panic) —
    /// the job's failure is delivered instead of stranding the drain.
    result_rx: Receiver<Result<TileResult, String>>,
    workers: Vec<JoinHandle<Metrics>>,
    next_request: u64,
    /// Requests submitted via [`Coordinator::submit`]/`try_submit` whose
    /// results have not been drained yet.  The synchronous APIs refuse
    /// to run while any are outstanding (they would steal each other's
    /// results off the shared channel).
    pending_async: usize,
    metrics: Arc<Mutex<Metrics>>,
    /// Per-pool [`TilePlan`] caches keyed by raw request width (uniform
    /// pad-to-tile plans) and by explicit block partition.  Plan
    /// resolution walks `plan::subtile_rows`' global mutex once per
    /// block, so before these caches every submission paid one mutex
    /// hit per block at the boundary; now a repeated shape is a single
    /// `HashMap` probe and an `Arc` bump — the submission path is
    /// lock-free in steady state.
    uniform_plans: HashMap<usize, Arc<TilePlan>>,
    partition_plans: HashMap<Vec<usize>, Arc<TilePlan>>,
}

/// Bound on the per-pool plan caches: serving workloads see a handful
/// of shapes, but a pathological client cycling widths must not grow
/// the maps without limit.
const PLAN_CACHE_CAP: usize = 1024;

impl Coordinator {
    pub fn new(config: CoordinatorConfig) -> Coordinator {
        assert!(config.workers >= 1);
        let (job_tx, job_rx) = sync_channel::<TileJob>(config.queue_depth);
        let (result_tx, result_rx) = sync_channel::<Result<TileResult, String>>(config.queue_depth);
        let job_rx = Arc::new(Mutex::new(job_rx));
        let metrics = Arc::new(Mutex::new(Metrics::new(config.bits)));
        let mut workers = Vec::new();
        for w in 0..config.workers {
            let job_rx = Arc::clone(&job_rx);
            let result_tx = result_tx.clone();
            let kind = config.kind.clone();
            let tile_n = config.tile_n;
            let bits = config.bits;
            let seed = config.seed.wrapping_add(w as u64 * 0x9E37);
            let chaos_panic = config.chaos.point_indexed("pool.worker.panic", w as u64);
            let chaos_stall = config.chaos.point_indexed("pool.worker.stall", w as u64);
            let chaos_slow = config.chaos.point_indexed("pool.worker.slow", w as u64);
            workers.push(std::thread::spawn(move || {
                let mut tile = Tile::new(tile_n, &kind, seed);
                // The worker's long-lived scratch: the engine's plane
                // loop performs no heap allocation in steady state.
                let mut arena = ScratchArena::new();
                let mut local = Metrics::new(bits);
                loop {
                    let job = {
                        let guard = job_rx.lock().expect("job queue poisoned");
                        guard.recv()
                    };
                    let Ok(job) = job else { break };
                    if chaos_stall.fire() {
                        std::thread::sleep(crate::chaos::STALL);
                    }
                    if chaos_slow.fire() {
                        std::thread::sleep(crate::chaos::SLOWDOWN);
                    }
                    let t0 = Instant::now();
                    // A panic inside the engine used to strand the job:
                    // its result never arrived, so the drain side blocked
                    // forever on a channel other workers kept alive.  Now
                    // the unwinding is caught, the job fails loudly (the
                    // router turns the error into poisoned-shard
                    // failover) and the worker exits like the crashed
                    // thread it just became.
                    let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
                        if chaos_panic.fire() {
                            panic!("chaos: injected pool worker panic");
                        }
                        execute_job(&mut tile, &job, bits, &mut arena)
                    }));
                    let elapsed = t0.elapsed();
                    match outcome {
                        Ok((out, expired)) => {
                            let served = expired.iter().filter(|&&e| !e).count();
                            local.record_job(
                                &out.stats,
                                out.planes_issued,
                                out.row_cycles,
                                served,
                                elapsed,
                            );
                            let _ = result_tx.send(Ok(TileResult {
                                request_id: job.request_id,
                                values: out.values,
                                outcome_stats: out.stats,
                                planes_issued: out.planes_issued,
                                row_cycles: out.row_cycles,
                                per_sample: out.per_sample,
                                expired,
                                elapsed,
                            }));
                        }
                        Err(_) => {
                            let _ = result_tx.send(Err(format!(
                                "worker {w} panicked executing job {}",
                                job.request_id
                            )));
                            break;
                        }
                    }
                }
                local
            }));
        }
        Coordinator {
            config,
            job_tx,
            result_rx,
            workers,
            next_request: 0,
            pending_async: 0,
            metrics,
            uniform_plans: HashMap::new(),
            partition_plans: HashMap::new(),
        }
    }

    /// Resolve (and cache) the uniform pad-to-tile plan for a raw
    /// request of `width` elements.
    fn uniform_plan(&mut self, width: usize) -> Arc<TilePlan> {
        if let Some(p) = self.uniform_plans.get(&width) {
            return Arc::clone(p);
        }
        if self.uniform_plans.len() >= PLAN_CACHE_CAP {
            self.uniform_plans.clear();
        }
        let p = Arc::new(TilePlan::uniform(self.config.tile_n, width));
        self.uniform_plans.insert(width, Arc::clone(&p));
        p
    }

    /// Resolve (and cache) the plan for an explicit block partition.
    /// Only valid partitions are cached, so a bad partition keeps
    /// erroring on every submission.
    fn partition_plan(&mut self, blocks: &[usize]) -> Result<Arc<TilePlan>> {
        if let Some(p) = self.partition_plans.get(blocks) {
            return Ok(Arc::clone(p));
        }
        if self.partition_plans.len() >= PLAN_CACHE_CAP {
            self.partition_plans.clear();
        }
        let p = Arc::new(TilePlan::new(self.config.tile_n, blocks)?);
        self.partition_plans.insert(blocks.to_vec(), Arc::clone(&p));
        Ok(p)
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.config
    }

    /// Requests submitted via [`Coordinator::submit`]/`try_submit` whose
    /// results have not been drained yet.  Callers multiplexing the
    /// async API (the [`crate::exec::Pooled`] executor) check this is
    /// zero before starting, so they never steal a foreign result off
    /// the shared channel.
    pub fn pending_async(&self) -> usize {
        self.pending_async
    }

    /// Validate the pool configuration at the submission boundary: a
    /// misconfigured `bits` (0, or past the quantizer's 16-bitplane
    /// ceiling) used to surface as a `Quantizer::new` panic deep inside a
    /// worker thread; now every submission API reports it as a clean
    /// error instead (mirroring the CLI's up-front `--tile`/`--bits`
    /// validation).
    fn validate_config(&self) -> Result<()> {
        let bits = self.config.bits;
        if !(1..=16).contains(&bits) {
            bail!(
                "pool is configured with bits = {bits}; the sign-magnitude quantizer \
                 supports 1..=16 magnitude bitplanes"
            );
        }
        Ok(())
    }

    /// Validate a request up front, so malformed input is a clean error
    /// at the submission boundary instead of a worker-side panic.
    fn validate(req: &TransformRequest) -> Result<()> {
        if req.x.is_empty() {
            bail!("transform request has an empty input vector");
        }
        if req.thresholds_units.len() != req.x.len() {
            bail!(
                "thresholds_units length {} does not match input length {}",
                req.thresholds_units.len(),
                req.x.len()
            );
        }
        if let Some(s) = req.scale {
            if !(s.is_finite() && s > 0.0) {
                bail!("pinned quantization scale must be positive and finite, got {s}");
            }
        }
        Ok(())
    }

    /// Build the job for one request.  `blocks = None` is the raw-serving
    /// default: pad to whole `tile_n` blocks (padding elements carry a
    /// zero threshold).  `blocks = Some(partition)` carries an explicit
    /// block partition — the NN executors' path — which must cover the
    /// request exactly; blocks narrower than the tile run under sub-tile
    /// masking.
    fn make_job(&mut self, req: &TransformRequest, blocks: Option<&[usize]>) -> Result<TileJob> {
        self.validate_config()?;
        Self::validate(req)?;
        let (x, thresholds, plan) = match blocks {
            None => {
                let plan = self.uniform_plan(req.x.len());
                let mut x = req.x.clone();
                x.resize(plan.width(), 0.0);
                let mut th = req.thresholds_units.clone();
                th.resize(plan.width(), 0.0);
                (x, th, plan)
            }
            Some(blocks) => {
                let plan = self.partition_plan(blocks)?;
                if plan.width() != req.x.len() {
                    bail!(
                        "block partition {blocks:?} covers {} elements, but the request \
                         is {} wide",
                        plan.width(),
                        req.x.len()
                    );
                }
                (req.x.clone(), req.thresholds_units.clone(), plan)
            }
        };
        let id = self.next_request;
        self.next_request += 1;
        Ok(TileJob {
            request_id: id,
            reqs: vec![TransformRequest {
                x,
                thresholds_units: thresholds,
                scale: req.scale,
                deadline: req.deadline,
            }],
            plan,
        })
    }

    /// Record one tile result into the shared metrics (see
    /// [`Metrics::record_job`] for the batch-job latency semantics).
    fn record(&self, r: &TileResult) {
        let mut m = self.metrics.lock().expect("metrics poisoned");
        m.record_job(
            &r.outcome_stats,
            r.planes_issued,
            r.row_cycles,
            r.values.len(),
            r.elapsed,
        );
    }

    /// Dispatch jobs and collect exactly `total` results.
    ///
    /// Sending happens on a helper thread so a job list deeper than the
    /// bounded queues cannot deadlock the leader against the workers
    /// (leader blocked on job_tx while workers block on result_tx).
    fn dispatch_collect(&mut self, jobs: Vec<TileJob>) -> Result<Vec<TileResult>> {
        let total = jobs.len();
        let job_tx = self.job_tx.clone();
        let mut results = Vec::with_capacity(total);
        std::thread::scope(|scope| -> Result<()> {
            let sender = scope.spawn(move || {
                for job in jobs {
                    if job_tx.send(job).is_err() {
                        return Err(anyhow!("worker pool shut down"));
                    }
                }
                Ok(())
            });
            for _ in 0..total {
                let r = self
                    .result_rx
                    .recv()
                    .map_err(|_| anyhow!("workers disconnected"))?
                    .map_err(|e| anyhow!(e))?;
                self.record(&r);
                results.push(r);
            }
            sender.join().expect("sender thread panicked")
        })?;
        Ok(results)
    }

    /// Clean error if async submissions are outstanding — the sync APIs
    /// would otherwise pop the wrong results off the shared channel.
    fn ensure_no_pending_async(&self) -> Result<()> {
        if self.pending_async > 0 {
            bail!(
                "{} submitted request(s) not yet drained; call drain_one() before \
                 transform()/transform_batch()",
                self.pending_async
            );
        }
        Ok(())
    }

    /// Execute one transform request synchronously.  Returns outputs at
    /// padded width (whole `tile_n` blocks).
    pub fn transform(&mut self, req: &TransformRequest) -> Result<Vec<f32>> {
        self.transform_inner(req, None)
    }

    /// Execute one request over an explicit block partition (sub-tile
    /// blocks run under masking).  Returns outputs at the partition's
    /// exact width — no padding.
    pub fn transform_planned(
        &mut self,
        req: &TransformRequest,
        blocks: &[usize],
    ) -> Result<Vec<f32>> {
        self.transform_inner(req, Some(blocks))
    }

    fn transform_inner(
        &mut self,
        req: &TransformRequest,
        blocks: Option<&[usize]>,
    ) -> Result<Vec<f32>> {
        self.ensure_no_pending_async()?;
        let job = self.make_job(req, blocks)?;
        let id = job.request_id;
        let mut results = self.dispatch_collect(vec![job])?;
        let r = results.pop().expect("one job, one result");
        assert_eq!(r.request_id, id, "single-flight transform");
        Ok(r.values.into_iter().next().expect("one request per job"))
    }

    /// Execute a batch of requests, pipelining all jobs across the pool
    /// before collecting (the batcher path).  Requests may have
    /// different widths; each is padded to whole `tile_n` blocks
    /// independently.
    pub fn transform_batch(&mut self, reqs: &[TransformRequest]) -> Result<Vec<Vec<f32>>> {
        self.ensure_no_pending_async()?;
        let base = self.next_request;
        let jobs: Vec<TileJob> = reqs
            .iter()
            .map(|r| self.make_job(r, None))
            .collect::<Result<_>>()?;
        let results = self.dispatch_collect(jobs)?;
        let mut outs: Vec<Vec<f32>> = vec![Vec::new(); reqs.len()];
        for r in results {
            let req_idx = (r.request_id - base) as usize;
            outs[req_idx] = r.values.into_iter().next().expect("one request per job");
        }
        Ok(outs)
    }

    /// Execute a whole batch of same-partition requests through the
    /// batch-fused engine: the batch is split into contiguous
    /// multi-sample chunks (up to 4x the worker count, so skewed batches
    /// load-balance across the pool), each chunk streams through one
    /// tile as a single job ([`schedule_batch`] — quantizer
    /// construction, row-map lookups and the identity-row decision
    /// hoisted out of the per-sample loop, no per-plane allocation), and
    /// outputs come back in request order at the partition's exact
    /// width.
    ///
    /// This is the [`crate::exec::Pooled`] executor's path.  On digital
    /// tiles it is bit-identical to submitting every request on its own
    /// (and to [`crate::nn::Backend::Quantized`] with pinned scales).
    pub fn transform_batch_planned(
        &mut self,
        reqs: &[TransformRequest],
        blocks: &[usize],
    ) -> Result<Vec<Vec<f32>>> {
        self.ensure_no_pending_async()?;
        self.validate_config()?;
        let plan = self.partition_plan(blocks)?;
        for req in reqs {
            Self::validate(req)?;
            if req.x.len() != plan.width() {
                bail!(
                    "request is {} wide, but the block partition {blocks:?} covers {}",
                    req.x.len(),
                    plan.width()
                );
            }
        }
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        // More chunks than workers (4x) so a skewed batch load-balances:
        // early-terminating chunks finish fast and their workers pull
        // the next queued chunk instead of idling behind one expensive
        // contiguous run; each chunk still amortizes per-plan setup over
        // several samples.
        let chunks = (self.config.workers * 4).min(reqs.len());
        let chunk_base = reqs.len() / chunks;
        let extra = reqs.len() % chunks;
        let base_id = self.next_request;
        let mut jobs = Vec::with_capacity(chunks);
        let mut chunk_starts = Vec::with_capacity(chunks);
        let mut off = 0usize;
        for c in 0..chunks {
            let take = chunk_base + usize::from(c < extra);
            let id = self.next_request;
            self.next_request += 1;
            // One clone per request, total: the data has to cross the
            // worker thread boundary owned, and the executor trait hands
            // us a borrow — an Arc<[_]> handoff would copy the same
            // bytes once to build the Arc.
            jobs.push(TileJob {
                request_id: id,
                reqs: reqs[off..off + take].to_vec(),
                plan: Arc::clone(&plan),
            });
            chunk_starts.push(off);
            off += take;
        }
        let results = self.dispatch_collect(jobs)?;
        let mut outs: Vec<Vec<f32>> = vec![Vec::new(); reqs.len()];
        for r in results {
            let chunk = (r.request_id - base_id) as usize;
            let start = chunk_starts[chunk];
            for (k, v) in r.values.into_iter().enumerate() {
                outs[start + k] = v;
            }
        }
        Ok(outs)
    }

    /// Submit one request without waiting for its result (blocks only
    /// while the bounded job queue is full).  Pair with
    /// [`Coordinator::drain_one`].
    pub fn submit(&mut self, req: &TransformRequest) -> Result<u64> {
        self.submit_inner(req, None)
    }

    /// [`Coordinator::submit`] over an explicit block partition.
    pub fn submit_planned(&mut self, req: &TransformRequest, blocks: &[usize]) -> Result<u64> {
        self.submit_inner(req, Some(blocks))
    }

    fn submit_inner(&mut self, req: &TransformRequest, blocks: Option<&[usize]>) -> Result<u64> {
        let job = self.make_job(req, blocks)?;
        let id = job.request_id;
        self.job_tx
            .send(job)
            .map_err(|_| anyhow!("worker pool shut down"))?;
        self.pending_async += 1;
        Ok(id)
    }

    /// Non-blocking submit: returns `Ok(None)` when the bounded queue is
    /// full, so admission layers can shed load instead of deadlocking
    /// behind the backpressure limit.
    pub fn try_submit(&mut self, req: &TransformRequest) -> Result<Option<u64>> {
        self.try_submit_inner(req, None)
    }

    /// [`Coordinator::try_submit`] over an explicit block partition
    /// (the executor/router path; sub-tile blocks run under masking).
    pub fn try_submit_planned(
        &mut self,
        req: &TransformRequest,
        blocks: &[usize],
    ) -> Result<Option<u64>> {
        self.try_submit_inner(req, Some(blocks))
    }

    fn try_submit_inner(
        &mut self,
        req: &TransformRequest,
        blocks: Option<&[usize]>,
    ) -> Result<Option<u64>> {
        let job = self.make_job(req, blocks)?;
        let id = job.request_id;
        match self.job_tx.try_send(job) {
            Ok(()) => {
                self.pending_async += 1;
                Ok(Some(id))
            }
            Err(TrySendError::Full(_)) => Ok(None),
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("worker pool shut down")),
        }
    }

    /// Non-blocking *batched* submit: enqueue `reqs` as one fused job
    /// that a single worker streams through its tile via the batch-fused
    /// engine ([`schedule_batch`]) — N same-partition samples, one
    /// channel send, one dispatch.  Returns `Ok(None)` on backpressure
    /// (bounded queue full).  Pair with [`Coordinator::drain_batch`],
    /// which hands back one [`CompletedTransform`] payload per sample.
    ///
    /// The caller supplies the resolved [`TilePlan`] directly (the shard
    /// router caches sub-plans per lane shape), so repeated fused
    /// submissions of the same shape are an `Arc` bump — no plan
    /// re-resolution, no cache probe.  The plan must have been resolved
    /// for this pool's tile width, and every request must span exactly
    /// `plan.width()` elements.
    pub fn try_submit_batch_planned(
        &mut self,
        reqs: &[TransformRequest],
        plan: &Arc<TilePlan>,
    ) -> Result<Option<u64>> {
        self.validate_config()?;
        if reqs.is_empty() {
            bail!("batched submission needs at least one request");
        }
        if plan.tile_n() != self.config.tile_n {
            bail!(
                "plan was resolved for {}-wide tiles, but this pool runs {}-wide tiles",
                plan.tile_n(),
                self.config.tile_n
            );
        }
        for req in reqs {
            Self::validate(req)?;
            if req.x.len() != plan.width() {
                bail!(
                    "request is {} wide, but the plan covers {}",
                    req.x.len(),
                    plan.width()
                );
            }
        }
        let id = self.next_request;
        self.next_request += 1;
        let job = TileJob {
            request_id: id,
            reqs: reqs.to_vec(),
            plan: Arc::clone(plan),
        };
        match self.job_tx.try_send(job) {
            Ok(()) => {
                self.pending_async += 1;
                Ok(Some(id))
            }
            Err(TrySendError::Full(_)) => Ok(None),
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("worker pool shut down")),
        }
    }

    /// Block for the next completed *job*, folding its stats into the
    /// shared metrics and decomposing it into per-sample payloads (in
    /// submission order).  Jobs arrive in completion order, not submit
    /// order — correlate via the returned request id.  Each sample's
    /// `busy` is the job's elapsed time apportioned by row-cycle share
    /// (equal split when the job executed zero row-cycles), so the trace
    /// layer can lay per-slice execute spans end to end inside the job's
    /// real execution window.
    pub fn drain_batch(&mut self) -> Result<CompletedBatch> {
        let r = self
            .result_rx
            .recv()
            .map_err(|_| anyhow!("workers disconnected"))?;
        self.pending_async = self.pending_async.saturating_sub(1);
        let r = r.map_err(|e| anyhow!(e))?;
        self.record(&r);
        let request_id = r.request_id;
        let elapsed = r.elapsed;
        let n = r.values.len();
        debug_assert_eq!(r.per_sample.len(), n);
        debug_assert_eq!(r.expired.len(), n);
        let total_rc: u64 = r.per_sample.iter().map(|s| s.row_cycles).sum();
        let samples = r
            .values
            .into_iter()
            .zip(r.per_sample)
            .zip(r.expired)
            .map(|((values, s), expired)| {
                let busy = if total_rc == 0 {
                    elapsed / (n.max(1) as u32)
                } else {
                    elapsed.mul_f64(s.row_cycles as f64 / total_rc as f64)
                };
                CompletedTransform {
                    request_id,
                    values,
                    busy,
                    planes_issued: s.planes_issued,
                    row_cycles: s.row_cycles,
                    elements: s.elements,
                    terminated_early: s.terminated_early,
                    expired,
                }
            })
            .collect();
        Ok(CompletedBatch {
            request_id,
            busy: elapsed,
            samples,
        })
    }

    /// Block for the next completed request, folding its stats into the
    /// shared metrics.  Results arrive in completion order, not submit
    /// order — correlate via the returned request id.  Only valid for
    /// single-sample submissions ([`Coordinator::submit`]/`try_submit`
    /// and their planned variants): draining a fused multi-sample job
    /// here is a clean error — use [`Coordinator::drain_batch`].
    pub fn drain_one(&mut self) -> Result<CompletedTransform> {
        let mut batch = self.drain_batch()?;
        if batch.samples.len() != 1 {
            bail!(
                "drain_one drained fused job {} carrying {} samples; batched submissions \
                 must be drained with drain_batch",
                batch.request_id,
                batch.samples.len()
            );
        }
        Ok(batch.samples.pop().expect("length checked above"))
    }

    /// Snapshot of aggregated metrics.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().expect("metrics poisoned").clone()
    }

    /// Shared handle to the live aggregated metrics — lets a serving
    /// front-end snapshot metrics while another thread owns the
    /// coordinator itself.
    pub fn metrics_handle(&self) -> Arc<Mutex<Metrics>> {
        Arc::clone(&self.metrics)
    }

    /// Chaos/test hook: sever the job channel so the workers finish what
    /// is already queued and exit.  Subsequent `submit`/`try_submit`
    /// calls fail with "worker pool shut down", and `drain_one` fails
    /// once buffered results are consumed — the failure signal
    /// [`crate::shard`]'s router turns into poisoned-shard load shedding.
    pub fn abort(&mut self) {
        let (dead_tx, _) = sync_channel::<TileJob>(1);
        self.job_tx = dead_tx;
    }

    /// Shut the pool down and collect per-worker metrics.
    pub fn shutdown(self) -> Metrics {
        drop(self.job_tx);
        let mut total = Metrics::new(self.config.bits);
        for w in self.workers {
            if let Ok(m) = w.join() {
                total.merge(&m);
            }
        }
        total
    }

    /// BWHT blocks a width-W request maps onto (for callers sizing work).
    pub fn blocks_for(&self, width: usize) -> Vec<usize> {
        wht::bwht_blocks(width, self.config.tile_n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitplane::QuantBwht;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut r = crate::util::rng::Rng::seed_from_u64(seed);
        (0..n).map(|_| r.uniform_range(-1.0, 1.0) as f32).collect()
    }

    #[test]
    fn single_tile_request_matches_golden_model() {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        let x = sample(16, 1);
        let out = c
            .transform(&TransformRequest {
                x: x.clone(),
                thresholds_units: vec![0.0; 16],
                scale: None,
                deadline: None,
            })
            .unwrap();
        let golden = QuantBwht::new(16, 128, 8).transform(&x);
        assert_eq!(out, golden);
        c.shutdown();
    }

    #[test]
    fn multi_block_request_reassembles_in_order() {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        let x = sample(64, 2); // 4 tile blocks
        let out = c
            .transform(&TransformRequest {
                x: x.clone(),
                thresholds_units: vec![0.0; 64],
                scale: None,
                deadline: None,
            })
            .unwrap();
        // blockwise golden: each 16-slice transformed independently
        for b in 0..4 {
            let golden = QuantBwht::new(16, 128, 8).transform(&x[b * 16..(b + 1) * 16]);
            assert_eq!(&out[b * 16..(b + 1) * 16], &golden[..], "block {b}");
        }
        c.shutdown();
    }

    #[test]
    fn batch_matches_sequential() {
        let reqs: Vec<TransformRequest> = (0..6)
            .map(|i| TransformRequest {
                x: sample(32, 10 + i),
                thresholds_units: vec![0.0; 32],
                scale: None,
                deadline: None,
            })
            .collect();
        let mut c1 = Coordinator::new(CoordinatorConfig::default());
        let batch = c1.transform_batch(&reqs).unwrap();
        let mut c2 = Coordinator::new(CoordinatorConfig::default());
        for (i, r) in reqs.iter().enumerate() {
            let single = c2.transform(r).unwrap();
            assert_eq!(batch[i], single, "request {i}");
        }
        c1.shutdown();
        c2.shutdown();
    }

    #[test]
    fn pads_non_multiple_widths() {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        let out = c
            .transform(&TransformRequest {
                x: sample(20, 3),
                thresholds_units: vec![0.0; 20],
                scale: None,
                deadline: None,
            })
            .unwrap();
        assert_eq!(out.len(), 32);
        c.shutdown();
    }

    #[test]
    fn planned_mixed_partition_matches_whole_width_golden_model() {
        // Width 20 as [16, 4]: the 4-block runs under sub-tile masking
        // on a 16-wide tile.  With the global quantization scale pinned,
        // the output is bit-identical to the 20-wide golden model.
        let mut c = Coordinator::new(CoordinatorConfig::default());
        let x = sample(20, 60);
        let scale = crate::quant::Quantizer::new(8).scale_for(&x);
        let out = c
            .transform_planned(
                &TransformRequest {
                    x: x.clone(),
                    thresholds_units: vec![0.0; 20],
                    scale: Some(scale),
                    deadline: None,
                },
                &[16, 4],
            )
            .unwrap();
        let golden = QuantBwht::new(20, 128, 8).transform(&x);
        assert_eq!(out, golden);
        assert_eq!(out.len(), 20, "planned requests are not padded");
        let m = c.metrics();
        assert_eq!(m.cycles.total_elements, 20, "masked rows are not billed");
        c.shutdown();
    }

    #[test]
    fn planned_partition_is_validated_at_the_boundary() {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        let req = TransformRequest::plain(sample(20, 61));
        // Partition does not cover the request.
        assert!(c.transform_planned(&req, &[16]).is_err());
        // Block wider than the tile.
        assert!(c.transform_planned(&req, &[32]).is_err());
        // Non-power-of-two block.
        let req12 = TransformRequest::plain(sample(12, 62));
        assert!(c.transform_planned(&req12, &[12]).is_err());
        // The pool still serves afterwards.
        assert_eq!(
            c.transform_planned(&req, &[16, 4]).unwrap().len(),
            20
        );
        c.shutdown();
    }

    #[test]
    fn metrics_accumulate_across_requests() {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        for i in 0..5 {
            c.transform(&TransformRequest {
                x: sample(16, 20 + i),
                thresholds_units: vec![0.0; 16],
                scale: None,
                deadline: None,
            })
            .unwrap();
        }
        let m = c.metrics();
        assert_eq!(m.requests, 5);
        assert_eq!(m.cycles.total_elements, 5 * 16);
        assert_eq!(m.row_cycles, 5 * 16 * 8, "T=0: no early termination");
        c.shutdown();
    }

    #[test]
    fn early_termination_reduces_row_cycles() {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        c.transform(&TransformRequest {
            x: sample(16, 30),
            thresholds_units: vec![1e9; 16],
            scale: None,
            deadline: None,
        })
        .unwrap();
        let m = c.metrics();
        assert!(m.row_cycles < 16 * 8);
        assert!(m.average_cycles() < 2.0);
        c.shutdown();
    }

    #[test]
    fn batch_planned_matches_per_request_planned() {
        // The chunked batch-fused path must be bit-identical to planned
        // per-request submission, mixed partition + pinned scale included
        // (20 requests on a 4-worker pool -> 16 chunks, some multi-sample).
        let blocks = [16usize, 4];
        let reqs: Vec<TransformRequest> = (0..20)
            .map(|i| {
                let x = sample(20, 300 + i);
                TransformRequest {
                    thresholds_units: vec![2.0; 20],
                    scale: Some(crate::quant::Quantizer::new(8).scale_for(&x)),
                    deadline: None,
                    x,
                }
            })
            .collect();
        let mut c1 = Coordinator::new(CoordinatorConfig::default());
        let batched = c1.transform_batch_planned(&reqs, &blocks).unwrap();
        let mut c2 = Coordinator::new(CoordinatorConfig::default());
        for (i, req) in reqs.iter().enumerate() {
            let single = c2.transform_planned(req, &blocks).unwrap();
            assert_eq!(batched[i], single, "request {i}");
        }
        assert_eq!(
            c1.metrics().cycles.total_elements,
            c2.metrics().cycles.total_elements,
            "batched accounting must bill the same logical rows"
        );
        c1.shutdown();
        c2.shutdown();
    }

    #[test]
    fn batch_planned_handles_more_requests_than_workers_and_empty_batches() {
        let mut c = Coordinator::new(CoordinatorConfig {
            workers: 2,
            ..Default::default()
        });
        assert!(c.transform_batch_planned(&[], &[16]).unwrap().is_empty());
        let reqs: Vec<TransformRequest> = (0..5)
            .map(|i| TransformRequest::plain(sample(16, 400 + i)))
            .collect();
        let outs = c.transform_batch_planned(&reqs, &[16]).unwrap();
        for (i, req) in reqs.iter().enumerate() {
            let golden = QuantBwht::new(16, 128, 8).transform(&req.x);
            assert_eq!(outs[i], golden, "request {i}");
        }
        assert_eq!(c.metrics().requests, 5);
        c.shutdown();
    }

    #[test]
    fn invalid_bits_is_a_clean_submission_error_at_both_bounds() {
        // bits = 0 and an absurd bits = 64 used to panic inside a worker
        // thread (`Quantizer::new`); both must now fail at submission
        // with a clean error on every API, and the pool must stay alive.
        for bits in [0u32, 64] {
            let mut c = Coordinator::new(CoordinatorConfig {
                bits,
                ..Default::default()
            });
            let req = TransformRequest::plain(sample(16, 500 + bits as u64));
            let err = c.transform(&req).unwrap_err();
            assert!(err.to_string().contains("1..=16"), "bits={bits}: {err}");
            assert!(c.submit(&req).is_err(), "bits={bits}: submit");
            assert!(c.try_submit(&req).is_err(), "bits={bits}: try_submit");
            let batch = c.transform_batch_planned(std::slice::from_ref(&req), &[16]);
            assert!(batch.is_err(), "bits={bits}: batch planned");
            c.shutdown();
        }
        // The bounds themselves are valid.
        for bits in [1u32, 16] {
            let mut c = Coordinator::new(CoordinatorConfig {
                bits,
                ..Default::default()
            });
            let req = TransformRequest::plain(sample(16, 600 + bits as u64));
            assert_eq!(c.transform(&req).unwrap().len(), 16, "bits={bits}");
            c.shutdown();
        }
    }

    #[test]
    fn plan_cache_reuses_resolved_plans_across_submissions() {
        // The submission boundary must not re-resolve (and re-walk the
        // global `subtile_rows` mutex for) a shape it has already seen:
        // the second submission of each shape reuses the same Arc.
        let mut c = Coordinator::new(CoordinatorConfig::default());
        let raw = TransformRequest::plain(sample(20, 700));
        c.transform(&raw).unwrap();
        let cached = Arc::clone(c.uniform_plans.get(&20).expect("uniform plan cached"));
        c.transform(&raw).unwrap();
        assert!(
            Arc::ptr_eq(&cached, c.uniform_plans.get(&20).unwrap()),
            "repeat submission must reuse the cached uniform plan"
        );
        let planned = TransformRequest::plain(sample(20, 701));
        c.transform_planned(&planned, &[16, 4]).unwrap();
        let cached = Arc::clone(
            c.partition_plans
                .get([16usize, 4].as_slice())
                .expect("partition plan cached"),
        );
        c.transform_planned(&planned, &[16, 4]).unwrap();
        assert!(
            Arc::ptr_eq(&cached, c.partition_plans.get([16usize, 4].as_slice()).unwrap()),
            "repeat submission must reuse the cached partition plan"
        );
        // Invalid partitions are never cached and keep failing cleanly.
        assert!(c.transform_planned(&planned, &[32]).is_err());
        assert!(c.partition_plans.get([32usize].as_slice()).is_none());
        c.shutdown();
    }

    #[test]
    fn drain_one_reports_execution_stats() {
        // The trace layer attributes execute spans from these counters,
        // so drained results must carry the engine's energy proxies.
        let mut c = Coordinator::new(CoordinatorConfig::default());
        c.submit(&TransformRequest::plain(sample(16, 70))).unwrap();
        let done = c.drain_one().unwrap();
        assert_eq!(done.elements, 16);
        assert_eq!(done.row_cycles, 16 * 8, "T=0: no early termination");
        assert_eq!(done.terminated_early, 0);
        assert!(done.planes_issued > 0);
        c.shutdown();
    }

    #[test]
    fn batch_submit_drain_matches_per_sample_submission() {
        // One fused 6-sample job must come back bit-identical (and with
        // identical engine counters) to six single-sample submissions.
        let blocks = [16usize, 4];
        let reqs: Vec<TransformRequest> = (0..6)
            .map(|i| {
                let x = sample(20, 800 + i);
                TransformRequest {
                    thresholds_units: vec![1.5; 20],
                    scale: Some(crate::quant::Quantizer::new(8).scale_for(&x)),
                    deadline: None,
                    x,
                }
            })
            .collect();
        let mut fused = Coordinator::new(CoordinatorConfig::default());
        let plan = Arc::new(TilePlan::new(16, &blocks).unwrap());
        let id = fused
            .try_submit_batch_planned(&reqs, &plan)
            .unwrap()
            .expect("queue empty");
        assert_eq!(fused.pending_async(), 1);
        let batch = fused.drain_batch().unwrap();
        assert_eq!(batch.request_id, id);
        assert_eq!(batch.samples.len(), reqs.len());
        assert_eq!(fused.pending_async(), 0);

        let mut single = Coordinator::new(CoordinatorConfig::default());
        let mut busy_sum = std::time::Duration::ZERO;
        for (i, req) in reqs.iter().enumerate() {
            single.submit_planned(req, &blocks).unwrap();
            let want = single.drain_one().unwrap();
            let got = &batch.samples[i];
            assert_eq!(got.values, want.values, "sample {i}");
            assert_eq!(got.planes_issued, want.planes_issued, "sample {i}");
            assert_eq!(got.row_cycles, want.row_cycles, "sample {i}");
            assert_eq!(got.elements, want.elements, "sample {i}");
            assert_eq!(got.terminated_early, want.terminated_early, "sample {i}");
            busy_sum += got.busy;
        }
        // Apportioned busy decomposes the job's busy time (up to
        // sub-microsecond float rounding).
        let slack = std::time::Duration::from_micros(1);
        assert!(
            busy_sum <= batch.busy + slack && busy_sum + slack >= batch.busy,
            "per-sample busy {busy_sum:?} must decompose the job busy {:?}",
            batch.busy
        );
        // One fused job, six requests: the fusion factor is observable.
        let m = fused.metrics();
        assert_eq!(m.jobs, 1);
        assert_eq!(m.requests, 6);
        assert_eq!(single.metrics().jobs, 6);
        fused.shutdown();
        single.shutdown();
    }

    #[test]
    fn batch_submit_validates_at_the_boundary() {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        let plan = Arc::new(TilePlan::new(16, &[16, 4]).unwrap());
        // Empty fused jobs are refused.
        assert!(c.try_submit_batch_planned(&[], &plan).is_err());
        // Width mismatch against the supplied plan.
        let narrow = TransformRequest::plain(sample(16, 810));
        assert!(c
            .try_submit_batch_planned(std::slice::from_ref(&narrow), &plan)
            .is_err());
        // Plan resolved for another tile geometry.
        let other = Arc::new(TilePlan::new(32, &[32]).unwrap());
        let wide = TransformRequest::plain(sample(32, 811));
        assert!(c
            .try_submit_batch_planned(std::slice::from_ref(&wide), &other)
            .is_err());
        // The pool still serves after the refusals.
        let ok = TransformRequest::plain(sample(20, 812));
        let id = c
            .try_submit_batch_planned(std::slice::from_ref(&ok), &plan)
            .unwrap();
        assert!(id.is_some());
        assert_eq!(c.drain_batch().unwrap().samples.len(), 1);
        c.shutdown();
    }

    #[test]
    fn drain_one_refuses_fused_multi_sample_jobs() {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        let plan = Arc::new(TilePlan::new(16, &[16]).unwrap());
        let reqs: Vec<TransformRequest> =
            (0..3).map(|i| TransformRequest::plain(sample(16, 820 + i))).collect();
        c.try_submit_batch_planned(&reqs, &plan).unwrap().expect("queue empty");
        let err = c.drain_one().unwrap_err();
        assert!(err.to_string().contains("drain_batch"), "{err}");
        // Single-sample async submissions still drain through drain_one.
        c.submit(&TransformRequest::plain(sample(16, 830))).unwrap();
        assert_eq!(c.drain_one().unwrap().values.len(), 16);
        c.shutdown();
    }

    #[test]
    fn abort_fails_submissions_cleanly() {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        c.abort();
        assert!(c
            .submit(&TransformRequest {
                x: sample(16, 50),
                thresholds_units: vec![0.0; 16],
                scale: None,
                deadline: None,
            })
            .is_err());
        assert!(c.drain_one().is_err(), "no buffered results after abort");
        c.shutdown();
    }

    #[test]
    fn deterministic_across_worker_counts_digital() {
        let x = sample(48, 40);
        let run = |workers| {
            let mut c = Coordinator::new(CoordinatorConfig {
                workers,
                ..Default::default()
            });
            let out = c
                .transform(&TransformRequest {
                    x: x.clone(),
                    thresholds_units: vec![0.0; 48],
                    scale: None,
                    deadline: None,
                })
                .unwrap();
            c.shutdown();
            out
        };
        assert_eq!(run(1), run(4), "digital path must be worker-count invariant");
    }

    #[test]
    fn expired_deadline_cancels_before_scheduling() {
        let mut c = Coordinator::new(CoordinatorConfig {
            workers: 1,
            ..Default::default()
        });
        let x = sample(16, 900);
        let mut req = TransformRequest::plain(x.clone());
        req.deadline = Some(Instant::now() - std::time::Duration::from_millis(1));
        let live = TransformRequest::plain(sample(16, 901));
        let plan = Arc::new(TilePlan::new(16, &[16]).unwrap());
        c.try_submit_batch_planned(&[req, live.clone()], &plan)
            .unwrap()
            .expect("queue empty");
        let batch = c.drain_batch().unwrap();
        assert_eq!(batch.samples.len(), 2);
        assert!(batch.samples[0].expired, "past-deadline sample is cancelled");
        assert_eq!(batch.samples[0].values, vec![0.0; 16], "cancelled output is zeros");
        assert_eq!(batch.samples[0].row_cycles, 0, "no tile cycles billed");
        assert!(!batch.samples[1].expired);
        let golden = QuantBwht::new(16, 128, 8).transform(&live.x);
        assert_eq!(
            batch.samples[1].values, golden,
            "live sample of a partially-expired job stays bit-identical"
        );
        assert_eq!(c.metrics().requests, 1, "only the served sample is counted");
        c.shutdown();
    }

    #[test]
    fn future_deadline_executes_normally() {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        let x = sample(16, 902);
        let mut req = TransformRequest::plain(x.clone());
        req.deadline = Some(Instant::now() + std::time::Duration::from_secs(60));
        let out = c.transform(&req).unwrap();
        assert_eq!(out, QuantBwht::new(16, 128, 8).transform(&x));
        c.shutdown();
    }

    #[test]
    fn fully_expired_job_drains_without_touching_the_tile() {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        let mut req = TransformRequest::plain(sample(16, 903));
        req.deadline = Some(Instant::now() - std::time::Duration::from_millis(1));
        let plan = Arc::new(TilePlan::new(16, &[16]).unwrap());
        c.try_submit_batch_planned(std::slice::from_ref(&req), &plan)
            .unwrap()
            .expect("queue empty");
        let batch = c.drain_batch().unwrap();
        assert!(batch.samples[0].expired);
        assert_eq!(c.metrics().row_cycles, 0);
        assert_eq!(c.metrics().requests, 0);
        c.shutdown();
    }

    #[cfg(feature = "chaos")]
    #[test]
    fn injected_worker_panic_fails_the_job_instead_of_stranding_it() {
        // Before the catch_unwind in the worker loop, a panic stranded
        // the in-flight job: drain blocked forever on a channel the
        // surviving workers kept alive.  Now the panic comes back as a
        // clean drain error the router can turn into failover.
        let chaos = crate::chaos::ChaosPlan::parse("pool.worker.panic=1.0,1").unwrap();
        let mut c = Coordinator::new(CoordinatorConfig {
            workers: 1,
            chaos,
            ..Default::default()
        });
        c.submit(&TransformRequest::plain(sample(16, 910))).unwrap();
        let err = c.drain_one().unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        assert_eq!(c.pending_async(), 0, "failed job still consumed its slot");
        c.shutdown();
    }

    #[cfg(feature = "chaos")]
    #[test]
    fn injected_stall_slows_but_does_not_corrupt() {
        let chaos = crate::chaos::ChaosPlan::parse("pool.worker.stall=1.0,2").unwrap();
        let mut c = Coordinator::new(CoordinatorConfig {
            workers: 1,
            chaos,
            ..Default::default()
        });
        let x = sample(16, 911);
        let t0 = Instant::now();
        let out = c.transform(&TransformRequest::plain(x.clone())).unwrap();
        assert!(t0.elapsed() >= crate::chaos::STALL, "stall point must bite");
        assert_eq!(out, QuantBwht::new(16, 128, 8).transform(&x));
        c.shutdown();
    }
}

//! Unified transform execution: one seam between the NN layers and every
//! substrate that can run a BWHT transform.
//!
//! Before this module, [`crate::nn::BwhtLayer`] computed its transforms
//! with private software loops (`Backend::{Float,Quantized,Noisy}`) that
//! never touched the tile scheduler, early termination, variability or
//! metrics machinery in [`crate::coordinator`] and [`crate::shard`].
//! The [`TransformExecutor`] trait closes that gap: the layer hands a
//! *batch* of [`TransformRequest`]s (one per sample, with per-channel
//! early-termination thresholds and the activation's pinned quantization
//! scale) to an executor and gets the frequency/spatial vectors back —
//! wherever they were computed:
//!
//! * [`InProcess`] — the original software loops (exact float, digital
//!   golden model, ANT-noisy), now with one RNG stream per sample index
//!   so noisy results are batch-size invariant;
//! * [`Pooled`] — a [`crate::coordinator::Coordinator`] tile pool; the
//!   batch is chunked across the workers via `transform_batch_planned`,
//!   each chunk streaming through one tile on the zero-allocation
//!   batch-fused engine ([`crate::coordinator::schedule_batch`]);
//! * [`Sharded`] — a [`crate::shard::ShardSet`], scatter–gathering each
//!   sample's blocks across every healthy pool.
//!
//! Bit-identity contract: on digital tiles, `Pooled` and `Sharded` are
//! **bit-identical** to [`Backend::Quantized`](crate::nn::Backend) for
//! *any* block partition whose widest block fits the pool's tile —
//! mixed partitions like `[128, 64, 16, 4]` included.  Blocks narrower
//! than the tile run under sub-tile masking
//! ([`crate::coordinator::plan::TilePlan`]): zero-padded input columns
//! plus a masked output row set computes the small transform
//! bit-exactly on the big tile, and pinned scales reproduce the
//! whole-width quantization on every block
//! (`tests/exec_equivalence.rs` pins this across widths — power-of-two
//! and not — × bits × shard counts).  The soft-threshold dead zone is
//! fused into the crossbar comparator path as early-termination
//! thresholds, so pooled execution also inherits the paper's
//! cycle/energy savings.

pub mod in_process;
pub mod pooled;
pub mod sharded;

use anyhow::{bail, Result};

use crate::coordinator::TransformRequest;

pub use in_process::InProcess;
pub use pooled::Pooled;
pub use sharded::Sharded;

/// An engine that can execute batches of BWHT transforms.
///
/// `blocks` is the layer's transform block partition (every request in
/// the batch has width `blocks.iter().sum()`); `streams[i]` is a caller-
/// chosen RNG stream id for request `i` (derived from the global sample
/// index, so stochastic backends are deterministic per sample regardless
/// of how a dataset is batched) — deterministic backends ignore it.
/// Outputs come back in request order at the same width.
pub trait TransformExecutor {
    /// Short label for errors and logs.
    fn name(&self) -> &'static str;

    /// Magnitude bitplanes of the quantized substrate, or `None` for the
    /// exact float path.  The layer uses this to decide whether to pin
    /// per-sample quantization scales and map thresholds into comparator
    /// units.
    fn quant_bits(&self) -> Option<u32>;

    /// Execute one batch of independent transforms.
    fn transform_batch(
        &mut self,
        blocks: &[usize],
        reqs: &[TransformRequest],
        streams: &[u64],
    ) -> Result<Vec<Vec<f32>>>;
}

/// Validate that every request in a batch matches the partition width
/// and that `streams` lines up (shared by the executor impls).
pub(crate) fn validate_batch(
    blocks: &[usize],
    reqs: &[TransformRequest],
    streams: &[u64],
) -> Result<usize> {
    let width: usize = blocks.iter().sum();
    if width == 0 {
        bail!("empty block partition");
    }
    if streams.len() != reqs.len() {
        bail!(
            "streams length {} does not match batch size {}",
            streams.len(),
            reqs.len()
        );
    }
    for (i, req) in reqs.iter().enumerate() {
        if req.x.len() != width {
            bail!(
                "request {i} has width {}, but the block partition covers {width}",
                req.x.len()
            );
        }
        if req.thresholds_units.len() != width {
            bail!(
                "request {i} has {} thresholds for width {width}",
                req.thresholds_units.len()
            );
        }
    }
    Ok(width)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_batch_checks_widths_and_streams() {
        let req = TransformRequest::plain(vec![0.0; 32]);
        assert_eq!(
            validate_batch(&[16, 16], std::slice::from_ref(&req), &[0]).unwrap(),
            32
        );
        assert!(validate_batch(&[16], std::slice::from_ref(&req), &[0]).is_err());
        assert!(validate_batch(&[16, 16], std::slice::from_ref(&req), &[]).is_err());
        assert!(validate_batch(&[], &[], &[]).is_err());
    }
}

//! The in-process executor: the original software transform loops of
//! [`crate::nn::BwhtLayer`], restated against the [`TransformExecutor`]
//! seam.
//!
//! * `Backend::Float` — exact blockwise Walsh transform ("with ADC"
//!   algorithmic baseline);
//! * `Backend::Quantized` — the digital golden model of the ADC-free
//!   crossbar arithmetic (Eq. 4), honoring pinned quantization scales so
//!   it stays bit-identical to [`crate::bitplane::QuantBwht`];
//! * `Backend::Noisy` — Eq. 4 with ANT noise on every PSUM.  Noise is
//!   drawn from a *per-sample* RNG stream derived from the executor's
//!   base seed and the caller's stream id, so a dataset evaluated in
//!   batches of 1 or 1000 sees exactly the same noise per sample.

use anyhow::Result;

use crate::analog::noise::NoiseModel;
use crate::bitplane::comparator;
use crate::coordinator::TransformRequest;
use crate::nn::Backend;
use crate::quant::Quantizer;
use crate::util::rng::Rng;
use crate::wht;

use super::{validate_batch, TransformExecutor};

/// In-process software execution of the three [`Backend`]s.
#[derive(Debug, Clone)]
pub struct InProcess {
    backend: Backend,
    /// Base seed for per-sample noise streams (noisy backend only).
    noise_seed: u64,
}

impl InProcess {
    pub fn new(backend: Backend, noise_seed: u64) -> InProcess {
        InProcess {
            backend,
            noise_seed,
        }
    }

    /// Per-sample RNG: one independent stream per (base seed, stream id).
    fn stream_rng(&self, stream: u64) -> Rng {
        Rng::seed_from_u64(
            self.noise_seed ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0x2545_F491_4F6C_DD1D),
        )
    }

    /// Quantize honoring a pinned scale when the request carries one.
    fn quantize(bits: u32, req: &TransformRequest) -> crate::quant::Quantized {
        let quantizer = Quantizer::new(bits);
        match req.scale {
            Some(s) => quantizer.quantize_with_scale(&req.x, s),
            None => quantizer.quantize(&req.x),
        }
    }

    /// Digital golden model: bitplanes MSB-first → blockwise integer
    /// Walsh PSUMs → comparator → binary recombination.  Matches
    /// [`crate::bitplane::QuantBwht::transform`] bit-for-bit.  Planes
    /// are streamed through one scratch slice (no per-plane `Vec<i8>`).
    fn transform_quantized(blocks: &[usize], bits: u32, req: &TransformRequest) -> Vec<f32> {
        let q = Self::quantize(bits, req);
        let n = req.x.len();
        let mut acc = vec![0f32; n];
        let mut plane = vec![0i8; n];
        let mut xi = vec![0i64; n];
        let mut planes = q.planes_msb_first();
        while let Some(b) = planes.next_into(&mut plane) {
            for (d, &v) in xi.iter_mut().zip(&plane) {
                *d = v as i64;
            }
            let psums = wht::bwht_apply_i64_blocks(&xi, blocks);
            let w = (1i64 << b) as f32;
            for (a, &psum) in acc.iter_mut().zip(&psums) {
                *a += comparator(psum) as f32 * w;
            }
        }
        acc.iter().map(|v| v * q.scale).collect()
    }

    /// Eq. 4 with ANT noise perturbing every PSUM before the comparator.
    fn transform_noisy(
        blocks: &[usize],
        bits: u32,
        sigma_ant: f64,
        req: &TransformRequest,
        rng: &mut Rng,
    ) -> Vec<f32> {
        let q = Self::quantize(bits, req);
        let nm = NoiseModel::new(sigma_ant, req.x.len());
        let n = req.x.len();
        let mut acc = vec![0f32; n];
        let mut plane = vec![0i8; n];
        let mut xi = vec![0i64; n];
        let mut obits = vec![0i8; n];
        let mut planes = q.planes_msb_first();
        while let Some(b) = planes.next_into(&mut plane) {
            for (d, &v) in xi.iter_mut().zip(&plane) {
                *d = v as i64;
            }
            let psums = wht::bwht_apply_i64_blocks(&xi, blocks);
            nm.perturb_and_compare_into(&psums, rng, &mut obits);
            let w = (1i64 << b) as f32;
            for (a, &o) in acc.iter_mut().zip(&obits) {
                *a += o as f32 * w;
            }
        }
        acc.iter().map(|v| v * q.scale).collect()
    }
}

impl TransformExecutor for InProcess {
    fn name(&self) -> &'static str {
        "in-process"
    }

    fn quant_bits(&self) -> Option<u32> {
        match self.backend {
            Backend::Float => None,
            Backend::Quantized { bits } => Some(bits),
            Backend::Noisy { bits, .. } => Some(bits),
        }
    }

    fn transform_batch(
        &mut self,
        blocks: &[usize],
        reqs: &[TransformRequest],
        streams: &[u64],
    ) -> Result<Vec<Vec<f32>>> {
        validate_batch(blocks, reqs, streams)?;
        let mut outs = Vec::with_capacity(reqs.len());
        for (req, &stream) in reqs.iter().zip(streams) {
            let y = match self.backend {
                Backend::Float => wht::bwht_apply_blocks(&req.x, blocks),
                Backend::Quantized { bits } => Self::transform_quantized(blocks, bits, req),
                Backend::Noisy { bits, sigma_ant } => {
                    let mut rng = self.stream_rng(stream);
                    Self::transform_noisy(blocks, bits, sigma_ant, req, &mut rng)
                }
            };
            outs.push(y);
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitplane::QuantBwht;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::seed_from_u64(seed);
        (0..n).map(|_| r.uniform_range(-1.5, 1.5) as f32).collect()
    }

    #[test]
    fn quantized_matches_golden_model_with_and_without_pinned_scale() {
        let x = sample(64, 3);
        let golden = QuantBwht::new(64, 128, 8).transform(&x);
        let mut ex = InProcess::new(Backend::Quantized { bits: 8 }, 0);
        let free = ex
            .transform_batch(&[64], &[TransformRequest::plain(x.clone())], &[0])
            .unwrap();
        assert_eq!(free[0], golden);
        let pinned = ex
            .transform_batch(
                &[64],
                &[TransformRequest {
                    thresholds_units: vec![0.0; 64],
                    scale: Some(Quantizer::new(8).scale_for(&x)),
                    deadline: None,
                    x,
                }],
                &[7],
            )
            .unwrap();
        assert_eq!(pinned[0], golden);
    }

    #[test]
    fn float_matches_blockwise_walsh() {
        let x = sample(32, 4);
        let mut ex = InProcess::new(Backend::Float, 0);
        let out = ex
            .transform_batch(&[16, 16], &[TransformRequest::plain(x.clone())], &[0])
            .unwrap();
        assert_eq!(out[0], wht::bwht_apply_blocks(&x, &[16, 16]));
        assert_eq!(ex.quant_bits(), None);
    }

    #[test]
    fn noisy_streams_are_per_sample_deterministic() {
        let x = sample(16, 5);
        let req = TransformRequest::plain(x);
        let backend = Backend::Noisy {
            bits: 8,
            sigma_ant: 0.5,
        };
        let mut ex = InProcess::new(backend, 42);
        // The same stream id reproduces; different ids differ.
        let a = ex
            .transform_batch(&[16], std::slice::from_ref(&req), &[3])
            .unwrap();
        let b = ex
            .transform_batch(&[16], std::slice::from_ref(&req), &[3])
            .unwrap();
        let c = ex
            .transform_batch(&[16], std::slice::from_ref(&req), &[4])
            .unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
        // Batch position does not matter, only the stream id.
        let batch = ex
            .transform_batch(
                &[16],
                &[req.clone(), req.clone()],
                &[99, 3],
            )
            .unwrap();
        assert_eq!(batch[1], a[0]);
    }
}

//! The sharded executor: NN transforms scatter–gathered across a
//! [`ShardSet`] of coordinator pools.
//!
//! Each sample's blocks — mixed widths included — are placed over the
//! healthy shards by the planner (row-cycle-balanced over the
//! heterogeneous block costs), executed in parallel and reassembled, so
//! one wide activation saturates every pool and a poisoned shard sheds
//! its slices to the survivors mid-batch.  Same-partition samples in a
//! batch fuse into multi-sample chunk jobs that run the pool workers'
//! zero-allocation batch engine
//! ([`crate::coordinator::schedule_batch`]) across the whole chunk;
//! failover stays per-slice (a poisoned shard's fused jobs re-queue as
//! single-request slices).  Blocks narrower than the
//! shard tile run under sub-tile masking
//! ([`crate::coordinator::plan::TilePlan`]); pinned quantization scales
//! ride along with every slice, which keeps the digital path
//! bit-identical to [`crate::nn::Backend::Quantized`] (any partition,
//! any placement, any shard count).

use anyhow::Result;

use crate::coordinator::TransformRequest;
use crate::shard::{router, ShardSet};

use super::{validate_batch, TransformExecutor};

/// Executor borrowing a shard set.
pub struct Sharded<'a> {
    set: &'a mut ShardSet,
}

impl<'a> Sharded<'a> {
    /// Wrap a shard set.  The set's `tile_n` must be at least the
    /// layer's widest transform block (checked per batch); narrower
    /// blocks run under sub-tile masking.
    pub fn new(set: &'a mut ShardSet) -> Sharded<'a> {
        Sharded { set }
    }
}

impl TransformExecutor for Sharded<'_> {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn quant_bits(&self) -> Option<u32> {
        Some(self.set.bits())
    }

    fn transform_batch(
        &mut self,
        blocks: &[usize],
        reqs: &[TransformRequest],
        streams: &[u64],
    ) -> Result<Vec<Vec<f32>>> {
        validate_batch(blocks, reqs, streams)?;
        router::transform_batch_planned(self.set, blocks, reqs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitplane::QuantBwht;
    use crate::quant::Quantizer;
    use crate::shard::ShardSetConfig;
    use crate::util::rng::Rng;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::seed_from_u64(seed);
        (0..n).map(|_| r.uniform_range(-1.0, 1.0) as f32).collect()
    }

    #[test]
    fn sharded_pinned_scale_matches_whole_width_golden_model() {
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 3,
            ..Default::default()
        })
        .unwrap();
        let mut ex = Sharded::new(&mut set);
        let x = sample(96, 9);
        let req = TransformRequest {
            thresholds_units: vec![0.0; 96],
            scale: Some(Quantizer::new(8).scale_for(&x)),
            deadline: None,
            x,
        };
        let out = ex
            .transform_batch(&[16; 6], std::slice::from_ref(&req), &[0])
            .unwrap();
        let golden = QuantBwht::new(96, 16, 8).transform(&req.x);
        assert_eq!(out[0], golden);
        set.shutdown();
    }

    #[test]
    fn sharded_mixed_partition_matches_whole_width_golden_model() {
        // 68 = [64, 4] on 64-wide tiles: the trailing 4-block runs under
        // sub-tile masking wherever the planner places it.
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            coordinator: crate::coordinator::CoordinatorConfig {
                tile_n: 64,
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        let mut ex = Sharded::new(&mut set);
        let x = sample(68, 19);
        let req = TransformRequest {
            thresholds_units: vec![0.0; 68],
            scale: Some(Quantizer::new(8).scale_for(&x)),
            deadline: None,
            x,
        };
        let out = ex
            .transform_batch(&[64, 4], std::slice::from_ref(&req), &[0])
            .unwrap();
        let golden = QuantBwht::new(68, 64, 8).transform(&req.x);
        assert_eq!(out[0], golden);
        set.shutdown();
    }

    #[test]
    fn rejects_blocks_wider_than_the_tile() {
        let mut set = ShardSet::new(ShardSetConfig::default()).unwrap();
        let mut ex = Sharded::new(&mut set);
        let req = TransformRequest::plain(vec![0.5; 64]);
        assert!(ex.transform_batch(&[32, 32], &[req], &[0]).is_err());
        set.shutdown();
    }
}

//! The pooled executor: NN transforms on a [`Coordinator`] crossbar tile
//! pool.
//!
//! The whole batch goes through
//! [`Coordinator::transform_batch_planned`]: contiguous multi-sample
//! chunks (oversubscribed over the workers so skewed batches
//! load-balance), each chunk streamed through one tile by the
//! batch-fused zero-allocation engine
//! ([`crate::coordinator::schedule_batch`] — quantizer construction,
//! row-map lookups and the identity-row decision hoisted out of the
//! per-sample loop).  The layer's block partition rides along with the
//! batch, so mixed partitions (`[128, 64, 16, 4]`) run with blocks
//! narrower than the tile under sub-tile masking.  With digital tiles
//! and pinned quantization scales this is bit-identical to
//! [`crate::nn::Backend::Quantized`]; noisy/analog tiles run the same
//! schedule with their physical models.  The layer's soft-threshold
//! dead zone arrives as early-termination thresholds, so the pool's
//! cycle/energy metrics reflect the fused comparator path.

use anyhow::Result;

use crate::coordinator::{Coordinator, TransformRequest};

use super::{validate_batch, TransformExecutor};

/// Executor borrowing a coordinator pool.
pub struct Pooled<'a> {
    coord: &'a mut Coordinator,
}

impl<'a> Pooled<'a> {
    /// Wrap a pool.  The pool's `tile_n` must be at least the layer's
    /// widest transform block (checked per batch); narrower blocks run
    /// under sub-tile masking.
    pub fn new(coord: &'a mut Coordinator) -> Pooled<'a> {
        Pooled { coord }
    }
}

impl TransformExecutor for Pooled<'_> {
    fn name(&self) -> &'static str {
        "pooled"
    }

    fn quant_bits(&self) -> Option<u32> {
        Some(self.coord.config().bits)
    }

    fn transform_batch(
        &mut self,
        blocks: &[usize],
        reqs: &[TransformRequest],
        _streams: &[u64],
    ) -> Result<Vec<Vec<f32>>> {
        validate_batch(blocks, reqs, _streams)?;
        // One batch-fused call: the pool validates the partition and the
        // undrained-submission hazard at its boundary, chunks the batch
        // across the workers, and every chunk runs zero-allocation on
        // one tile.
        self.coord.transform_batch_planned(reqs, blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitplane::QuantBwht;
    use crate::coordinator::CoordinatorConfig;
    use crate::quant::Quantizer;
    use crate::util::rng::Rng;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::seed_from_u64(seed);
        (0..n).map(|_| r.uniform_range(-1.0, 1.0) as f32).collect()
    }

    #[test]
    fn pinned_scale_batch_matches_whole_width_golden_model() {
        // Width 64 split over 16-wide tiles: without a pinned scale each
        // tile quantizes locally and diverges from the whole-width golden
        // model; with the global scale pinned it matches bit-for-bit.
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        let mut ex = Pooled::new(&mut coord);
        let blocks = [16usize, 16, 16, 16];
        let reqs: Vec<TransformRequest> = (0..5)
            .map(|i| {
                let x = sample(64, 40 + i);
                TransformRequest {
                    thresholds_units: vec![0.0; 64],
                    scale: Some(Quantizer::new(8).scale_for(&x)),
                    deadline: None,
                    x,
                }
            })
            .collect();
        let outs = ex.transform_batch(&blocks, &reqs, &[0, 1, 2, 3, 4]).unwrap();
        for (i, req) in reqs.iter().enumerate() {
            // Golden: one global quantization, 16-wide Walsh blocks.
            let golden = QuantBwht::new(64, 16, 8).transform(&req.x);
            assert_eq!(outs[i], golden, "request {i}");
        }
        coord.shutdown();
    }

    #[test]
    fn mixed_partition_batch_matches_whole_width_golden_model() {
        // Width 20 as [16, 4] on 16-wide tiles: the 4-block runs under
        // sub-tile masking, bit-identical to the golden model.
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        let mut ex = Pooled::new(&mut coord);
        let blocks = [16usize, 4];
        let reqs: Vec<TransformRequest> = (0..3)
            .map(|i| {
                let x = sample(20, 70 + i);
                TransformRequest {
                    thresholds_units: vec![0.0; 20],
                    scale: Some(Quantizer::new(8).scale_for(&x)),
                    deadline: None,
                    x,
                }
            })
            .collect();
        let outs = ex.transform_batch(&blocks, &reqs, &[0, 1, 2]).unwrap();
        for (i, req) in reqs.iter().enumerate() {
            let golden = QuantBwht::new(20, 128, 8).transform(&req.x);
            assert_eq!(outs[i], golden, "request {i}");
        }
        coord.shutdown();
    }

    #[test]
    fn rejects_blocks_wider_than_the_tile() {
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        let mut ex = Pooled::new(&mut coord);
        let req = TransformRequest::plain(vec![0.5; 64]);
        let err = ex.transform_batch(&[64], &[req], &[0]).unwrap_err();
        assert!(err.to_string().contains("tile_n"), "{err}");
        coord.shutdown();
    }

    #[test]
    fn refuses_to_run_with_undrained_submissions() {
        // A foreign undrained submit would have its result stolen off
        // the shared channel; the executor must refuse cleanly instead.
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        coord
            .submit(&TransformRequest::plain(vec![0.5; 16]))
            .unwrap();
        let mut ex = Pooled::new(&mut coord);
        let req = TransformRequest::plain(vec![0.25; 16]);
        let err = ex.transform_batch(&[16], &[req], &[0]).unwrap_err();
        assert!(err.to_string().contains("not yet drained"), "{err}");
        coord.drain_one().unwrap();
        coord.shutdown();
    }
}

//! Walsh–Hadamard transform substrate (paper Sec. II-A).
//!
//! Provides Sylvester Hadamard matrices (Eq. 2), sequency-ordered Walsh
//! matrices, the in-place fast WHT butterfly with sequency reordering, and
//! the blockwise (BWHT) partitioning of Pan et al. [26] used to map
//! arbitrary channel widths onto power-of-two crossbar tiles.
//!
//! Must stay bit-identical to `python/compile/walsh.py` — the python tests
//! pin the same partition/order conventions and the AOT artifacts bake the
//! same matrices.

pub mod fast;
pub mod matrix;

pub use fast::{fwht_inplace, wht_sequency};
pub use matrix::{hadamard, sign_changes, walsh, WalshMatrix};

/// Smallest useful transform block: a 1- or 2-point WHT carries no
/// frequency content worth thresholding (mirrors `walsh.MIN_BLOCK`).
pub const MIN_BLOCK: usize = 4;

/// Smallest power of two `>= n` (n must be positive).
pub fn next_pow2(n: usize) -> usize {
    assert!(n > 0, "next_pow2 requires n > 0");
    n.next_power_of_two()
}

/// BWHT block sizes covering `dim` channels (greedy largest-fits-first,
/// capped at `max_block`, floored at [`MIN_BLOCK`]).  Identical to
/// `python/compile/walsh.bwht_blocks`.
pub fn bwht_blocks(dim: usize, max_block: usize) -> Vec<usize> {
    assert!(dim > 0, "dim must be positive");
    assert!(
        max_block.is_power_of_two() && max_block >= MIN_BLOCK,
        "max_block must be a power of two >= {MIN_BLOCK}, got {max_block}"
    );
    let mut blocks = Vec::new();
    let mut rem = dim;
    while rem >= MIN_BLOCK {
        let b = (1usize << (usize::BITS - 1 - rem.leading_zeros())).min(max_block);
        blocks.push(b);
        rem -= b;
    }
    if rem > 0 {
        // Final sub-MIN_BLOCK remainder: one zero-padded MIN_BLOCK block.
        blocks.push(MIN_BLOCK);
    }
    blocks
}

/// Total (possibly padded) width of the BWHT covering `dim` channels.
pub fn bwht_padded_dim(dim: usize, max_block: usize) -> usize {
    bwht_blocks(dim, max_block).iter().sum()
}

/// Blockwise WHT of `x` (length = padded dim), using the fast butterfly
/// per block.  Equivalent to multiplying by the block-diagonal BWHT matrix.
pub fn bwht_apply(x: &[f32], dim: usize, max_block: usize) -> Vec<f32> {
    bwht_apply_blocks(x, &bwht_blocks(dim, max_block))
}

/// Blockwise WHT over an explicit block partition.
///
/// [`bwht_apply`] recomputes the partition from the *padded* width, which
/// is lossy for widths whose partition is not a fixed point of the greedy
/// decomposition (e.g. `[4, 4]` re-decomposes as `[8]`); callers that
/// carry the true partition — the [`crate::exec`] executors — use this.
pub fn bwht_apply_blocks(x: &[f32], blocks: &[usize]) -> Vec<f32> {
    let padded: usize = blocks.iter().sum();
    assert_eq!(
        x.len(),
        padded,
        "input must be padded to {padded}, got {}",
        x.len()
    );
    let mut out = x.to_vec();
    let mut off = 0;
    for &b in blocks {
        wht_sequency(&mut out[off..off + b]);
        off += b;
    }
    out
}

/// Exact integer blockwise WHT for integer (quantized) inputs.
pub fn bwht_apply_i64(x: &[i64], dim: usize, max_block: usize) -> Vec<i64> {
    bwht_apply_i64_blocks(x, &bwht_blocks(dim, max_block))
}

/// Integer blockwise WHT over an explicit block partition
/// (see [`bwht_apply_blocks`]).
pub fn bwht_apply_i64_blocks(x: &[i64], blocks: &[usize]) -> Vec<i64> {
    let padded: usize = blocks.iter().sum();
    assert_eq!(x.len(), padded);
    let mut out = x.to_vec();
    let mut off = 0;
    for &b in blocks {
        fast::wht_sequency_i64(&mut out[off..off + b]);
        off += b;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_match_python_convention() {
        assert_eq!(bwht_blocks(64, 128), vec![64]);
        assert_eq!(bwht_blocks(256, 128), vec![128, 128]);
        assert_eq!(bwht_blocks(20, 128), vec![16, 4]);
        assert_eq!(bwht_blocks(300, 128), vec![128, 128, 32, 8, 4]);
        assert_eq!(bwht_blocks(5, 128), vec![4, 4]);
    }

    #[test]
    fn padded_dim_sums_blocks() {
        for dim in [1, 3, 5, 20, 64, 129, 300] {
            assert_eq!(
                bwht_padded_dim(dim, 128),
                bwht_blocks(dim, 128).iter().sum::<usize>()
            );
        }
    }

    #[test]
    #[should_panic(expected = "max_block")]
    fn invalid_max_block_panics() {
        bwht_blocks(10, 24);
    }

    #[test]
    fn bwht_apply_matches_matrix_multiply() {
        let dim = 20;
        let padded = bwht_padded_dim(dim, 128);
        let x: Vec<f32> = (0..padded).map(|i| (i as f32) * 0.3 - 2.0).collect();
        let fast = bwht_apply(&x, dim, 128);
        // dense block-diagonal multiply
        let blocks = bwht_blocks(dim, 128);
        let mut want = vec![0f32; padded];
        let mut off = 0;
        for &b in &blocks {
            let k = b.trailing_zeros() as usize;
            let w = walsh(k);
            for i in 0..b {
                let mut acc = 0f32;
                for j in 0..b {
                    acc += w.get(i, j) as f32 * x[off + j];
                }
                want[off + i] = acc;
            }
            off += b;
        }
        for (a, b) in fast.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn bwht_apply_i64_exact() {
        let x: Vec<i64> = (0..16).map(|i| i - 8).collect();
        let y = bwht_apply_i64(&x, 16, 128);
        let w = walsh(4);
        for i in 0..16 {
            let want: i64 = (0..16).map(|j| w.get(i, j) as i64 * x[j]).sum();
            assert_eq!(y[i], want);
        }
    }
}

//! Fast Walsh–Hadamard transform: O(n log n) in-place butterfly plus the
//! sequency (Walsh-order) permutation.
//!
//! The crossbar computes the transform as a dense analog matvec; this fast
//! digital path is the *baseline* the paper compares against and the
//! reference the simulator is validated on.

use std::collections::HashMap;
use std::sync::Arc;
use std::sync::{Mutex, OnceLock};

use super::matrix::{hadamard, sign_changes};

/// In-place fast WHT butterfly in *natural (Hadamard)* order.
/// `x.len()` must be a power of two.  After the call, `x = H_k x`.
pub fn fwht_inplace(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "fwht length must be a power of two");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += h * 2;
        }
        h *= 2;
    }
}

/// Integer variant (exact for quantized operands).
pub fn fwht_inplace_i64(x: &mut [i64]) {
    let n = x.len();
    assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += h * 2;
        }
        h *= 2;
    }
}

/// Permutation mapping sequency row `i` to natural (Hadamard) row index.
/// `perm[i] = h` such that Walsh row `i` equals Hadamard row `h`.
pub fn sequency_perm(k: usize) -> Arc<Vec<usize>> {
    static CACHE: OnceLock<Mutex<HashMap<usize, Arc<Vec<usize>>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = cache.lock().expect("sequency cache poisoned");
    guard
        .entry(k)
        .or_insert_with(|| {
            let h = hadamard(k);
            let n = h.size();
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by_key(|&i| sign_changes(h.row(i)));
            Arc::new(order)
        })
        .clone()
}

/// Full sequency-ordered WHT: butterfly + permutation.  `x = W_k x`.
pub fn wht_sequency(x: &mut [f32]) {
    let n = x.len();
    if n == 1 {
        return;
    }
    let k = n.trailing_zeros() as usize;
    fwht_inplace(x);
    let perm = sequency_perm(k);
    let tmp = x.to_vec();
    for (i, &h) in perm.iter().enumerate() {
        x[i] = tmp[h];
    }
}

/// Integer sequency-ordered WHT.
pub fn wht_sequency_i64(x: &mut [i64]) {
    let n = x.len();
    if n == 1 {
        return;
    }
    let k = n.trailing_zeros() as usize;
    fwht_inplace_i64(x);
    let perm = sequency_perm(k);
    let tmp = x.to_vec();
    for (i, &h) in perm.iter().enumerate() {
        x[i] = tmp[h];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wht::matrix::walsh;

    #[test]
    fn fwht_matches_hadamard_matvec() {
        for k in 0..8usize {
            let n = 1 << k;
            let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).sin()).collect();
            let mut fast = x.clone();
            fwht_inplace(&mut fast);
            let h = hadamard(k);
            for i in 0..n {
                let want: f32 = (0..n).map(|j| h.get(i, j) as f32 * x[j]).sum();
                assert!((fast[i] - want).abs() < 1e-3, "k={k} i={i}");
            }
        }
    }

    #[test]
    fn wht_sequency_matches_walsh_matvec() {
        for k in 1..8usize {
            let n = 1 << k;
            let x: Vec<f32> = (0..n).map(|i| (i as f32 * 1.3).cos()).collect();
            let mut fast = x.clone();
            wht_sequency(&mut fast);
            let w = walsh(k);
            let want = w.matvec(&x);
            for i in 0..n {
                assert!((fast[i] - want[i]).abs() < 1e-3, "k={k} i={i}");
            }
        }
    }

    #[test]
    fn involution_up_to_n() {
        let n = 32;
        let x: Vec<f32> = (0..n).map(|i| i as f32 - 16.0).collect();
        let mut y = x.clone();
        wht_sequency(&mut y);
        wht_sequency(&mut y);
        for i in 0..n {
            assert!((y[i] - n as f32 * x[i]).abs() < 1e-2);
        }
    }

    #[test]
    fn integer_exactness() {
        let x: Vec<i64> = (0..64).map(|i| (i * 37 % 23) - 11).collect();
        let mut fast = x.clone();
        wht_sequency_i64(&mut fast);
        let w = walsh(6);
        for i in 0..64 {
            let want: i64 = (0..64).map(|j| w.get(i, j) as i64 * x[j]).sum();
            assert_eq!(fast[i], want);
        }
    }

    #[test]
    fn length_one_noop() {
        let mut x = [5.0f32];
        wht_sequency(&mut x);
        assert_eq!(x[0], 5.0);
    }
}

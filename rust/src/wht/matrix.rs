//! Dense Hadamard/Walsh matrices with ±1 entries (Eq. 2 + sequency order).

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};
use std::sync::Arc;

/// A dense ±1 matrix stored as `i8`, row-major.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WalshMatrix {
    n: usize,
    data: Vec<i8>,
}

impl WalshMatrix {
    pub fn size(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn get(&self, row: usize, col: usize) -> i8 {
        self.data[row * self.n + col]
    }

    pub fn row(&self, row: usize) -> &[i8] {
        &self.data[row * self.n..(row + 1) * self.n]
    }

    /// Matrix–vector product `W x` in f64 (for small exact checks).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n);
        (0..self.n)
            .map(|i| {
                let r = self.row(i);
                r.iter().zip(x).map(|(&w, &v)| w as f32 * v).sum()
            })
            .collect()
    }
}

/// Sylvester Hadamard matrix `H_k` of size `2^k x 2^k` (Eq. 2).
pub fn hadamard(k: usize) -> WalshMatrix {
    let n = 1usize << k;
    let mut data = vec![1i8; n * n];
    // H_{m} blocks built iteratively: entry (i,j) = (-1)^{popcount(i & j)}.
    // (Equivalent to the recursive construction and much cheaper.)
    for i in 0..n {
        for j in 0..n {
            if (i & j).count_ones() % 2 == 1 {
                data[i * n + j] = -1;
            }
        }
    }
    WalshMatrix { n, data }
}

/// Number of sign changes along a ±1 row (the row's sequency).
pub fn sign_changes(row: &[i8]) -> usize {
    row.windows(2).filter(|w| w[0] != w[1]).count()
}

fn walsh_uncached(k: usize) -> WalshMatrix {
    let h = hadamard(k);
    let n = h.size();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| sign_changes(h.row(i)));
    let mut data = Vec::with_capacity(n * n);
    for &i in &order {
        data.extend_from_slice(h.row(i));
    }
    WalshMatrix { n, data }
}

/// Walsh (sequency-ordered) matrix `W_k`: rows of `H_k` sorted by sign
/// changes; row `i` has exactly `i` sign changes.  Cached per `k` (the
/// matrices are parameter-free and shared by every crossbar tile).
pub fn walsh(k: usize) -> Arc<WalshMatrix> {
    static CACHE: OnceLock<Mutex<HashMap<usize, Arc<WalshMatrix>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = cache.lock().expect("walsh cache poisoned");
    guard
        .entry(k)
        .or_insert_with(|| Arc::new(walsh_uncached(k)))
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hadamard_base_and_recursion() {
        let h0 = hadamard(0);
        assert_eq!(h0.get(0, 0), 1);
        let h1 = hadamard(1);
        assert_eq!(
            (0..2).flat_map(|i| (0..2).map(move |j| (i, j))).map(|(i, j)| h1.get(i, j)).collect::<Vec<_>>(),
            vec![1, 1, 1, -1]
        );
        // recursive structure: lower-right quadrant of H2 = -H1
        let h2 = hadamard(2);
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(h2.get(i + 2, j + 2), -h1.get(i, j));
            }
        }
    }

    #[test]
    fn orthogonality() {
        for k in 0..8 {
            let h = hadamard(k);
            let n = h.size();
            for i in 0..n.min(8) {
                for j in 0..n.min(8) {
                    let dot: i64 = (0..n)
                        .map(|c| h.get(i, c) as i64 * h.get(j, c) as i64)
                        .sum();
                    assert_eq!(dot, if i == j { n as i64 } else { 0 });
                }
            }
        }
    }

    #[test]
    fn walsh_sequency_order() {
        for k in 1..8 {
            let w = walsh(k);
            for i in 0..w.size() {
                assert_eq!(sign_changes(w.row(i)), i, "k={k} row {i}");
            }
        }
    }

    #[test]
    fn walsh_is_row_permutation_of_hadamard() {
        let k = 5;
        let h = hadamard(k);
        let w = walsh(k);
        let hset: std::collections::HashSet<Vec<i8>> =
            (0..h.size()).map(|i| h.row(i).to_vec()).collect();
        for i in 0..w.size() {
            assert!(hset.contains(w.row(i)));
        }
    }

    #[test]
    fn walsh_cache_returns_same_instance() {
        let a = walsh(6);
        let b = walsh(6);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn matvec_small() {
        let w = walsh(1); // [[1,1],[1,-1]]
        assert_eq!(w.matvec(&[3.0, 2.0]), vec![5.0, 1.0]);
    }
}

//! Energy/power model of the crossbar macro (Figs. 11(d), 12, Table I).
//!
//! CV²α bookkeeping over the node classes of the Fig. 4 design:
//! local O/OB nodes (precharge), bit lines, input drivers (CL/CLB), row
//! lines, the CM/RM stitching switches, and the row comparators.  The
//! early-termination peripheral cost (digital comparators, shift
//! registers, Fig. 10) is modelled as a per-cycle overhead factor taken
//! from the 7nm-std-cell data the paper cites [43].
//!
//! ## Calibration (DESIGN.md §1)
//!
//! Relative component shares come from the capacitance model below
//! (stitching ≈ 27% of macro power, matching Fig. 12); the absolute scale
//! is pinned to the paper's headline operating point:
//!
//! * 16×16, 8-bit inputs, VDD = 0.8 V, no early termination
//!   ⇒ **1602 TOPS/W** (8 bitplane cycles per 8-bit input);
//! * with early termination (avg 1.34 cycles, Fig. 9c) and the ET logic
//!   overhead ⇒ **5311 TOPS/W**.
//!
//! The ET overhead factor (0.80× macro energy per executed cycle) is
//! *inferred* from those two numbers: 8 / (5311/1602 × 1.34) − 1 ≈ 0.80.

/// Unit-capacitance constants (femtofarads).  Shares tuned so the 16×16
/// breakdown matches Fig. 12; absolute scale set by [`CALIBRATION`].
#[derive(Debug, Clone, Copy)]
pub struct Capacitances {
    /// One local output node (O or OB).
    pub c_local: f64,
    /// Bit line, per attached cell.
    pub c_bl_per_cell: f64,
    /// Column input line (CL/CLB), per attached cell.
    pub c_cl_per_cell: f64,
    /// Row line, per attached cell.
    pub c_rl_per_cell: f64,
    /// One stitching (CM/RM) pass-transistor gate+junction.
    pub c_switch: f64,
    /// Comparator input + latch.
    pub c_comparator: f64,
}

impl Default for Capacitances {
    fn default() -> Self {
        Capacitances {
            c_local: 0.10,
            c_bl_per_cell: 0.05,
            c_cl_per_cell: 0.04,
            c_rl_per_cell: 0.03,
            c_switch: 0.0583,
            c_comparator: 1.2,
        }
    }
}

/// Global scale factor pinning the model to 1602 TOPS/W at the paper's
/// 16×16 / 0.8 V / no-ET anchor (see module docs).
pub const CALIBRATION: f64 = 4.8216;

/// Early-termination digital-logic overhead per executed bitplane cycle,
/// as a fraction of the macro cycle energy (inferred from Table I).
pub const ET_OVERHEAD: f64 = 0.80;

/// Average activity factors.
const ALPHA_PRECHARGE: f64 = 0.5;
const ALPHA_BITLINE: f64 = 0.5;
const ALPHA_INPUT: f64 = 0.5;

/// Energy breakdown of one bitplane operation (femtojoules).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Breakdown {
    pub precharge: f64,
    pub bitlines: f64,
    pub input_drivers: f64,
    pub row_lines: f64,
    pub stitching: f64,
    pub comparators: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.precharge
            + self.bitlines
            + self.input_drivers
            + self.row_lines
            + self.stitching
            + self.comparators
    }

    /// (component name, fJ, share) rows for the Fig. 12 report.
    pub fn rows(&self) -> Vec<(&'static str, f64, f64)> {
        let t = self.total();
        vec![
            ("precharge (O/OB)", self.precharge, self.precharge / t),
            ("bit lines", self.bitlines, self.bitlines / t),
            ("input drivers (CL/CLB)", self.input_drivers, self.input_drivers / t),
            ("row lines (RL)", self.row_lines, self.row_lines / t),
            ("stitching (CM/RM)", self.stitching, self.stitching / t),
            ("comparators", self.comparators, self.comparators / t),
        ]
    }
}

/// The macro energy model for one crossbar tile.
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    pub n: usize,
    pub vdd: f64,
    pub merge_boost: f64,
    pub caps: Capacitances,
}

impl EnergyModel {
    pub fn new(n: usize, vdd: f64) -> Self {
        EnergyModel {
            n,
            vdd,
            merge_boost: 0.0,
            caps: Capacitances::default(),
        }
    }

    pub fn with_boost(mut self, boost: f64) -> Self {
        self.merge_boost = boost;
        self
    }

    /// Per-bitplane-operation energy breakdown (fJ).
    pub fn bitplane_breakdown(&self) -> Breakdown {
        let n = self.n as f64;
        let v2 = self.vdd * self.vdd;
        let vboost2 = (self.vdd + self.merge_boost).powi(2);
        let c = &self.caps;
        let k = CALIBRATION;
        Breakdown {
            precharge: k * n * n * 2.0 * c.c_local * v2 * ALPHA_PRECHARGE,
            bitlines: k * 2.0 * n * (c.c_bl_per_cell * n) * v2 * ALPHA_BITLINE,
            input_drivers: k * 2.0 * n * (c.c_cl_per_cell * n) * v2 * ALPHA_INPUT,
            row_lines: k * n * (c.c_rl_per_cell * n) * v2,
            stitching: k * 2.0 * n * (n - 1.0) * c.c_switch * vboost2,
            comparators: k * n * c.c_comparator * v2,
        }
    }

    /// Energy of one bitplane operation (fJ).
    pub fn bitplane_energy_fj(&self) -> f64 {
        self.bitplane_breakdown().total()
    }

    /// 1-bit MAC energy per *operation* in attojoules (Fig. 11(d)):
    /// one bitplane op performs `2·N²` ops (N² multiplies + N² adds).
    pub fn mac_energy_aj(&self) -> f64 {
        self.bitplane_energy_fj() * 1e3 / (2.0 * (self.n * self.n) as f64)
    }

    /// TOPS/W without early termination for `bits`-bit inputs:
    /// `bits` cycles, `bits·2N²` ops.
    pub fn tops_per_watt(&self, bits: u32) -> f64 {
        let ops = bits as f64 * 2.0 * (self.n * self.n) as f64;
        let energy_j = bits as f64 * self.bitplane_energy_fj() * 1e-15;
        ops / energy_j / 1e12
    }

    /// TOPS/W with early termination: same useful ops, `avg_cycles`
    /// executed cycles, each carrying the ET logic overhead.
    pub fn tops_per_watt_et(&self, bits: u32, avg_cycles: f64) -> f64 {
        assert!(avg_cycles > 0.0 && avg_cycles <= bits as f64);
        let ops = bits as f64 * 2.0 * (self.n * self.n) as f64;
        let energy_j =
            avg_cycles * self.bitplane_energy_fj() * (1.0 + ET_OVERHEAD) * 1e-15;
        ops / energy_j / 1e12
    }

    /// Energy to process one full `bits`-bit input vector (fJ), with or
    /// without early termination.
    pub fn vector_energy_fj(&self, bits: u32, avg_cycles: Option<f64>) -> f64 {
        match avg_cycles {
            None => bits as f64 * self.bitplane_energy_fj(),
            Some(c) => c * self.bitplane_energy_fj() * (1.0 + ET_OVERHEAD),
        }
    }
}

/// One row of the Table I comparison.
#[derive(Debug, Clone)]
pub struct TableRow {
    pub label: &'static str,
    pub technology: &'static str,
    pub computing_mode: &'static str,
    pub input_bits: &'static str,
    pub adc: &'static str,
    pub dac: &'static str,
    pub network: &'static str,
    pub accuracy: &'static str,
    pub tops_per_watt: String,
}

/// Literature baselines of Table I ([37]–[42]) plus our computed row.
pub fn table1(ours_no_et: f64, ours_et: f64, our_accuracy: f64) -> Vec<TableRow> {
    let mut rows = vec![TableRow {
        label: "Ours",
        technology: "16nm",
        computing_mode: "CMOS Analog",
        input_bits: "4/8",
        adc: "No",
        dac: "No",
        network: "MobileNetV2",
        accuracy: Box::leak(format!("{our_accuracy:.2}%").into_boxed_str()),
        tops_per_watt: format!("{ours_no_et:.0}* / {ours_et:.0}**"),
    }];
    let baselines: [(&str, &str, &str, &str, &str, &str, &str, &str, f64); 6] = [
        ("Neuro-CIM [37]", "28nm", "Neuromorphic", "4", "No", "No", "ResNet-18", "92.80%", 310.4),
        ("Sinangil [38]", "7nm", "CMOS CiM", "4", "4-bit", "Capacitor", "VGG9", "90.18%", 351.0),
        ("ReRAM CIM [39]", "22nm", "ReRAM CiM", "2", "No", "No", "ResNet20", "88.9%", 121.0),
        ("DIANA [40]", "22nm", "CMOS Analog", "7", "6-bit", "7-bit", "ResNet20", "89%", 600.0),
        ("Dong [41]", "7nm", "CMOS CiM", "4", "4-bit", "No", "MLP", "98.47%", 351.0),
        ("Jia [42]", "16nm", "CMOS Analog", "8", "8-bit", "No", "VGG", "91.51%", 121.0),
    ];
    for (label, tech, mode, ibits, adc, dac, net, acc, topsw) in baselines {
        rows.push(TableRow {
            label,
            technology: tech,
            computing_mode: mode,
            input_bits: ibits,
            adc,
            dac,
            network: net,
            accuracy: acc,
            tops_per_watt: format!("{topsw:.2}"),
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_to_paper_anchor() {
        // 16×16, 0.8 V, 8-bit, no ET ⇒ 1602 TOPS/W (±1%).
        let m = EnergyModel::new(16, 0.8);
        let t = m.tops_per_watt(8);
        assert!(
            (t - 1602.0).abs() / 1602.0 < 0.01,
            "anchor TOPS/W off: {t:.1}"
        );
    }

    #[test]
    fn et_matches_paper_second_anchor() {
        // avg 1.34 cycles (Fig. 9c) ⇒ 5311 TOPS/W (±1%).
        let m = EnergyModel::new(16, 0.8);
        let t = m.tops_per_watt_et(8, 1.34);
        assert!(
            (t - 5311.0).abs() / 5311.0 < 0.01,
            "ET anchor TOPS/W off: {t:.1}"
        );
    }

    #[test]
    fn stitching_share_matches_fig12() {
        let b = EnergyModel::new(16, 0.8).bitplane_breakdown();
        let share = b.stitching / b.total();
        assert!(
            (share - 0.27).abs() < 0.02,
            "stitching share should be ~27%, got {share:.3}"
        );
    }

    #[test]
    fn energy_scales_quadratically_with_vdd() {
        let lo = EnergyModel::new(16, 0.6).bitplane_energy_fj();
        let hi = EnergyModel::new(16, 0.9).bitplane_energy_fj();
        let ratio = hi / lo;
        let want = (0.9f64 / 0.6).powi(2);
        assert!((ratio - want).abs() < 0.01, "CV² scaling violated: {ratio}");
    }

    #[test]
    fn mac_energy_weakly_depends_on_array_size() {
        // Fig. 11(d): per-op energy nearly flat in N (bit lines split
        // cell-wise).  Allow ±20% between 16 and 32.
        let e16 = EnergyModel::new(16, 0.8).mac_energy_aj();
        let e32 = EnergyModel::new(32, 0.8).mac_energy_aj();
        assert!(
            (e16 - e32).abs() / e16 < 0.2,
            "per-MAC energy should be ~size-independent: {e16:.0} vs {e32:.0} aJ"
        );
    }

    #[test]
    fn boost_costs_energy() {
        let plain = EnergyModel::new(32, 0.7).bitplane_energy_fj();
        let boosted = EnergyModel::new(32, 0.7).with_boost(0.2).bitplane_energy_fj();
        assert!(boosted > plain);
    }

    #[test]
    fn et_always_wins_when_cycles_low_enough() {
        let m = EnergyModel::new(16, 0.8);
        // Break-even avg cycles: 8 / 1.8 ≈ 4.44.
        assert!(m.tops_per_watt_et(8, 4.0) > m.tops_per_watt(8));
        assert!(m.tops_per_watt_et(8, 5.0) < m.tops_per_watt(8));
    }

    #[test]
    fn table1_has_our_row_first() {
        let rows = table1(1602.0, 5311.0, 91.04);
        assert_eq!(rows[0].label, "Ours");
        assert_eq!(rows.len(), 7);
        assert!(rows[0].tops_per_watt.contains("1602"));
    }

    #[test]
    fn vector_energy_consistency() {
        let m = EnergyModel::new(16, 0.8);
        let no_et = m.vector_energy_fj(8, None);
        assert!((no_et - 8.0 * m.bitplane_energy_fj()).abs() < 1e-9);
        let et = m.vector_energy_fj(8, Some(1.34));
        assert!(et < no_et);
    }
}

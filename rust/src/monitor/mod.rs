//! Fidelity monitor: sampled shadow verification of noisy/analog shards
//! against the digital golden path, with closed-loop drift detection.
//!
//! The ADC/DAC-free scheme trains against the highly quantized
//! comparator outputs, so an analog crossbar that drifts (rising
//! `sigma_ant`, device aging) corrupts inference *silently* — latency,
//! throughput and readiness all look healthy.  This module watches
//! numerical health: 1-in-K slices served by a non-digital shard are
//! also enqueued to a dedicated checker thread that re-executes the
//! exact same sub-request (same block partition, same pinned
//! quantization scale, same early-termination thresholds) through a
//! private digital [`Coordinator`] and measures the divergence in
//! quantized units.
//!
//! ```text
//!   router drain ──▶ MonitorHandle::wants_sample(shard)?   (hot path:
//!        │                                                  1–2 branches)
//!        ▼ sampled
//!   bounded queue (drop-OLDEST on overflow — the monitor can lag,
//!        │          but it can never back-pressure serving)
//!        ▼
//!   checker thread: digital golden re-execution ─▶ DivergenceRecord
//!        │                                          (sign flips, |Δq|,
//!        ▼                                          per-block mismatch)
//!   per-slot EWMA > --drift-threshold?  ─▶ clear slot_health flag:
//!                                          /readyz degrades, batcher
//!                                          health tick respawns the slot
//! ```
//!
//! Everything is observable: `repro_fidelity_*` on `/metrics`,
//! `GET /debug/fidelity` for a JSON snapshot, and the `monitor-off`
//! cargo feature compiles the whole subsystem down to dead branches
//! (mirroring `trace-off`).
//!
//! Divergence is measured on the quantization lattice.  Every transform
//! output is an integer PSUM times the block's quantization scale, so
//! `Δq = (observed − golden) / scale` is the error in quantizer LSBs —
//! comparable across requests, bits and input magnitudes.  A digital
//! shard shadow-checks to *exactly zero* divergence (the golden path is
//! the same arithmetic), which is this module's like-for-like canary.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use crate::coordinator::{Coordinator, CoordinatorConfig, TileKind, TransformRequest};
use crate::quant::Quantizer;
use crate::util::json::Json;

/// Fidelity monitor configuration (`--fidelity-sample`,
/// `--drift-threshold` on the CLI).
#[derive(Debug, Clone)]
pub struct MonitorConfig {
    /// Shadow-verify 1-in-K sampled slices on non-digital shards;
    /// 0 disables the monitor entirely.
    pub sample_every: u32,
    /// A slot whose divergence EWMA (mean |Δq| per element, in
    /// quantizer LSBs) exceeds this is marked drifting/unhealthy.
    pub drift_threshold: f64,
    /// EWMA smoothing factor α (weight of the newest check).
    pub ewma_alpha: f64,
    /// Last-N divergence records kept for `/debug/fidelity`.
    pub recent_capacity: usize,
    /// Bounded shadow-sample queue depth; on overflow the OLDEST
    /// sample is dropped so the hot path never blocks.
    pub queue_depth: usize,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig {
            sample_every: 16,
            drift_threshold: 1.0,
            ewma_alpha: 0.2,
            recent_capacity: 64,
            queue_depth: 256,
        }
    }
}

/// One sampled slice captured at the router's drain point: the exact
/// sub-request a shard executed plus what it returned.
#[derive(Debug, Clone)]
pub struct ShadowSample {
    /// Shard slot that served the slice.
    pub shard: usize,
    /// The sub-request (pinned scale and thresholds included), exactly
    /// as submitted to the shard.
    pub request: TransformRequest,
    /// Block partition of the sub-request.
    pub blocks: Vec<usize>,
    /// The shard's output values.
    pub observed: Vec<f32>,
}

/// Divergence of one shadow-checked slice vs the digital golden path.
#[derive(Debug, Clone)]
pub struct DivergenceRecord {
    pub shard: usize,
    /// Output elements compared.
    pub elements: usize,
    /// Elements whose observed and golden outputs have strictly
    /// opposite (nonzero) signs.
    pub sign_flips: u64,
    /// Elements off the golden lattice point by more than half an LSB.
    pub mismatched: u64,
    /// Mean |Δq| per element, in quantizer LSBs.
    pub mean_abs_dq: f64,
    /// Max |Δq| over the slice, in quantizer LSBs.
    pub max_abs_dq: f64,
    /// Per-block mismatched-element fraction, one entry per block.
    pub block_mismatch: Vec<f64>,
}

/// Bucket bounds for the mean-|Δq| divergence histogram (LSB units).
pub const DELTA_BUCKETS: &[f64] = &[0.01, 0.05, 0.25, 1.0, 4.0, 16.0, 64.0, 256.0];

/// Bucket bounds for the per-block mismatch-fraction histogram.
pub const MISMATCH_BUCKETS: &[f64] = &[0.0, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0];

/// A fixed-bound histogram (the divergence stats are unit-less ratios /
/// LSB counts, so the latency-tuned `LatencyHistogram` buckets do not
/// fit).  Rendered cumulatively for Prometheus.
#[derive(Debug, Clone)]
pub struct FixedHistogram {
    bounds: &'static [f64],
    /// One count per bound, plus a trailing overflow (+Inf) slot.
    counts: Vec<u64>,
    sum: f64,
}

impl FixedHistogram {
    pub fn new(bounds: &'static [f64]) -> FixedHistogram {
        FixedHistogram {
            bounds,
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
        }
    }

    pub fn record(&mut self, v: f64) {
        let i = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[i] += 1;
        self.sum += v;
    }

    pub fn bounds(&self) -> &'static [f64] {
        self.bounds
    }

    /// Cumulative counts, one per bound plus the trailing +Inf slot.
    pub fn cumulative(&self) -> Vec<u64> {
        let mut total = 0u64;
        self.counts
            .iter()
            .map(|&c| {
                total += c;
                total
            })
            .collect()
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }
}

/// Public per-slot view for `/debug/fidelity` and `/metrics`.
#[derive(Debug, Clone)]
pub struct SlotSnapshot {
    pub shard: usize,
    /// Whether the slot runs a non-digital backend (only those sample).
    pub eligible: bool,
    /// Divergence EWMA in quantizer LSBs.
    pub ewma: f64,
    /// Shadow checks absorbed for this slot (resets on respawn).
    pub checks: u64,
    /// Currently marked drifting (cleared when the slot respawns).
    pub flagged: bool,
}

#[derive(Debug, Default)]
struct SlotState {
    ewma: f64,
    checks: u64,
    flagged: bool,
}

struct Shared {
    config: MonitorConfig,
    eligible: Vec<bool>,
    /// Hot-path 1-in-K sampling counter (eligible-shard drains only).
    counter: AtomicU64,
    checked: AtomicU64,
    dropped: AtomicU64,
    flagged_total: AtomicU64,
    drift_respawns: AtomicU64,
    check_errors: AtomicU64,
    queue: Mutex<VecDeque<ShadowSample>>,
    cv: Condvar,
    shutdown: AtomicBool,
    slots: Vec<Mutex<SlotState>>,
    recent: Mutex<VecDeque<DivergenceRecord>>,
    delta_hist: Mutex<FixedHistogram>,
    mismatch_hist: Mutex<FixedHistogram>,
    /// The `ShardSet`'s per-slot readiness flags: a drift-flagged slot
    /// degrades `/readyz` immediately, without waiting for the batcher.
    slot_health: Arc<Vec<AtomicBool>>,
}

impl Shared {
    /// Fold one checked record into the per-slot EWMA, the histograms
    /// and the recent ring; flag the slot if its EWMA crossed the
    /// threshold.
    fn absorb(&self, rec: DivergenceRecord) {
        self.checked.fetch_add(1, Ordering::Relaxed);
        {
            let mut h = self.delta_hist.lock().expect("delta hist poisoned");
            h.record(rec.mean_abs_dq);
        }
        {
            let mut h = self.mismatch_hist.lock().expect("mismatch hist poisoned");
            for &f in &rec.block_mismatch {
                h.record(f);
            }
        }
        if let Some(slot) = self.slots.get(rec.shard) {
            let mut s = slot.lock().expect("slot state poisoned");
            s.checks += 1;
            s.ewma = if s.checks == 1 {
                rec.mean_abs_dq
            } else {
                self.config.ewma_alpha * rec.mean_abs_dq
                    + (1.0 - self.config.ewma_alpha) * s.ewma
            };
            if !s.flagged && s.ewma > self.config.drift_threshold {
                s.flagged = true;
                self.flagged_total.fetch_add(1, Ordering::Relaxed);
                if let Some(flag) = self.slot_health.get(rec.shard) {
                    flag.store(false, Ordering::Release);
                }
            }
        }
        let mut r = self.recent.lock().expect("recent ring poisoned");
        if r.len() >= self.config.recent_capacity.max(1) {
            r.pop_front();
        }
        r.push_back(rec);
    }
}

/// The hot-path capture handle threaded into the shard router — the
/// monitor-side analogue of [`crate::trace::TraceHandle`].  A disabled
/// monitor (or the `monitor-off` feature) hands out an inactive handle:
/// every check is one dead branch.
#[derive(Clone)]
pub struct MonitorHandle(Option<Arc<Shared>>);

impl MonitorHandle {
    pub fn inactive() -> MonitorHandle {
        MonitorHandle(None)
    }

    #[inline]
    pub fn is_active(&self) -> bool {
        self.0.is_some()
    }

    /// Should this drained slice be shadow-verified?  Inactive handle
    /// or digital shard: no (1–2 branches).  Otherwise 1-in-K over the
    /// eligible-slice counter.
    pub fn wants_sample(&self, shard: usize) -> bool {
        let Some(s) = &self.0 else { return false };
        if !s.eligible.get(shard).copied().unwrap_or(false) {
            return false;
        }
        s.counter.fetch_add(1, Ordering::Relaxed) % u64::from(s.config.sample_every.max(1)) == 0
    }

    /// Hand a sampled slice to the checker.  Never blocks: when the
    /// bounded queue is full the OLDEST queued sample is dropped (and
    /// counted) — monitoring lags under load, serving does not.
    pub fn enqueue(&self, sample: ShadowSample) {
        let Some(s) = &self.0 else { return };
        {
            let mut q = s.queue.lock().expect("monitor queue poisoned");
            if q.len() >= s.config.queue_depth.max(1) {
                q.pop_front();
                s.dropped.fetch_add(1, Ordering::Relaxed);
            }
            q.push_back(sample);
        }
        s.cv.notify_one();
    }
}

/// Re-execute one sampled slice through the digital golden coordinator
/// and measure its divergence in quantized units.
///
/// The golden pool re-runs the *same* `TransformRequest` (pinned scale
/// and thresholds included) over the *same* block partition, so a
/// digital source shard produces bit-identical output and exactly zero
/// divergence; anything nonzero is the analog backend's doing.
pub fn shadow_check(golden: &mut Coordinator, sample: &ShadowSample) -> Result<DivergenceRecord> {
    let expect = golden.transform_planned(&sample.request, &sample.blocks)?;
    if expect.len() != sample.observed.len() {
        bail!(
            "shadow output width {} does not match observed width {}",
            expect.len(),
            sample.observed.len()
        );
    }
    let quant = Quantizer::new(golden.config().bits);
    let mut sign_flips = 0u64;
    let mut mismatched = 0u64;
    let mut abs_sum = 0f64;
    let mut abs_max = 0f64;
    let mut block_mismatch = Vec::with_capacity(sample.blocks.len());
    let mut off = 0usize;
    for &w in &sample.blocks {
        // Per-block scale: pinned when the request pins one (the NN
        // executor path), otherwise re-derived from the block's own
        // amax — the same rule the shard applied, so Δ/scale is the
        // error on the lattice the shard actually quantized to.
        let scale = f64::from(
            sample
                .request
                .scale
                .unwrap_or_else(|| quant.scale_for(&sample.request.x[off..off + w])),
        );
        let mut block_miss = 0u64;
        for i in off..off + w {
            let obs = f64::from(sample.observed[i]);
            let exp = f64::from(expect[i]);
            let dq = (obs - exp) / scale;
            let a = dq.abs();
            abs_sum += a;
            if a > abs_max {
                abs_max = a;
            }
            if a > 0.5 {
                mismatched += 1;
                block_miss += 1;
            }
            if obs * exp < 0.0 {
                sign_flips += 1;
            }
        }
        block_mismatch.push(block_miss as f64 / w as f64);
        off += w;
    }
    Ok(DivergenceRecord {
        shard: sample.shard,
        elements: expect.len(),
        sign_flips,
        mismatched,
        mean_abs_dq: abs_sum / expect.len().max(1) as f64,
        max_abs_dq: abs_max,
        block_mismatch,
    })
}

fn checker_loop(shared: Arc<Shared>, golden_config: CoordinatorConfig) {
    let mut golden = Coordinator::new(golden_config);
    loop {
        let sample = {
            let mut q = shared.queue.lock().expect("monitor queue poisoned");
            loop {
                if let Some(s) = q.pop_front() {
                    break Some(s);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.cv.wait(q).expect("monitor queue poisoned");
            }
        };
        let Some(sample) = sample else { break };
        match shadow_check(&mut golden, &sample) {
            Ok(rec) => shared.absorb(rec),
            Err(_) => {
                shared.check_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    golden.shutdown();
}

/// The fidelity monitor: owns the checker thread and the divergence
/// state; hands the router a cheap capture handle.
pub struct Monitor {
    shared: Option<Arc<Shared>>,
    checker: Option<JoinHandle<()>>,
}

impl Monitor {
    /// Start the monitor.  `golden` is the serving pool's coordinator
    /// config — the checker derives a single-worker *digital* pool from
    /// it (same tile/bits), which is what makes the comparison
    /// like-for-like.  `eligible[s]` marks the slots running
    /// non-digital backends; with none (or `sample_every == 0`, or the
    /// `monitor-off` feature) the monitor is disabled and costs one
    /// dead branch per drain.
    pub fn start(
        config: MonitorConfig,
        golden: CoordinatorConfig,
        eligible: Vec<bool>,
        slot_health: Arc<Vec<AtomicBool>>,
    ) -> Monitor {
        Monitor::start_inner(config, golden, eligible, slot_health, true)
    }

    fn start_inner(
        config: MonitorConfig,
        golden: CoordinatorConfig,
        eligible: Vec<bool>,
        slot_health: Arc<Vec<AtomicBool>>,
        spawn_checker: bool,
    ) -> Monitor {
        let active = !cfg!(feature = "monitor-off")
            && config.sample_every > 0
            && eligible.iter().any(|&e| e);
        if !active {
            return Monitor::disabled();
        }
        let golden_config = CoordinatorConfig {
            kind: TileKind::Digital,
            workers: 1,
            seed: 0,
            ..golden
        };
        let shared = Arc::new(Shared {
            eligible,
            counter: AtomicU64::new(0),
            checked: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            flagged_total: AtomicU64::new(0),
            drift_respawns: AtomicU64::new(0),
            check_errors: AtomicU64::new(0),
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            slots: (0..slot_health.len()).map(|_| Mutex::new(SlotState::default())).collect(),
            recent: Mutex::new(VecDeque::new()),
            delta_hist: Mutex::new(FixedHistogram::new(DELTA_BUCKETS)),
            mismatch_hist: Mutex::new(FixedHistogram::new(MISMATCH_BUCKETS)),
            slot_health,
            config,
        });
        let checker = if spawn_checker {
            let shared = Arc::clone(&shared);
            Some(std::thread::spawn(move || checker_loop(shared, golden_config)))
        } else {
            None
        };
        Monitor {
            shared: Some(shared),
            checker,
        }
    }

    /// A permanently inactive monitor (digital-only serving, tests).
    pub fn disabled() -> Monitor {
        Monitor {
            shared: None,
            checker: None,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.shared.is_some()
    }

    pub fn handle(&self) -> MonitorHandle {
        MonitorHandle(self.shared.clone())
    }

    pub fn sample_every(&self) -> u32 {
        self.shared.as_ref().map_or(0, |s| s.config.sample_every)
    }

    pub fn drift_threshold(&self) -> f64 {
        self.shared
            .as_ref()
            .map_or(0.0, |s| s.config.drift_threshold)
    }

    pub fn checked_total(&self) -> u64 {
        self.load(|s| &s.checked)
    }

    pub fn dropped_total(&self) -> u64 {
        self.load(|s| &s.dropped)
    }

    pub fn flagged_total(&self) -> u64 {
        self.load(|s| &s.flagged_total)
    }

    pub fn drift_respawns_total(&self) -> u64 {
        self.load(|s| &s.drift_respawns)
    }

    pub fn check_errors_total(&self) -> u64 {
        self.load(|s| &s.check_errors)
    }

    fn load(&self, f: impl Fn(&Shared) -> &AtomicU64) -> u64 {
        self.shared
            .as_ref()
            .map_or(0, |s| f(s).load(Ordering::Relaxed))
    }

    /// Record that the batcher respawned a slot because of drift.
    pub fn note_drift_respawn(&self) {
        if let Some(s) = &self.shared {
            s.drift_respawns.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Slots currently marked drifting (awaiting a recycle by the
    /// batcher health tick).
    pub fn flagged_slots(&self) -> Vec<usize> {
        let Some(s) = &self.shared else {
            return Vec::new();
        };
        s.slots
            .iter()
            .enumerate()
            .filter(|(_, slot)| slot.lock().expect("slot state poisoned").flagged)
            .map(|(i, _)| i)
            .collect()
    }

    /// Reset a slot's drift state after it respawned as a fresh pool.
    pub fn reset_slot(&self, shard: usize) {
        let Some(s) = &self.shared else { return };
        if let Some(slot) = s.slots.get(shard) {
            let mut st = slot.lock().expect("slot state poisoned");
            *st = SlotState::default();
        }
    }

    /// Per-slot snapshots (empty when disabled).
    pub fn slots(&self) -> Vec<SlotSnapshot> {
        let Some(s) = &self.shared else {
            return Vec::new();
        };
        s.slots
            .iter()
            .enumerate()
            .map(|(i, slot)| {
                let st = slot.lock().expect("slot state poisoned");
                SlotSnapshot {
                    shard: i,
                    eligible: s.eligible.get(i).copied().unwrap_or(false),
                    ewma: st.ewma,
                    checks: st.checks,
                    flagged: st.flagged,
                }
            })
            .collect()
    }

    /// The newest `n` divergence records, newest first.
    pub fn recent(&self, n: usize) -> Vec<DivergenceRecord> {
        let Some(s) = &self.shared else {
            return Vec::new();
        };
        let ring = s.recent.lock().expect("recent ring poisoned");
        ring.iter().rev().take(n).cloned().collect()
    }

    /// Snapshots of the (mean-|Δq|, per-block mismatch) histograms.
    /// A disabled monitor reports empty histograms with the same bucket
    /// structure, so the `/metrics` exposition shape never changes.
    pub fn histograms(&self) -> (FixedHistogram, FixedHistogram) {
        match &self.shared {
            Some(s) => (
                s.delta_hist.lock().expect("delta hist poisoned").clone(),
                s.mismatch_hist
                    .lock()
                    .expect("mismatch hist poisoned")
                    .clone(),
            ),
            None => (
                FixedHistogram::new(DELTA_BUCKETS),
                FixedHistogram::new(MISMATCH_BUCKETS),
            ),
        }
    }

    #[cfg(test)]
    #[allow(dead_code)] // only exercised in non-`monitor-off` test builds
    fn queue_len(&self) -> usize {
        self.shared.as_ref().map_or(0, |s| {
            s.queue.lock().expect("monitor queue poisoned").len()
        })
    }

    /// The `GET /debug/fidelity` snapshot: config + counters + per-slot
    /// EWMA state + the newest `n` divergence records.
    pub fn fidelity_json(&self, n: usize) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("enabled".into(), Json::Bool(self.is_enabled()));
        obj.insert(
            "sample_every".into(),
            Json::Num(f64::from(self.sample_every())),
        );
        obj.insert(
            "drift_threshold".into(),
            Json::Num(self.drift_threshold()),
        );
        obj.insert("checked".into(), Json::Num(self.checked_total() as f64));
        obj.insert("dropped".into(), Json::Num(self.dropped_total() as f64));
        obj.insert("flagged".into(), Json::Num(self.flagged_total() as f64));
        obj.insert(
            "drift_respawns".into(),
            Json::Num(self.drift_respawns_total() as f64),
        );
        obj.insert(
            "check_errors".into(),
            Json::Num(self.check_errors_total() as f64),
        );
        let slots = self
            .slots()
            .into_iter()
            .map(|s| {
                let mut o = std::collections::BTreeMap::new();
                o.insert("shard".into(), Json::Num(s.shard as f64));
                o.insert("eligible".into(), Json::Bool(s.eligible));
                o.insert("ewma".into(), Json::Num(s.ewma));
                o.insert("checks".into(), Json::Num(s.checks as f64));
                o.insert("flagged".into(), Json::Bool(s.flagged));
                Json::Obj(o)
            })
            .collect();
        obj.insert("slots".into(), Json::Arr(slots));
        let recent = self
            .recent(n)
            .into_iter()
            .map(|r| {
                let mut o = std::collections::BTreeMap::new();
                o.insert("shard".into(), Json::Num(r.shard as f64));
                o.insert("elements".into(), Json::Num(r.elements as f64));
                o.insert("sign_flips".into(), Json::Num(r.sign_flips as f64));
                o.insert("mismatched".into(), Json::Num(r.mismatched as f64));
                o.insert("mean_abs_dq".into(), Json::Num(r.mean_abs_dq));
                o.insert("max_abs_dq".into(), Json::Num(r.max_abs_dq));
                o.insert(
                    "block_mismatch".into(),
                    Json::Arr(r.block_mismatch.iter().map(|&f| Json::Num(f)).collect()),
                );
                Json::Obj(o)
            })
            .collect();
        obj.insert("recent".into(), Json::Arr(recent));
        Json::Obj(obj)
    }
}

impl Drop for Monitor {
    fn drop(&mut self) {
        if let Some(s) = &self.shared {
            s.shutdown.store(true, Ordering::Release);
            s.cv.notify_all();
        }
        if let Some(h) = self.checker.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn health(n: usize) -> Arc<Vec<AtomicBool>> {
        Arc::new((0..n).map(|_| AtomicBool::new(true)).collect())
    }

    fn sample_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect()
    }

    #[test]
    #[cfg(not(feature = "monitor-off"))]
    fn digital_shadow_check_reports_zero_divergence_across_random_partitions_and_bits() {
        // The like-for-like canary: a *digital* shard re-checked through
        // the digital golden path must diverge by exactly zero — same
        // pinned scales, same thresholds, same partition — across random
        // partitions, bits, threshold patterns and scale pinning.
        let mut rng = Rng::seed_from_u64(0xF1DE);
        for case in 0..40 {
            let bits = 1 + (rng.int_range(0, 7) as u32);
            let n_blocks = 1 + rng.int_range(0, 3) as usize;
            let blocks: Vec<usize> = (0..n_blocks)
                .map(|_| [4usize, 8, 16][rng.int_range(0, 2) as usize])
                .collect();
            let width: usize = blocks.iter().sum();
            let x = sample_vec(&mut rng, width);
            let thresholds: Vec<f64> = (0..width)
                .map(|_| rng.int_range(0, 2) as f64)
                .collect();
            let scale = if case % 2 == 0 {
                Some(Quantizer::new(bits).scale_for(&x))
            } else {
                None
            };
            let request = TransformRequest {
                x,
                thresholds_units: thresholds,
                scale,
                deadline: None,
            };
            let config = CoordinatorConfig {
                bits,
                workers: 1,
                ..Default::default()
            };
            let mut shard = Coordinator::new(config.clone());
            let observed = shard.transform_planned(&request, &blocks).unwrap();
            shard.shutdown();
            let mut golden = Coordinator::new(config);
            let rec = shadow_check(
                &mut golden,
                &ShadowSample {
                    shard: 0,
                    request,
                    blocks: blocks.clone(),
                    observed,
                },
            )
            .unwrap();
            golden.shutdown();
            assert_eq!(rec.sign_flips, 0, "case {case}: {blocks:?} bits {bits}");
            assert_eq!(rec.mismatched, 0, "case {case}");
            assert_eq!(rec.mean_abs_dq, 0.0, "case {case}");
            assert_eq!(rec.max_abs_dq, 0.0, "case {case}");
            assert!(rec.block_mismatch.iter().all(|&f| f == 0.0), "case {case}");
            assert_eq!(rec.elements, width, "case {case}");
        }
    }

    #[test]
    #[cfg(not(feature = "monitor-off"))]
    fn gross_divergence_flags_the_slot_and_degrades_its_health_flag() {
        let slot_health = health(2);
        let monitor = Monitor::start(
            MonitorConfig {
                sample_every: 1,
                drift_threshold: 1.0,
                ..Default::default()
            },
            CoordinatorConfig::default(),
            vec![false, true],
            Arc::clone(&slot_health),
        );
        assert!(monitor.is_enabled());
        let handle = monitor.handle();
        let mut rng = Rng::seed_from_u64(9);
        let x = sample_vec(&mut rng, 16);
        let request = TransformRequest::plain(x.clone());
        // "Observed" output grossly off the golden lattice: 10 LSBs of
        // bias on every element.
        let mut golden = Coordinator::new(CoordinatorConfig {
            workers: 1,
            ..Default::default()
        });
        let expect = golden.transform_planned(&request, &[16]).unwrap();
        golden.shutdown();
        let scale = Quantizer::new(8).scale_for(&x);
        let observed: Vec<f32> = expect.iter().map(|v| v + 10.0 * scale).collect();
        for _ in 0..3 {
            assert!(handle.wants_sample(1), "sample_every=1 samples everything");
            handle.enqueue(ShadowSample {
                shard: 1,
                request: request.clone(),
                blocks: vec![16],
                observed: observed.clone(),
            });
        }
        // The checker flags asynchronously; wait for it.
        let t0 = std::time::Instant::now();
        while monitor.flagged_slots().is_empty() {
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(10),
                "checker never flagged the drifting slot"
            );
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(monitor.flagged_slots(), vec![1]);
        assert!(
            !slot_health[1].load(Ordering::Acquire),
            "flagging must clear the slot's readiness flag"
        );
        assert!(slot_health[0].load(Ordering::Acquire));
        assert_eq!(monitor.flagged_total(), 1);
        assert!(monitor.checked_total() >= 1);
        let slots = monitor.slots();
        assert!(slots[1].ewma > 1.0 && slots[1].flagged && slots[1].checks >= 1);
        assert!(!slots[0].flagged && slots[0].checks == 0);
        let recent = monitor.recent(8);
        assert!(!recent.is_empty());
        assert!(recent[0].mean_abs_dq > 5.0 && recent[0].mismatched == 16);
        let (delta, mismatch) = monitor.histograms();
        assert!(delta.count() >= 1 && mismatch.count() >= 1);
        // Recycle: the batcher resets the slot after respawning it.
        monitor.note_drift_respawn();
        monitor.reset_slot(1);
        assert_eq!(monitor.drift_respawns_total(), 1);
        assert!(monitor.flagged_slots().is_empty());
        assert_eq!(monitor.slots()[1].checks, 0);
        // The JSON snapshot parses and carries the slot array.
        let text = monitor.fidelity_json(4).to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.get("slots").and_then(Json::as_arr).unwrap().len(), 2);
    }

    #[test]
    #[cfg(not(feature = "monitor-off"))]
    fn bounded_queue_drops_oldest_without_blocking() {
        // No checker thread: the queue fills deterministically.
        let monitor = Monitor::start_inner(
            MonitorConfig {
                sample_every: 1,
                queue_depth: 2,
                ..Default::default()
            },
            CoordinatorConfig::default(),
            vec![true],
            health(1),
            false,
        );
        let handle = monitor.handle();
        for seed in 0..4u64 {
            let mut rng = Rng::seed_from_u64(seed);
            handle.enqueue(ShadowSample {
                shard: 0,
                request: TransformRequest::plain(sample_vec(&mut rng, 16)),
                blocks: vec![16],
                observed: vec![0.0; 16],
            });
        }
        assert_eq!(monitor.queue_len(), 2, "queue is bounded at depth 2");
        assert_eq!(
            monitor.dropped_total(),
            2,
            "two oldest samples were dropped, not the newest"
        );
    }

    #[test]
    #[cfg(not(feature = "monitor-off"))]
    fn sampling_gate_is_one_in_k_and_skips_digital_slots() {
        let monitor = Monitor::start_inner(
            MonitorConfig {
                sample_every: 4,
                ..Default::default()
            },
            CoordinatorConfig::default(),
            vec![true, false],
            health(2),
            false,
        );
        let handle = monitor.handle();
        let pattern: Vec<bool> = (0..8).map(|_| handle.wants_sample(0)).collect();
        assert_eq!(
            pattern,
            vec![true, false, false, false, true, false, false, false]
        );
        assert!(
            (0..8).all(|_| !handle.wants_sample(1)),
            "digital slots never sample"
        );
        assert!(!MonitorHandle::inactive().is_active());
        assert!(!MonitorHandle::inactive().wants_sample(0));
    }

    #[test]
    fn disabled_configurations_cost_one_dead_branch() {
        // sample_every = 0 and all-digital slot maps both disable the
        // monitor outright.
        for (k, eligible) in [(0u32, vec![true]), (16, vec![false, false])] {
            let m = Monitor::start(
                MonitorConfig {
                    sample_every: k,
                    ..Default::default()
                },
                CoordinatorConfig::default(),
                eligible,
                health(2),
            );
            assert!(!m.is_enabled());
            assert!(!m.handle().is_active());
            assert!(m.slots().is_empty());
            assert_eq!(m.checked_total(), 0);
            let (d, mm) = m.histograms();
            assert_eq!(d.count(), 0);
            assert_eq!(mm.count(), 0);
        }
    }

    #[test]
    #[cfg(feature = "monitor-off")]
    fn monitor_off_feature_disables_everything() {
        let m = Monitor::start(
            MonitorConfig {
                sample_every: 1,
                ..Default::default()
            },
            CoordinatorConfig::default(),
            vec![true],
            health(1),
        );
        assert!(!m.is_enabled());
        assert!(!m.handle().is_active());
        assert!(!m.handle().wants_sample(0));
    }

    #[test]
    fn fixed_histogram_buckets_are_cumulative_with_overflow() {
        let mut h = FixedHistogram::new(&[1.0, 2.0, 4.0]);
        for v in [0.5, 1.0, 3.0, 100.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.cumulative(), vec![2, 2, 3, 4]);
        assert!((h.sum() - 104.5).abs() < 1e-9);
    }
}

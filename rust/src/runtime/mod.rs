//! PJRT runtime: load and execute the AOT artifacts from `make artifacts`.
//!
//! The interchange format is HLO *text* (NOT a serialized HloModuleProto:
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids).  Each artifact is compiled
//! once per process and cached; the rust request path never touches
//! python.
//!
//! Artifacts are lowered with `return_tuple=True`, so executions return a
//! 1-level tuple that we unpack into a `Vec<Literal>`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Shape/dtype of one artifact argument (from manifest.json).
#[derive(Debug, Clone, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub tau: f64,
    pub bits: u32,
    pub sgd_lr: f64,
    pub artifacts: HashMap<String, (String, Vec<ArgSpec>)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = json::parse(text)?;
        let get_num = |k: &str| -> Result<f64> {
            root.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        let mut artifacts = HashMap::new();
        let arts = root
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        for (name, meta) in arts {
            let file = meta
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{name}: missing file"))?
                .to_string();
            let args = meta
                .get("args")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing args"))?
                .iter()
                .map(|a| {
                    Ok(ArgSpec {
                        name: a
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("bad arg name"))?
                            .to_string(),
                        shape: a
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow!("bad arg shape"))?
                            .iter()
                            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
                            .collect::<Result<_>>()?,
                        dtype: a
                            .get("dtype")
                            .and_then(Json::as_str)
                            .unwrap_or("float32")
                            .to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(name.clone(), (file, args));
        }
        Ok(Manifest {
            tau: get_num("tau")?,
            bits: get_num("bits")? as u32,
            sgd_lr: get_num("sgd_lr")?,
            artifacts,
        })
    }
}

/// A loaded, compiled artifact.
pub struct Executable {
    pub name: String,
    pub args: Vec<ArgSpec>,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: CPU client + compiled artifact cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<String, Executable>,
}

/// A typed host tensor for artifact I/O.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32(shape.to_vec(), data)
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32(shape.to_vec(), data)
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(_, d) => Ok(d),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got {} elements", d.len());
        }
        Ok(d[0])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32(shape, data) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            HostTensor::I32(shape, data) => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(HostTensor::I32(dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

impl Runtime {
    /// Create a CPU PJRT client over an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and fetch an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let (file, args) = self
                .manifest
                .artifacts
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact {name}"))?
                .clone();
            let path = self.dir.join(&file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e}"))?;
            self.cache.insert(
                name.to_string(),
                Executable {
                    name: name.to_string(),
                    args,
                    exe,
                },
            );
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact with host tensors; returns the unpacked tuple.
    pub fn run(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let exe = self.load(name)?;
        if inputs.len() != exe.args.len() {
            bail!(
                "{name}: expected {} args, got {}",
                exe.args.len(),
                inputs.len()
            );
        }
        for (inp, spec) in inputs.iter().zip(&exe.args) {
            let shape = match inp {
                HostTensor::F32(s, _) => s,
                HostTensor::I32(s, _) => s,
            };
            if shape != &spec.shape {
                bail!(
                    "{name}: arg {} shape mismatch: got {shape:?}, want {:?}",
                    spec.name,
                    spec.shape
                );
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let mut result = exe
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync {name}: {e}"))?;
        // Artifacts are lowered with return_tuple=True: unpack the tuple.
        let elements = result
            .decompose_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e}"))?;
        elements.iter().map(HostTensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
        "tau": 24.0, "bits": 8, "sgd_lr": 0.02,
        "artifacts": {
            "wht16": {"file": "wht16.hlo.txt",
                       "args": [{"name": "x", "shape": [16, 16], "dtype": "float32"}]}
        }
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(m.bits, 8);
        let (file, args) = &m.artifacts["wht16"];
        assert_eq!(file, "wht16.hlo.txt");
        assert_eq!(args[0].shape, vec![16, 16]);
    }

    #[test]
    fn manifest_rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"tau": 1}"#).is_err());
    }

    #[test]
    fn host_tensor_shape_checks() {
        let t = HostTensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.as_f32().unwrap().len(), 4);
        assert!(t.scalar_f32().is_err());
        let s = HostTensor::f32(&[1], vec![7.0]);
        assert_eq!(s.scalar_f32().unwrap(), 7.0);
    }

    // Full PJRT round-trips are exercised by tests/runtime_integration.rs
    // (they need the artifacts directory built by `make artifacts`).
}

//! Bitplane-wise ADC-free transform engine (Eq. 4, Fig. 6) — digital
//! golden model.
//!
//! This is the exact arithmetic the analog crossbar implements: the
//! multi-bit input is quantized to sign-magnitude bitplanes, each plane's
//! ±1 matvec against the Walsh block is collapsed to one bit per output by
//! the row comparator (`sign`, with `sign(0) = 0`), and per-plane bits are
//! recombined with binary weights.  The analog simulator ([`crate::analog`])
//! is validated against this model, and [`early_term`] implements the
//! paper's predictive termination on top of the same plane stream.

pub mod early_term;

use crate::quant::{Quantized, Quantizer};
use crate::wht;

/// Comparator convention: `sign(0) = 0` (an exactly balanced charge share
/// trips neither way; training treats it as 0) — matches `ref.py`.
#[inline]
pub fn comparator(psum: i64) -> i8 {
    match psum.cmp(&0) {
        std::cmp::Ordering::Greater => 1,
        std::cmp::Ordering::Less => -1,
        std::cmp::Ordering::Equal => 0,
    }
}

/// Exact digital Eq. 4 engine over a BWHT-partitioned width.
#[derive(Debug, Clone)]
pub struct QuantBwht {
    pub dim: usize,
    pub max_block: usize,
    pub quantizer: Quantizer,
}

/// Per-plane comparator outputs plus the recombined result.
#[derive(Debug, Clone)]
pub struct PlaneTrace {
    /// `obits[p][i]`: comparator output of output element `i` during the
    /// processing of plane `p` (index 0 = MSB, matching hardware order).
    pub obits: Vec<Vec<i8>>,
    /// Input quantization scale (output rescale factor).
    pub scale: f32,
    /// Number of magnitude bitplanes.
    pub bits: u32,
}

impl PlaneTrace {
    /// Recombine all planes: `y_i = scale * sum_b obit_b,i * 2^(b-1)`.
    pub fn recombine(&self) -> Vec<f32> {
        let n = self.obits[0].len();
        let mut acc = vec![0f32; n];
        for (p, plane) in self.obits.iter().enumerate() {
            // plane index 0 is the MSB => weight 2^(bits-1-p).
            let w = (1i64 << (self.bits as usize - 1 - p)) as f32;
            for (a, &o) in acc.iter_mut().zip(plane) {
                *a += o as f32 * w;
            }
        }
        acc.iter().map(|v| v * self.scale).collect()
    }
}

impl QuantBwht {
    pub fn new(dim: usize, max_block: usize, bits: u32) -> Self {
        QuantBwht {
            dim,
            max_block,
            quantizer: Quantizer::new(bits),
        }
    }

    pub fn padded_dim(&self) -> usize {
        wht::bwht_padded_dim(self.dim, self.max_block)
    }

    /// Per-plane integer PSUMs (pre-comparator) of one plane's ±1 inputs.
    pub fn plane_psums(&self, plane: &[i8]) -> Vec<i64> {
        let x: Vec<i64> = plane.iter().map(|&v| v as i64).collect();
        wht::bwht_apply_i64(&x, self.dim, self.max_block)
    }

    /// Full trace: quantize → stream planes MSB-first → comparator bits.
    pub fn trace(&self, x: &[f32]) -> PlaneTrace {
        assert_eq!(x.len(), self.padded_dim(), "input must be padded");
        let q: Quantized = self.quantizer.quantize(x);
        let mut plane = vec![0i8; x.len()];
        let mut planes = q.planes_msb_first();
        let mut obits = Vec::with_capacity(self.quantizer.bits as usize);
        while planes.next_into(&mut plane).is_some() {
            obits.push(
                self.plane_psums(&plane)
                    .into_iter()
                    .map(comparator)
                    .collect(),
            );
        }
        PlaneTrace {
            obits,
            scale: q.scale,
            bits: self.quantizer.bits,
        }
    }

    /// The transform a downstream consumer sees (matches
    /// `ref.quant_bwht_ref` bit-for-bit).
    pub fn transform(&self, x: &[f32]) -> Vec<f32> {
        self.trace(x).recombine()
    }

    /// Float (non-quantized) blockwise transform — the "with ADC" baseline.
    pub fn transform_exact(&self, x: &[f32]) -> Vec<f32> {
        wht::bwht_apply(x, self.dim, self.max_block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s % 4000) as f32 / 1000.0) - 2.0
            })
            .collect()
    }

    #[test]
    fn comparator_sign_convention() {
        assert_eq!(comparator(5), 1);
        assert_eq!(comparator(-5), -1);
        assert_eq!(comparator(0), 0);
    }

    #[test]
    fn recombined_outputs_are_bounded() {
        let eng = QuantBwht::new(16, 128, 8);
        let x = sample(16, 1);
        let y = eng.transform(&x);
        let q = eng.quantizer.quantize(&x);
        let bound = (q.scale) * ((1 << 8) - 1) as f32;
        assert!(y.iter().all(|v| v.abs() <= bound + 1e-4));
    }

    #[test]
    fn one_bit_trace_single_plane() {
        let eng = QuantBwht::new(16, 128, 1);
        let t = eng.trace(&sample(16, 2));
        assert_eq!(t.obits.len(), 1);
        assert!(t.obits[0].iter().all(|&o| (-1..=1).contains(&o)));
    }

    #[test]
    fn sign_tracks_exact_transform() {
        // Eq. 4 output signs must correlate strongly with the exact
        // transform's signs (the paper's trainability premise).
        let eng = QuantBwht::new(64, 128, 8);
        let mut agree = 0usize;
        let mut total = 0usize;
        for seed in 0..20 {
            let x = sample(64, seed + 10);
            let approx = eng.transform(&x);
            let exact = eng.transform_exact(&x);
            for (a, e) in approx.iter().zip(&exact) {
                if e.abs() > 1e-3 {
                    total += 1;
                    if (a.signum() - e.signum()).abs() < 0.5 {
                        agree += 1;
                    }
                }
            }
        }
        let frac = agree as f64 / total as f64;
        assert!(frac > 0.7, "sign agreement too low: {frac}");
    }

    #[test]
    fn trace_matches_manual_eq4() {
        let eng = QuantBwht::new(4, 128, 2);
        let x = vec![1.0, -0.5, 0.25, -1.0];
        let y = eng.transform(&x);
        // manual: quantize to ±3 range
        let q = eng.quantizer.quantize(&x);
        let w = crate::wht::walsh(2);
        let mut want = vec![0f32; 4];
        for b in 0..2u32 {
            let plane = q.bitplane(b);
            for i in 0..4 {
                let psum: i64 = (0..4)
                    .map(|j| w.get(i, j) as i64 * plane[j] as i64)
                    .sum();
                want[i] += comparator(psum) as f32 * (1 << b) as f32;
            }
        }
        for w_ in want.iter_mut() {
            *w_ *= q.scale;
        }
        assert_eq!(y, want);
    }

    #[test]
    #[should_panic(expected = "padded")]
    fn unpadded_input_panics() {
        QuantBwht::new(20, 128, 4).transform(&[0.0; 19]);
    }
}

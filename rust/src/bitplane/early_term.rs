//! Predictive early termination (paper Sec. III-C, Figs. 9-10).
//!
//! The soft-threshold activation `S_T` zeroes any output with `|y| <= T`.
//! Processing bitplanes MSB-first, the running recombined output
//! `y_b = Σ_{k>=b} O_k 2^(k-1)` has computable bounds over the not-yet-
//! processed planes:
//!
//! ```text
//!   y_UB = running + Σ_{k<b} 2^(k-1)       (all remaining bits +1)
//!   y_LB = running - Σ_{k<b} 2^(k-1)       (all remaining bits -1)
//! ```
//!
//! If `y_UB <= T` and `y_LB >= -T`, the output is *guaranteed* zero after
//! activation and its remaining bitplane cycles are skipped (Fig. 10's
//! digital comparator/shift-register implementation).

use crate::util::rng::Rng;

/// Decision after feeding one comparator bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// More planes needed.
    Continue,
    /// Output is provably zero post-activation; stop processing.
    TerminateZero,
    /// All planes consumed; output is the final running sum.
    Complete,
}

/// Per-output-element early-termination tracker.
///
/// Operates in *comparator units* (integer recombination weights); the
/// caller converts its float threshold `T` into these units by dividing by
/// the input quantization scale (and any basis normalization).
#[derive(Debug, Clone)]
pub struct EarlyTerminator {
    bits: u32,
    /// Next plane to process, counting MSB-first: weight 2^(bits-1-planes_done).
    planes_done: u32,
    running: i64,
    threshold_units: f64,
}

impl EarlyTerminator {
    pub fn new(bits: u32, threshold_units: f64) -> Self {
        assert!(bits >= 1);
        EarlyTerminator {
            bits,
            planes_done: 0,
            running: 0,
            threshold_units: threshold_units.abs(),
        }
    }

    /// Weight of the plane about to be processed.
    fn next_weight(&self) -> i64 {
        1i64 << (self.bits - 1 - self.planes_done)
    }

    /// Sum of weights of all *remaining* planes (after `planes_done`):
    /// `Σ 2^k for k = 0..bits-planes_done-1 = 2^(bits-planes_done) - 1`.
    fn remaining_mass(&self) -> i64 {
        (1i64 << (self.bits - self.planes_done)) - 1
    }

    pub fn running(&self) -> i64 {
        self.running
    }

    pub fn planes_done(&self) -> u32 {
        self.planes_done
    }

    /// Current bounds (Fig. 9b): `(y_LB, y_UB)` given unknown planes
    /// clamped to ±1.
    pub fn bounds(&self) -> (i64, i64) {
        let rem = self.remaining_mass();
        (self.running - rem, self.running + rem)
    }

    /// Feed the comparator output of the next plane (MSB-first).
    pub fn step(&mut self, obit: i8) -> Decision {
        assert!(self.planes_done < self.bits, "all planes already consumed");
        debug_assert!((-1..=1).contains(&obit));
        self.running += obit as i64 * self.next_weight();
        self.planes_done += 1;
        if self.planes_done == self.bits {
            return Decision::Complete;
        }
        let (lb, ub) = self.bounds();
        if (ub as f64) <= self.threshold_units && (lb as f64) >= -self.threshold_units {
            Decision::TerminateZero
        } else {
            Decision::Continue
        }
    }
}

/// Outcome of running one output element through the terminator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ElementOutcome {
    /// Bitplane cycles actually consumed.
    pub cycles: u32,
    /// Whether the element terminated early (provably-zero output).
    pub terminated: bool,
    /// Recombined value in comparator units (0 if terminated).
    pub value_units: i64,
}

/// Run the full plane stream of one output element (`obits` MSB-first).
pub fn run_element(obits: &[i8], bits: u32, threshold_units: f64) -> ElementOutcome {
    assert_eq!(obits.len(), bits as usize);
    let mut et = EarlyTerminator::new(bits, threshold_units);
    for (i, &o) in obits.iter().enumerate() {
        match et.step(o) {
            Decision::Continue => {}
            Decision::TerminateZero => {
                return ElementOutcome {
                    cycles: (i + 1) as u32,
                    terminated: true,
                    value_units: 0,
                }
            }
            Decision::Complete => {
                let v = et.running();
                let value = if (v.unsigned_abs() as f64) <= threshold_units.abs() {
                    0
                } else {
                    v
                };
                return ElementOutcome {
                    cycles: bits,
                    terminated: false,
                    value_units: value,
                };
            }
        }
    }
    unreachable!("stream must end in Complete or TerminateZero")
}

/// Aggregate cycle statistics (Fig. 9c histogram).
#[derive(Debug, Clone, Default)]
pub struct CycleStats {
    /// histogram[c-1] = #elements finishing in exactly c cycles.
    pub histogram: Vec<u64>,
    pub total_elements: u64,
    pub terminated_early: u64,
}

impl CycleStats {
    pub fn new(bits: u32) -> Self {
        CycleStats {
            histogram: vec![0; bits as usize],
            total_elements: 0,
            terminated_early: 0,
        }
    }

    pub fn record(&mut self, outcome: &ElementOutcome) {
        self.histogram[(outcome.cycles - 1) as usize] += 1;
        self.total_elements += 1;
        if outcome.terminated {
            self.terminated_early += 1;
        }
    }

    pub fn merge(&mut self, other: &CycleStats) {
        assert_eq!(self.histogram.len(), other.histogram.len());
        for (a, b) in self.histogram.iter_mut().zip(&other.histogram) {
            *a += b;
        }
        self.total_elements += other.total_elements;
        self.terminated_early += other.terminated_early;
    }

    /// Average bitplane cycles per output element (paper: 1.34 with the
    /// Wald-regularized T distribution at 8 bits).
    pub fn average_cycles(&self) -> f64 {
        if self.total_elements == 0 {
            return 0.0;
        }
        let sum: u64 = self
            .histogram
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as u64 + 1) * c)
            .sum();
        sum as f64 / self.total_elements as f64
    }
}

/// Threshold distributions compared in Fig. 9(a)/(c).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ThresholdDist {
    /// Training without the Eq. 8 regularizer: T ~ Uniform(-Tmax, Tmax).
    Uniform,
    /// Training with the regularizer: |T| concentrates near Tmax
    /// (inverted-Gaussian / Wald shape with mode at the boundary).
    Wald,
}

/// Sample a threshold in `[-t_max, t_max]` from the given distribution.
pub fn sample_threshold(rng: &mut Rng, dist: ThresholdDist, t_max: f64) -> f64 {
    match dist {
        ThresholdDist::Uniform => rng.uniform_range(-t_max, t_max),
        ThresholdDist::Wald => {
            // |T| = Tmax * clip(1.19 - |half-normal(sigma=0.12)|, 0, 1):
            // mass piles at AND saturates on the ±Tmax boundary, matching
            // the trained Fig. 9a histogram (the regularizer pushes T past
            // the clamp, so a large fraction sits exactly at ±1 — this is
            // what makes cycle-1 termination dominate and yields the
            // paper's ~1.34 average cycles in Fig. 9c).
            let gap: f64 = rng.gaussian().abs() * 0.12;
            let mag = (1.19 - gap).clamp(0.01, 1.0) * t_max;
            if rng.coin() {
                mag
            } else {
                -mag
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_tighten_monotonically() {
        let mut et = EarlyTerminator::new(8, 0.0);
        let mut widths = Vec::new();
        for _ in 0..7 {
            let (lb, ub) = et.bounds();
            widths.push(ub - lb);
            et.step(1);
        }
        for w in widths.windows(2) {
            assert!(w[1] <= w[0], "bounds must tighten: {widths:?}");
        }
    }

    #[test]
    fn terminates_immediately_with_huge_threshold() {
        // T larger than the max possible |y|: one plane is enough.
        let out = run_element(&[1, 1, 1, 1, 1, 1, 1, 1], 8, 1000.0);
        assert!(out.terminated);
        assert_eq!(out.cycles, 1);
        assert_eq!(out.value_units, 0);
    }

    #[test]
    fn never_terminates_with_zero_threshold_unless_certain() {
        // T = 0: termination needs UB <= 0 <= LB, i.e. bounds collapse on 0,
        // impossible while planes remain, so all 8 cycles are used.
        let out = run_element(&[1, -1, 1, -1, 1, -1, 1, -1], 8, 0.0);
        assert!(!out.terminated);
        assert_eq!(out.cycles, 8);
    }

    #[test]
    fn full_run_value_matches_recombination() {
        let obits = [1i8, -1, 0, 1, 1, -1, 0, 1];
        let out = run_element(&obits, 8, 0.0);
        let want: i64 = obits
            .iter()
            .enumerate()
            .map(|(p, &o)| o as i64 * (1i64 << (7 - p)))
            .sum();
        assert_eq!(out.value_units, want);
    }

    #[test]
    fn termination_is_sound() {
        // Whenever ET fires, the full recombined value must satisfy |y|<=T.
        let mut rng = Rng::seed_from_u64(99);
        for _ in 0..2000 {
            let bits = 8u32;
            let obits: Vec<i8> = (0..bits).map(|_| rng.ternary()).collect();
            let t_units = rng.uniform_range(0.0, 300.0);
            let out = run_element(&obits, bits, t_units);
            let full: i64 = obits
                .iter()
                .enumerate()
                .map(|(p, &o)| o as i64 * (1i64 << (bits as usize - 1 - p)))
                .sum();
            if out.terminated {
                assert!(
                    (full.unsigned_abs() as f64) <= t_units,
                    "unsound termination: |{full}| > {t_units} after {} cycles",
                    out.cycles
                );
            } else {
                // value must be exact (post-threshold)
                let want = if (full.unsigned_abs() as f64) <= t_units { 0 } else { full };
                assert_eq!(out.value_units, want);
            }
        }
    }

    #[test]
    fn wald_thresholds_terminate_faster_than_uniform() {
        // Realistic comparator streams (Fig. 9c setting): random 8-bit
        // inputs against a random ±1 row, obits = sign of the per-plane
        // PSUM — not i.i.d. ternary noise (real streams are sign-coherent
        // across planes, which is what early termination exploits).
        let mut rng = Rng::seed_from_u64(42);
        let bits = 8u32;
        let n = 16usize;
        let avg = |dist: ThresholdDist, rng: &mut Rng| {
            let mut stats = CycleStats::new(bits);
            for _ in 0..3000 {
                let x: Vec<f32> = (0..n)
                    .map(|_| rng.uniform_range(-1.0, 1.0) as f32)
                    .collect();
                let row: Vec<i8> = (0..n).map(|_| if rng.coin() { 1 } else { -1 }).collect();
                let q = crate::quant::Quantizer::new(bits).quantize(&x);
                let mut plane = vec![0i8; n];
                let mut planes = q.planes_msb_first();
                let mut obits: Vec<i8> = Vec::with_capacity(bits as usize);
                while planes.next_into(&mut plane).is_some() {
                    let psum: i64 = plane
                        .iter()
                        .zip(&row)
                        .map(|(&p, &w)| p as i64 * w as i64)
                        .sum();
                    obits.push(crate::bitplane::comparator(psum));
                }
                // PSUM units: T scaled to the recombination range (max 255).
                let t = sample_threshold(rng, dist, 1.0) * 255.0;
                stats.record(&run_element(&obits, bits, t.abs()));
            }
            stats.average_cycles()
        };
        let wald = avg(ThresholdDist::Wald, &mut rng);
        let uniform = avg(ThresholdDist::Uniform, &mut rng);
        assert!(
            wald < uniform,
            "Wald T must terminate earlier: wald={wald:.2} uniform={uniform:.2}"
        );
        assert!(wald < 2.0, "paper reports avg < 2 cycles, got {wald:.2}");
    }

    #[test]
    fn cycle_stats_bookkeeping() {
        let mut s = CycleStats::new(4);
        s.record(&ElementOutcome { cycles: 1, terminated: true, value_units: 0 });
        s.record(&ElementOutcome { cycles: 4, terminated: false, value_units: 7 });
        assert_eq!(s.total_elements, 2);
        assert_eq!(s.terminated_early, 1);
        assert!((s.average_cycles() - 2.5).abs() < 1e-9);
        let mut s2 = CycleStats::new(4);
        s2.merge(&s);
        assert_eq!(s2.total_elements, 2);
    }

    #[test]
    fn remaining_mass_formula() {
        let et = EarlyTerminator::new(8, 0.0);
        // before any plane: remaining after processing the MSB would be 127,
        // but bounds() is called pre-step: all 8 planes remain => 255.
        let (lb, ub) = et.bounds();
        assert_eq!(ub, 255);
        assert_eq!(lb, -255);
    }
}

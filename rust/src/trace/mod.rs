//! End-to-end request tracing and per-stage latency attribution.
//!
//! The serving stack measures energy and latency in *per-stage*
//! phenomena — plane counts, early-termination depth, queue wait — but a
//! single end-to-end histogram cannot say whether a slow p99 was batch
//! wait, shard scatter, tile execution, or drain.  This module threads a
//! lightweight trace through the whole request path:
//!
//! ```text
//! admission → queue → plan → scatter → pool_queue → execute → drain → respond
//! ```
//!
//! Design constraints (std-only, allocation-light):
//!
//! - A request is sampled **once**, at admission ([`Tracer::begin`]).
//!   The resulting [`TraceHandle`] is an `Option<Arc<..>>`; a
//!   sampled-out (or feature-disabled) request carries `None` and every
//!   downstream stage pays exactly one branch ([`TraceHandle::is_active`])
//!   — no clock reads, no locks, no allocation.
//! - Active handles append [`Span`]s to a small per-request buffer;
//!   [`Tracer::finish`] folds the spans into per-stage
//!   [`LatencyHistogram`]s (exported as `repro_stage_seconds{stage=…}`),
//!   accumulates execute-payload counters (planes, ET depth), emits a
//!   structured slow-request log line when configured, and pushes the
//!   trace into a bounded ring of recent traces served by
//!   `GET /debug/traces` — as plain JSON or Chrome `trace_event` format
//!   (loadable in `chrome://tracing` / Perfetto).
//! - Timestamps are microseconds on a process-wide monotonic epoch
//!   ([`now_us`]), so spans recorded on different threads (handler,
//!   batcher) line up on one timeline.
//! - Building with `--features trace-off` compiles sampling away:
//!   [`Tracer::begin`] unconditionally returns the inactive handle and
//!   the branch-per-stage fast path is all that remains.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::coordinator::LatencyHistogram;
use crate::util::json::Json;

/// Process-wide monotonic epoch.  Initialised on first use (the server
/// constructs its [`Tracer`] before accepting connections, so every
/// request timestamp lands after the epoch).
static EPOCH: OnceLock<Instant> = OnceLock::new();

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process trace epoch.
pub fn now_us() -> u64 {
    instant_us(Instant::now())
}

/// Convert an [`Instant`] (e.g. a request's enqueue time) to
/// microseconds on the trace epoch.  Instants predating the epoch clamp
/// to zero rather than panicking.
pub fn instant_us(t: Instant) -> u64 {
    t.checked_duration_since(epoch())
        .map(|d| d.as_micros().min(u128::from(u64::MAX)) as u64)
        .unwrap_or(0)
}

/// The pipeline stages a request passes through.  `as_str` values are
/// the `stage` label of `repro_stage_seconds` and the span names in the
/// Chrome export.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Handler entry to admission-permit acquired.
    Admission = 0,
    /// Waiting in the batcher's coalescing queue.
    Queue = 1,
    /// Per-request cost estimation + LPT block planning in the router.
    Plan = 2,
    /// Submitting one slice to a shard's job queue.
    Scatter = 3,
    /// A slice waiting in a coordinator pool before workers pick it up.
    PoolQueue = 4,
    /// `schedule_batch` on the worker (carries plane/ET payloads).
    Execute = 5,
    /// Draining a completed slice back to the batcher and gathering.
    Drain = 6,
    /// Serialising and writing the HTTP response.
    Respond = 7,
}

impl Stage {
    /// Every stage, in pipeline order.
    pub const ALL: [Stage; 8] = [
        Stage::Admission,
        Stage::Queue,
        Stage::Plan,
        Stage::Scatter,
        Stage::PoolQueue,
        Stage::Execute,
        Stage::Drain,
        Stage::Respond,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::Queue => "queue",
            Stage::Plan => "plan",
            Stage::Scatter => "scatter",
            Stage::PoolQueue => "pool_queue",
            Stage::Execute => "execute",
            Stage::Drain => "drain",
            Stage::Respond => "respond",
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

/// Execution payload attached to [`Stage::Execute`] spans: the analog
/// engine's energy-proxy counters for one completed slice.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExecStats {
    /// MSB-first bitplanes actually issued.
    pub planes: u32,
    /// Row activation cycles executed (the dominant energy proxy).
    pub row_cycles: u64,
    /// Output elements produced.
    pub elements: u64,
    /// Elements resolved before their final bitplane (ET depth signal).
    pub terminated_early: u64,
}

impl ExecStats {
    /// Mean bitplane cycles per element — the effective ET depth.
    pub fn avg_cycles(&self) -> f64 {
        if self.elements == 0 {
            0.0
        } else {
            self.row_cycles as f64 / self.elements as f64
        }
    }

    /// Elements still live at the final plane.
    pub fn live_rows(&self) -> u64 {
        self.elements - self.terminated_early.min(self.elements)
    }
}

/// One recorded stage interval on the process timeline.
#[derive(Debug, Clone)]
pub struct Span {
    pub stage: Stage,
    pub start_us: u64,
    pub dur_us: u64,
    /// Shard that executed this span, for scatter/pool/execute/drain.
    pub shard: Option<usize>,
    /// Engine counters, present on execute spans.
    pub exec: Option<ExecStats>,
}

/// A finished request trace, as stored in the recent-trace ring.
#[derive(Debug, Clone)]
pub struct Trace {
    pub id: u64,
    pub endpoint: &'static str,
    pub begin_us: u64,
    pub end_us: u64,
    pub spans: Vec<Span>,
}

impl Trace {
    pub fn total_us(&self) -> u64 {
        self.end_us.saturating_sub(self.begin_us)
    }
}

/// Span buffer shared between the handler thread (admission/respond),
/// the batcher (queue) and the router completion path (plan..drain).
#[derive(Debug)]
struct TraceShared {
    id: u64,
    endpoint: &'static str,
    spans: Mutex<Vec<Span>>,
}

/// Per-request tracing handle.  Cloning is cheap (an `Arc` bump for
/// sampled requests, a copy of `None` otherwise); a sampled-out request
/// pays one branch per stage and nothing else.
#[derive(Debug, Clone, Default)]
pub struct TraceHandle(Option<Arc<TraceShared>>);

impl TraceHandle {
    /// The handle carried by sampled-out requests: every recording
    /// method is a single-branch no-op.
    pub fn inactive() -> TraceHandle {
        TraceHandle(None)
    }

    /// Whether this request is being traced — the one branch a
    /// sampled-out request pays per stage.
    #[inline]
    pub fn is_active(&self) -> bool {
        self.0.is_some()
    }

    /// Trace ID, if active.
    pub fn id(&self) -> Option<u64> {
        self.0.as_ref().map(|s| s.id)
    }

    /// Record a plain stage span.
    pub fn record(&self, stage: Stage, start_us: u64, dur_us: u64) {
        self.push(Span { stage, start_us, dur_us, shard: None, exec: None });
    }

    /// Record a stage span attributed to one shard.
    pub fn record_shard(&self, stage: Stage, start_us: u64, dur_us: u64, shard: usize) {
        self.push(Span { stage, start_us, dur_us, shard: Some(shard), exec: None });
    }

    /// Record an execute span with its engine payload.
    pub fn record_exec(&self, start_us: u64, dur_us: u64, shard: usize, exec: ExecStats) {
        self.push(Span {
            stage: Stage::Execute,
            start_us,
            dur_us,
            shard: Some(shard),
            exec: Some(exec),
        });
    }

    fn push(&self, span: Span) {
        if let Some(shared) = &self.0 {
            shared
                .spans
                .lock()
                .expect("trace span buffer poisoned")
                .push(span);
        }
    }
}

/// Tracer configuration, plumbed from `repro serve` flags.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Trace one request in every `sample_every` (1 = all, 0 = none).
    pub sample_every: u32,
    /// Emit a structured JSON log line to stderr for sampled requests
    /// slower than this (0 disables slow-request logging).
    pub slow_us: u64,
    /// Recent-trace ring capacity.
    pub capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> TraceConfig {
        TraceConfig { sample_every: 1, slow_us: 0, capacity: 256 }
    }
}

/// Process-wide trace collector: samples requests, stores recent
/// finished traces in a bounded ring, and aggregates per-stage
/// histograms plus execute-payload counters for `/metrics`.
#[derive(Debug)]
pub struct Tracer {
    config: TraceConfig,
    counter: AtomicU64,
    store: Mutex<VecDeque<Trace>>,
    stage_hist: Mutex<Vec<LatencyHistogram>>,
    sampled_total: AtomicU64,
    slow_total: AtomicU64,
    planes_total: AtomicU64,
    elements_total: AtomicU64,
    terminated_total: AtomicU64,
}

impl Tracer {
    pub fn new(config: TraceConfig) -> Tracer {
        // Pin the epoch now so request Instants (taken later) never
        // predate it.
        let _ = epoch();
        Tracer {
            config,
            counter: AtomicU64::new(0),
            store: Mutex::new(VecDeque::new()),
            stage_hist: Mutex::new((0..Stage::ALL.len()).map(|_| LatencyHistogram::new()).collect()),
            sampled_total: AtomicU64::new(0),
            slow_total: AtomicU64::new(0),
            planes_total: AtomicU64::new(0),
            elements_total: AtomicU64::new(0),
            terminated_total: AtomicU64::new(0),
        }
    }

    /// A tracer that samples nothing (used by paths that need a tracer
    /// but want it inert).
    pub fn disabled() -> Tracer {
        Tracer::new(TraceConfig { sample_every: 0, ..TraceConfig::default() })
    }

    /// Sampling period (0 = disabled).
    pub fn sample_every(&self) -> u32 {
        self.config.sample_every
    }

    /// Admit a request into tracing.  Returns the inactive handle for
    /// sampled-out requests — and for *every* request when compiled
    /// with `--features trace-off`, which reduces tracing to the
    /// branch-per-stage fast path.
    pub fn begin(&self, endpoint: &'static str) -> TraceHandle {
        if cfg!(feature = "trace-off") || self.config.sample_every == 0 {
            return TraceHandle::inactive();
        }
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        if n % u64::from(self.config.sample_every) != 0 {
            return TraceHandle::inactive();
        }
        self.sampled_total.fetch_add(1, Ordering::Relaxed);
        TraceHandle(Some(Arc::new(TraceShared {
            id: n,
            endpoint,
            spans: Mutex::new(Vec::with_capacity(Stage::ALL.len() * 2)),
        })))
    }

    /// Finish a trace: fold its spans into the per-stage histograms and
    /// counters, log it if slow, and retain it in the recent ring.
    /// No-op for inactive handles.
    pub fn finish(&self, handle: TraceHandle) {
        let Some(shared) = handle.0 else { return };
        let spans = std::mem::take(
            &mut *shared.spans.lock().expect("trace span buffer poisoned"),
        );
        let begin_us = spans.iter().map(|s| s.start_us).min().unwrap_or(0);
        let end_us = spans
            .iter()
            .map(|s| s.start_us + s.dur_us)
            .max()
            .unwrap_or(begin_us);
        {
            let mut hists = self.stage_hist.lock().expect("stage histograms poisoned");
            for span in &spans {
                hists[span.stage.index()].record(Duration::from_micros(span.dur_us));
                if let Some(exec) = &span.exec {
                    self.planes_total
                        .fetch_add(u64::from(exec.planes), Ordering::Relaxed);
                    self.elements_total.fetch_add(exec.elements, Ordering::Relaxed);
                    self.terminated_total
                        .fetch_add(exec.terminated_early, Ordering::Relaxed);
                }
            }
        }
        let trace = Trace { id: shared.id, endpoint: shared.endpoint, begin_us, end_us, spans };
        if self.config.slow_us > 0 && trace.total_us() >= self.config.slow_us {
            self.slow_total.fetch_add(1, Ordering::Relaxed);
            eprintln!("{}", slow_log_line(&trace, self.config.slow_us));
        }
        let mut store = self.store.lock().expect("trace store poisoned");
        if store.len() >= self.config.capacity.max(1) {
            store.pop_front();
        }
        store.push_back(trace);
    }

    /// Up to `n` most recent finished traces, newest first.
    pub fn recent(&self, n: usize) -> Vec<Trace> {
        let store = self.store.lock().expect("trace store poisoned");
        store.iter().rev().take(n).cloned().collect()
    }

    /// Per-stage latency histograms, `(stage label, histogram)`.
    pub fn stage_histograms(&self) -> Vec<(&'static str, LatencyHistogram)> {
        let hists = self.stage_hist.lock().expect("stage histograms poisoned");
        Stage::ALL
            .iter()
            .map(|s| (s.as_str(), hists[s.index()].clone()))
            .collect()
    }

    pub fn sampled_total(&self) -> u64 {
        self.sampled_total.load(Ordering::Relaxed)
    }

    pub fn slow_total(&self) -> u64 {
        self.slow_total.load(Ordering::Relaxed)
    }

    pub fn planes_total(&self) -> u64 {
        self.planes_total.load(Ordering::Relaxed)
    }

    pub fn elements_total(&self) -> u64 {
        self.elements_total.load(Ordering::Relaxed)
    }

    pub fn terminated_total(&self) -> u64 {
        self.terminated_total.load(Ordering::Relaxed)
    }
}

fn span_json(span: &Span) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("stage".to_string(), Json::Str(span.stage.as_str().to_string()));
    obj.insert("start_us".to_string(), Json::Num(span.start_us as f64));
    obj.insert("dur_us".to_string(), Json::Num(span.dur_us as f64));
    if let Some(shard) = span.shard {
        obj.insert("shard".to_string(), Json::Num(shard as f64));
    }
    if let Some(exec) = &span.exec {
        obj.insert("planes".to_string(), Json::Num(f64::from(exec.planes)));
        obj.insert("row_cycles".to_string(), Json::Num(exec.row_cycles as f64));
        obj.insert("elements".to_string(), Json::Num(exec.elements as f64));
        obj.insert(
            "terminated_early".to_string(),
            Json::Num(exec.terminated_early as f64),
        );
        obj.insert("avg_cycles".to_string(), Json::Num(exec.avg_cycles()));
        obj.insert("live_rows".to_string(), Json::Num(exec.live_rows() as f64));
    }
    Json::Obj(obj)
}

/// Plain-JSON view of recent traces (`GET /debug/traces`).
pub fn traces_json(traces: &[Trace]) -> Json {
    let arr = traces
        .iter()
        .map(|t| {
            let mut obj = BTreeMap::new();
            obj.insert("id".to_string(), Json::Num(t.id as f64));
            obj.insert("endpoint".to_string(), Json::Str(t.endpoint.to_string()));
            obj.insert("begin_us".to_string(), Json::Num(t.begin_us as f64));
            obj.insert("end_us".to_string(), Json::Num(t.end_us as f64));
            obj.insert("total_us".to_string(), Json::Num(t.total_us() as f64));
            obj.insert("spans".to_string(), Json::Arr(t.spans.iter().map(span_json).collect()));
            Json::Obj(obj)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("traces".to_string(), Json::Arr(arr));
    Json::Obj(root)
}

/// Chrome `trace_event` view (`GET /debug/traces?format=chrome`),
/// loadable in `chrome://tracing` or Perfetto: one complete (`ph:"X"`)
/// event per span, one track (`tid`) per trace.
pub fn traces_chrome(traces: &[Trace]) -> Json {
    let mut events = Vec::new();
    for t in traces {
        for span in &t.spans {
            let mut args = BTreeMap::new();
            args.insert("trace_id".to_string(), Json::Num(t.id as f64));
            args.insert("endpoint".to_string(), Json::Str(t.endpoint.to_string()));
            if let Some(shard) = span.shard {
                args.insert("shard".to_string(), Json::Num(shard as f64));
            }
            if let Some(exec) = &span.exec {
                args.insert("planes".to_string(), Json::Num(f64::from(exec.planes)));
                args.insert("row_cycles".to_string(), Json::Num(exec.row_cycles as f64));
                args.insert("elements".to_string(), Json::Num(exec.elements as f64));
                args.insert(
                    "terminated_early".to_string(),
                    Json::Num(exec.terminated_early as f64),
                );
                args.insert("avg_cycles".to_string(), Json::Num(exec.avg_cycles()));
            }
            let mut ev = BTreeMap::new();
            ev.insert("name".to_string(), Json::Str(span.stage.as_str().to_string()));
            ev.insert("cat".to_string(), Json::Str("repro".to_string()));
            ev.insert("ph".to_string(), Json::Str("X".to_string()));
            ev.insert("ts".to_string(), Json::Num(span.start_us as f64));
            ev.insert("dur".to_string(), Json::Num(span.dur_us as f64));
            ev.insert("pid".to_string(), Json::Num(1.0));
            ev.insert("tid".to_string(), Json::Num(t.id as f64));
            ev.insert("args".to_string(), Json::Obj(args));
            events.push(Json::Obj(ev));
        }
    }
    let mut root = BTreeMap::new();
    root.insert("traceEvents".to_string(), Json::Arr(events));
    root.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    Json::Obj(root)
}

/// Structured slow-request log line: total latency plus a per-stage
/// duration breakdown (summed across a stage's spans).
pub fn slow_log_line(trace: &Trace, threshold_us: u64) -> Json {
    let mut per_stage = [0u64; Stage::ALL.len()];
    for span in &trace.spans {
        per_stage[span.stage.index()] += span.dur_us;
    }
    let mut stages = BTreeMap::new();
    for stage in Stage::ALL {
        let us = per_stage[stage.index()];
        if us > 0 {
            stages.insert(stage.as_str().to_string(), Json::Num(us as f64));
        }
    }
    let mut obj = BTreeMap::new();
    obj.insert("event".to_string(), Json::Str("slow_request".to_string()));
    obj.insert("trace_id".to_string(), Json::Num(trace.id as f64));
    obj.insert("endpoint".to_string(), Json::Str(trace.endpoint.to_string()));
    obj.insert("total_us".to_string(), Json::Num(trace.total_us() as f64));
    obj.insert("threshold_us".to_string(), Json::Num(threshold_us as f64));
    obj.insert("stages".to_string(), Json::Obj(stages));
    Json::Obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn finished(tracer: &Tracer, endpoint: &'static str) -> bool {
        let h = tracer.begin(endpoint);
        let active = h.is_active();
        if active {
            let t = now_us();
            h.record(Stage::Admission, t, 5);
            h.record(Stage::Queue, t + 5, 10);
            h.record_exec(
                t + 15,
                40,
                0,
                ExecStats { planes: 8, row_cycles: 128, elements: 16, terminated_early: 4 },
            );
            h.record(Stage::Respond, t + 55, 2);
        }
        tracer.finish(h);
        active
    }

    #[cfg(not(feature = "trace-off"))]
    #[test]
    fn sampling_keeps_one_in_every_n() {
        let tracer = Tracer::new(TraceConfig { sample_every: 3, ..TraceConfig::default() });
        let sampled = (0..9).filter(|_| finished(&tracer, "/t")).count();
        assert_eq!(sampled, 3);
        assert_eq!(tracer.sampled_total(), 3);
        // sample_every == 0 disables tracing entirely.
        let off = Tracer::disabled();
        assert!(!off.begin("/t").is_active());
    }

    #[cfg(feature = "trace-off")]
    #[test]
    fn trace_off_feature_disables_sampling() {
        let tracer = Tracer::new(TraceConfig::default());
        assert!(!tracer.begin("/t").is_active());
        assert_eq!(tracer.sampled_total(), 0);
    }

    #[test]
    fn inactive_handle_records_nothing() {
        let h = TraceHandle::inactive();
        assert!(!h.is_active());
        assert_eq!(h.id(), None);
        h.record(Stage::Plan, 0, 1);
        h.record_exec(0, 1, 0, ExecStats::default());
        Tracer::disabled().finish(h); // no-op, no panic
    }

    #[cfg(not(feature = "trace-off"))]
    #[test]
    fn ring_is_bounded_and_newest_first() {
        let tracer =
            Tracer::new(TraceConfig { sample_every: 1, slow_us: 0, capacity: 4 });
        for _ in 0..10 {
            finished(&tracer, "/t");
        }
        let recent = tracer.recent(16);
        assert_eq!(recent.len(), 4, "ring evicts beyond capacity");
        for w in recent.windows(2) {
            assert!(w[0].id > w[1].id, "newest first");
        }
        assert_eq!(tracer.recent(2).len(), 2);
    }

    #[cfg(not(feature = "trace-off"))]
    #[test]
    fn finish_folds_histograms_and_exec_counters() {
        let tracer = Tracer::new(TraceConfig::default());
        finished(&tracer, "/v1/infer");
        let hists = tracer.stage_histograms();
        assert_eq!(hists.len(), Stage::ALL.len());
        let by_name: BTreeMap<&str, u64> =
            hists.iter().map(|(n, h)| (*n, h.count())).collect();
        assert_eq!(by_name["admission"], 1);
        assert_eq!(by_name["queue"], 1);
        assert_eq!(by_name["execute"], 1);
        assert_eq!(by_name["plan"], 0, "unrecorded stages stay empty");
        assert_eq!(tracer.planes_total(), 8);
        assert_eq!(tracer.elements_total(), 16);
        assert_eq!(tracer.terminated_total(), 4);
        let t = &tracer.recent(1)[0];
        assert_eq!(t.endpoint, "/v1/infer");
        assert_eq!(t.total_us(), 57, "begin/end derived from span extents");
    }

    #[cfg(not(feature = "trace-off"))]
    #[test]
    fn chrome_export_is_valid_trace_event_json() {
        let tracer = Tracer::new(TraceConfig::default());
        finished(&tracer, "/v1/infer");
        let text = traces_chrome(&tracer.recent(8)).to_string();
        let parsed = parse(&text).expect("chrome export must be valid JSON");
        let events = parsed
            .get("traceEvents")
            .and_then(|v| v.as_arr())
            .expect("traceEvents array");
        assert_eq!(events.len(), 4);
        for ev in events {
            assert_eq!(ev.get("ph").and_then(|v| v.as_str()), Some("X"));
            assert!(ev.get("ts").and_then(|v| v.as_f64()).is_some());
            assert!(ev.get("dur").and_then(|v| v.as_f64()).is_some());
            assert!(ev.get("name").and_then(|v| v.as_str()).is_some());
        }
        let exec = events
            .iter()
            .find(|e| e.get("name").and_then(|v| v.as_str()) == Some("execute"))
            .expect("execute event present");
        assert_eq!(exec.path(&["args", "planes"]).and_then(|v| v.as_f64()), Some(8.0));
        assert_eq!(
            exec.path(&["args", "avg_cycles"]).and_then(|v| v.as_f64()),
            Some(8.0)
        );
    }

    #[cfg(not(feature = "trace-off"))]
    #[test]
    fn slow_log_line_breaks_latency_down_by_stage() {
        let tracer = Tracer::new(TraceConfig::default());
        finished(&tracer, "/v1/transform");
        let t = &tracer.recent(1)[0];
        let line = slow_log_line(t, 50).to_string();
        let parsed = parse(&line).unwrap();
        assert_eq!(parsed.get("event").and_then(|v| v.as_str()), Some("slow_request"));
        assert_eq!(parsed.get("total_us").and_then(|v| v.as_f64()), Some(57.0));
        assert_eq!(
            parsed.path(&["stages", "execute"]).and_then(|v| v.as_f64()),
            Some(40.0)
        );
        assert_eq!(
            parsed.path(&["stages", "queue"]).and_then(|v| v.as_f64()),
            Some(10.0)
        );
        assert!(parsed.path(&["stages", "plan"]).is_none(), "empty stages omitted");
    }

    #[test]
    fn exec_stats_derive_depth_signals() {
        let s = ExecStats { planes: 8, row_cycles: 96, elements: 16, terminated_early: 10 };
        assert_eq!(s.avg_cycles(), 6.0);
        assert_eq!(s.live_rows(), 6);
        assert_eq!(ExecStats::default().avg_cycles(), 0.0);
    }

    #[test]
    fn instants_before_the_epoch_clamp_to_zero() {
        let t = Instant::now();
        let _ = epoch();
        assert!(instant_us(t) == 0 || instant_us(t) < 5);
        let (a, b) = (now_us(), now_us());
        assert!(a <= b, "trace clock is monotonic");
    }
}

//! # repro — ADC/DAC-free analog acceleration of frequency-domain DNNs
//!
//! Rust + JAX + Pallas reproduction of Darabi et al., *"ADC/DAC-Free Analog
//! Acceleration of Deep Neural Networks with Frequency Transformation"*
//! (2023).  See `DESIGN.md` for the system inventory and the mapping of
//! every paper table/figure to a module and bench target.
//!
//! Layer map:
//! * **L4 ([`server`])** — the network serving subsystem: a std-only
//!   HTTP/1.1 front-end with dynamic micro-batching, admission control
//!   (bounded in-flight + per-client token buckets) and a Prometheus
//!   `/metrics` endpoint, turning the coordinator into a long-running
//!   inference service (`repro serve --listen ADDR`).
//! * **Observability seam ([`trace`])** — sampled end-to-end request
//!   tracing threaded through the serving path (admission → queue →
//!   plan → scatter → pool queue → execute → drain → respond), feeding
//!   per-stage latency histograms in `/metrics`, recent traces at
//!   `GET /debug/traces` (plain JSON or Chrome `trace_event`) and
//!   slow-request structured logs.
//! * **Fault-tolerance seam ([`chaos`], [`shard::breaker`])** —
//!   deterministic fault injection (seeded, named injection points at
//!   every seam, compiled out unless the `chaos` feature is on),
//!   end-to-end request deadlines, and per-shard circuit breakers
//!   with exponential open windows + respawn backoff, so the serving
//!   vertical degrades and recovers instead of hanging or storming.
//! * **Fidelity seam ([`monitor`])** — sampled shadow verification of
//!   noisy/analog shards: 1-in-K served slices re-execute through a
//!   private digital golden pool with the same pinned quantization
//!   scales, divergence is tracked per shard slot as an EWMA in
//!   quantizer LSBs, and a drifting slot degrades `/readyz` and is
//!   respawned by the batcher health tick.  Exposed as the
//!   `repro_fidelity_*` metric family and `GET /debug/fidelity`.
//! * **Execution seam ([`exec`])** — the [`exec::TransformExecutor`]
//!   trait unifying every way a BWHT transform can run (in-process
//!   float/quantized/noisy loops, one coordinator pool, a shard set);
//!   [`nn`] layers delegate all transforms through it, so the same model
//!   runs on software loops or the full tile-scheduling machinery —
//!   bit-identically on the digital path.
//! * **L3.5 ([`shard`])** — scatter–gather sharding: a placement planner
//!   and router that partition one wide transform across N independent
//!   coordinator pools (balanced by estimated row-cycles, with poisoned
//!   shards shedding load to siblings) and merge their metrics into one
//!   logical-accelerator snapshot.
//! * **L3 (this crate)** — the coordinator: crossbar tile pool, bitplane
//!   scheduling with predictive early termination, request batching, plus
//!   every substrate the paper depends on (Walsh transforms, sign-magnitude
//!   quantization, the analog crossbar behavioral simulator standing in for
//!   the paper's HSPICE/PTM testbed, and the energy model).
//! * **L2/L1 (python/, build-time only)** — the JAX model and Pallas
//!   kernels, AOT-lowered to `artifacts/*.hlo.txt` and loaded at runtime by
//!   [`runtime`] through the PJRT C API.  Python never runs on the request
//!   path.  The PJRT loader needs the XLA toolchain, so it is gated behind
//!   the non-default `pjrt` cargo feature; the default build is fully
//!   offline.

pub mod analog;
pub mod bitplane;
pub mod chaos;
pub mod coordinator;
pub mod energy;
pub mod exec;
pub mod monitor;
pub mod nn;
pub mod npy;
pub mod quant;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod server;
pub mod shard;
pub mod trace;
pub mod util;
pub mod wht;

//! L4 network serving subsystem: an std-only HTTP/1.1 front-end that
//! turns a [`crate::shard::ShardSet`] of coordinator pools into a
//! long-running inference service (`repro serve --listen ADDR`).
//!
//! ```text
//!   clients ──▶ epoll reactors (N threads, EPOLLEXCLUSIVE accept)
//!                  │  per-connection state machine, zero-copy parsing
//!                  │  admission control: in-flight cap + token buckets
//!                  ▼
//!              dynamic micro-batcher (max_batch / max_wait coalescing)
//!                  │  one scatter–gather dispatch per coalesced batch
//!                  │  completions ──▶ eventfd waker ──▶ reactor resumes
//!                  ▼
//!              ShardSet (N coordinator pools) ──▶ per-request replies
//! ```
//!
//! The front end is **event-driven**: a few reactor threads
//! (`event_loop`) multiplex every connection over nonblocking sockets
//! with a hand-rolled epoll binding ([`reactor`]; the build box is
//! offline, so no tokio/mio).  Each connection is a bounded state
//! machine (`ReadHead → ReadBody → Dispatched → Write → KeepAlive/
//! Close`) over reusable read/write buffers; request heads parse
//! zero-copy as byte spans ([`http::Head`]) and bodies are framed by
//! `Content-Length` in place.  Dispatched requests park the connection
//! — no thread blocks — and the batcher's reply re-enters the loop
//! through an eventfd-backed completion queue.  Idle, slowloris, write
//! and in-flight deadlines all come from one coarse timer wheel.
//!
//! Endpoints:
//! * `POST /v1/transform` — `{"x": [...], "thresholds": [...]}` →
//!   `{"y": [...], "padded_dim": N, "latency_us": L}`;
//! * `POST /v1/infer` — `{"x": [...]}` (one sample) or
//!   `{"x": [[...], ...]}` (a batch) → logits from the model loaded at
//!   startup (`repro serve --weights mlp.json`), with the BWHT layer's
//!   transforms executed on the shard set through the
//!   [`crate::exec::Sharded`] executor — digital-backend logits are
//!   bit-identical to `Mlp::forward` with `Backend::Quantized`;
//! * `GET /metrics` — Prometheus text format (cycle/energy accounting,
//!   admission counters, `repro_infer_*` series, p50/p95/p99 latency,
//!   per-stage `repro_stage_seconds{stage=...}` attribution, connection
//!   gauges and build info);
//! * `GET /healthz` — liveness probe;
//! * `GET /readyz` — shard-health-aware readiness: 503 with a per-shard
//!   JSON body while any shard slot is poisoned/respawning;
//! * `GET /debug/traces?n=K[&format=chrome]` — recent sampled request
//!   traces as plain JSON or Chrome `trace_event` format (see
//!   [`crate::trace`]);
//! * `GET /debug/fidelity?n=K` — live fidelity-monitor snapshot:
//!   per-shard drift EWMAs plus the `K` most recent shadow-check
//!   divergence records (see [`crate::monitor`]).
//!
//! The batcher thread doubles as the shard-health loop: on a periodic
//! tick (and before each batch) it respawns poisoned shards
//! ([`crate::shard::ShardSet::respawn`]) so a dead pool heals instead of
//! permanently shrinking capacity.
//!
//! Everything is `std`-only (the build box is offline): hand-rolled HTTP
//! in [`http`], the epoll/eventfd/timer-wheel bindings in [`reactor`],
//! the connection state machine in `event_loop`, batching in
//! [`batcher`], shedding in [`admission`] and the exposition format in
//! [`metrics_export`].

pub mod admission;
pub mod batcher;
mod event_loop;
pub mod http;
pub mod metrics_export;
pub mod reactor;

use std::collections::BTreeMap;
use std::net::{IpAddr, SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use anyhow::{Context, Result};

use crate::analog::crossbar::CrossbarConfig;
use crate::coordinator::{
    required_tile, CoordinatorConfig, LatencyHistogram, Metrics, TileKind, TransformRequest,
};
use crate::energy::EnergyModel;
use crate::monitor::{Monitor, MonitorConfig};
use crate::nn::Mlp;
use crate::shard::{BreakerSet, MetricsAggregator, ShardSet, ShardSetConfig};
use crate::trace::{self, Stage, TraceConfig, TraceHandle, Tracer};
use crate::util::json::{self, Json};

use admission::{Admission, InflightPermit};
pub use admission::{AdmissionConfig, Rejection};
use batcher::{BatchItem, BatchPayload, ReplyResult};
pub use batcher::BatchReply;
use reactor::{Completions, Waker};

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address, e.g. `127.0.0.1:8080` (`:0` for an ephemeral port).
    pub listen: String,
    /// Per-shard tile pool configuration (`kind` selects the
    /// digital/noisy/analog backend; per-shard and per-worker
    /// variability seeds are derived from `seed`).
    pub coordinator: CoordinatorConfig,
    /// Independent coordinator pools to scatter–gather across.
    pub shards: usize,
    /// Admission-control policy.
    pub admission: AdmissionConfig,
    /// Micro-batching: dispatch when this many requests are pending...
    pub max_batch: usize,
    /// ...or when the oldest has waited this long (µs).
    pub max_wait_us: u64,
    /// Largest accepted input width.
    pub max_dim: usize,
    /// Concurrent-connection cap (excess gets a best-effort 503).  The
    /// event loop multiplexes connections over a few reactor threads,
    /// so each one costs two buffers, not an OS thread.
    pub max_connections: usize,
    /// Reactor (event loop) threads sharing the listener via
    /// `EPOLLEXCLUSIVE`.  The front end is epoll-multiplexed, so a
    /// couple of threads drive tens of thousands of connections; the
    /// batcher and pool workers do the heavy lifting.
    pub reactor_threads: usize,
    /// Supply voltage for the `/metrics` energy model.
    pub vdd: f64,
    /// How long a connection waits for its batch reply; older work is
    /// dropped by the batcher instead of executed.
    pub request_timeout: Duration,
    /// Requests served per keep-alive connection before the server
    /// closes it (bounds per-connection state residency).
    pub keepalive_max_requests: usize,
    /// How long an idle keep-alive connection is held open waiting for
    /// its next request.
    pub keepalive_idle: Duration,
    /// How long a fresh connection may take to deliver its first
    /// request (slowloris guard; also bounds half-sent heads).
    pub first_byte_timeout: Duration,
    /// Model served by `POST /v1/infer` (loaded from `--weights` by the
    /// CLI).  When set, the shard set's tile width is raised (if needed)
    /// to the model's widest BWHT block; narrower blocks of a mixed
    /// partition run under sub-tile masking, so *any* hidden width
    /// serves with digital inference bit-identical to
    /// `Backend::Quantized`.  `None` disables the endpoint.
    pub model: Option<Mlp>,
    /// Largest sample count accepted in one `/v1/infer` request.
    pub max_infer_batch: usize,
    /// Respawn poisoned shards from the batcher's health tick.
    pub auto_respawn: bool,
    /// Health-tick period: how often an idle batcher checks for (and
    /// heals) poisoned shards.
    pub health_tick: Duration,
    /// Trace one request in every N (1 = every request, 0 = tracing
    /// off).  Sampled traces feed `repro_stage_seconds`, the
    /// `/debug/traces` ring and slow-request logging; sampled-out
    /// requests pay one branch per stage.
    pub trace_sample: u32,
    /// Log a structured JSON line to stderr for any sampled request
    /// slower than this many milliseconds (0 disables).
    pub slow_ms: u64,
    /// Shadow-verify one in every N slices served by a noisy/analog
    /// shard against the digital golden path (0 disables the monitor;
    /// it is also off when every shard is digital — there is nothing to
    /// check).
    pub fidelity_sample: u32,
    /// Drift threshold in quantizer LSBs: a shard slot whose shadow-check
    /// EWMA of mean |Δq| exceeds this is marked unhealthy (degrading
    /// `/readyz`) and respawned by the batcher health tick.
    pub drift_threshold: f64,
    /// Optional per-shard tile kinds (heterogeneous sets, e.g. one noisy
    /// canary slot among digital shards).  `None` gives every shard
    /// `coordinator.kind`.  Length must equal `shards`.
    pub shard_kinds: Option<Vec<TileKind>>,
    /// Deadline applied to requests that send no `X-Deadline-Ms` header
    /// (`None` = such requests are bounded only by `request_timeout`).
    pub default_deadline_ms: Option<u64>,
    /// Upper clamp on any per-request deadline, header-supplied or
    /// defaulted — clients cannot buy unbounded queueing time.
    pub max_deadline_ms: u64,
    /// How long a graceful drain ([`Server::drain`], SIGTERM/SIGINT in
    /// the CLI) waits for in-flight requests to finish before forcing
    /// shutdown.
    pub drain_timeout_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            listen: "127.0.0.1:8080".to_string(),
            coordinator: CoordinatorConfig::default(),
            shards: 1,
            admission: AdmissionConfig::default(),
            max_batch: 32,
            max_wait_us: 200,
            max_dim: 1 << 16,
            max_connections: 512,
            reactor_threads: 2,
            vdd: 0.8,
            request_timeout: Duration::from_secs(5),
            keepalive_max_requests: 64,
            keepalive_idle: Duration::from_secs(5),
            first_byte_timeout: Duration::from_secs(10),
            model: None,
            max_infer_batch: 64,
            auto_respawn: true,
            health_tick: Duration::from_millis(250),
            trace_sample: 1,
            slow_ms: 0,
            fidelity_sample: 16,
            drift_threshold: 1.0,
            shard_kinds: None,
            default_deadline_ms: None,
            max_deadline_ms: 60_000,
            drain_timeout_ms: 5_000,
        }
    }
}

/// State shared between the reactors, the batcher and the metrics
/// exporter.
pub(crate) struct ServerState {
    /// Admission gates; `Arc` so connections can hold owned in-flight
    /// permits across the asynchronous dispatch.
    pub admission: Arc<Admission>,
    pub e2e_latency: Mutex<LatencyHistogram>,
    /// End-to-end `/v1/infer` latency (enqueue to logits fan-out).
    pub infer_latency: Mutex<LatencyHistogram>,
    /// Merged + per-shard accelerator metrics across the shard set.
    pub shard_metrics: MetricsAggregator,
    /// Healthy-shard count maintained by the [`ShardSet`].
    pub shards_healthy: Arc<AtomicUsize>,
    /// Lifetime shard respawns performed by the health tick.
    pub shard_respawns: Arc<AtomicU64>,
    pub energy: EnergyModel,
    pub batches_total: AtomicU64,
    pub requests_ok: AtomicU64,
    pub bad_requests: AtomicU64,
    /// `/v1/infer` requests answered with 200.
    pub infer_requests_ok: AtomicU64,
    /// Samples successfully pushed through the model.
    pub infer_samples_total: AtomicU64,
    /// Model forward passes dispatched by the batcher.
    pub infer_batches_total: AtomicU64,
    /// Items the batcher discarded because their client timed out.
    pub stale_dropped_total: AtomicU64,
    /// Requests whose end-to-end deadline expired before a reply could
    /// be delivered — shed in the batcher queue, discarded after
    /// execution, or timed out at the connection.  Each expiry counts
    /// exactly once (the paths are disjoint).
    pub deadline_expired_total: AtomicU64,
    /// 504s delivered because the batcher dropped the reply sink
    /// (stale/deadline shed, worker failure or injected fault).
    pub dropped_reply_total: AtomicU64,
    /// 504s delivered because the connection's in-flight deadline fired
    /// before any completion arrived.
    pub dropped_deadline_total: AtomicU64,
    /// Currently open connections across every reactor.
    pub connections: AtomicUsize,
    /// Lifetime accepted connections.
    pub connections_accepted: AtomicU64,
    /// Connections closed by an idle/slowloris/write deadline.
    pub connections_timed_out: AtomicU64,
    /// High-water mark of the reused `/metrics` render buffer, in bytes.
    pub metrics_buf_hwm: AtomicUsize,
    /// Per-shard-slot health flags for `/readyz` (slot-granular, kept
    /// current by the [`ShardSet`] through poison/respawn/shutdown).
    pub slot_health: Arc<Vec<AtomicBool>>,
    /// Per-shard circuit breakers shared with the [`ShardSet`] router;
    /// feeds `/readyz` breaker labels and the `repro_shard_breaker_state`
    /// / `repro_shard_respawn_backoff_seconds` gauge families.
    pub breakers: Arc<BreakerSet>,
    /// Set once a graceful drain begins: `/readyz` fails and the
    /// reactors stop accepting new connections while in-flight work
    /// finishes.
    pub draining: AtomicBool,
    /// Request tracer feeding `repro_stage_seconds`, `/debug/traces`
    /// and slow-request logging.
    pub tracer: Arc<Tracer>,
    /// Fidelity monitor feeding `repro_fidelity_*`, `/debug/fidelity`
    /// and the batcher's drift-respawn pass.
    pub monitor: Arc<Monitor>,
    /// Process start, for the uptime gauge.
    pub started: Instant,
    /// Process start as seconds since the Unix epoch
    /// (`repro_process_start_time_seconds`).
    pub started_unix_s: f64,
}

impl ServerState {
    pub(crate) fn new(
        admission: AdmissionConfig,
        shard_metrics: MetricsAggregator,
        shards_healthy: Arc<AtomicUsize>,
        shard_respawns: Arc<AtomicU64>,
        slot_health: Arc<Vec<AtomicBool>>,
        energy: EnergyModel,
        tracer: Arc<Tracer>,
        monitor: Arc<Monitor>,
    ) -> ServerState {
        ServerState {
            admission: Arc::new(Admission::new(admission)),
            e2e_latency: Mutex::new(LatencyHistogram::new()),
            infer_latency: Mutex::new(LatencyHistogram::new()),
            shard_metrics,
            shards_healthy,
            shard_respawns,
            energy,
            batches_total: AtomicU64::new(0),
            requests_ok: AtomicU64::new(0),
            bad_requests: AtomicU64::new(0),
            infer_requests_ok: AtomicU64::new(0),
            infer_samples_total: AtomicU64::new(0),
            infer_batches_total: AtomicU64::new(0),
            stale_dropped_total: AtomicU64::new(0),
            deadline_expired_total: AtomicU64::new(0),
            dropped_reply_total: AtomicU64::new(0),
            dropped_deadline_total: AtomicU64::new(0),
            connections: AtomicUsize::new(0),
            connections_accepted: AtomicU64::new(0),
            connections_timed_out: AtomicU64::new(0),
            metrics_buf_hwm: AtomicUsize::new(0),
            // A standalone breaker set sized to the slots; `Server::start`
            // swaps in the one shared with the ShardSet's router.
            breakers: Arc::new(BreakerSet::new(slot_health.len(), 0)),
            draining: AtomicBool::new(false),
            slot_health,
            tracer,
            monitor,
            started: Instant::now(),
            started_unix_s: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs_f64())
                .unwrap_or(0.0),
        }
    }

    pub(crate) fn record_latency(&self, latency: Duration) {
        self.e2e_latency
            .lock()
            .expect("latency poisoned")
            .record(latency);
    }

    pub(crate) fn record_infer_latency(&self, latency: Duration) {
        self.infer_latency
            .lock()
            .expect("latency poisoned")
            .record(latency);
    }
}

/// A running server; drop-in lifecycle handle.
pub struct Server {
    /// Actual bound address (useful with an ephemeral `:0` bind).
    pub addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    reactor_threads: Vec<JoinHandle<()>>,
    /// One completion queue (with its eventfd waker) per reactor, kept
    /// to ring the reactors out of `epoll_wait` at shutdown.
    completions: Vec<Arc<Completions>>,
    batcher_thread: JoinHandle<Metrics>,
    state: Arc<ServerState>,
}

impl Server {
    /// Bind, spawn the batcher and the reactor threads, and return.
    pub fn start(config: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&config.listen)
            .with_context(|| format!("binding {}", config.listen))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        // A hosted model only constrains the tile geometry from below:
        // the tile must be at least as wide as the model's widest BWHT
        // block (narrower blocks run under sub-tile masking, which keeps
        // digital /v1/infer bit-identical to `Backend::Quantized` for
        // *any* hidden width).  An analog backend's crossbar geometry
        // must follow the override — Tile::new asserts config.n ==
        // tile_n in every worker thread.
        let mut coordinator = config.coordinator.clone();
        let mut shard_kinds = config.shard_kinds.clone();
        if let Some(model) = &config.model {
            let tile = required_tile(model.bwht.transform_blocks()).context(
                "the model's BWHT partition does not map onto power-of-two crossbar tiles",
            )?;
            if coordinator.tile_n < tile {
                coordinator.tile_n = tile;
                if let TileKind::Analog { config: xbar } = &mut coordinator.kind {
                    *xbar = CrossbarConfig::new(tile, config.vdd);
                }
                // Per-shard analog kinds must track the raised geometry
                // too — Tile::new asserts config.n == tile_n per worker.
                if let Some(kinds) = &mut shard_kinds {
                    for kind in kinds.iter_mut() {
                        if let TileKind::Analog { config: xbar } = kind {
                            *xbar = CrossbarConfig::new(tile, config.vdd);
                        }
                    }
                }
            }
        }

        let mut shards = ShardSet::new(ShardSetConfig {
            shards: config.shards.max(1),
            coordinator: coordinator.clone(),
            kinds: shard_kinds,
            ..Default::default()
        })?;
        // Shadow verification: re-execute 1-in-K sampled noisy/analog
        // slices through a private digital golden pool.  The monitor is
        // inert (one dead branch on the drain path) when sampling is off
        // or every shard is digital.
        let monitor = Arc::new(Monitor::start(
            MonitorConfig {
                sample_every: config.fidelity_sample,
                drift_threshold: config.drift_threshold,
                ..MonitorConfig::default()
            },
            coordinator.clone(),
            shards.non_digital_slots(),
            shards.slot_health_handle(),
        ));
        shards.set_monitor(monitor.handle());
        let tracer = Arc::new(Tracer::new(TraceConfig {
            sample_every: config.trace_sample,
            slow_us: config.slow_ms.saturating_mul(1000),
            ..TraceConfig::default()
        }));
        let mut server_state = ServerState::new(
            config.admission.clone(),
            shards.aggregator(),
            shards.health_handle(),
            shards.respawns_handle(),
            shards.slot_health_handle(),
            EnergyModel::new(coordinator.tile_n, config.vdd),
            tracer,
            monitor,
        );
        // Share the shard set's breakers so /readyz and /metrics report
        // the same state machine the router consults.
        server_state.breakers = Arc::clone(shards.breakers());
        let state = Arc::new(server_state);

        let (batch_tx, batch_rx) = mpsc::channel::<BatchItem>();
        let max_batch = config.max_batch.max(1);
        let max_wait = Duration::from_micros(config.max_wait_us);
        let stale_after = config.request_timeout;
        let model = config.model.clone();
        let auto_respawn = config.auto_respawn;
        let health_tick = config.health_tick.max(Duration::from_millis(10));
        let batcher_state = Arc::clone(&state);
        let batcher_thread = std::thread::spawn(move || {
            batcher::run_batcher(
                batch_rx,
                shards,
                model,
                max_batch,
                max_wait,
                stale_after,
                health_tick,
                auto_respawn,
                batcher_state,
            )
        });

        let shutdown = Arc::new(AtomicBool::new(false));
        let config = Arc::new(config);
        let n_reactors = config.reactor_threads.clamp(1, 64);
        let mut reactor_threads = Vec::with_capacity(n_reactors);
        let mut completions = Vec::with_capacity(n_reactors);
        for i in 0..n_reactors {
            let queue = Arc::new(Completions::new(Waker::new()?));
            completions.push(Arc::clone(&queue));
            let reactor = event_loop::Reactor::new(
                listener.try_clone()?,
                queue,
                Arc::clone(&state),
                Arc::clone(&config),
                batch_tx.clone(),
                Arc::clone(&shutdown),
            )?;
            reactor_threads.push(
                std::thread::Builder::new()
                    .name(format!("reactor-{i}"))
                    .spawn(move || reactor.run())?,
            );
        }
        // The reactors hold the only live senders now: when they exit at
        // shutdown, the batcher drains its queue and exits too.
        drop(batch_tx);

        Ok(Server {
            addr,
            shutdown,
            reactor_threads,
            completions,
            batcher_thread,
            state,
        })
    }

    /// Merged snapshot of the live accelerator metrics across shards.
    pub fn metrics(&self) -> Metrics {
        self.state.shard_metrics.merged()
    }

    /// Begin a graceful drain: `/readyz` starts answering 503 (so load
    /// balancers steer new traffic away), the reactors stop accepting
    /// connections and close idle keep-alive ones, and every in-flight
    /// request keeps being served to completion with
    /// `Connection: close` on its reply.
    pub fn begin_drain(&self) {
        self.state.draining.store(true, Ordering::SeqCst);
        // Ring every reactor out of epoll_wait so it notices the flag,
        // deregisters the listener and sweeps idle connections.
        for queue in &self.completions {
            queue.waker().wake();
        }
    }

    /// Gracefully drain and shut down: stop accepting, wait up to
    /// `timeout` for in-flight requests *and* their response writes to
    /// finish, then stop the reactors and batcher.  In-flight clients
    /// get their real replies, not resets — the integration tests
    /// assert zero dropped responses across a drain.
    pub fn drain(self, timeout: Duration) -> Metrics {
        self.begin_drain();
        let give_up = Instant::now() + timeout;
        while (self.state.admission.inflight() > 0
            || self.state.connections.load(Ordering::Acquire) > 0)
            && Instant::now() < give_up
        {
            std::thread::sleep(Duration::from_millis(2));
        }
        self.shutdown()
    }

    /// Graceful shutdown: stop the reactors (closing their connections),
    /// drain the batcher, shut the pool down, and return the merged
    /// worker metrics.
    pub fn shutdown(self) -> Metrics {
        self.shutdown.store(true, Ordering::SeqCst);
        for queue in &self.completions {
            queue.waker().wake();
        }
        for thread in self.reactor_threads {
            let _ = thread.join();
        }
        self.batcher_thread
            .join()
            .expect("batcher thread panicked")
    }
}

/// What routing one parsed request produced.
pub(crate) enum RouteOutcome {
    /// Immediately serializable response (sync endpoints and errors).
    Response(http::Response),
    /// The body was rendered into the reactor's reused scratch buffer
    /// (the `/metrics` fast path): serialize from parts, no body copy.
    Scratch,
    /// Admitted work for the batcher; the connection parks until the
    /// completion queue delivers the reply.
    Dispatch(Dispatch),
}

/// An admitted request on its way into the batcher.
pub(crate) struct Dispatch {
    pub payload: BatchPayload,
    pub kind: PendingKind,
    pub trace: TraceHandle,
    pub permit: InflightPermit,
    /// End-to-end deadline budget (`X-Deadline-Ms` clamped, or the
    /// configured default).  The event loop anchors it at the request's
    /// first byte and threads the absolute deadline through the batcher
    /// into the tile pool.
    pub deadline_budget: Option<Duration>,
}

/// Which endpoint a parked connection is waiting on, with what it needs
/// to render the reply.
#[derive(Debug, Clone, Copy)]
pub(crate) enum PendingKind {
    Transform,
    Infer {
        nested: bool,
        classes: usize,
        samples: usize,
    },
}

/// Route one request.  Synchronous endpoints answer inline; `/metrics`
/// renders into `scratch` (reused across scrapes); POST endpoints
/// validate + admit here and hand back a [`Dispatch`] for the batcher.
pub(crate) fn route_request(
    req: &http::Req<'_>,
    peer: IpAddr,
    state: &ServerState,
    config: &ServerConfig,
    scratch: &mut String,
) -> RouteOutcome {
    let (path, query) = req.path_and_query();
    match (req.method(), path) {
        ("GET", "/healthz") => RouteOutcome::Response(http::Response::text(200, "ok\n")),
        ("GET", "/readyz") => RouteOutcome::Response(readyz_response(state)),
        ("GET", "/metrics") => {
            metrics_export::render_into(state, scratch);
            RouteOutcome::Scratch
        }
        ("GET", "/debug/traces") => RouteOutcome::Response(handle_traces(state, query)),
        ("GET", "/debug/fidelity") => RouteOutcome::Response(handle_fidelity(state, query)),
        ("POST", "/v1/transform") => match transform_dispatch(req, peer, state, config) {
            Ok(dispatch) => RouteOutcome::Dispatch(dispatch),
            Err(response) => RouteOutcome::Response(response),
        },
        ("POST", "/v1/infer") => match infer_dispatch(req, peer, state, config) {
            Ok(dispatch) => RouteOutcome::Dispatch(dispatch),
            Err(response) => RouteOutcome::Response(response),
        },
        (_, "/v1/transform") | (_, "/v1/infer") | (_, "/metrics") | (_, "/healthz")
        | (_, "/readyz") | (_, "/debug/traces") | (_, "/debug/fidelity") => {
            RouteOutcome::Response(http::Response::json(405, &error_json("method not allowed")))
        }
        _ => RouteOutcome::Response(http::Response::json(404, &error_json("not found"))),
    }
}

/// Render the reply for a parked request once its completion arrives.
/// `result` is `None` when the batcher dropped the item (stale shed) or
/// the in-flight deadline fired first — a 504 either way, exactly like
/// the old blocking handler's `recv_timeout` path.
pub(crate) fn render_reply(
    kind: PendingKind,
    result: Option<ReplyResult>,
    state: &ServerState,
) -> http::Response {
    match kind {
        PendingKind::Transform => match result {
            Some(Ok(reply)) => {
                state.requests_ok.fetch_add(1, Ordering::Relaxed);
                let mut obj = BTreeMap::new();
                obj.insert(
                    "y".to_string(),
                    Json::Arr(reply.values.iter().map(|&v| Json::Num(v as f64)).collect()),
                );
                obj.insert(
                    "padded_dim".to_string(),
                    Json::Num(reply.values.len() as f64),
                );
                obj.insert(
                    "latency_us".to_string(),
                    Json::Num(reply.latency.as_micros() as f64),
                );
                http::Response::json(200, &Json::Obj(obj))
            }
            Some(Err(message)) => http::Response::json(500, &error_json(&message)),
            None => http::Response::json(504, &error_json("timed out waiting for the tile pool")),
        },
        PendingKind::Infer {
            nested,
            classes,
            samples,
        } => match result {
            Some(Ok(reply)) => {
                state.infer_requests_ok.fetch_add(1, Ordering::Relaxed);
                let logits_json = if nested {
                    Json::Arr(
                        reply
                            .values
                            .chunks_exact(classes)
                            .map(|row| {
                                Json::Arr(row.iter().map(|&v| Json::Num(v as f64)).collect())
                            })
                            .collect(),
                    )
                } else {
                    Json::Arr(reply.values.iter().map(|&v| Json::Num(v as f64)).collect())
                };
                let mut obj = BTreeMap::new();
                obj.insert("logits".to_string(), logits_json);
                obj.insert("classes".to_string(), Json::Num(classes as f64));
                obj.insert("samples".to_string(), Json::Num(samples as f64));
                obj.insert(
                    "latency_us".to_string(),
                    Json::Num(reply.latency.as_micros() as f64),
                );
                http::Response::json(200, &Json::Obj(obj))
            }
            Some(Err(message)) => http::Response::json(500, &error_json(&message)),
            None => http::Response::json(504, &error_json("timed out waiting for the model")),
        },
    }
}

/// Shard-health-aware readiness: 200 when every shard slot is healthy
/// and the server is not draining, 503 (with the same per-shard body)
/// while any slot is poisoned/mid-respawn or a graceful drain is in
/// progress — load balancers keep draining the node without killing it,
/// since `/healthz` stays green.  Each shard entry carries its circuit
/// breaker state (`closed`/`half-open`/`open`) so operators can tell a
/// shedding slot from a dead one.
fn readyz_response(state: &ServerState) -> http::Response {
    let draining = state.draining.load(Ordering::Acquire);
    let breakers = state.breakers.snapshot();
    let mut all_healthy = true;
    let mut shards = Vec::with_capacity(state.slot_health.len());
    for (slot, flag) in state.slot_health.iter().enumerate() {
        let healthy = flag.load(Ordering::Acquire);
        all_healthy &= healthy;
        let mut obj = BTreeMap::new();
        obj.insert("shard".to_string(), Json::Num(slot as f64));
        obj.insert("healthy".to_string(), Json::Bool(healthy));
        let breaker = breakers
            .get(slot)
            .map(|b| b.state.label())
            .unwrap_or("closed");
        obj.insert("breaker".to_string(), Json::Str(breaker.to_string()));
        shards.push(Json::Obj(obj));
    }
    let ready = all_healthy && !draining;
    let mut obj = BTreeMap::new();
    obj.insert("ready".to_string(), Json::Bool(ready));
    obj.insert("draining".to_string(), Json::Bool(draining));
    obj.insert("shards".to_string(), Json::Arr(shards));
    http::Response::json(if ready { 200 } else { 503 }, &Json::Obj(obj))
}

/// First value of `key` in a URL query string (no percent-decoding —
/// the debug API's keys and values are plain identifiers).
fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query
        .split('&')
        .find_map(|kv| kv.split_once('=').filter(|(k, _)| *k == key).map(|(_, v)| v))
}

/// `GET /debug/traces?n=K[&format=chrome]`: the most recent `K` sampled
/// traces (default 32, capped at 256), newest first, as plain JSON or
/// Chrome `trace_event` format.
fn handle_traces(state: &ServerState, query: &str) -> http::Response {
    let n = query_param(query, "n")
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(32)
        .min(256);
    let traces = state.tracer.recent(n);
    let body = match query_param(query, "format") {
        Some("chrome") => trace::traces_chrome(&traces),
        _ => trace::traces_json(&traces),
    };
    http::Response::json(200, &body)
}

/// `GET /debug/fidelity?n=K`: live fidelity-monitor snapshot — the
/// enabled/sampling state, per-shard drift EWMAs and flags, and the `K`
/// most recent shadow-check divergence records (default 32, capped at
/// 256), newest first.
fn handle_fidelity(state: &ServerState, query: &str) -> http::Response {
    let n = query_param(query, "n")
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(32)
        .min(256);
    http::Response::json(200, &state.monitor.fidelity_json(n))
}

pub(crate) fn error_json(message: &str) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("error".to_string(), Json::Str(message.to_string()));
    Json::Obj(obj)
}

fn bad_request(state: &ServerState, message: &str) -> http::Response {
    state.bad_requests.fetch_add(1, Ordering::Relaxed);
    http::Response::json(400, &error_json(message))
}

/// Effective per-request deadline budget: the client's `X-Deadline-Ms`
/// (if sent) clamped to `[1, max_ms]`, else the configured default
/// (same clamp), else `None` — in which case only `request_timeout`
/// bounds the request.  Pure so the arithmetic is unit-testable.
pub(crate) fn deadline_budget(
    header_ms: Option<u64>,
    default_ms: Option<u64>,
    max_ms: u64,
) -> Option<Duration> {
    let ms = header_ms.or(default_ms)?;
    Some(Duration::from_millis(ms.clamp(1, max_ms.max(1))))
}

/// Parse `X-Deadline-Ms` into a millisecond count.  Absent is fine
/// (`Ok(None)`); present-but-garbage (non-numeric, zero) is a client
/// error the caller maps to a 400.
fn parse_deadline_header(req: &http::Req<'_>) -> std::result::Result<Option<u64>, ()> {
    match req.header("x-deadline-ms") {
        None => Ok(None),
        Some(v) => match v.trim().parse::<u64>() {
            Ok(ms) if ms > 0 => Ok(Some(ms)),
            _ => Err(()),
        },
    }
}

/// Deadline budget for one parsed request, or the 400 to answer with.
fn request_deadline_budget(
    req: &http::Req<'_>,
    state: &ServerState,
    config: &ServerConfig,
) -> std::result::Result<Option<Duration>, http::Response> {
    match parse_deadline_header(req) {
        Ok(header_ms) => Ok(deadline_budget(
            header_ms,
            config.default_deadline_ms,
            config.max_deadline_ms,
        )),
        Err(()) => Err(bad_request(
            state,
            "X-Deadline-Ms must be a positive integer (milliseconds)",
        )),
    }
}

/// Admit a parsed request, mapping rejections to 429s.
fn admit(
    state: &ServerState,
    peer: IpAddr,
) -> std::result::Result<InflightPermit, http::Response> {
    match state.admission.try_acquire(peer, Instant::now()) {
        Ok(permit) => Ok(permit),
        Err(Rejection::Overloaded) => Err(http::Response::json(
            429,
            &error_json("overloaded: in-flight limit reached"),
        )
        .with_header("Retry-After", "1")),
        Err(Rejection::RateLimited) => {
            Err(http::Response::json(429, &error_json("rate limited"))
                .with_header("Retry-After", "1"))
        }
    }
}

/// Parse + admit one `POST /v1/transform`; the event loop enqueues the
/// returned dispatch and parks the connection.
fn transform_dispatch(
    req: &http::Req<'_>,
    peer: IpAddr,
    state: &ServerState,
    config: &ServerConfig,
) -> std::result::Result<Dispatch, http::Response> {
    let t0 = Instant::now();
    let body = req
        .body_str()
        .map_err(|_| bad_request(state, "body must be UTF-8 JSON"))?;
    let parsed = json::parse(body)
        .map_err(|e| bad_request(state, &format!("invalid JSON: {e}")))?;
    let Some(xs) = parsed.get("x").and_then(Json::as_arr) else {
        return Err(bad_request(state, "missing \"x\" array"));
    };
    if xs.is_empty() {
        return Err(bad_request(state, "\"x\" must be non-empty"));
    }
    if xs.len() > config.max_dim {
        return Err(bad_request(
            state,
            &format!(
                "\"x\" has {} elements; the limit is {}",
                xs.len(),
                config.max_dim
            ),
        ));
    }
    let mut x = Vec::with_capacity(xs.len());
    for v in xs {
        match v.as_f64() {
            Some(f) if f.is_finite() => x.push(f as f32),
            _ => return Err(bad_request(state, "\"x\" must contain finite numbers")),
        }
    }
    let thresholds_units = match parsed.get("thresholds") {
        None => vec![0.0; x.len()],
        Some(t) => {
            let Some(arr) = t.as_arr() else {
                return Err(bad_request(state, "\"thresholds\" must be an array"));
            };
            if arr.len() != x.len() {
                return Err(bad_request(state, "\"thresholds\" length must match \"x\""));
            }
            let mut th = Vec::with_capacity(arr.len());
            for v in arr {
                match v.as_f64() {
                    Some(f) if f.is_finite() => th.push(f.abs()),
                    _ => {
                        return Err(bad_request(
                            state,
                            "\"thresholds\" must contain finite numbers",
                        ))
                    }
                }
            }
            th
        }
    };

    let deadline_budget = request_deadline_budget(req, state, config)?;
    let permit = admit(state, peer)?;
    let trace = trace_admitted(state, "/v1/transform", t0);
    Ok(Dispatch {
        payload: BatchPayload::Transform(TransformRequest {
            x,
            thresholds_units,
            scale: None,
            deadline: None,
        }),
        kind: PendingKind::Transform,
        trace,
        permit,
        deadline_budget,
    })
}

/// Mint the request's trace handle right after admission and record the
/// admission span (handler entry → permit acquired).
fn trace_admitted(state: &ServerState, endpoint: &'static str, t0: Instant) -> TraceHandle {
    let trace = state.tracer.begin(endpoint);
    if trace.is_active() {
        let start = trace::instant_us(t0);
        trace.record(Stage::Admission, start, trace::now_us().saturating_sub(start));
    }
    trace
}

/// Record the respond span (reply received → response serialized) and
/// retire the trace into the recent-trace ring.
pub(crate) fn finish_trace(state: &ServerState, trace: TraceHandle, respond_start: u64) {
    if trace.is_active() {
        trace.record(
            Stage::Respond,
            respond_start,
            trace::now_us().saturating_sub(respond_start),
        );
    }
    state.tracer.finish(trace);
}

/// Parse one finite-f32 row out of a JSON array.
fn parse_row(values: &[Json], din: usize) -> std::result::Result<Vec<f32>, String> {
    if values.len() != din {
        return Err(format!(
            "each sample needs {din} features, got {}",
            values.len()
        ));
    }
    let mut row = Vec::with_capacity(values.len());
    for v in values {
        match v.as_f64() {
            Some(f) if f.is_finite() => row.push(f as f32),
            _ => return Err("\"x\" must contain finite numbers".to_string()),
        }
    }
    Ok(row)
}

/// Parse + admit one `POST /v1/infer`.
///
/// Accepts `{"x": [f, ...]}` (one sample, flat logits back) or
/// `{"x": [[f, ...], ...]}` (a batch, nested logits back).  The batcher
/// coalesces concurrent infer requests into one model forward whose BWHT
/// transforms scatter–gather across the shard set.
fn infer_dispatch(
    req: &http::Req<'_>,
    peer: IpAddr,
    state: &ServerState,
    config: &ServerConfig,
) -> std::result::Result<Dispatch, http::Response> {
    let t0 = Instant::now();
    let Some(model) = &config.model else {
        return Err(http::Response::json(
            503,
            &error_json("no model loaded; start the server with --weights PATH"),
        ));
    };
    let din = model.din();
    let classes = model.classes;

    let body = req
        .body_str()
        .map_err(|_| bad_request(state, "body must be UTF-8 JSON"))?;
    let parsed = json::parse(body)
        .map_err(|e| bad_request(state, &format!("invalid JSON: {e}")))?;
    let Some(xs) = parsed.get("x").and_then(Json::as_arr) else {
        return Err(bad_request(state, "missing \"x\" array"));
    };
    if xs.is_empty() {
        return Err(bad_request(state, "\"x\" must be non-empty"));
    }

    // Shape sniff: an array of arrays is a batch; an array of numbers is
    // one sample.
    let nested = xs[0].as_arr().is_some();
    let mut x = Vec::new();
    let samples = if nested {
        if xs.len() > config.max_infer_batch.max(1) {
            return Err(bad_request(
                state,
                &format!(
                    "batch of {} samples exceeds the limit of {}",
                    xs.len(),
                    config.max_infer_batch.max(1)
                ),
            ));
        }
        for row in xs {
            let Some(row) = row.as_arr() else {
                return Err(bad_request(state, "\"x\" rows must all be arrays"));
            };
            match parse_row(row, din) {
                Ok(mut r) => x.append(&mut r),
                Err(e) => return Err(bad_request(state, &e)),
            }
        }
        xs.len()
    } else {
        match parse_row(xs, din) {
            Ok(r) => x = r,
            Err(e) => return Err(bad_request(state, &e)),
        }
        1
    };

    let deadline_budget = request_deadline_budget(req, state, config)?;
    let permit = admit(state, peer)?;
    let trace = trace_admitted(state, "/v1/infer", t0);
    Ok(Dispatch {
        payload: BatchPayload::Infer { x, samples },
        kind: PendingKind::Infer {
            nested,
            classes,
            samples,
        },
        trace,
        permit,
        deadline_budget,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, CoordinatorConfig};

    fn test_state(slot_health: Vec<bool>) -> ServerState {
        let coord = Coordinator::new(CoordinatorConfig::default());
        let agg = MetricsAggregator::new(vec![coord.metrics_handle()], 8);
        let healthy = slot_health.iter().filter(|&&h| h).count();
        ServerState::new(
            AdmissionConfig::default(),
            agg,
            Arc::new(AtomicUsize::new(healthy)),
            Arc::new(AtomicU64::new(0)),
            Arc::new(slot_health.into_iter().map(AtomicBool::new).collect::<Vec<_>>()),
            EnergyModel::new(16, 0.8),
            Arc::new(Tracer::new(TraceConfig::default())),
            Arc::new(Monitor::disabled()),
        )
    }

    #[test]
    fn readyz_is_200_when_every_slot_is_healthy() {
        let state = test_state(vec![true, true]);
        let resp = readyz_response(&state);
        assert_eq!(resp.status, 200);
        let body = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert!(matches!(body.get("ready"), Some(Json::Bool(true))));
        assert_eq!(body.get("shards").and_then(Json::as_arr).unwrap().len(), 2);
    }

    #[test]
    fn readyz_is_503_with_per_shard_body_when_a_slot_is_poisoned() {
        let state = test_state(vec![true, false, true]);
        let resp = readyz_response(&state);
        assert_eq!(resp.status, 503);
        let body = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert!(matches!(body.get("ready"), Some(Json::Bool(false))));
        let shards = body.get("shards").and_then(Json::as_arr).unwrap();
        assert_eq!(shards.len(), 3);
        assert!(matches!(shards[0].get("healthy"), Some(Json::Bool(true))));
        assert!(matches!(shards[1].get("healthy"), Some(Json::Bool(false))));
        assert_eq!(shards[1].get("shard").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn debug_fidelity_endpoint_reports_a_disabled_monitor() {
        let state = test_state(vec![true]);
        let resp = handle_fidelity(&state, "n=8");
        assert_eq!(resp.status, 200);
        let body = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert!(matches!(body.get("enabled"), Some(Json::Bool(false))));
        assert_eq!(body.get("checked").and_then(Json::as_f64), Some(0.0));
        assert!(body.get("slots").and_then(Json::as_arr).is_some());
        assert!(body.get("recent").and_then(Json::as_arr).is_some());
    }

    #[test]
    fn deadline_budget_clamps_header_and_falls_back_to_the_default() {
        // Header wins over the default and is clamped to max.
        assert_eq!(
            deadline_budget(Some(250), Some(1_000), 60_000),
            Some(Duration::from_millis(250))
        );
        assert_eq!(
            deadline_budget(Some(120_000), None, 60_000),
            Some(Duration::from_millis(60_000)),
            "header above max clamps down"
        );
        // No header: the configured default applies (same clamp).
        assert_eq!(
            deadline_budget(None, Some(90_000), 60_000),
            Some(Duration::from_millis(60_000))
        );
        // Neither: no deadline at all.
        assert_eq!(deadline_budget(None, None, 60_000), None);
        // Degenerate max never produces a zero-length budget.
        assert_eq!(
            deadline_budget(Some(5), None, 0),
            Some(Duration::from_millis(1))
        );
    }

    #[test]
    fn garbage_deadline_header_answers_400() {
        let state = test_state(vec![true]);
        let config = ServerConfig::default();
        let peer = IpAddr::V4(std::net::Ipv4Addr::LOCALHOST);
        let mut scratch = String::new();
        let body = r#"{"x": [0.5, -0.25]}"#;
        let raw = format!(
            "POST /v1/transform HTTP/1.1\r\nX-Deadline-Ms: soon\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let mut buf = raw.into_bytes();
        let mut head = http::Head::default();
        assert_eq!(head.parse(&mut buf).unwrap(), http::Parse::Complete);
        let req = head.req(&buf);
        let RouteOutcome::Response(resp) = route_request(&req, peer, &state, &config, &mut scratch)
        else {
            panic!("a garbage deadline header must answer inline");
        };
        assert_eq!(resp.status, 400);
        assert_eq!(state.bad_requests.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn valid_deadline_header_rides_into_the_dispatch() {
        let state = test_state(vec![true]);
        let config = ServerConfig::default();
        let peer = IpAddr::V4(std::net::Ipv4Addr::LOCALHOST);
        let mut scratch = String::new();
        let body = r#"{"x": [0.5, -0.25]}"#;
        let raw = format!(
            "POST /v1/transform HTTP/1.1\r\nX-Deadline-Ms: 750\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let mut buf = raw.into_bytes();
        let mut head = http::Head::default();
        assert_eq!(head.parse(&mut buf).unwrap(), http::Parse::Complete);
        let req = head.req(&buf);
        let RouteOutcome::Dispatch(dispatch) =
            route_request(&req, peer, &state, &config, &mut scratch)
        else {
            panic!("a valid transform must dispatch");
        };
        assert_eq!(dispatch.deadline_budget, Some(Duration::from_millis(750)));
    }

    #[test]
    fn readyz_reports_draining_and_breaker_states() {
        let state = test_state(vec![true, true]);
        // A tripped breaker shows up by label even while the slot flag
        // is still healthy (shedding, not dead).
        state.breakers.force_open(1, Instant::now());
        let resp = readyz_response(&state);
        assert_eq!(resp.status, 200, "open breaker alone does not fail readiness");
        let body = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let shards = body.get("shards").and_then(Json::as_arr).unwrap();
        assert!(matches!(shards[0].get("breaker"), Some(Json::Str(s)) if s == "closed"));
        assert!(matches!(shards[1].get("breaker"), Some(Json::Str(s)) if s == "open"));
        // Draining fails readiness even with every slot healthy.
        state.draining.store(true, Ordering::SeqCst);
        let resp = readyz_response(&state);
        assert_eq!(resp.status, 503);
        let body = json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert!(matches!(body.get("ready"), Some(Json::Bool(false))));
        assert!(matches!(body.get("draining"), Some(Json::Bool(true))));
    }

    #[test]
    fn query_param_picks_first_match() {
        assert_eq!(query_param("n=4&format=chrome", "n"), Some("4"));
        assert_eq!(query_param("n=4&format=chrome", "format"), Some("chrome"));
        assert_eq!(query_param("n=4", "format"), None);
        assert_eq!(query_param("", "n"), None);
        assert_eq!(query_param("n=1&n=2", "n"), Some("1"));
    }

    #[test]
    fn debug_traces_endpoint_serves_both_formats() {
        let state = test_state(vec![true]);
        let h = state.tracer.begin("/v1/transform");
        if h.is_active() {
            h.record(Stage::Admission, trace::now_us(), 3);
        }
        state.tracer.finish(h);
        let plain = handle_traces(&state, "n=8");
        assert_eq!(plain.status, 200);
        let parsed = json::parse(std::str::from_utf8(&plain.body).unwrap()).unwrap();
        assert!(parsed.get("traces").and_then(Json::as_arr).is_some());
        let chrome = handle_traces(&state, "n=8&format=chrome");
        let parsed = json::parse(std::str::from_utf8(&chrome.body).unwrap()).unwrap();
        assert!(parsed.get("traceEvents").and_then(Json::as_arr).is_some());
    }

    #[test]
    fn route_request_parses_and_admits_through_the_dispatch_seam() {
        let state = test_state(vec![true]);
        let config = ServerConfig::default();
        let peer = IpAddr::V4(std::net::Ipv4Addr::LOCALHOST);
        let mut scratch = String::new();
        let raw = |body: &str| {
            format!(
                "POST /v1/transform HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
        };
        let route = |raw: &str, scratch: &mut String| {
            let mut buf = raw.as_bytes().to_vec();
            let mut head = http::Head::default();
            assert_eq!(head.parse(&mut buf).unwrap(), http::Parse::Complete);
            let req = head.req(&buf);
            route_request(&req, peer, &state, &config, scratch)
        };
        // A valid body dispatches with a held permit.
        let outcome = route(&raw(r#"{"x": [0.5, -0.25]}"#), &mut scratch);
        let RouteOutcome::Dispatch(dispatch) = outcome else {
            panic!("valid transform must dispatch");
        };
        assert!(matches!(dispatch.kind, PendingKind::Transform));
        assert_eq!(state.admission.inflight(), 1);
        drop(dispatch);
        assert_eq!(state.admission.inflight(), 0, "permit released on drop");
        // Bad JSON answers 400 inline and counts.
        let outcome = route(&raw("this is not json"), &mut scratch);
        let RouteOutcome::Response(resp) = outcome else {
            panic!("bad JSON must answer inline");
        };
        assert_eq!(resp.status, 400);
        assert_eq!(state.bad_requests.load(Ordering::Relaxed), 1);
        // /metrics renders into the reused scratch buffer.
        let outcome = route("GET /metrics HTTP/1.1\r\n\r\n", &mut scratch);
        assert!(matches!(outcome, RouteOutcome::Scratch));
        assert!(scratch.contains("repro_connections_open"), "{scratch}");
    }

    #[test]
    fn render_reply_maps_outcomes_to_statuses_and_counters() {
        let state = test_state(vec![true]);
        let ok = render_reply(
            PendingKind::Transform,
            Some(Ok(BatchReply {
                values: vec![1.0, -1.0],
                latency: Duration::from_micros(7),
            })),
            &state,
        );
        assert_eq!(ok.status, 200);
        assert_eq!(state.requests_ok.load(Ordering::Relaxed), 1);
        let body = json::parse(std::str::from_utf8(&ok.body).unwrap()).unwrap();
        assert_eq!(body.get("padded_dim").and_then(Json::as_f64), Some(2.0));
        let failed = render_reply(PendingKind::Transform, Some(Err("boom".into())), &state);
        assert_eq!(failed.status, 500);
        let timed_out = render_reply(PendingKind::Transform, None, &state);
        assert_eq!(timed_out.status, 504);
        assert!(std::str::from_utf8(&timed_out.body).unwrap().contains("tile pool"));
        let infer_timeout = render_reply(
            PendingKind::Infer {
                nested: false,
                classes: 3,
                samples: 1,
            },
            None,
            &state,
        );
        assert!(std::str::from_utf8(&infer_timeout.body).unwrap().contains("model"));
        assert_eq!(state.requests_ok.load(Ordering::Relaxed), 1, "only the 200 counted");
    }
}

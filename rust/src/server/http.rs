//! Minimal hand-rolled HTTP/1.1 parsing and response writing.
//!
//! The build box is offline, so no hyper/axum: this implements exactly
//! the subset the serving subsystem needs — persistent connections
//! (HTTP/1.1 keep-alive semantics, honoring `Connection: close` /
//! `keep-alive` anywhere in the token list per RFC 9112 §9.3),
//! `Content-Length`-framed bodies, header lookup, and deterministic
//! wire formatting.
//!
//! Parsing is **incremental and zero-copy**: the event loop
//! (`server::event_loop`) appends whatever bytes the socket
//! has into a per-connection reusable buffer and calls
//! [`Head::parse`] until it reports [`Parse::Complete`].  The parsed
//! head stores byte spans into that buffer (header names are
//! lower-cased in place), and [`Head::req`] wraps buffer + head into a
//! borrowed [`Req`] view — no per-request `String`/`Vec` is ever
//! allocated for the wire bytes.  Timeouts, the per-connection request
//! cap and pipelining live in the connection state machine, which owns
//! the socket and the buffer.

use std::io::Write;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Hard cap on accepted bodies (JSON transform requests are small).
pub const MAX_BODY_BYTES: usize = 4 << 20;
/// Hard cap on the total header block (request line + headers + blank).
pub const MAX_HEADER_BYTES: usize = 16 << 10;

/// Byte span into the connection's read buffer.
type Span = (usize, usize);

fn span(buf: &[u8], s: Span) -> &[u8] {
    &buf[s.0..s.1]
}

/// Outcome of one [`Head::parse`] attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Parse {
    /// The full head is framed; `body_start`/`content_length` are set.
    Complete,
    /// No blank line yet — read more bytes and call `parse` again.
    NeedMore,
}

/// One parsed request head: byte spans into the connection's read
/// buffer instead of owned strings.  Reused across requests on the same
/// connection (the span vector keeps its capacity).
#[derive(Debug, Default)]
pub struct Head {
    method: Span,
    path: Span,
    http11: bool,
    /// `(name, value)` spans; names are lower-cased in place at parse.
    headers: Vec<(Span, Span)>,
    /// Offset of the first body byte (one past the blank line).
    pub body_start: usize,
    /// Declared `Content-Length` (0 when absent).
    pub content_length: usize,
}

impl Head {
    /// Clear per-request state while keeping allocated capacity.
    pub fn reset(&mut self) {
        self.method = (0, 0);
        self.path = (0, 0);
        self.http11 = false;
        self.headers.clear();
        self.body_start = 0;
        self.content_length = 0;
    }

    /// Total request framing size: head plus declared body.
    pub fn total_len(&self) -> usize {
        self.body_start + self.content_length
    }

    /// Try to parse a request head from the front of `buf`.
    ///
    /// Returns [`Parse::NeedMore`] until the blank line has arrived;
    /// errors are protocol violations (malformed request line, bad
    /// `Content-Length`, oversized head or body) and must close the
    /// connection after a 400.  Header names are ASCII-lower-cased in
    /// place, which is why `buf` is `&mut`.
    pub fn parse(&mut self, buf: &mut [u8]) -> Result<Parse> {
        let Some(head_end) = find_head_end(buf)? else {
            return Ok(Parse::NeedMore);
        };

        self.reset();
        self.body_start = head_end;

        let mut lines = lines(&buf[..head_end]);
        let request_line = lines.next().unwrap_or((0, 0));
        self.parse_request_line(buf, request_line)?;
        for line in lines {
            if line.0 == line.1 {
                break; // the blank line terminating the head
            }
            let header = parse_header_line(buf, line)?;
            self.headers.push(header);
        }
        // Lower-case header names in place so lookups and the
        // `content-length` scan below are byte comparisons.
        for &(name, _) in &self.headers {
            buf[name.0..name.1].make_ascii_lowercase();
        }

        self.content_length = match self.raw_header(buf, "content-length") {
            Some(v) => {
                let text = std::str::from_utf8(v).unwrap_or("");
                match text.trim().parse::<usize>() {
                    Ok(n) => n,
                    Err(_) => bail!("invalid Content-Length {text:?}"),
                }
            }
            None => 0,
        };
        if self.content_length > MAX_BODY_BYTES {
            bail!(
                "body of {} bytes exceeds the {MAX_BODY_BYTES}-byte limit",
                self.content_length
            );
        }
        Ok(Parse::Complete)
    }

    fn parse_request_line(&mut self, buf: &[u8], line: Span) -> Result<()> {
        let mut pos = line.0;
        let method = token(buf, &mut pos, line.1);
        let path = token(buf, &mut pos, line.1);
        let version = token(buf, &mut pos, line.1);
        let (Some(method), Some(path), Some(version)) = (method, path, version) else {
            let text = String::from_utf8_lossy(span(buf, line));
            bail!("malformed request line {text:?}");
        };
        let version_bytes = span(buf, version);
        if !version_bytes.starts_with(b"HTTP/1.") {
            bail!(
                "unsupported protocol {}",
                String::from_utf8_lossy(version_bytes)
            );
        }
        if std::str::from_utf8(&buf[line.0..line.1]).is_err() {
            bail!("request line is not valid UTF-8");
        }
        self.method = method;
        self.path = path;
        self.http11 = version_bytes == b"HTTP/1.1";
        Ok(())
    }

    fn raw_header<'b>(&self, buf: &'b [u8], name: &str) -> Option<&'b [u8]> {
        self.headers
            .iter()
            .find(|(n, _)| span(buf, *n).eq_ignore_ascii_case(name.as_bytes()))
            .map(|(_, v)| span(buf, *v))
    }

    /// Borrow `buf` through this head as a request view.  `buf` must be
    /// the same buffer `parse` completed against.
    pub fn req<'b>(&'b self, buf: &'b [u8]) -> Req<'b> {
        Req { buf, head: self }
    }
}

/// Locate the end of the head (offset one past the blank line),
/// enforcing [`MAX_HEADER_BYTES`] even while incomplete so a
/// newline-free flood errors instead of buffering without bound.
fn find_head_end(buf: &[u8]) -> Result<Option<usize>> {
    let mut line_start = 0usize;
    while let Some(nl) = buf[line_start..].iter().position(|&b| b == b'\n') {
        let line_end = line_start + nl;
        let content = trim_cr(buf, (line_start, line_end));
        if content.0 == content.1 && line_start > 0 {
            return Ok(Some(line_end + 1));
        }
        if content.0 == content.1 {
            bail!("malformed request line \"\"");
        }
        line_start = line_end + 1;
        if line_start > MAX_HEADER_BYTES {
            bail!("header block larger than {MAX_HEADER_BYTES} bytes");
        }
    }
    if buf.len() > MAX_HEADER_BYTES {
        bail!("header block larger than {MAX_HEADER_BYTES} bytes");
    }
    Ok(None)
}

/// Iterate `\n`-separated lines of `head` as spans with any trailing
/// `\r` stripped.
fn lines(head: &[u8]) -> impl Iterator<Item = Span> + '_ {
    let mut start = 0usize;
    std::iter::from_fn(move || {
        if start >= head.len() {
            return None;
        }
        let nl = head[start..].iter().position(|&b| b == b'\n')?;
        let line = trim_cr(head, (start, start + nl));
        start += nl + 1;
        Some(line)
    })
}

fn trim_cr(buf: &[u8], line: Span) -> Span {
    if line.1 > line.0 && buf[line.1 - 1] == b'\r' {
        (line.0, line.1 - 1)
    } else {
        line
    }
}

/// Next whitespace-separated token in `buf[*pos..end]`.
fn token(buf: &[u8], pos: &mut usize, end: usize) -> Option<Span> {
    while *pos < end && (buf[*pos] == b' ' || buf[*pos] == b'\t') {
        *pos += 1;
    }
    let start = *pos;
    while *pos < end && buf[*pos] != b' ' && buf[*pos] != b'\t' {
        *pos += 1;
    }
    (*pos > start).then_some((start, *pos))
}

fn parse_header_line(buf: &[u8], line: Span) -> Result<(Span, Span)> {
    let bytes = span(buf, line);
    let Some(colon) = bytes.iter().position(|&b| b == b':') else {
        let text = String::from_utf8_lossy(bytes);
        bail!("malformed header line {text:?}");
    };
    let name = trim_span(buf, (line.0, line.0 + colon));
    let value = trim_span(buf, (line.0 + colon + 1, line.1));
    Ok((name, value))
}

fn trim_span(buf: &[u8], mut s: Span) -> Span {
    while s.0 < s.1 && buf[s.0].is_ascii_whitespace() {
        s.0 += 1;
    }
    while s.1 > s.0 && buf[s.1 - 1].is_ascii_whitespace() {
        s.1 -= 1;
    }
    s
}

/// Borrowed view of one request: spans resolved against the
/// connection's read buffer.  All accessors are zero-copy.
#[derive(Clone, Copy)]
pub struct Req<'b> {
    buf: &'b [u8],
    head: &'b Head,
}

impl<'b> Req<'b> {
    pub fn method(&self) -> &'b str {
        // The whole request line was UTF-8-validated at parse time.
        std::str::from_utf8(span(self.buf, self.head.method)).unwrap_or("")
    }

    pub fn path(&self) -> &'b str {
        std::str::from_utf8(span(self.buf, self.head.path)).unwrap_or("")
    }

    /// `true` for HTTP/1.1 (keep-alive by default), `false` for 1.0.
    pub fn http11(&self) -> bool {
        self.head.http11
    }

    /// Case-insensitive header lookup.  Non-UTF-8 values read as absent.
    pub fn header(&self, name: &str) -> Option<&'b str> {
        let raw = self.head.raw_header(self.buf, name)?;
        std::str::from_utf8(raw).ok()
    }

    /// The `Content-Length`-framed body.  The caller (the connection
    /// state machine) guarantees the buffer holds the full body before
    /// constructing the view.
    pub fn body(&self) -> &'b [u8] {
        let start = self.head.body_start.min(self.buf.len());
        let end = self.head.total_len().min(self.buf.len());
        &self.buf[start..end]
    }

    pub fn body_str(&self) -> Result<&'b str> {
        Ok(std::str::from_utf8(self.body())?)
    }

    /// Split the request target into path and query string (query is
    /// `""` when absent) — `path` is stored verbatim off the wire.
    pub fn path_and_query(&self) -> (&'b str, &'b str) {
        match self.path().split_once('?') {
            Some((p, q)) => (p, q),
            None => (self.path(), ""),
        }
    }

    /// Persistent-connection semantics: HTTP/1.1 keeps the connection
    /// open unless the client says `close`; HTTP/1.0 closes unless the
    /// client says `keep-alive`.  See [`connection_keep_alive`].
    pub fn wants_keep_alive(&self) -> bool {
        connection_keep_alive(self.header("connection"), self.http11())
    }
}

/// Decide persistence from a `Connection` header value.
///
/// RFC 9112 §9.3: the header is a comma-separated **token list**
/// (`Connection: keep-alive, upgrade`), so membership must be tested
/// per token, not against the whole string.  `close` anywhere in the
/// list wins over `keep-alive`; with neither token present the HTTP
/// version decides (1.1 persists, 1.0 closes).
pub fn connection_keep_alive(value: Option<&str>, http11: bool) -> bool {
    let Some(value) = value else { return http11 };
    let mut keep = None;
    for tok in value.split(',') {
        let tok = tok.trim();
        if tok.eq_ignore_ascii_case("close") {
            return false;
        }
        if tok.eq_ignore_ascii_case("keep-alive") {
            keep = Some(true);
        }
    }
    keep.unwrap_or(http11)
}

/// One response, serialized by [`Response::serialize_into`].
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    pub extra_headers: Vec<(String, String)>,
}

impl Response {
    pub fn json(status: u16, body: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.to_string().into_bytes(),
            extra_headers: Vec::new(),
        }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.as_bytes().to_vec(),
            extra_headers: Vec::new(),
        }
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.extra_headers.push((name.to_string(), value.to_string()));
        self
    }

    /// Serialize into a reusable write buffer (appends; callers clear).
    pub fn serialize_into(&self, keep_alive: bool, out: &mut Vec<u8>) {
        serialize_parts_into(
            self.status,
            self.content_type,
            &self.extra_headers,
            &self.body,
            keep_alive,
            out,
        );
    }

    /// Serialize with `Connection: close` (one-shot responses: tests,
    /// the pre-handler 503 path).
    pub fn write_to<W: Write>(&self, writer: &mut W) -> std::io::Result<()> {
        self.write_to_with(writer, false)
    }

    /// Serialize, advertising whether the server will keep the
    /// connection open for another request.
    pub fn write_to_with<W: Write>(&self, writer: &mut W, keep_alive: bool) -> std::io::Result<()> {
        let mut out = Vec::with_capacity(self.body.len() + 128);
        self.serialize_into(keep_alive, &mut out);
        writer.write_all(&out)?;
        writer.flush()
    }
}

/// Serialize a response from parts, so callers that render a body into
/// a reused scratch buffer (the `/metrics` fast path) never build a
/// `Response` with an owned body copy.
pub fn serialize_parts_into(
    status: u16,
    content_type: &str,
    extra_headers: &[(String, String)],
    body: &[u8],
    keep_alive: bool,
    out: &mut Vec<u8>,
) {
    // Writing to a Vec cannot fail; ignore the io::Result plumbing.
    let _ = write!(
        out,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        status,
        reason(status),
        content_type,
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (name, value) in extra_headers {
        let _ = write!(out, "{name}: {value}\r\n");
    }
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(body);
}

/// Reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Parse a complete request held in one buffer, mirroring what the
    /// event loop does incrementally.
    fn parse(raw: &str) -> Result<(Head, Vec<u8>)> {
        let mut buf = raw.as_bytes().to_vec();
        let mut head = Head::default();
        match head.parse(&mut buf)? {
            Parse::Complete if buf.len() >= head.total_len() => Ok((head, buf)),
            Parse::Complete => bail!("truncated body"),
            Parse::NeedMore => bail!("incomplete head"),
        }
    }

    #[test]
    fn splits_path_and_query() {
        let (head, buf) = parse("GET /debug/traces?n=4&format=chrome HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap();
        assert_eq!(
            head.req(&buf).path_and_query(),
            ("/debug/traces", "n=4&format=chrome")
        );
        let (head, buf) = parse("GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(head.req(&buf).path_and_query(), ("/healthz", ""));
    }

    #[test]
    fn parses_post_with_body() {
        let (head, buf) =
            parse("POST /v1/transform HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd")
                .unwrap();
        let req = head.req(&buf);
        assert_eq!(req.method(), "POST");
        assert_eq!(req.path(), "/v1/transform");
        assert_eq!(req.header("HOST"), Some("x"));
        assert_eq!(req.body(), b"abcd");
        assert_eq!(req.body_str().unwrap(), "abcd");
    }

    #[test]
    fn parses_get_without_body() {
        let (head, buf) = parse("GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let req = head.req(&buf);
        assert_eq!(req.method(), "GET");
        assert!(req.body().is_empty());
    }

    #[test]
    fn incremental_parse_waits_for_the_blank_line() {
        let raw = b"POST /v1/transform HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi";
        let mut head = Head::default();
        let mut buf = Vec::new();
        for (i, &b) in raw.iter().enumerate() {
            buf.push(b);
            let status = head.parse(&mut buf).unwrap();
            // Head completes at the final `\n` of the blank line.
            let head_done = i + 1 >= raw.len() - 2;
            assert_eq!(status == Parse::Complete, head_done, "byte {i}");
        }
        assert_eq!(head.content_length, 2);
        assert_eq!(head.req(&buf).body(), b"hi");
    }

    #[test]
    fn head_reuse_across_pipelined_requests() {
        let mut buf = b"GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\nX: y\r\n\r\n".to_vec();
        let mut head = Head::default();
        assert_eq!(head.parse(&mut buf).unwrap(), Parse::Complete);
        assert_eq!(head.req(&buf).path(), "/healthz");
        // The state machine consumes the framed request, then re-parses.
        buf.drain(..head.total_len());
        assert_eq!(head.parse(&mut buf).unwrap(), Parse::Complete);
        let req = head.req(&buf);
        assert_eq!(req.path(), "/metrics");
        assert_eq!(req.header("x"), Some("y"));
    }

    #[test]
    fn rejects_malformed_request_line() {
        assert!(parse("GETS-NO-PATH\r\n\r\n").is_err());
        assert!(parse("GET / SMTP/1.0\r\n\r\n").is_err());
        assert!(parse("\r\n\r\n").is_err());
    }

    #[test]
    fn rejects_bad_content_length_and_oversized_length() {
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: abc\r\n\r\n").is_err());
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: -1\r\n\r\n").is_err());
        let huge = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 1 << 30);
        assert!(parse(&huge).is_err());
    }

    #[test]
    fn rejects_unterminated_oversized_lines() {
        // A newline-free flood must error at the cap, not buffer forever.
        let flood = "A".repeat(64 << 10);
        assert!(parse(&flood).is_err());
        let header_flood = format!("GET / HTTP/1.1\r\nX-Junk: {flood}\r\n\r\n");
        assert!(parse(&header_flood).is_err());
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        Response::text(200, "ok\n")
            .with_header("Retry-After", "1")
            .write_to(&mut out)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 3\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.ends_with("\r\n\r\nok\n"));
    }

    #[test]
    fn keep_alive_semantics_follow_http_version_and_connection_header() {
        let wants = |raw: &str| {
            let (head, buf) = parse(raw).unwrap();
            head.req(&buf).wants_keep_alive()
        };
        // HTTP/1.1 defaults to keep-alive.
        assert!(wants("GET / HTTP/1.1\r\nHost: x\r\n\r\n"));
        assert!(!wants("GET / HTTP/1.1\r\nConnection: close\r\n\r\n"));
        // HTTP/1.0 defaults to close.
        assert!(!wants("GET / HTTP/1.0\r\nHost: x\r\n\r\n"));
        assert!(wants("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n"));
        // Case-insensitive header values.
        assert!(!wants("GET / HTTP/1.1\r\nConnection: Close\r\n\r\n"));
    }

    #[test]
    fn connection_header_token_lists_follow_rfc_9112() {
        // Membership is per comma-separated token, not whole-string.
        assert!(connection_keep_alive(Some("keep-alive, upgrade"), true));
        assert!(connection_keep_alive(Some("upgrade, keep-alive"), false));
        assert!(connection_keep_alive(Some("Keep-Alive , Upgrade"), false));
        // `close` anywhere in the list wins, in either order.
        assert!(!connection_keep_alive(Some("keep-alive, close"), true));
        assert!(!connection_keep_alive(Some("close, keep-alive"), true));
        assert!(!connection_keep_alive(Some("upgrade, Close"), true));
        // Unknown tokens alone fall back to the HTTP-version default.
        assert!(connection_keep_alive(Some("upgrade"), true));
        assert!(!connection_keep_alive(Some("upgrade"), false));
        // Degenerate values.
        assert!(connection_keep_alive(Some(""), true));
        assert!(!connection_keep_alive(Some(",,"), false));
        assert!(connection_keep_alive(None, true));
        assert!(!connection_keep_alive(None, false));
    }

    #[test]
    fn response_advertises_keep_alive_when_asked() {
        let mut out = Vec::new();
        Response::text(200, "ok\n").write_to_with(&mut out, true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Connection: keep-alive\r\n"), "{text}");
        let mut out = Vec::new();
        Response::text(200, "ok\n").write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Connection: close\r\n"), "{text}");
    }

    #[test]
    fn serialize_parts_matches_response_serialization() {
        let resp = Response::json(200, &crate::util::json::parse(r#"{"y":[1,2]}"#).unwrap());
        let mut whole = Vec::new();
        resp.serialize_into(true, &mut whole);
        let mut parts = Vec::new();
        serialize_parts_into(200, "application/json", &[], &resp.body, true, &mut parts);
        assert_eq!(whole, parts);
    }

    #[test]
    fn json_response_round_trips() {
        let body = crate::util::json::parse(r#"{"y":[1,2]}"#).unwrap();
        let resp = Response::json(200, &body);
        assert_eq!(resp.content_type, "application/json");
        let parsed = crate::util::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(parsed, body);
    }
}

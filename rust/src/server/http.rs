//! Minimal hand-rolled HTTP/1.1 parsing and response writing.
//!
//! The build box is offline, so no hyper/axum: this implements exactly
//! the subset the serving subsystem needs — persistent connections
//! (HTTP/1.1 keep-alive semantics, honoring `Connection: close` /
//! `keep-alive`), `Content-Length`-framed bodies, header lookup, and
//! deterministic wire formatting.  The per-connection request cap and
//! idle timeout live in the connection handler
//! ([`crate::server`]), which owns the socket.

use std::io::{BufRead, Read, Write};

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Hard cap on accepted bodies (JSON transform requests are small).
pub const MAX_BODY_BYTES: usize = 4 << 20;
/// Hard cap on the total header block.
const MAX_HEADER_BYTES: usize = 16 << 10;

/// One parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// `true` for HTTP/1.1 (keep-alive by default), `false` for 1.0.
    pub http11: bool,
    /// Header names are lower-cased at parse time.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> Result<&str> {
        Ok(std::str::from_utf8(&self.body)?)
    }

    /// Split the request target into path and query string (query is
    /// `""` when absent) — `path` is stored verbatim off the wire.
    pub fn path_and_query(&self) -> (&str, &str) {
        match self.path.split_once('?') {
            Some((p, q)) => (p, q),
            None => (self.path.as_str(), ""),
        }
    }

    /// Persistent-connection semantics: HTTP/1.1 keeps the connection
    /// open unless the client says `Connection: close`; HTTP/1.0 closes
    /// unless the client says `Connection: keep-alive`.
    pub fn wants_keep_alive(&self) -> bool {
        match self.header("connection") {
            Some(v) if v.eq_ignore_ascii_case("close") => false,
            Some(v) if v.eq_ignore_ascii_case("keep-alive") => true,
            _ => self.http11,
        }
    }
}

/// Read one `\n`-terminated line, erroring (instead of buffering without
/// bound) once it exceeds `limit` bytes.  `Ok(None)` on immediate EOF.
fn read_bounded_line<R: BufRead>(reader: &mut R, limit: usize) -> Result<Option<String>> {
    let mut line = String::new();
    let n = reader.by_ref().take(limit as u64 + 1).read_line(&mut line)?;
    if n == 0 {
        return Ok(None);
    }
    if n > limit {
        bail!("line longer than {limit} bytes");
    }
    Ok(Some(line))
}

/// Read one request from the stream.  Returns `Ok(None)` on a clean EOF
/// before any bytes (the peer closed an idle connection).
pub fn read_request<R: BufRead>(reader: &mut R) -> Result<Option<Request>> {
    let Some(line) = read_bounded_line(reader, MAX_HEADER_BYTES)? else {
        return Ok(None);
    };
    let request_line = line.trim_end();
    let mut parts = request_line.split_whitespace();
    let (Some(method), Some(path), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        bail!("malformed request line {request_line:?}");
    };
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol {version}");
    }

    let mut headers = Vec::new();
    let mut header_bytes = 0usize;
    loop {
        let Some(h) = read_bounded_line(reader, MAX_HEADER_BYTES)? else {
            bail!("connection closed inside the header block");
        };
        header_bytes += h.len();
        if header_bytes > MAX_HEADER_BYTES {
            bail!("header block larger than {MAX_HEADER_BYTES} bytes");
        }
        let trimmed = h.trim_end();
        if trimmed.is_empty() {
            break;
        }
        let Some((name, value)) = trimmed.split_once(':') else {
            bail!("malformed header line {trimmed:?}");
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    let content_length: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.parse())
        .transpose()?
        .unwrap_or(0);
    if content_length > MAX_BODY_BYTES {
        bail!("body of {content_length} bytes exceeds the {MAX_BODY_BYTES}-byte limit");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;

    Ok(Some(Request {
        method: method.to_string(),
        path: path.to_string(),
        http11: version == "HTTP/1.1",
        headers,
        body,
    }))
}

/// One response, serialized by [`Response::write_to`].
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    pub extra_headers: Vec<(String, String)>,
}

impl Response {
    pub fn json(status: u16, body: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.to_string().into_bytes(),
            extra_headers: Vec::new(),
        }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.as_bytes().to_vec(),
            extra_headers: Vec::new(),
        }
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.extra_headers.push((name.to_string(), value.to_string()));
        self
    }

    /// Serialize with `Connection: close` (one-shot responses: tests,
    /// the pre-handler 503 path).
    pub fn write_to<W: Write>(&self, writer: &mut W) -> std::io::Result<()> {
        self.write_to_with(writer, false)
    }

    /// Serialize, advertising whether the server will keep the
    /// connection open for another request.
    pub fn write_to_with<W: Write>(&self, writer: &mut W, keep_alive: bool) -> std::io::Result<()> {
        write!(
            writer,
            "HTTP/1.1 {} {}\r\n",
            self.status,
            reason(self.status)
        )?;
        write!(writer, "Content-Type: {}\r\n", self.content_type)?;
        write!(writer, "Content-Length: {}\r\n", self.body.len())?;
        write!(
            writer,
            "Connection: {}\r\n",
            if keep_alive { "keep-alive" } else { "close" }
        )?;
        for (name, value) in &self.extra_headers {
            write!(writer, "{name}: {value}\r\n")?;
        }
        writer.write_all(b"\r\n")?;
        writer.write_all(&self.body)?;
        writer.flush()
    }
}

/// Reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Option<Request>> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()))
    }

    #[test]
    fn splits_path_and_query() {
        let req = parse("GET /debug/traces?n=4&format=chrome HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(req.path_and_query(), ("/debug/traces", "n=4&format=chrome"));
        let plain = parse("GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap().unwrap();
        assert_eq!(plain.path_and_query(), ("/healthz", ""));
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse(
            "POST /v1/transform HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/transform");
        assert_eq!(req.header("HOST"), Some("x"));
        assert_eq!(req.body, b"abcd".to_vec());
        assert_eq!(req.body_str().unwrap(), "abcd");
    }

    #[test]
    fn parses_get_without_body() {
        let req = parse("GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn clean_eof_is_none() {
        assert!(parse("").unwrap().is_none());
    }

    #[test]
    fn rejects_malformed_request_line() {
        assert!(parse("GETS-NO-PATH\r\n\r\n").is_err());
        assert!(parse("GET / SMTP/1.0\r\n\r\n").is_err());
    }

    #[test]
    fn rejects_truncated_body_and_oversized_length() {
        assert!(parse("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").is_err());
        let huge = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 1 << 30);
        assert!(parse(&huge).is_err());
    }

    #[test]
    fn rejects_unterminated_oversized_lines() {
        // A newline-free flood must error at the cap, not buffer forever.
        let flood = "A".repeat(64 << 10);
        assert!(parse(&flood).is_err());
        let header_flood = format!("GET / HTTP/1.1\r\nX-Junk: {flood}\r\n\r\n");
        assert!(parse(&header_flood).is_err());
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        Response::text(200, "ok\n")
            .with_header("Retry-After", "1")
            .write_to(&mut out)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 3\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.ends_with("\r\n\r\nok\n"));
    }

    #[test]
    fn keep_alive_semantics_follow_http_version_and_connection_header() {
        let req = |raw: &str| parse(raw).unwrap().unwrap();
        // HTTP/1.1 defaults to keep-alive.
        assert!(req("GET / HTTP/1.1\r\nHost: x\r\n\r\n").wants_keep_alive());
        assert!(!req("GET / HTTP/1.1\r\nConnection: close\r\n\r\n").wants_keep_alive());
        // HTTP/1.0 defaults to close.
        assert!(!req("GET / HTTP/1.0\r\nHost: x\r\n\r\n").wants_keep_alive());
        assert!(req("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").wants_keep_alive());
        // Case-insensitive header values.
        assert!(!req("GET / HTTP/1.1\r\nConnection: Close\r\n\r\n").wants_keep_alive());
    }

    #[test]
    fn response_advertises_keep_alive_when_asked() {
        let mut out = Vec::new();
        Response::text(200, "ok\n")
            .write_to_with(&mut out, true)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Connection: keep-alive\r\n"), "{text}");
        let mut out = Vec::new();
        Response::text(200, "ok\n").write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Connection: close\r\n"), "{text}");
    }

    #[test]
    fn json_response_round_trips() {
        let body = crate::util::json::parse(r#"{"y":[1,2]}"#).unwrap();
        let resp = Response::json(200, &body);
        assert_eq!(resp.content_type, "application/json");
        let parsed = crate::util::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(parsed, body);
    }
}

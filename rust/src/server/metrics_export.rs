//! Prometheus-text-format rendering of the serving metrics.
//!
//! Exposes the shard set's merged cycle/energy accounting (row-cycles,
//! planes issued, early-termination savings, modelled TOPS/W from the
//! [`crate::energy::EnergyModel`]) plus per-shard labeled series and the
//! healthy-shard gauge, alongside the HTTP layer's admission counters
//! and latency histograms with p50/p95/p99 gauges.  Unlabeled
//! `repro_*` accelerator series are the sum over all shards.

use std::fmt::Write as _;
use std::sync::atomic::Ordering;

use crate::coordinator::LatencyHistogram;
use crate::monitor::FixedHistogram;

use super::ServerState;

fn fmt_f64(v: f64) -> String {
    if v.is_infinite() {
        "+Inf".to_string()
    } else {
        format!("{v}")
    }
}

fn counter_u64(out: &mut String, name: &str, help: &str, value: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {value}");
}

fn counter_f64(out: &mut String, name: &str, help: &str, value: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {}", fmt_f64(value));
}

fn gauge_f64(out: &mut String, name: &str, help: &str, value: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {}", fmt_f64(value));
}

fn histogram(out: &mut String, name: &str, help: &str, hist: &LatencyHistogram) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (bound, cumulative) in hist.cumulative_buckets() {
        let le = match bound {
            Some(us) => fmt_f64(us as f64 * 1e-6),
            None => "+Inf".to_string(),
        };
        let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cumulative}");
    }
    let _ = writeln!(out, "{name}_sum {}", fmt_f64(hist.sum_us() as f64 * 1e-6));
    let _ = writeln!(out, "{name}_count {}", hist.count());
    for (suffix, q) in [("p50", 0.5), ("p95", 0.95), ("p99", 0.99)] {
        gauge_f64(
            out,
            &format!("{name}_{suffix}"),
            &format!("Estimated {suffix} of {name} (upper bucket bound)."),
            hist.quantile_us(q) * 1e-6,
        );
    }
}

/// A fixed-bound divergence histogram ([`FixedHistogram`]), rendered
/// cumulatively like the latency histograms.
fn fixed_histogram(out: &mut String, name: &str, help: &str, hist: &FixedHistogram) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    let cumulative = hist.cumulative();
    for (i, &bound) in hist.bounds().iter().enumerate() {
        let _ = writeln!(
            out,
            "{name}_bucket{{le=\"{}\"}} {}",
            fmt_f64(bound),
            cumulative[i]
        );
    }
    let _ = writeln!(
        out,
        "{name}_bucket{{le=\"+Inf\"}} {}",
        cumulative[hist.bounds().len()]
    );
    let _ = writeln!(out, "{name}_sum {}", fmt_f64(hist.sum()));
    let _ = writeln!(out, "{name}_count {}", hist.count());
}

/// `backend_features` label value for `repro_build_info`: the compiled
/// feature set, so a scrape can tell apart otherwise identical builds.
fn backend_features() -> &'static str {
    match (
        cfg!(feature = "pjrt"),
        cfg!(feature = "trace-off"),
        cfg!(feature = "monitor-off"),
        cfg!(feature = "chaos"),
    ) {
        (true, true, true, true) => "pjrt,trace-off,monitor-off,chaos",
        (true, true, true, false) => "pjrt,trace-off,monitor-off",
        (true, true, false, true) => "pjrt,trace-off,chaos",
        (true, true, false, false) => "pjrt,trace-off",
        (true, false, true, true) => "pjrt,monitor-off,chaos",
        (true, false, true, false) => "pjrt,monitor-off",
        (true, false, false, true) => "pjrt,chaos",
        (true, false, false, false) => "pjrt",
        (false, true, true, true) => "trace-off,monitor-off,chaos",
        (false, true, true, false) => "trace-off,monitor-off",
        (false, true, false, true) => "trace-off,chaos",
        (false, true, false, false) => "trace-off",
        (false, false, true, true) => "monitor-off,chaos",
        (false, false, true, false) => "monitor-off",
        (false, false, false, true) => "chaos",
        (false, false, false, false) => "default",
    }
}

/// Render the full exposition document into a fresh `String`.
///
/// Tests and one-shot callers only; the serving path uses
/// [`render_into`] with a per-reactor scratch buffer so a scrape costs
/// zero steady-state allocation.
pub(crate) fn render(state: &ServerState) -> String {
    let mut out = String::new();
    render_into(state, &mut out);
    out
}

/// Render the full exposition document into `out` (cleared first).
///
/// The buffer is reused across scrapes — after the first scrape its
/// capacity covers the whole document and rendering allocates nothing.
/// The observed capacity feeds the `repro_metrics_buffer_bytes` gauge
/// (reported one scrape behind, since the document renders before its
/// own final size is known).
pub(crate) fn render_into(state: &ServerState, out: &mut String) {
    out.clear();
    let coord = state.shard_metrics.merged();
    let per_shard = state.shard_metrics.per_shard();
    let e2e = state.e2e_latency.lock().expect("latency poisoned").clone();

    // Build/process identity.
    let _ = writeln!(
        out,
        "# HELP repro_build_info Build metadata as labels (value is always 1)."
    );
    let _ = writeln!(out, "# TYPE repro_build_info gauge");
    let _ = writeln!(
        out,
        "repro_build_info{{version=\"{}\",git_sha=\"{}\",backend_features=\"{}\"}} 1",
        env!("CARGO_PKG_VERSION"),
        option_env!("REPRO_GIT_SHA").unwrap_or("unknown"),
        backend_features(),
    );
    gauge_f64(
        out,
        "repro_process_start_time_seconds",
        "Unix time the server process started.",
        state.started_unix_s,
    );
    gauge_f64(
        out,
        "repro_process_uptime_seconds",
        "Seconds since the server process started.",
        state.started.elapsed().as_secs_f64(),
    );

    // Accelerator accounting, merged across the shard set.
    counter_u64(
        out,
        "repro_requests_total",
        "Transform slices completed across the shard set (one per request per shard lane touched).",
        coord.requests,
    );
    counter_u64(
        out,
        "repro_pool_jobs_total",
        "Pool jobs executed across the shard set; requests/jobs is the router's slice-fusion factor.",
        coord.jobs,
    );
    counter_u64(
        out,
        "repro_planes_issued_total",
        "Tile-level bitplane operations issued.",
        coord.planes_issued,
    );
    counter_u64(
        out,
        "repro_row_cycles_total",
        "Row-cycles executed (energy-relevant granularity).",
        coord.row_cycles,
    );
    counter_u64(
        out,
        "repro_row_cycles_saved_total",
        "Row-cycles skipped by predictive early termination vs the no-ET baseline.",
        coord.row_cycles_saved(),
    );
    counter_u64(
        out,
        "repro_elements_total",
        "Output elements produced.",
        coord.cycles.total_elements,
    );
    counter_u64(
        out,
        "repro_elements_terminated_early_total",
        "Output elements that terminated before their last bitplane.",
        coord.cycles.terminated_early,
    );
    gauge_f64(
        out,
        "repro_avg_bitplane_cycles",
        "Average executed bitplane cycles per output element (paper Fig. 9c).",
        coord.average_cycles(),
    );
    counter_f64(
        out,
        "repro_energy_femtojoules_total",
        "Modelled crossbar energy for the work served (fJ).",
        coord.energy_fj(&state.energy),
    );
    gauge_f64(
        out,
        "repro_tops_per_watt",
        "Effective TOPS/W of the work served (paper Table I headline).",
        coord.tops_per_watt(&state.energy),
    );
    counter_f64(
        out,
        "repro_worker_busy_seconds_total",
        "Cumulative worker busy time across every shard's tile pool.",
        coord.busy.as_secs_f64(),
    );

    // Per-shard breakdown (slot-indexed; poisoned shards keep reporting
    // what they served before dying).
    gauge_f64(
        out,
        "repro_shards_healthy",
        "Shards currently accepting work.",
        state.shards_healthy.load(Ordering::Acquire) as f64,
    );
    gauge_f64(
        out,
        "repro_shards_total",
        "Shards the set was started with.",
        state.shard_metrics.shards() as f64,
    );
    counter_u64(
        out,
        "repro_shard_respawns_total",
        "Poisoned shards respawned by the serve loop's health tick.",
        state.shard_respawns.load(Ordering::Acquire),
    );
    // Circuit-breaker state machine, per shard slot: 0 = closed,
    // 1 = half-open (probing), 2 = open (shedding), plus the current
    // respawn backoff the heal pass honours for the slot.
    let breakers = state.breakers.snapshot();
    let _ = writeln!(
        out,
        "# HELP repro_shard_breaker_state Circuit breaker state, by shard (0=closed, 1=half-open, 2=open)."
    );
    let _ = writeln!(out, "# TYPE repro_shard_breaker_state gauge");
    for (s, b) in breakers.iter().enumerate() {
        let _ = writeln!(
            out,
            "repro_shard_breaker_state{{shard=\"{s}\"}} {}",
            b.state.code()
        );
    }
    let _ = writeln!(
        out,
        "# HELP repro_shard_breaker_failure_ewma Failure-rate EWMA driving the breaker, by shard."
    );
    let _ = writeln!(out, "# TYPE repro_shard_breaker_failure_ewma gauge");
    for (s, b) in breakers.iter().enumerate() {
        let _ = writeln!(
            out,
            "repro_shard_breaker_failure_ewma{{shard=\"{s}\"}} {}",
            fmt_f64(b.failure_ewma)
        );
    }
    let _ = writeln!(
        out,
        "# HELP repro_shard_respawn_backoff_seconds Current respawn backoff the heal pass honours, by shard."
    );
    let _ = writeln!(out, "# TYPE repro_shard_respawn_backoff_seconds gauge");
    for (s, b) in breakers.iter().enumerate() {
        let _ = writeln!(
            out,
            "repro_shard_respawn_backoff_seconds{{shard=\"{s}\"}} {}",
            fmt_f64(b.respawn_backoff.as_secs_f64())
        );
    }
    let _ = writeln!(
        out,
        "# HELP repro_shard_requests_total Transform slices completed, by shard."
    );
    let _ = writeln!(out, "# TYPE repro_shard_requests_total counter");
    for (s, m) in per_shard.iter().enumerate() {
        let _ = writeln!(out, "repro_shard_requests_total{{shard=\"{s}\"}} {}", m.requests);
    }
    let _ = writeln!(
        out,
        "# HELP repro_shard_row_cycles_total Row-cycles executed, by shard."
    );
    let _ = writeln!(out, "# TYPE repro_shard_row_cycles_total counter");
    for (s, m) in per_shard.iter().enumerate() {
        let _ = writeln!(
            out,
            "repro_shard_row_cycles_total{{shard=\"{s}\"}} {}",
            m.row_cycles
        );
    }
    let _ = writeln!(
        out,
        "# HELP repro_shard_busy_seconds_total Worker busy time, by shard."
    );
    let _ = writeln!(out, "# TYPE repro_shard_busy_seconds_total counter");
    for (s, m) in per_shard.iter().enumerate() {
        let _ = writeln!(
            out,
            "repro_shard_busy_seconds_total{{shard=\"{s}\"}} {}",
            fmt_f64(m.busy.as_secs_f64())
        );
    }
    // Per-shard energy telemetry: the same energy model applied to each
    // slot's own cycle accounting, so a heterogeneous set (e.g. one
    // noisy canary among digital shards) shows its per-slot efficiency
    // live instead of only the merged aggregate.
    let _ = writeln!(
        out,
        "# HELP repro_shard_energy_femtojoules_total Modelled crossbar energy for the work served, by shard (fJ)."
    );
    let _ = writeln!(out, "# TYPE repro_shard_energy_femtojoules_total counter");
    for (s, m) in per_shard.iter().enumerate() {
        let _ = writeln!(
            out,
            "repro_shard_energy_femtojoules_total{{shard=\"{s}\"}} {}",
            fmt_f64(m.energy_fj(&state.energy))
        );
    }
    let _ = writeln!(
        out,
        "# HELP repro_shard_tops_per_watt Effective TOPS/W of the work served, by shard."
    );
    let _ = writeln!(out, "# TYPE repro_shard_tops_per_watt gauge");
    for (s, m) in per_shard.iter().enumerate() {
        let _ = writeln!(
            out,
            "repro_shard_tops_per_watt{{shard=\"{s}\"}} {}",
            fmt_f64(m.tops_per_watt(&state.energy))
        );
    }
    let _ = writeln!(
        out,
        "# HELP repro_shard_avg_bitplane_cycles Average executed bitplane cycles per output element, by shard."
    );
    let _ = writeln!(out, "# TYPE repro_shard_avg_bitplane_cycles gauge");
    for (s, m) in per_shard.iter().enumerate() {
        let _ = writeln!(
            out,
            "repro_shard_avg_bitplane_cycles{{shard=\"{s}\"}} {}",
            fmt_f64(m.average_cycles())
        );
    }

    // HTTP front-end counters.
    counter_u64(
        out,
        "repro_http_requests_ok_total",
        "Transform requests answered with 200.",
        state.requests_ok.load(Ordering::Relaxed),
    );
    counter_u64(
        out,
        "repro_http_bad_requests_total",
        "Requests rejected with 400 (malformed payloads).",
        state.bad_requests.load(Ordering::Relaxed),
    );
    counter_u64(
        out,
        "repro_http_admitted_total",
        "Requests admitted past admission control.",
        state.admission.admitted_total(),
    );
    let _ = writeln!(
        out,
        "# HELP repro_http_shed_total Requests shed with 429 by admission control."
    );
    let _ = writeln!(out, "# TYPE repro_http_shed_total counter");
    let _ = writeln!(
        out,
        "repro_http_shed_total{{reason=\"overload\"}} {}",
        state.admission.shed_overload_total()
    );
    let _ = writeln!(
        out,
        "repro_http_shed_total{{reason=\"rate_limited\"}} {}",
        state.admission.shed_ratelimited_total()
    );
    gauge_f64(
        out,
        "repro_inflight_requests",
        "Requests currently between admission and reply.",
        state.admission.inflight() as f64,
    );
    counter_u64(
        out,
        "repro_batches_total",
        "Micro-batches dispatched into the coordinator.",
        state.batches_total.load(Ordering::Relaxed),
    );
    counter_u64(
        out,
        "repro_stale_dropped_total",
        "Queued requests dropped because their client timed out first.",
        state.stale_dropped_total.load(Ordering::Relaxed),
    );
    counter_u64(
        out,
        "repro_requests_deadline_expired_total",
        "Requests whose end-to-end deadline expired before a reply (queue shed, post-execution discard or connection timeout).",
        state.deadline_expired_total.load(Ordering::Relaxed),
    );
    let _ = writeln!(
        out,
        "# HELP repro_requests_dropped_total Requests answered 504 without a real reply, by reason."
    );
    let _ = writeln!(out, "# TYPE repro_requests_dropped_total counter");
    let _ = writeln!(
        out,
        "repro_requests_dropped_total{{reason=\"reply_dropped\"}} {}",
        state.dropped_reply_total.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        out,
        "repro_requests_dropped_total{{reason=\"deadline\"}} {}",
        state.dropped_deadline_total.load(Ordering::Relaxed)
    );
    gauge_f64(
        out,
        "repro_server_draining",
        "Whether a graceful drain is in progress (1) or not (0).",
        f64::from(u8::from(state.draining.load(Ordering::Acquire))),
    );
    gauge_f64(
        out,
        "repro_open_connections",
        "Currently open HTTP connections.",
        state.connections.load(Ordering::Relaxed) as f64,
    );
    // Event-loop connection accounting (repro_connections_open repeats
    // repro_open_connections under the family's canonical name; the old
    // gauge stays for dashboard compatibility).
    gauge_f64(
        out,
        "repro_connections_open",
        "Connections currently registered with the reactors.",
        state.connections.load(Ordering::Relaxed) as f64,
    );
    counter_u64(
        out,
        "repro_connections_accepted_total",
        "Connections accepted and registered by the reactors.",
        state.connections_accepted.load(Ordering::Relaxed),
    );
    counter_u64(
        out,
        "repro_connections_timed_out_total",
        "Connections closed by an idle, slowloris or write deadline.",
        state.connections_timed_out.load(Ordering::Relaxed),
    );
    gauge_f64(
        out,
        "repro_metrics_buffer_bytes",
        "High-water capacity of the reused /metrics render buffer (previous scrapes).",
        state.metrics_buf_hwm.load(Ordering::Relaxed) as f64,
    );
    gauge_f64(
        out,
        "repro_ratelimit_tracked_clients",
        "Client token buckets currently tracked by the rate limiter.",
        state.admission.tracked_clients() as f64,
    );

    // NN inference over the hosted model (/v1/infer).
    counter_u64(
        out,
        "repro_infer_requests_total",
        "Inference requests answered with 200.",
        state.infer_requests_ok.load(Ordering::Relaxed),
    );
    counter_u64(
        out,
        "repro_infer_samples_total",
        "Samples pushed through the hosted model.",
        state.infer_samples_total.load(Ordering::Relaxed),
    );
    counter_u64(
        out,
        "repro_infer_batches_total",
        "Coalesced model forward passes dispatched by the batcher.",
        state.infer_batches_total.load(Ordering::Relaxed),
    );

    // Latency distributions.
    histogram(
        out,
        "repro_request_latency_seconds",
        "End-to-end request latency (enqueue to reply fan-out).",
        &e2e,
    );
    histogram(
        out,
        "repro_infer_latency_seconds",
        "End-to-end inference latency (enqueue to logits fan-out).",
        &state
            .infer_latency
            .lock()
            .expect("latency poisoned")
            .clone(),
    );
    histogram(
        out,
        "repro_worker_latency_seconds",
        "Per-request worker busy time inside the tile pool.",
        &coord.latency,
    );

    // Request tracing: per-stage latency attribution over sampled
    // requests, plus execution-shape counters folded out of the traces.
    // One HELP/TYPE pair, then the per-stage labeled series — the label
    // is part of the same `repro_stage_seconds` metric family.
    let _ = writeln!(
        out,
        "# HELP repro_stage_seconds Per-stage latency of sampled traced requests."
    );
    let _ = writeln!(out, "# TYPE repro_stage_seconds histogram");
    let stage_hists = state.tracer.stage_histograms();
    for (stage, hist) in &stage_hists {
        for (bound, cumulative) in hist.cumulative_buckets() {
            let le = match bound {
                Some(us) => fmt_f64(us as f64 * 1e-6),
                None => "+Inf".to_string(),
            };
            let _ = writeln!(
                out,
                "repro_stage_seconds_bucket{{stage=\"{stage}\",le=\"{le}\"}} {cumulative}"
            );
        }
        let _ = writeln!(
            out,
            "repro_stage_seconds_sum{{stage=\"{stage}\"}} {}",
            fmt_f64(hist.sum_us() as f64 * 1e-6)
        );
        let _ = writeln!(
            out,
            "repro_stage_seconds_count{{stage=\"{stage}\"}} {}",
            hist.count()
        );
    }
    counter_u64(
        out,
        "repro_traces_sampled_total",
        "Requests that drew an active trace at admission.",
        state.tracer.sampled_total(),
    );
    counter_u64(
        out,
        "repro_trace_slow_requests_total",
        "Traced requests that exceeded the --slow-ms threshold.",
        state.tracer.slow_total(),
    );
    counter_u64(
        out,
        "repro_trace_planes_total",
        "Bitplane operations observed inside traced execute spans.",
        state.tracer.planes_total(),
    );
    counter_u64(
        out,
        "repro_trace_elements_total",
        "Output elements observed inside traced execute spans.",
        state.tracer.elements_total(),
    );
    counter_u64(
        out,
        "repro_trace_elements_terminated_total",
        "Traced output elements that early-terminated before their last bitplane.",
        state.tracer.terminated_total(),
    );

    // Fidelity monitor: shadow-verification volume, per-slot drift EWMAs
    // and the divergence distributions.  A disabled monitor renders the
    // same families with zero values (and no per-slot series), so the
    // exposition shape is stable across configurations.
    let monitor = &state.monitor;
    gauge_f64(
        out,
        "repro_fidelity_enabled",
        "Whether the fidelity monitor is active (1) or disabled (0).",
        f64::from(u8::from(monitor.is_enabled())),
    );
    gauge_f64(
        out,
        "repro_fidelity_sample_every",
        "Shadow-verify 1 in this many slices served by non-digital shards (0 = off).",
        f64::from(monitor.sample_every()),
    );
    gauge_f64(
        out,
        "repro_fidelity_drift_threshold",
        "Drift threshold on the per-slot divergence EWMA (quantizer LSBs).",
        monitor.drift_threshold(),
    );
    counter_u64(
        out,
        "repro_fidelity_checked_total",
        "Sampled slices re-executed through the digital golden path.",
        monitor.checked_total(),
    );
    counter_u64(
        out,
        "repro_fidelity_dropped_total",
        "Sampled slices dropped because the shadow queue was full (oldest first).",
        monitor.dropped_total(),
    );
    counter_u64(
        out,
        "repro_fidelity_flagged_total",
        "Shard slots flagged as drifting by the EWMA detector.",
        monitor.flagged_total(),
    );
    counter_u64(
        out,
        "repro_fidelity_check_errors_total",
        "Shadow checks that failed to execute (golden-path errors).",
        monitor.check_errors_total(),
    );
    counter_u64(
        out,
        "repro_shard_drift_respawns_total",
        "Drifting shard slots recycled (poisoned + respawned) by the health tick.",
        monitor.drift_respawns_total(),
    );
    let slots = monitor.slots();
    let _ = writeln!(
        out,
        "# HELP repro_fidelity_drift_ewma Divergence EWMA (mean |dq| per element, quantizer LSBs), by shard."
    );
    let _ = writeln!(out, "# TYPE repro_fidelity_drift_ewma gauge");
    for s in &slots {
        let _ = writeln!(
            out,
            "repro_fidelity_drift_ewma{{shard=\"{}\"}} {}",
            s.shard,
            fmt_f64(s.ewma)
        );
    }
    let _ = writeln!(
        out,
        "# HELP repro_fidelity_slot_flagged Whether the slot is currently marked drifting, by shard."
    );
    let _ = writeln!(out, "# TYPE repro_fidelity_slot_flagged gauge");
    for s in &slots {
        let _ = writeln!(
            out,
            "repro_fidelity_slot_flagged{{shard=\"{}\"}} {}",
            s.shard,
            u8::from(s.flagged)
        );
    }
    let (delta_hist, mismatch_hist) = monitor.histograms();
    fixed_histogram(
        out,
        "repro_fidelity_mean_abs_dq",
        "Mean |dq| per element of shadow-checked slices (quantizer LSBs).",
        &delta_hist,
    );
    fixed_histogram(
        out,
        "repro_fidelity_block_mismatch_fraction",
        "Per-block fraction of elements off the golden lattice by more than half an LSB.",
        &mismatch_hist,
    );
    state
        .metrics_buf_hwm
        .fetch_max(out.capacity(), Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, CoordinatorConfig, TransformRequest};
    use crate::energy::EnergyModel;
    use crate::monitor::Monitor;
    use crate::server::admission::AdmissionConfig;
    use crate::shard::MetricsAggregator;
    use crate::trace::{TraceConfig, Tracer};
    use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize};
    use std::sync::Arc;
    use std::time::Duration;

    fn metric_value(text: &str, name: &str) -> f64 {
        text.lines()
            .find_map(|line| {
                let rest = line.strip_prefix(name)?;
                let rest = rest.strip_prefix(' ')?;
                rest.trim().parse::<f64>().ok()
            })
            .unwrap_or(f64::NAN)
    }

    #[test]
    fn renders_live_coordinator_state() {
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        let state = Arc::new(ServerState::new(
            AdmissionConfig::default(),
            MetricsAggregator::new(vec![coord.metrics_handle()], 8),
            Arc::new(AtomicUsize::new(1)),
            Arc::new(AtomicU64::new(0)),
            Arc::new(vec![AtomicBool::new(true)]),
            EnergyModel::new(16, 0.8),
            Arc::new(Tracer::new(TraceConfig::default())),
            Arc::new(Monitor::disabled()),
        ));
        // One full-precision request and one that early-terminates.
        let x: Vec<f32> = (0..16).map(|i| ((i + 1) as f32 * 0.21).sin()).collect();
        coord
            .transform(&TransformRequest {
                x: x.clone(),
                thresholds_units: vec![0.0; 16],
                scale: None,
                deadline: None,
            })
            .unwrap();
        coord
            .transform(&TransformRequest {
                x,
                thresholds_units: vec![1e9; 16],
                scale: None,
                deadline: None,
            })
            .unwrap();
        state.record_latency(Duration::from_micros(300));
        coord.shutdown();

        let text = render(&state);
        assert_eq!(metric_value(&text, "repro_requests_total"), 2.0, "{text}");
        assert_eq!(metric_value(&text, "repro_pool_jobs_total"), 2.0, "{text}");
        assert!(metric_value(&text, "repro_row_cycles_saved_total") > 0.0);
        assert!(metric_value(&text, "repro_tops_per_watt") > 0.0);
        assert!(metric_value(&text, "repro_request_latency_seconds_p50") > 0.0);
        assert!(metric_value(&text, "repro_request_latency_seconds_p99") > 0.0);
        assert!(text.contains("# TYPE repro_request_latency_seconds histogram"));
        assert!(text.contains("repro_request_latency_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("repro_http_shed_total{reason=\"overload\"} 0"));
        assert_eq!(metric_value(&text, "repro_shards_healthy"), 1.0, "{text}");
        assert!(text.contains("repro_shard_requests_total{shard=\"0\"} 2"), "{text}");
    }

    #[test]
    fn renders_build_info_process_gauges_and_stage_series() {
        let coord = Coordinator::new(CoordinatorConfig::default());
        let state = Arc::new(ServerState::new(
            AdmissionConfig::default(),
            MetricsAggregator::new(vec![coord.metrics_handle()], 8),
            Arc::new(AtomicUsize::new(1)),
            Arc::new(AtomicU64::new(0)),
            Arc::new(vec![AtomicBool::new(true)]),
            EnergyModel::new(16, 0.8),
            Arc::new(Tracer::new(TraceConfig::default())),
            Arc::new(Monitor::disabled()),
        ));
        coord.shutdown();
        let text = render(&state);
        let version = env!("CARGO_PKG_VERSION");
        assert!(
            text.contains(&format!("repro_build_info{{version=\"{version}\",git_sha=\"")),
            "{text}"
        );
        assert!(metric_value(&text, "repro_process_start_time_seconds") > 0.0);
        assert!(metric_value(&text, "repro_process_uptime_seconds") >= 0.0);
        // The stage family renders every stage (zero-count included), with
        // exactly one HELP/TYPE pair for the whole labeled family.
        assert!(text.contains("# TYPE repro_stage_seconds histogram"));
        for stage in ["admission", "queue", "plan", "scatter", "pool_queue", "execute", "drain", "respond"]
        {
            assert!(
                text.contains(&format!(
                    "repro_stage_seconds_bucket{{stage=\"{stage}\",le=\"+Inf\"}} 0"
                )),
                "missing {stage} series in {text}"
            );
        }
        assert_eq!(
            text.matches("# TYPE repro_stage_seconds histogram").count(),
            1
        );
        assert_eq!(metric_value(&text, "repro_traces_sampled_total"), 0.0);
    }

    #[cfg(not(feature = "trace-off"))]
    #[test]
    fn finished_traces_land_in_stage_histograms_and_counters() {
        use crate::trace::{ExecStats, Stage};
        let coord = Coordinator::new(CoordinatorConfig::default());
        let tracer = Arc::new(Tracer::new(TraceConfig::default()));
        let state = Arc::new(ServerState::new(
            AdmissionConfig::default(),
            MetricsAggregator::new(vec![coord.metrics_handle()], 8),
            Arc::new(AtomicUsize::new(1)),
            Arc::new(AtomicU64::new(0)),
            Arc::new(vec![AtomicBool::new(true)]),
            EnergyModel::new(16, 0.8),
            Arc::clone(&tracer),
            Arc::new(Monitor::disabled()),
        ));
        coord.shutdown();
        let handle = tracer.begin("/v1/transform");
        handle.record(Stage::Admission, 10, 50);
        handle.record_exec(
            100,
            400,
            0,
            ExecStats {
                planes: 6,
                row_cycles: 96,
                elements: 16,
                terminated_early: 4,
            },
        );
        tracer.finish(handle);
        let text = render(&state);
        assert!(
            text.contains("repro_stage_seconds_count{stage=\"execute\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("repro_stage_seconds_count{stage=\"admission\"} 1"),
            "{text}"
        );
        assert_eq!(metric_value(&text, "repro_traces_sampled_total"), 1.0);
        assert_eq!(metric_value(&text, "repro_trace_planes_total"), 6.0);
        assert_eq!(metric_value(&text, "repro_trace_elements_total"), 16.0);
        assert_eq!(
            metric_value(&text, "repro_trace_elements_terminated_total"),
            4.0
        );
    }

    #[test]
    fn renders_per_shard_series_for_a_multi_shard_set() {
        use crate::shard::{router, ShardSet, ShardSetConfig};
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            ..Default::default()
        })
        .unwrap();
        let x: Vec<f32> = (0..64).map(|i| ((i + 1) as f32 * 0.13).sin()).collect();
        router::transform(
            &mut set,
            &TransformRequest {
                x,
                thresholds_units: vec![0.0; 64],
                scale: None,
                deadline: None,
            },
        )
        .unwrap();
        let state = Arc::new(ServerState::new(
            AdmissionConfig::default(),
            set.aggregator(),
            set.health_handle(),
            set.respawns_handle(),
            set.slot_health_handle(),
            EnergyModel::new(16, 0.8),
            Arc::new(Tracer::new(TraceConfig::default())),
            Arc::new(Monitor::disabled()),
        ));
        set.shutdown();
        let text = render(&state);
        assert_eq!(metric_value(&text, "repro_shards_total"), 2.0, "{text}");
        // Both shards served slices of the 4-block request.
        assert!(text.contains("repro_shard_requests_total{shard=\"0\"}"), "{text}");
        assert!(text.contains("repro_shard_requests_total{shard=\"1\"}"), "{text}");
        assert!(
            metric_value(&text, "repro_elements_total") >= 64.0,
            "{text}"
        );
        // Per-shard energy telemetry rides the same per_shard snapshots.
        assert!(
            text.contains("repro_shard_energy_femtojoules_total{shard=\"1\"}"),
            "{text}"
        );
        assert!(
            text.contains("repro_shard_tops_per_watt{shard=\"0\"}"),
            "{text}"
        );
        assert!(
            text.contains("repro_shard_avg_bitplane_cycles{shard=\"0\"}"),
            "{text}"
        );
    }

    #[test]
    fn disabled_monitor_renders_zeroed_fidelity_families() {
        let coord = Coordinator::new(CoordinatorConfig::default());
        let state = Arc::new(ServerState::new(
            AdmissionConfig::default(),
            MetricsAggregator::new(vec![coord.metrics_handle()], 8),
            Arc::new(AtomicUsize::new(1)),
            Arc::new(AtomicU64::new(0)),
            Arc::new(vec![AtomicBool::new(true)]),
            EnergyModel::new(16, 0.8),
            Arc::new(Tracer::new(TraceConfig::default())),
            Arc::new(Monitor::disabled()),
        ));
        coord.shutdown();
        let text = render(&state);
        assert_eq!(metric_value(&text, "repro_fidelity_enabled"), 0.0, "{text}");
        assert_eq!(metric_value(&text, "repro_fidelity_checked_total"), 0.0);
        assert_eq!(metric_value(&text, "repro_shard_drift_respawns_total"), 0.0);
        // The histogram families keep their full bucket structure.
        assert!(
            text.contains("repro_fidelity_mean_abs_dq_bucket{le=\"+Inf\"} 0"),
            "{text}"
        );
        assert!(
            text.contains("repro_fidelity_block_mismatch_fraction_bucket{le=\"+Inf\"} 0"),
            "{text}"
        );
        assert!(text.contains("# TYPE repro_fidelity_drift_ewma gauge"));
    }

    #[test]
    fn render_into_reuses_the_buffer_and_tracks_connection_series() {
        let coord = Coordinator::new(CoordinatorConfig::default());
        let state = Arc::new(ServerState::new(
            AdmissionConfig::default(),
            MetricsAggregator::new(vec![coord.metrics_handle()], 8),
            Arc::new(AtomicUsize::new(1)),
            Arc::new(AtomicU64::new(0)),
            Arc::new(vec![AtomicBool::new(true)]),
            EnergyModel::new(16, 0.8),
            Arc::new(Tracer::new(TraceConfig::default())),
            Arc::new(Monitor::disabled()),
        ));
        coord.shutdown();
        state.connections.fetch_add(2, Ordering::Relaxed);
        state.connections_accepted.fetch_add(3, Ordering::Relaxed);
        state.connections_timed_out.fetch_add(1, Ordering::Relaxed);
        let mut buf = String::new();
        render_into(&state, &mut buf);
        let cap = buf.capacity();
        assert_eq!(metric_value(&buf, "repro_connections_open"), 2.0, "{buf}");
        assert_eq!(metric_value(&buf, "repro_open_connections"), 2.0);
        assert_eq!(metric_value(&buf, "repro_connections_accepted_total"), 3.0);
        assert_eq!(metric_value(&buf, "repro_connections_timed_out_total"), 1.0);
        // The first scrape reports a zero high-water (nothing recorded
        // yet when the gauge rendered); the second reports the first's
        // capacity, and the buffer is reused rather than regrown.
        assert_eq!(metric_value(&buf, "repro_metrics_buffer_bytes"), 0.0);
        render_into(&state, &mut buf);
        assert_eq!(metric_value(&buf, "repro_metrics_buffer_bytes"), cap as f64);
        assert!(buf.capacity() >= cap);
    }

    #[test]
    fn renders_breaker_deadline_and_drop_families() {
        use std::time::Instant;
        let coord = Coordinator::new(CoordinatorConfig::default());
        let state = Arc::new(ServerState::new(
            AdmissionConfig::default(),
            MetricsAggregator::new(vec![coord.metrics_handle()], 8),
            Arc::new(AtomicUsize::new(2)),
            Arc::new(AtomicU64::new(0)),
            Arc::new(vec![AtomicBool::new(true), AtomicBool::new(true)]),
            EnergyModel::new(16, 0.8),
            Arc::new(Tracer::new(TraceConfig::default())),
            Arc::new(Monitor::disabled()),
        ));
        coord.shutdown();
        state.deadline_expired_total.fetch_add(3, Ordering::Relaxed);
        state.dropped_reply_total.fetch_add(2, Ordering::Relaxed);
        state.dropped_deadline_total.fetch_add(1, Ordering::Relaxed);
        state.breakers.force_open(1, Instant::now());
        let text = render(&state);
        assert_eq!(
            metric_value(&text, "repro_requests_deadline_expired_total"),
            3.0,
            "{text}"
        );
        assert!(
            text.contains("repro_requests_dropped_total{reason=\"reply_dropped\"} 2"),
            "{text}"
        );
        assert!(
            text.contains("repro_requests_dropped_total{reason=\"deadline\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("repro_shard_breaker_state{shard=\"0\"} 0"),
            "{text}"
        );
        assert!(
            text.contains("repro_shard_breaker_state{shard=\"1\"} 2"),
            "{text}"
        );
        assert!(
            text.contains("repro_shard_respawn_backoff_seconds{shard=\"0\"} 0"),
            "{text}"
        );
        assert_eq!(metric_value(&text, "repro_server_draining"), 0.0);
        state.draining.store(true, Ordering::SeqCst);
        let text = render(&state);
        assert_eq!(metric_value(&text, "repro_server_draining"), 1.0);
    }

    #[cfg(not(feature = "monitor-off"))]
    #[test]
    fn enabled_monitor_renders_per_slot_drift_series() {
        use crate::monitor::MonitorConfig;
        let coord = Coordinator::new(CoordinatorConfig::default());
        let slot_health: Arc<Vec<AtomicBool>> =
            Arc::new(vec![AtomicBool::new(true), AtomicBool::new(true)]);
        let monitor = Arc::new(Monitor::start(
            MonitorConfig {
                sample_every: 4,
                drift_threshold: 2.5,
                ..Default::default()
            },
            CoordinatorConfig::default(),
            vec![false, true],
            Arc::clone(&slot_health),
        ));
        assert!(monitor.is_enabled());
        let state = Arc::new(ServerState::new(
            AdmissionConfig::default(),
            MetricsAggregator::new(vec![coord.metrics_handle()], 8),
            Arc::new(AtomicUsize::new(1)),
            Arc::new(AtomicU64::new(0)),
            slot_health,
            EnergyModel::new(16, 0.8),
            Arc::new(Tracer::new(TraceConfig::default())),
            monitor,
        ));
        coord.shutdown();
        let text = render(&state);
        assert_eq!(metric_value(&text, "repro_fidelity_enabled"), 1.0, "{text}");
        assert_eq!(metric_value(&text, "repro_fidelity_sample_every"), 4.0);
        assert_eq!(metric_value(&text, "repro_fidelity_drift_threshold"), 2.5);
        assert!(
            text.contains("repro_fidelity_drift_ewma{shard=\"0\"} 0"),
            "{text}"
        );
        assert!(
            text.contains("repro_fidelity_drift_ewma{shard=\"1\"} 0"),
            "{text}"
        );
        assert!(
            text.contains("repro_fidelity_slot_flagged{shard=\"1\"} 0"),
            "{text}"
        );
    }
}

//! The reactor: an epoll-driven connection state machine.
//!
//! Each reactor thread owns one [`Epoll`] instance, a slab of
//! connections, a [`TimerWheel`] and a [`Completions`] queue, and
//! multiplexes every connection it accepted over nonblocking sockets:
//!
//! ```text
//!   Accept ──▶ ReadHead ──▶ ReadBody ──▶ route ──┬─▶ Write ──▶ KeepAlive
//!                  ▲                             │      │          │
//!                  │        (batcher reply via   └─▶ Await ─▶ Write │
//!                  │         eventfd completion) ────────┘          │
//!                  └────────────────────────────────────────────────┘
//! ```
//!
//! * **Zero-copy parsing** — socket bytes land in a per-connection
//!   reusable read buffer; [`http::Head::parse`] frames requests in
//!   place and the borrowed [`http::Req`] view feeds the router without
//!   allocating per-request strings.  Responses serialize into a
//!   reusable write buffer.
//! * **Asynchronous dispatch** — admitted POST work is handed to the
//!   batcher with an event [`ReplySink`]; the connection parks in
//!   `Await` (no readiness interest, matching the old blocking server
//!   which never cancelled work on peer close) until the completion
//!   queue delivers the reply and the reactor resumes it.
//! * **Deadlines** — one coarse timer wheel enforces the first-request
//!   (slowloris), keep-alive idle, in-flight (504) and write-stall
//!   deadlines.  Wheel entries are hints validated against the
//!   connection's live deadline, so re-arming is free.
//! * **Identity** — slab slots carry a generation counter; every epoll
//!   and completion token packs `(slot, gen, seq)` so events for a
//!   closed (reused) connection or a superseded request are ignored.
//!
//! Several reactors share the listener via `EPOLLEXCLUSIVE`, each
//! accepting (and then exclusively owning) a share of the connections.

use std::io::{self, Read as _, Write as _};
use std::net::{IpAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::batcher::{BatchItem, ReplyResult, ReplySink};
use super::http;
use super::reactor::{interest, Completion, Completions, Epoll, Event, TimerWheel};
use super::{
    error_json, finish_trace, render_reply, route_request, Dispatch, PendingKind, RouteOutcome,
    ServerConfig, ServerState,
};
use crate::chaos::ChaosPoint;
use crate::server::admission::InflightPermit;
use crate::trace::{self, TraceHandle};

/// Epoll token of the shared listener.
const TOKEN_LISTENER: u64 = u64::MAX;
/// Epoll token of the completion-queue waker.
const TOKEN_WAKER: u64 = u64::MAX - 1;

/// Timer wheel tick; deadlines round up to the next tick.
const WHEEL_GRANULARITY: Duration = Duration::from_millis(10);
/// Wheel size: ~10s horizon; later deadlines clamp and revalidate.
const WHEEL_BUCKETS: usize = 1024;

/// Pack a connection identity into an epoll/completion token.
fn pack(slot: u32, gen: u16, seq: u16) -> u64 {
    slot as u64 | (gen as u64) << 32 | (seq as u64) << 48
}

fn unpack(token: u64) -> (u32, u16, u16) {
    (token as u32, (token >> 32) as u16, (token >> 48) as u16)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ConnState {
    /// Accumulating bytes until the head's blank line.
    ReadHead,
    /// Head framed; accumulating the `Content-Length` body.
    ReadBody,
    /// Parked on the batcher; resumed by a completion (or its deadline).
    Await,
    /// Draining the serialized response to the socket.
    Write,
}

/// What a parked connection needs to finish its in-flight request.
struct Pending {
    kind: PendingKind,
    trace: TraceHandle,
    permit: InflightPermit,
}

struct Conn {
    stream: TcpStream,
    peer: IpAddr,
    /// Bumped per dispatched request; completion tokens must match.
    seq: u16,
    state: ConnState,
    /// Reusable read buffer; requests parse zero-copy out of it.
    rbuf: Vec<u8>,
    /// Reused parsed-head spans into `rbuf`.
    head: http::Head,
    /// Reusable write buffer holding the serialized response.
    wbuf: Vec<u8>,
    /// Flush progress into `wbuf`.
    wpos: usize,
    /// Persistence decision for the in-flight request.
    keep_alive: bool,
    /// Requests served on this connection (keep-alive cap).
    served: usize,
    /// Live deadline; wheel hints revalidate against this.
    deadline: Instant,
    /// When the in-flight request's first byte arrived — the anchor for
    /// its end-to-end deadline (`X-Deadline-Ms` counts from here, not
    /// from admission, so slow uploads spend their own budget).
    req_start: Option<Instant>,
    /// Currently registered epoll interest.
    interest: u32,
    /// Peer shut down its write half: serve what is buffered, then close.
    peer_eof: bool,
    pending: Option<Pending>,
}

struct Slot {
    /// Generation, bumped when the slot's connection closes so stale
    /// epoll/completion/timer tokens for a reused slot are ignored.
    gen: u16,
    conn: Option<Conn>,
}

enum FlushResult {
    Done,
    Blocked,
    Close,
}

/// One event-loop thread: epoll, connection slab, timer wheel.
pub(crate) struct Reactor {
    epoll: Epoll,
    listener: TcpListener,
    completions: Arc<Completions>,
    state: Arc<ServerState>,
    config: Arc<ServerConfig>,
    batch_tx: Sender<BatchItem>,
    shutdown: Arc<AtomicBool>,
    slots: Vec<Slot>,
    free: Vec<u32>,
    wheel: TimerWheel,
    /// Reused `/metrics` render buffer (satellite perf fix: the
    /// exposition no longer allocates a fresh `String` per scrape).
    scratch: String,
    /// Latched once `state.draining` is observed: the listener is
    /// deregistered, idle connections closed, and replies carry
    /// `Connection: close` while in-flight work finishes.
    draining: bool,
    /// Fault injection at the socket seams (inert unless the binary is
    /// built with `--features chaos` and a spec names them).
    chaos_reset: ChaosPoint,
    chaos_short_read: ChaosPoint,
    chaos_short_write: ChaosPoint,
}

impl Reactor {
    pub(crate) fn new(
        listener: TcpListener,
        completions: Arc<Completions>,
        state: Arc<ServerState>,
        config: Arc<ServerConfig>,
        batch_tx: Sender<BatchItem>,
        shutdown: Arc<AtomicBool>,
    ) -> io::Result<Reactor> {
        listener.set_nonblocking(true)?;
        let epoll = Epoll::new()?;
        epoll.add_exclusive(listener.as_raw_fd(), TOKEN_LISTENER)?;
        epoll.add(
            completions.waker().as_raw_fd(),
            interest::READ,
            TOKEN_WAKER,
        )?;
        let chaos = &config.coordinator.chaos;
        let chaos_reset = chaos.point("conn.reset");
        let chaos_short_read = chaos.point("conn.short_read");
        let chaos_short_write = chaos.point("conn.short_write");
        Ok(Reactor {
            epoll,
            listener,
            completions,
            state,
            config,
            batch_tx,
            shutdown,
            slots: Vec::new(),
            free: Vec::new(),
            wheel: TimerWheel::new(WHEEL_GRANULARITY, WHEEL_BUCKETS, Instant::now()),
            scratch: String::new(),
            draining: false,
            chaos_reset,
            chaos_short_read,
            chaos_short_write,
        })
    }

    pub(crate) fn run(mut self) {
        let mut events: Vec<Event> = Vec::new();
        let mut done: Vec<Completion> = Vec::new();
        let mut fired: Vec<(u32, u16)> = Vec::new();
        while !self.shutdown.load(Ordering::SeqCst) {
            if !self.draining && self.state.draining.load(Ordering::Acquire) {
                self.begin_drain();
            }
            let timeout = self.wheel.next_timeout(Instant::now());
            if self.epoll.wait(&mut events, timeout).is_err() {
                break;
            }
            let mut burst = false;
            for &event in &events {
                match event.token {
                    TOKEN_LISTENER => burst = true,
                    TOKEN_WAKER => self.completions.waker().drain(),
                    _ => self.socket_event(event),
                }
            }
            if burst {
                self.accept_burst();
            }
            done.clear();
            self.completions.drain_into(&mut done);
            for completion in done.drain(..) {
                self.complete(completion);
            }
            fired.clear();
            self.wheel.advance(Instant::now(), &mut fired);
            for &(slot, gen) in &fired {
                self.timer_fired(slot, gen);
            }
        }
        // Dropping the reactor closes every connection (releasing any
        // held admission permits) and drops this thread's batch sender,
        // letting the batcher drain and exit once all reactors stop.
    }

    /// Graceful drain: stop accepting (deregister the listener), close
    /// connections with nothing in flight, and let the rest finish
    /// their current request — `write_done` closes them afterwards
    /// because `keep_alive` is forced off while draining.
    fn begin_drain(&mut self) {
        self.draining = true;
        let _ = self.epoll.delete(self.listener.as_raw_fd());
        let idle: Vec<u32> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(slot, entry)| {
                let conn = entry.conn.as_ref()?;
                (conn.state == ConnState::ReadHead && conn.rbuf.is_empty()).then_some(slot as u32)
            })
            .collect();
        for slot in idle {
            self.close(slot, false);
        }
    }

    fn accept_burst(&mut self) {
        if self.draining {
            return;
        }
        loop {
            match self.listener.accept() {
                Ok((stream, addr)) => self.admit_conn(stream, addr.ip()),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn admit_conn(&mut self, stream: TcpStream, peer: IpAddr) {
        // Connection cap: best-effort 503, exactly like the old
        // thread-per-connection front end.  The accepted socket is
        // still blocking here, so this small write is effectively
        // synchronous.
        let cap = self.config.max_connections.max(1);
        if self.state.connections.load(Ordering::Acquire) >= cap {
            let mut out = Vec::with_capacity(160);
            http::Response::json(503, &error_json("too many connections"))
                .with_header("Retry-After", "1")
                .serialize_into(false, &mut out);
            let mut stream = stream;
            let _ = stream.write_all(&out);
            return;
        }
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let _ = stream.set_nodelay(true);
        let slot = match self.free.pop() {
            Some(slot) => slot,
            None => {
                self.slots.push(Slot { gen: 0, conn: None });
                (self.slots.len() - 1) as u32
            }
        };
        let gen = self.slots[slot as usize].gen;
        if self
            .epoll
            .add(stream.as_raw_fd(), interest::READ, pack(slot, gen, 0))
            .is_err()
        {
            self.free.push(slot);
            return;
        }
        let deadline = Instant::now() + self.config.first_byte_timeout;
        self.slots[slot as usize].conn = Some(Conn {
            stream,
            peer,
            seq: 0,
            state: ConnState::ReadHead,
            rbuf: Vec::new(),
            head: http::Head::default(),
            wbuf: Vec::new(),
            wpos: 0,
            keep_alive: true,
            served: 0,
            deadline,
            req_start: None,
            interest: interest::READ,
            peer_eof: false,
            pending: None,
        });
        self.wheel.insert(deadline, slot, gen);
        self.state.connections.fetch_add(1, Ordering::AcqRel);
        self.state
            .connections_accepted
            .fetch_add(1, Ordering::Relaxed);
    }

    fn conn_state(&self, slot: u32, gen: u16) -> Option<ConnState> {
        let entry = self.slots.get(slot as usize)?;
        let conn = entry.conn.as_ref()?;
        (entry.gen == gen).then_some(conn.state)
    }

    fn socket_event(&mut self, event: Event) {
        let (slot, gen, _) = unpack(event.token);
        let Some(state) = self.conn_state(slot, gen) else {
            return;
        };
        if event.error {
            self.close(slot, false);
            return;
        }
        match state {
            ConnState::Write if event.writable => {
                self.flush(slot);
                if self.can_continue(slot) {
                    self.advance(slot);
                }
            }
            ConnState::ReadHead | ConnState::ReadBody if event.readable || event.rdhup => {
                self.fill(slot);
            }
            _ => {}
        }
    }

    /// Read everything the socket has into the connection's buffer,
    /// then run the parse/dispatch loop.
    fn fill(&mut self, slot: u32) {
        // Injected connection reset: the peer vanishes mid-request.
        if self.chaos_reset.fire() {
            self.close(slot, false);
            return;
        }
        // Injected short read: take one byte and yield, exercising the
        // incremental parser (level-triggered epoll re-fires readable).
        let short_read = self.chaos_short_read.fire();
        let mut failed = false;
        {
            let Some(conn) = self.slots[slot as usize].conn.as_mut() else {
                return;
            };
            let mut buf = [0u8; 16 << 10];
            loop {
                let cap = if short_read { 1 } else { buf.len() };
                match conn.stream.read(&mut buf[..cap]) {
                    Ok(0) => {
                        conn.peer_eof = true;
                        break;
                    }
                    Ok(n) => {
                        conn.rbuf.extend_from_slice(&buf[..n]);
                        if conn.req_start.is_none() {
                            conn.req_start = Some(Instant::now());
                        }
                        if short_read || n < cap {
                            break;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        failed = true;
                        break;
                    }
                }
            }
        }
        if failed {
            self.close(slot, false);
            return;
        }
        self.advance(slot);
    }

    /// Parse/dispatch loop: frame as many buffered requests as possible.
    /// Iterative (not recursive through the write path), so a flood of
    /// pipelined requests cannot grow the stack.
    fn advance(&mut self, slot: u32) {
        enum Step {
            Dispatch,
            Protocol(String),
            CloseSilent,
            Done,
        }
        loop {
            let step = {
                let Some(conn) = self.slots[slot as usize].conn.as_mut() else {
                    return;
                };
                match conn.state {
                    ConnState::ReadHead => match conn.head.parse(&mut conn.rbuf) {
                        Ok(http::Parse::Complete) => {
                            conn.state = ConnState::ReadBody;
                            continue;
                        }
                        Ok(http::Parse::NeedMore) => {
                            if conn.peer_eof {
                                Step::CloseSilent
                            } else {
                                Step::Done
                            }
                        }
                        Err(e) => Step::Protocol(format!("bad request: {e}")),
                    },
                    ConnState::ReadBody => {
                        if conn.rbuf.len() >= conn.head.total_len() {
                            Step::Dispatch
                        } else if conn.peer_eof {
                            Step::CloseSilent
                        } else {
                            Step::Done
                        }
                    }
                    ConnState::Await | ConnState::Write => Step::Done,
                }
            };
            match step {
                Step::Dispatch => {
                    if !self.dispatch(slot) {
                        return;
                    }
                }
                Step::Protocol(message) => {
                    self.state.bad_requests.fetch_add(1, Ordering::Relaxed);
                    let response = http::Response::json(400, &error_json(&message));
                    self.start_write(slot, &response, false);
                    return;
                }
                Step::CloseSilent => {
                    self.close(slot, false);
                    return;
                }
                Step::Done => return,
            }
        }
    }

    /// Route one fully framed request.  Returns `true` when the
    /// response was handled inline and the connection is back in
    /// `ReadHead` (so `advance` may keep parsing pipelined input).
    fn dispatch(&mut self, slot: u32) -> bool {
        enum Routed {
            Inline(http::Response, bool),
            Metrics(bool),
            Enqueue(Box<Dispatch>, bool, Instant),
        }
        let gen = self.slots[slot as usize].gen;
        let draining = self.draining;
        let routed = {
            let state = &self.state;
            let config = &self.config;
            let scratch = &mut self.scratch;
            let Some(conn) = self.slots[slot as usize].conn.as_mut() else {
                return false;
            };
            let total = conn.head.total_len();
            conn.served += 1;
            let req = conn.head.req(&conn.rbuf);
            let keep_alive = req.wants_keep_alive()
                && conn.served < config.keepalive_max_requests.max(1)
                && !draining;
            let outcome = route_request(&req, conn.peer, state, config, scratch);
            // The request is consumed: drop its framed bytes so the
            // buffer fronts the next pipelined request (if any).
            conn.rbuf.drain(..total);
            // The consumed request's first byte anchors its deadline;
            // pipelined bytes already buffered count from now.
            let now = Instant::now();
            let anchor = conn.req_start.take().unwrap_or(now);
            conn.req_start = (!conn.rbuf.is_empty()).then_some(now);
            match outcome {
                RouteOutcome::Response(response) => Routed::Inline(response, keep_alive),
                RouteOutcome::Scratch => Routed::Metrics(keep_alive),
                RouteOutcome::Dispatch(dispatch) => {
                    Routed::Enqueue(Box::new(dispatch), keep_alive, anchor)
                }
            }
        };
        match routed {
            Routed::Inline(response, keep_alive) => {
                self.start_write(slot, &response, keep_alive);
                self.can_continue(slot)
            }
            Routed::Metrics(keep_alive) => {
                {
                    let Some(conn) = self.slots[slot as usize].conn.as_mut() else {
                        return false;
                    };
                    conn.keep_alive = keep_alive;
                    conn.wbuf.clear();
                    conn.wpos = 0;
                    http::serialize_parts_into(
                        200,
                        "text/plain; charset=utf-8",
                        &[],
                        self.scratch.as_bytes(),
                        keep_alive,
                        &mut conn.wbuf,
                    );
                    conn.state = ConnState::Write;
                }
                self.flush(slot);
                self.can_continue(slot)
            }
            Routed::Enqueue(dispatch, keep_alive, anchor) => {
                self.enqueue(slot, gen, *dispatch, keep_alive, anchor);
                false
            }
        }
    }

    /// Hand admitted work to the batcher and park the connection.
    /// `anchor` is when the request's first byte arrived — its
    /// `X-Deadline-Ms` budget counts from there.
    fn enqueue(
        &mut self,
        slot: u32,
        gen: u16,
        dispatch: Dispatch,
        keep_alive: bool,
        anchor: Instant,
    ) {
        let Dispatch {
            payload,
            kind,
            trace,
            permit,
            deadline_budget,
        } = dispatch;
        let seq = {
            let Some(conn) = self.slots[slot as usize].conn.as_mut() else {
                return;
            };
            conn.seq = conn.seq.wrapping_add(1);
            conn.keep_alive = keep_alive;
            conn.seq
        };
        let now = Instant::now();
        let hard_deadline = deadline_budget.map(|budget| anchor + budget);
        let item = BatchItem {
            payload,
            reply: ReplySink::event(Arc::clone(&self.completions), pack(slot, gen, seq)),
            enqueued: now,
            deadline: hard_deadline,
            trace: trace.clone(),
        };
        if self.batch_tx.send(item).is_err() {
            self.state.tracer.finish(trace);
            drop(permit);
            let response = http::Response::json(503, &error_json("server shutting down"));
            self.start_write(slot, &response, false);
            return;
        }
        // The connection waits until the request's own deadline (when it
        // has one) or the server-wide in-flight timeout; whichever path
        // fires first takes `pending` and the other is a no-op.
        let deadline = hard_deadline.unwrap_or(now + self.config.request_timeout);
        if let Some(conn) = self.slots[slot as usize].conn.as_mut() {
            conn.pending = Some(Pending {
                kind,
                trace,
                permit,
            });
            conn.state = ConnState::Await;
            conn.deadline = deadline;
        }
        self.wheel.insert(deadline, slot, gen);
        // No readiness interest while parked: the old blocking server
        // never cancelled dispatched work on peer close, and level-
        // triggered read interest would spin on buffered pipelined
        // bytes.  Errors/hangups are still delivered.
        self.set_interest(slot, interest::NONE);
    }

    /// A batcher completion arrived; validate it against the live
    /// connection identity and resume the state machine.
    fn complete(&mut self, completion: Completion) {
        let (slot, gen, seq) = unpack(completion.token);
        let pending = {
            let Some(entry) = self.slots.get_mut(slot as usize) else {
                return;
            };
            if entry.gen != gen {
                return;
            }
            let Some(conn) = entry.conn.as_mut() else {
                return;
            };
            if conn.state != ConnState::Await || conn.seq != seq {
                return;
            }
            match conn.pending.take() {
                Some(pending) => pending,
                None => return,
            }
        };
        if completion.result.is_none() {
            // The batcher dropped the reply sink without answering
            // (stale/deadline shed, worker failure, injected fault).
            self.state
                .dropped_reply_total
                .fetch_add(1, Ordering::Relaxed);
        }
        self.resolve(slot, pending, completion.result);
    }

    /// Render the reply for a request that left the batcher (result) or
    /// hit its in-flight deadline (`None` → 504), then write it out.  A
    /// dropped reply closes the connection after the 504: the server
    /// cannot know whether the batcher side-effects for this request
    /// ever happened, so the keep-alive stream is not reusable.
    fn resolve(&mut self, slot: u32, pending: Pending, result: Option<ReplyResult>) {
        let dropped = result.is_none();
        let respond_start = if pending.trace.is_active() {
            trace::now_us()
        } else {
            0
        };
        let response = render_reply(pending.kind, result, &self.state);
        finish_trace(&self.state, pending.trace, respond_start);
        drop(pending.permit);
        let keep_alive = !dropped
            && !self.draining
            && self.slots[slot as usize]
                .conn
                .as_ref()
                .is_some_and(|c| c.keep_alive);
        self.start_write(slot, &response, keep_alive);
        if self.can_continue(slot) {
            self.advance(slot);
        }
    }

    /// Serialize a response into the connection's write buffer and
    /// start flushing.
    fn start_write(&mut self, slot: u32, response: &http::Response, keep_alive: bool) {
        {
            let Some(conn) = self.slots[slot as usize].conn.as_mut() else {
                return;
            };
            conn.keep_alive = keep_alive;
            conn.wbuf.clear();
            conn.wpos = 0;
            response.serialize_into(keep_alive, &mut conn.wbuf);
            conn.state = ConnState::Write;
        }
        self.flush(slot);
    }

    fn flush(&mut self, slot: u32) {
        // Injected short write: put one byte on the wire and report
        // Blocked, exercising the write-interest/stall-deadline path.
        let short_write = self.chaos_short_write.fire();
        let result = {
            let Some(conn) = self.slots[slot as usize].conn.as_mut() else {
                return;
            };
            loop {
                if conn.wpos >= conn.wbuf.len() {
                    break FlushResult::Done;
                }
                let end = if short_write {
                    conn.wpos + 1
                } else {
                    conn.wbuf.len()
                };
                match conn.stream.write(&conn.wbuf[conn.wpos..end]) {
                    Ok(0) => break FlushResult::Close,
                    Ok(n) => {
                        conn.wpos += n;
                        if short_write {
                            break FlushResult::Blocked;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break FlushResult::Blocked,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => break FlushResult::Close,
                }
            }
        };
        match result {
            FlushResult::Done => self.write_done(slot),
            FlushResult::Blocked => {
                // First block on this response: arm the write-stall
                // deadline and write interest (later partial flushes
                // find both already armed).
                let gen = self.slots[slot as usize].gen;
                let already = self.slots[slot as usize]
                    .conn
                    .as_ref()
                    .is_some_and(|c| c.interest == interest::WRITE);
                if !already {
                    let deadline = Instant::now() + self.config.request_timeout;
                    if let Some(conn) = self.slots[slot as usize].conn.as_mut() {
                        conn.deadline = deadline;
                    }
                    self.wheel.insert(deadline, slot, gen);
                    self.set_interest(slot, interest::WRITE);
                }
            }
            FlushResult::Close => self.close(slot, false),
        }
    }

    /// The response is fully flushed: close, or re-arm for the next
    /// keep-alive request.
    fn write_done(&mut self, slot: u32) {
        let gen = self.slots[slot as usize].gen;
        let keep = {
            let Some(conn) = self.slots[slot as usize].conn.as_mut() else {
                return;
            };
            conn.wbuf.clear();
            conn.wpos = 0;
            conn.keep_alive && !conn.peer_eof
        };
        if !keep || self.draining {
            self.close(slot, false);
            return;
        }
        let deadline = Instant::now() + self.config.keepalive_idle;
        if let Some(conn) = self.slots[slot as usize].conn.as_mut() {
            conn.state = ConnState::ReadHead;
            conn.deadline = deadline;
        }
        self.wheel.insert(deadline, slot, gen);
        self.set_interest(slot, interest::READ);
    }

    /// Whether `advance` may keep parsing (connection back in ReadHead).
    fn can_continue(&self, slot: u32) -> bool {
        self.slots
            .get(slot as usize)
            .and_then(|entry| entry.conn.as_ref())
            .is_some_and(|conn| conn.state == ConnState::ReadHead)
    }

    /// A timer-wheel hint fired: revalidate against the live deadline,
    /// re-arming if it moved, expiring the connection if it passed.
    fn timer_fired(&mut self, slot: u32, gen: u16) {
        enum Action {
            Rearm(Instant),
            CloseTimedOut,
            Expire(Pending),
        }
        let now = Instant::now();
        let action = {
            let Some(entry) = self.slots.get_mut(slot as usize) else {
                return;
            };
            if entry.gen != gen {
                return;
            }
            let Some(conn) = entry.conn.as_mut() else {
                return;
            };
            if conn.deadline > now {
                Action::Rearm(conn.deadline)
            } else {
                match conn.state {
                    // Idle/slowloris/write stalls close silently, as the
                    // blocking server's socket timeouts did.
                    ConnState::ReadHead | ConnState::ReadBody | ConnState::Write => {
                        Action::CloseTimedOut
                    }
                    ConnState::Await => match conn.pending.take() {
                        Some(pending) => Action::Expire(pending),
                        None => return,
                    },
                }
            }
        };
        match action {
            Action::Rearm(deadline) => self.wheel.insert(deadline, slot, gen),
            Action::CloseTimedOut => self.close(slot, true),
            Action::Expire(pending) => {
                // In-flight deadline: a 504, exactly like the old
                // handler's recv_timeout.  A late batcher reply for
                // this request is ignored (pending is gone, and any
                // newer request on the connection has a newer seq).
                self.state
                    .deadline_expired_total
                    .fetch_add(1, Ordering::Relaxed);
                self.state
                    .dropped_deadline_total
                    .fetch_add(1, Ordering::Relaxed);
                self.resolve(slot, pending, None);
            }
        }
    }

    fn set_interest(&mut self, slot: u32, want: u32) {
        let gen = self.slots[slot as usize].gen;
        let Some(conn) = self.slots[slot as usize].conn.as_mut() else {
            return;
        };
        if conn.interest == want {
            return;
        }
        if self
            .epoll
            .modify(conn.stream.as_raw_fd(), want, pack(slot, gen, 0))
            .is_ok()
        {
            conn.interest = want;
        }
    }

    fn close(&mut self, slot: u32, timed_out: bool) {
        let entry = &mut self.slots[slot as usize];
        let Some(conn) = entry.conn.take() else {
            return;
        };
        entry.gen = entry.gen.wrapping_add(1);
        let _ = self.epoll.delete(conn.stream.as_raw_fd());
        if let Some(pending) = conn.pending {
            // The connection died mid-dispatch: release admission now
            // and retire the trace; the batcher's late completion (if
            // any) targets a dead generation and is ignored.
            self.state.tracer.finish(pending.trace);
            drop(pending.permit);
        }
        self.free.push(slot);
        self.state.connections.fetch_sub(1, Ordering::AcqRel);
        if timed_out {
            self.state
                .connections_timed_out
                .fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_round_trip_identity() {
        for (slot, gen, seq) in [(0u32, 0u16, 0u16), (7, 1, 2), (u32::MAX - 2, 513, 40000)] {
            let token = pack(slot, gen, seq);
            assert_eq!(unpack(token), (slot, gen, seq));
            assert_ne!(token, TOKEN_LISTENER);
            assert_ne!(token, TOKEN_WAKER);
        }
    }

    #[test]
    fn listener_and_waker_tokens_do_not_collide_with_connections() {
        // Slots are bounded far below u32::MAX, so the sentinel tokens
        // (which decode to slot u32::MAX) can never match a live slot.
        let (slot, _, _) = unpack(TOKEN_LISTENER);
        assert_eq!(slot, u32::MAX);
        let (slot, _, _) = unpack(TOKEN_WAKER);
        assert_eq!(slot, u32::MAX);
    }
}

//! Dynamic micro-batching over the shard set.
//!
//! One batcher thread owns the [`ShardSet`].  It blocks for the first
//! pending request, keeps collecting until `max_batch` requests are in
//! hand or `max_wait` has elapsed, dispatches the whole batch across the
//! shard pools in one scatter–gather
//! [`crate::shard::router::transform_batch`] call (so tile utilization
//! stays high under bursty concurrent load — wide requests additionally
//! parallelize *within* themselves across shards), then fans the replies
//! back out over per-request channels.
//!
//! Under a backlog the `recv_timeout` calls return instantly, so deep
//! batches form with no added latency; on an idle server a lone request
//! pays at most `max_wait` of coalescing delay.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::{Metrics, TransformRequest};
use crate::shard::{router, ShardSet};

use super::ServerState;

/// One queued request: payload plus its reply channel.
pub struct BatchItem {
    pub req: TransformRequest,
    pub reply: Sender<Result<BatchReply, String>>,
    pub enqueued: Instant,
}

/// Successful per-request outcome.
#[derive(Debug, Clone)]
pub struct BatchReply {
    /// Transform outputs at padded width.
    pub values: Vec<f32>,
    /// Queue + execution latency as observed by the batcher.
    pub latency: Duration,
}

/// Run the batching loop until every [`BatchItem`] sender is dropped,
/// then shut the pool down and return the merged worker metrics.
///
/// Items older than `stale_after` (the HTTP handler's reply timeout)
/// are dropped instead of executed: their client already gave up, and
/// skipping them lets an overload backlog drain at channel speed
/// instead of pool-execution speed — no congestion collapse.
pub(crate) fn run_batcher(
    rx: Receiver<BatchItem>,
    mut shards: ShardSet,
    max_batch: usize,
    max_wait: Duration,
    stale_after: Duration,
    state: Arc<ServerState>,
) -> Metrics {
    while let Ok(first) = rx.recv() {
        let mut batch = vec![first];
        let deadline = Instant::now() + max_wait;
        while batch.len() < max_batch {
            let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
                break;
            };
            match rx.recv_timeout(remaining) {
                Ok(item) => batch.push(item),
                Err(RecvTimeoutError::Timeout | RecvTimeoutError::Disconnected) => break,
            }
        }
        let now = Instant::now();
        let before = batch.len();
        batch.retain(|item| now.saturating_duration_since(item.enqueued) < stale_after);
        let dropped = (before - batch.len()) as u64;
        if dropped > 0 {
            // Dropping the reply sender wakes any still-blocked handler
            // with a disconnect, which it reports as a 504.
            state.stale_dropped_total.fetch_add(dropped, Ordering::Relaxed);
        }
        if batch.is_empty() {
            continue;
        }
        state.batches_total.fetch_add(1, Ordering::Relaxed);
        // Move the payloads out instead of cloning them — the only copy
        // left on the dispatch path is the coordinator's own padding.
        let mut reqs = Vec::with_capacity(batch.len());
        let mut waiters = Vec::with_capacity(batch.len());
        for item in batch {
            reqs.push(item.req);
            waiters.push((item.reply, item.enqueued));
        }
        match router::transform_batch(&mut shards, &reqs) {
            Ok(outputs) => {
                for ((reply, enqueued), values) in waiters.into_iter().zip(outputs) {
                    let latency = enqueued.elapsed();
                    state.record_latency(latency);
                    let _ = reply.send(Ok(BatchReply { values, latency }));
                }
            }
            Err(e) => {
                // Requests are validated before enqueueing, so this is a
                // set-level failure (every shard poisoned): report it to
                // every waiter.
                let msg = format!("batch execution failed: {e}");
                for (reply, _) in waiters {
                    let _ = reply.send(Err(msg.clone()));
                }
            }
        }
    }
    shards.shutdown()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitplane::QuantBwht;
    use crate::energy::EnergyModel;
    use crate::server::admission::AdmissionConfig;
    use crate::shard::ShardSetConfig;
    use std::sync::mpsc;

    fn test_set(shards: usize) -> ShardSet {
        ShardSet::new(ShardSetConfig {
            shards,
            ..Default::default()
        })
        .unwrap()
    }

    fn test_state(set: &ShardSet) -> Arc<ServerState> {
        Arc::new(ServerState::new(
            AdmissionConfig::default(),
            set.aggregator(),
            set.health_handle(),
            EnergyModel::new(16, 0.8),
        ))
    }

    #[test]
    fn coalesces_a_queued_burst_into_one_batch_and_fans_out() {
        let set = test_set(1);
        let state = test_state(&set);
        let (tx, rx) = mpsc::channel();
        // Enqueue the whole burst before the batcher runs, so coalescing
        // is deterministic: one batch of six.
        let mut waiters = Vec::new();
        for i in 0..6u64 {
            let (reply_tx, reply_rx) = mpsc::channel();
            let x: Vec<f32> = (0..16).map(|j| ((i * 16 + j) as f32 * 0.1).sin()).collect();
            tx.send(BatchItem {
                req: TransformRequest {
                    x: x.clone(),
                    thresholds_units: vec![0.0; 16],
                },
                reply: reply_tx,
                enqueued: Instant::now(),
            })
            .unwrap();
            waiters.push((x, reply_rx));
        }
        drop(tx);
        let metrics = run_batcher(
            rx,
            set,
            8,
            Duration::from_millis(5),
            Duration::from_secs(5),
            Arc::clone(&state),
        );
        for (x, reply_rx) in waiters {
            let reply = reply_rx.recv().unwrap().unwrap();
            let golden = QuantBwht::new(16, 16, 8).transform(&x);
            assert_eq!(reply.values, golden);
        }
        assert_eq!(metrics.requests, 6);
        assert_eq!(
            state.batches_total.load(Ordering::Relaxed),
            1,
            "a queued burst must coalesce into a single batch"
        );
        assert_eq!(state.e2e_latency.lock().unwrap().count(), 6);
    }

    #[test]
    fn max_batch_splits_oversized_bursts() {
        let set = test_set(2);
        let state = test_state(&set);
        let (tx, rx) = mpsc::channel();
        let mut waiters = Vec::new();
        for _ in 0..5 {
            let (reply_tx, reply_rx) = mpsc::channel();
            tx.send(BatchItem {
                req: TransformRequest {
                    x: vec![0.5; 16],
                    thresholds_units: vec![0.0; 16],
                },
                reply: reply_tx,
                enqueued: Instant::now(),
            })
            .unwrap();
            waiters.push(reply_rx);
        }
        drop(tx);
        let metrics = run_batcher(
            rx,
            set,
            2,
            Duration::from_millis(5),
            Duration::from_secs(5),
            Arc::clone(&state),
        );
        for reply_rx in waiters {
            assert!(reply_rx.recv().unwrap().is_ok());
        }
        assert_eq!(metrics.requests, 5);
        assert_eq!(
            state.batches_total.load(Ordering::Relaxed),
            3,
            "5 queued requests at max_batch=2 -> 2+2+1"
        );
    }

    #[test]
    fn stale_items_are_dropped_not_executed() {
        let set = test_set(1);
        let state = test_state(&set);
        let (tx, rx) = mpsc::channel();
        let mut waiters = Vec::new();
        for _ in 0..3 {
            let (reply_tx, reply_rx) = mpsc::channel();
            tx.send(BatchItem {
                req: TransformRequest {
                    x: vec![0.5; 16],
                    thresholds_units: vec![0.0; 16],
                },
                reply: reply_tx,
                enqueued: Instant::now(),
            })
            .unwrap();
            waiters.push(reply_rx);
        }
        drop(tx);
        // stale_after = 0: everything is already expired at dispatch.
        let metrics = run_batcher(
            rx,
            set,
            8,
            Duration::from_millis(5),
            Duration::ZERO,
            Arc::clone(&state),
        );
        assert_eq!(metrics.requests, 0, "stale work must not reach the pool");
        assert_eq!(state.stale_dropped_total.load(Ordering::Relaxed), 3);
        assert_eq!(state.batches_total.load(Ordering::Relaxed), 0);
        for reply_rx in waiters {
            assert!(reply_rx.recv().is_err(), "reply sender must be dropped");
        }
    }
}

//! Dynamic micro-batching over the shard set.
//!
//! One batcher thread owns the [`ShardSet`] (and the served [`Mlp`], if
//! any).  It blocks for the first pending request, keeps collecting
//! until `max_batch` requests are in hand or `max_wait` has elapsed,
//! then dispatches the whole batch:
//!
//! * raw transform items go through one scatter–gather
//!   [`crate::shard::router::transform_batch`] call;
//! * infer items are concatenated into one `(samples, din)` activation
//!   and pushed through `Mlp::forward_with` over a
//!   [`crate::exec::Sharded`] executor — every sample's BWHT blocks fan
//!   out across the healthy pools, bit-identically (digital backend) to
//!   `Backend::Quantized`.
//!
//! Both paths land on the pool workers' zero-allocation bitplane engine
//! ([`crate::coordinator::schedule_batch`]), as the router's fused
//! multi-sample chunk jobs: same-partition requests in the batch are
//! planned as one group and same-shard slices are submitted through
//! [`crate::coordinator::Coordinator::try_submit_batch_planned`], so a
//! deep batch costs ~`shards × workers` pool jobs rather than one job
//! per sample per shard lane.
//!
//! Replies fan back out over per-request channels.  Under a backlog the
//! `recv_timeout` calls return instantly, so deep batches form with no
//! added latency; on an idle server a lone request pays at most
//! `max_wait` of coalescing delay.
//!
//! The batcher doubles as the shard-health loop: before each batch and
//! on an idle `health_tick` it respawns poisoned shards through the
//! per-slot respawn backoff ([`ShardSet::respawn_backed_off`]) — the
//! first heal of a slot is free, repeat heals without intervening
//! served traffic double their wait, so a permanently sick shard
//! converges to open-breaker shedding instead of a respawn storm.  The
//! same pass recycles slots the fidelity monitor flagged as drifting:
//! the pool still answers, but its numbers are wrong, so it is poisoned
//! (tripping its breaker — the drift side of the breaker's inputs) and
//! respawned like a dead one (counted separately as
//! `repro_shard_drift_respawns_total`).
//!
//! Deadlines: a [`BatchItem`] may carry an absolute deadline (captured
//! at the connection front end from `X-Deadline-Ms`).  Expired items
//! are dropped *before* dispatch — their sink's drop delivers the 504 —
//! and the deadline rides the [`TransformRequest`] into the pool so a
//! worker can cancel samples that expire while queued behind a batch.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::chaos::ChaosPoint;
use crate::coordinator::{Metrics, TransformRequest};
use crate::exec::Sharded;
use crate::monitor::Monitor;
use crate::nn::Mlp;
use crate::shard::{router, ShardSet};
use crate::trace::{self, Stage, TraceHandle};

use super::reactor::{Completion, Completions};
use super::ServerState;

/// What one queued request wants executed.
pub enum BatchPayload {
    /// A raw BWHT transform (`POST /v1/transform`).
    Transform(TransformRequest),
    /// `samples` rows of a `(samples, din)` activation for the hosted
    /// model (`POST /v1/infer`).
    Infer { x: Vec<f32>, samples: usize },
}

/// The per-request outcome the batcher reports back.
pub type ReplyResult = Result<BatchReply, String>;

/// Where one request's reply goes.
///
/// The event-driven front end parks the connection and receives the
/// reply through a reactor [`Completions`] queue (`Event`); tests and
/// other synchronous callers block on an mpsc channel (`Channel`).
/// Dropping an unsent `Event` sink — the batcher's stale-shed path
/// retains a batch and simply drops expired items — delivers a `None`
/// completion, which the connection reports as a 504.  That mirrors
/// the old contract where dropping the channel sender woke the
/// blocked handler with a disconnect.
pub enum ReplySink {
    Channel(Option<Sender<ReplyResult>>),
    Event {
        completions: Arc<Completions>,
        token: u64,
        sent: bool,
    },
}

impl ReplySink {
    pub fn channel(tx: Sender<ReplyResult>) -> ReplySink {
        ReplySink::Channel(Some(tx))
    }

    pub fn event(completions: Arc<Completions>, token: u64) -> ReplySink {
        ReplySink::Event {
            completions,
            token,
            sent: false,
        }
    }

    /// Deliver the reply (consumes the sink; send failures mean the
    /// receiver is gone and are ignored, matching channel semantics).
    pub fn send(mut self, result: ReplyResult) {
        match &mut self {
            ReplySink::Channel(tx) => {
                if let Some(tx) = tx.take() {
                    let _ = tx.send(result);
                }
            }
            ReplySink::Event {
                completions,
                token,
                sent,
            } => {
                *sent = true;
                completions.push(Completion {
                    token: *token,
                    result: Some(result),
                });
            }
        }
    }
}

impl Drop for ReplySink {
    fn drop(&mut self) {
        if let ReplySink::Event {
            completions,
            token,
            sent: false,
        } = self
        {
            completions.push(Completion {
                token: *token,
                result: None,
            });
        }
    }
}

/// One queued request: payload plus its reply sink.
pub struct BatchItem {
    pub payload: BatchPayload,
    pub reply: ReplySink,
    pub enqueued: Instant,
    /// Absolute end-to-end deadline (from `X-Deadline-Ms`, clamped by
    /// the server config).  `None` means only the stale-shed window
    /// bounds the item.  An item that expires in the queue is dropped
    /// before dispatch; for transform items the deadline also rides the
    /// [`TransformRequest`] so the pool worker can cancel it mid-batch.
    pub deadline: Option<Instant>,
    /// Sampled request trace, inactive for unsampled requests.  The
    /// batcher records the queue span here and threads the handle into
    /// the shard set's trace scope for the dispatch.
    pub trace: TraceHandle,
}

/// Successful per-request outcome.
#[derive(Debug, Clone)]
pub struct BatchReply {
    /// Transform outputs at padded width, or `(samples, classes)` logits.
    pub values: Vec<f32>,
    /// Queue + execution latency as observed by the batcher.
    pub latency: Duration,
}

/// Respawn any poisoned shards (no-op when disabled or all healthy).
///
/// Drift-flagged slots are a special case: the fidelity monitor already
/// cleared their readiness flag, but the pool is still *live* — it keeps
/// answering, just wrongly — so the heal pass poisons it first (retiring
/// the pool and merging its metrics) and then respawns it alongside any
/// genuinely dead slots.  The monitor's per-slot drift state resets once
/// the fresh pool is up, so a recycled slot starts with a clean EWMA.
fn heal_shards(shards: &mut ShardSet, auto_respawn: bool, monitor: &Monitor) {
    // Chaos disruption fires on the same tick cadence as healing, so a
    // `shard.kill` this pass is healed (backoff permitting) on a later
    // one — the full kill → shed → probe → recover loop runs under the
    // batcher's own clock.  A constant no-op without `--features chaos`.
    shards.chaos_disrupt();
    if !auto_respawn {
        return;
    }
    let drifting = monitor.flagged_slots();
    for &slot in &drifting {
        // Poisoning force-opens the slot's breaker: drift is the second
        // input (besides failures) that trips it.
        shards.poison(slot);
    }
    if shards.healthy_count() < shards.len() {
        shards.respawn_backed_off(Instant::now());
    }
    for &slot in &drifting {
        if shards.is_healthy(slot) {
            monitor.note_drift_respawn();
            monitor.reset_slot(slot);
        }
    }
}

/// Deliver a reply through the `batcher.reply.drop` injection point:
/// when it fires the sink is dropped unsent, which the event front end
/// surfaces as a 504 with `Connection: close` (exactly the failure mode
/// of a reply lost between batcher and connection).
fn deliver(reply: ReplySink, result: ReplyResult, chaos_drop: &ChaosPoint) {
    if chaos_drop.fire() {
        drop(reply);
        return;
    }
    reply.send(result);
}

/// Run the batching loop until every [`BatchItem`] sender is dropped,
/// then shut the pool down and return the merged worker metrics.
///
/// Items older than `stale_after` (the HTTP handler's reply timeout)
/// are dropped instead of executed: their client already gave up, and
/// skipping them lets an overload backlog drain at channel speed
/// instead of pool-execution speed — no congestion collapse.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_batcher(
    rx: Receiver<BatchItem>,
    mut shards: ShardSet,
    model: Option<Mlp>,
    max_batch: usize,
    max_wait: Duration,
    stale_after: Duration,
    health_tick: Duration,
    auto_respawn: bool,
    state: Arc<ServerState>,
) -> Metrics {
    // Monotonic sample offset feeding per-sample noise streams.  Only
    // in-process executors consume stream ids (pool backends draw noise
    // from per-worker RNG state), but keeping the offset monotonic per
    // attempt costs nothing and keeps the seam uniform.  Deliberately
    // not the `infer_samples_total` metric: failed forwards advance the
    // offset but must not count as served samples.
    let mut stream_offset: u64 = 0;
    let chaos_stall = shards.config().coordinator.chaos.point("batcher.stall");
    let chaos_reply_drop = shards.config().coordinator.chaos.point("batcher.reply.drop");
    loop {
        let first = match rx.recv_timeout(health_tick) {
            Ok(item) => item,
            Err(RecvTimeoutError::Timeout) => {
                // Idle tick: heal dead shards while nothing is queued.
                heal_shards(&mut shards, auto_respawn, &state.monitor);
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break,
        };
        if chaos_stall.fire() {
            // Injected batcher stall: the whole serving pipeline behind
            // the batch queue stops for a beat, exactly like a long GC
            // pause or scheduler hiccup would look to clients.
            std::thread::sleep(crate::chaos::STALL);
        }
        heal_shards(&mut shards, auto_respawn, &state.monitor);
        let mut batch = vec![first];
        let deadline = Instant::now() + max_wait;
        while batch.len() < max_batch {
            let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
                break;
            };
            match rx.recv_timeout(remaining) {
                Ok(item) => batch.push(item),
                Err(RecvTimeoutError::Timeout | RecvTimeoutError::Disconnected) => break,
            }
        }
        let now = Instant::now();
        let mut expired = 0u64;
        let mut stale = 0u64;
        batch.retain(|item| {
            // Expired work is cancelled *before* it can occupy the
            // pool: the client's deadline has passed, so executing it
            // would be pure waste under overload.
            if item.deadline.is_some_and(|d| now >= d) {
                expired += 1;
                return false;
            }
            if now.saturating_duration_since(item.enqueued) >= stale_after {
                stale += 1;
                return false;
            }
            true
        });
        if expired > 0 {
            state.deadline_expired_total.fetch_add(expired, Ordering::Relaxed);
        }
        if stale > 0 {
            // Dropping the reply sender wakes any still-blocked handler
            // with a disconnect, which it reports as a 504.
            state.stale_dropped_total.fetch_add(stale, Ordering::Relaxed);
        }
        if batch.is_empty() {
            continue;
        }
        state.batches_total.fetch_add(1, Ordering::Relaxed);

        // Split the coalesced batch by payload kind, moving payloads out
        // instead of cloning them.
        let mut transform_reqs = Vec::new();
        let mut transform_waiters = Vec::new();
        let mut transform_traces: Vec<TraceHandle> = Vec::new();
        let mut infer_x: Vec<f32> = Vec::new();
        let mut infer_waiters = Vec::new();
        let mut infer_traces: Vec<TraceHandle> = Vec::new();
        let mut infer_samples = 0usize;
        for item in batch {
            let BatchItem {
                payload,
                reply,
                enqueued,
                deadline,
                trace,
            } = item;
            if trace.is_active() {
                // Queue = enqueued at the handler -> pulled into a batch.
                let start = trace::instant_us(enqueued);
                trace.record(Stage::Queue, start, trace::now_us().saturating_sub(start));
            }
            match payload {
                BatchPayload::Transform(mut req) => {
                    // The item-level deadline rides the request into the
                    // pool so a worker can cancel it mid-batch.
                    if req.deadline.is_none() {
                        req.deadline = deadline;
                    }
                    transform_reqs.push(req);
                    transform_waiters.push((reply, enqueued, deadline));
                    transform_traces.push(trace);
                }
                BatchPayload::Infer { x, samples } => {
                    infer_x.extend_from_slice(&x);
                    infer_samples += samples;
                    // The router sees one request per sample row, so the
                    // scope needs one handle clone per sample.
                    for _ in 0..samples {
                        infer_traces.push(trace.clone());
                    }
                    infer_waiters.push((reply, enqueued, samples, deadline));
                }
            }
        }

        if !transform_reqs.is_empty() {
            let traced = transform_traces.iter().any(TraceHandle::is_active);
            if traced {
                shards.set_trace_scope(std::mem::take(&mut transform_traces));
            }
            let result = router::transform_batch(&mut shards, &transform_reqs);
            if traced {
                shards.clear_trace_scope();
            }
            match result {
                Ok(outputs) => {
                    let now = Instant::now();
                    for ((reply, enqueued, deadline), values) in
                        transform_waiters.into_iter().zip(outputs)
                    {
                        // A request that expired *during* execution was
                        // cancelled by the worker (its values are
                        // placeholder zeros) or simply missed its
                        // deadline; either way the client gets the 504,
                        // never a fabricated payload.
                        if deadline.is_some_and(|d| now >= d) {
                            state.deadline_expired_total.fetch_add(1, Ordering::Relaxed);
                            drop(reply);
                            continue;
                        }
                        let latency = enqueued.elapsed();
                        state.record_latency(latency);
                        deliver(reply, Ok(BatchReply { values, latency }), &chaos_reply_drop);
                    }
                }
                Err(e) => {
                    // Requests are validated before enqueueing, so this
                    // is a set-level failure (every shard poisoned or a
                    // retry budget exhausted): report it to every
                    // waiter.
                    let msg = format!("batch execution failed: {e}");
                    for (reply, _, _) in transform_waiters {
                        deliver(reply, Err(msg.clone()), &chaos_reply_drop);
                    }
                }
            }
        }

        if infer_samples > 0 {
            match &model {
                None => {
                    for (reply, _, _, _) in infer_waiters {
                        deliver(reply, Err("no model loaded".to_string()), &chaos_reply_drop);
                    }
                }
                Some(mlp) => {
                    let offset = stream_offset;
                    stream_offset += infer_samples as u64;
                    let classes = mlp.classes;
                    let traced = infer_traces.iter().any(TraceHandle::is_active);
                    if traced {
                        shards.set_trace_scope(std::mem::take(&mut infer_traces));
                    }
                    let result = {
                        let mut exec = Sharded::new(&mut shards);
                        mlp.forward_with(&mut exec, &infer_x, infer_samples, offset)
                    };
                    if traced {
                        shards.clear_trace_scope();
                    }
                    match result {
                        Ok(logits) => {
                            state.infer_batches_total.fetch_add(1, Ordering::Relaxed);
                            state
                                .infer_samples_total
                                .fetch_add(infer_samples as u64, Ordering::Relaxed);
                            let mut row = 0usize;
                            let now = Instant::now();
                            for (reply, enqueued, samples, deadline) in infer_waiters {
                                let values =
                                    logits[row * classes..(row + samples) * classes].to_vec();
                                row += samples;
                                if deadline.is_some_and(|d| now >= d) {
                                    state
                                        .deadline_expired_total
                                        .fetch_add(1, Ordering::Relaxed);
                                    drop(reply);
                                    continue;
                                }
                                let latency = enqueued.elapsed();
                                state.record_infer_latency(latency);
                                deliver(
                                    reply,
                                    Ok(BatchReply { values, latency }),
                                    &chaos_reply_drop,
                                );
                            }
                        }
                        Err(e) => {
                            let msg = format!("inference failed: {e}");
                            for (reply, _, _, _) in infer_waiters {
                                deliver(reply, Err(msg.clone()), &chaos_reply_drop);
                            }
                        }
                    }
                }
            }
        }
    }
    shards.shutdown()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitplane::QuantBwht;
    use crate::energy::EnergyModel;
    use crate::nn::Backend;
    use crate::server::admission::AdmissionConfig;
    use crate::shard::ShardSetConfig;
    use crate::util::rng::Rng;
    use std::sync::mpsc;

    fn test_set(shards: usize) -> ShardSet {
        ShardSet::new(ShardSetConfig {
            shards,
            ..Default::default()
        })
        .unwrap()
    }

    fn test_state(set: &ShardSet) -> Arc<ServerState> {
        Arc::new(ServerState::new(
            AdmissionConfig::default(),
            set.aggregator(),
            set.health_handle(),
            set.respawns_handle(),
            set.slot_health_handle(),
            EnergyModel::new(16, 0.8),
            Arc::new(trace::Tracer::new(trace::TraceConfig::default())),
            Arc::new(Monitor::disabled()),
        ))
    }

    fn run(
        rx: Receiver<BatchItem>,
        set: ShardSet,
        model: Option<Mlp>,
        max_batch: usize,
        stale_after: Duration,
        state: Arc<ServerState>,
    ) -> Metrics {
        run_batcher(
            rx,
            set,
            model,
            max_batch,
            Duration::from_millis(5),
            stale_after,
            Duration::from_millis(50),
            true,
            state,
        )
    }

    fn transform_item(x: Vec<f32>, reply: Sender<ReplyResult>) -> BatchItem {
        let thresholds_units = vec![0.0; x.len()];
        BatchItem {
            payload: BatchPayload::Transform(TransformRequest {
                x,
                thresholds_units,
                scale: None,
                deadline: None,
            }),
            reply: ReplySink::channel(reply),
            enqueued: Instant::now(),
            deadline: None,
            trace: TraceHandle::inactive(),
        }
    }

    #[test]
    fn coalesces_a_queued_burst_into_one_batch_and_fans_out() {
        let set = test_set(1);
        let state = test_state(&set);
        let (tx, rx) = mpsc::channel();
        // Enqueue the whole burst before the batcher runs, so coalescing
        // is deterministic: one batch of six.
        let mut waiters = Vec::new();
        for i in 0..6u64 {
            let (reply_tx, reply_rx) = mpsc::channel();
            let x: Vec<f32> = (0..16).map(|j| ((i * 16 + j) as f32 * 0.1).sin()).collect();
            tx.send(transform_item(x.clone(), reply_tx)).unwrap();
            waiters.push((x, reply_rx));
        }
        drop(tx);
        let metrics = run(rx, set, None, 8, Duration::from_secs(5), Arc::clone(&state));
        for (x, reply_rx) in waiters {
            let reply = reply_rx.recv().unwrap().unwrap();
            let golden = QuantBwht::new(16, 16, 8).transform(&x);
            assert_eq!(reply.values, golden);
        }
        assert_eq!(metrics.requests, 6);
        assert_eq!(
            state.batches_total.load(Ordering::Relaxed),
            1,
            "a queued burst must coalesce into a single batch"
        );
        assert_eq!(state.e2e_latency.lock().unwrap().count(), 6);
    }

    #[test]
    fn max_batch_splits_oversized_bursts() {
        let set = test_set(2);
        let state = test_state(&set);
        let (tx, rx) = mpsc::channel();
        let mut waiters = Vec::new();
        for _ in 0..5 {
            let (reply_tx, reply_rx) = mpsc::channel();
            tx.send(transform_item(vec![0.5; 16], reply_tx)).unwrap();
            waiters.push(reply_rx);
        }
        drop(tx);
        let metrics = run(rx, set, None, 2, Duration::from_secs(5), Arc::clone(&state));
        for reply_rx in waiters {
            assert!(reply_rx.recv().unwrap().is_ok());
        }
        assert_eq!(metrics.requests, 5);
        assert_eq!(
            state.batches_total.load(Ordering::Relaxed),
            3,
            "5 queued requests at max_batch=2 -> 2+2+1"
        );
    }

    #[test]
    fn stale_items_are_dropped_not_executed() {
        let set = test_set(1);
        let state = test_state(&set);
        let (tx, rx) = mpsc::channel();
        let mut waiters = Vec::new();
        for _ in 0..3 {
            let (reply_tx, reply_rx) = mpsc::channel();
            tx.send(transform_item(vec![0.5; 16], reply_tx)).unwrap();
            waiters.push(reply_rx);
        }
        drop(tx);
        // stale_after = 0: everything is already expired at dispatch.
        let metrics = run(rx, set, None, 8, Duration::ZERO, Arc::clone(&state));
        assert_eq!(metrics.requests, 0, "stale work must not reach the pool");
        assert_eq!(state.stale_dropped_total.load(Ordering::Relaxed), 3);
        assert_eq!(state.batches_total.load(Ordering::Relaxed), 0);
        for reply_rx in waiters {
            assert!(reply_rx.recv().is_err(), "reply sender must be dropped");
        }
    }

    #[test]
    fn expired_deadline_items_are_dropped_before_dispatch() {
        let set = test_set(1);
        let state = test_state(&set);
        let (tx, rx) = mpsc::channel();
        // One live item, one whose deadline has already passed.
        let (live_tx, live_rx) = mpsc::channel();
        tx.send(transform_item(vec![0.5; 16], live_tx)).unwrap();
        let (dead_tx, dead_rx) = mpsc::channel();
        let mut dead = transform_item(vec![0.25; 16], dead_tx);
        dead.deadline = Some(Instant::now() - Duration::from_millis(1));
        tx.send(dead).unwrap();
        drop(tx);
        let metrics = run(rx, set, None, 8, Duration::from_secs(5), Arc::clone(&state));
        assert!(live_rx.recv().unwrap().is_ok(), "the live item still serves");
        assert!(dead_rx.recv().is_err(), "expired sink is dropped, not answered");
        assert_eq!(state.deadline_expired_total.load(Ordering::Relaxed), 1);
        assert_eq!(
            state.stale_dropped_total.load(Ordering::Relaxed),
            0,
            "deadline expiry is its own counter, not a stale drop"
        );
        assert_eq!(metrics.requests, 1, "expired work never reaches the pool");
    }

    #[test]
    fn future_deadline_rides_through_to_a_normal_reply() {
        let set = test_set(1);
        let state = test_state(&set);
        let (tx, rx) = mpsc::channel();
        let (reply_tx, reply_rx) = mpsc::channel();
        let x = vec![0.5; 16];
        let mut item = transform_item(x.clone(), reply_tx);
        item.deadline = Some(Instant::now() + Duration::from_secs(30));
        tx.send(item).unwrap();
        drop(tx);
        run(rx, set, None, 8, Duration::from_secs(5), Arc::clone(&state));
        let reply = reply_rx.recv().unwrap().unwrap();
        assert_eq!(reply.values, QuantBwht::new(16, 16, 8).transform(&x));
        assert_eq!(state.deadline_expired_total.load(Ordering::Relaxed), 0);
    }

    #[cfg(feature = "chaos")]
    #[test]
    fn injected_batcher_stall_slows_replies_without_corrupting_them() {
        use crate::chaos::ChaosPlan;
        let set = ShardSet::new(ShardSetConfig {
            shards: 1,
            coordinator: crate::coordinator::CoordinatorConfig {
                chaos: ChaosPlan::parse("batcher.stall=1.0,11").unwrap(),
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        let state = test_state(&set);
        let (tx, rx) = mpsc::channel();
        let (reply_tx, reply_rx) = mpsc::channel();
        let x = vec![0.5; 16];
        tx.send(transform_item(x.clone(), reply_tx)).unwrap();
        drop(tx);
        let t0 = Instant::now();
        run(rx, set, None, 8, Duration::from_secs(5), state);
        assert!(
            t0.elapsed() >= crate::chaos::STALL,
            "the stall point must actually stall the batch loop"
        );
        let reply = reply_rx.recv().unwrap().unwrap();
        assert_eq!(reply.values, QuantBwht::new(16, 16, 8).transform(&x));
    }

    #[cfg(feature = "chaos")]
    #[test]
    fn injected_reply_drop_loses_the_reply_not_the_server() {
        use crate::chaos::ChaosPlan;
        let set = ShardSet::new(ShardSetConfig {
            shards: 1,
            coordinator: crate::coordinator::CoordinatorConfig {
                chaos: ChaosPlan::parse("batcher.reply.drop=1.0,12").unwrap(),
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        let state = test_state(&set);
        let (tx, rx) = mpsc::channel();
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(transform_item(vec![0.5; 16], reply_tx)).unwrap();
        drop(tx);
        let metrics = run(rx, set, None, 8, Duration::from_secs(5), state);
        assert!(
            reply_rx.recv().is_err(),
            "a dropped reply surfaces as a disconnected sink (the 504 path)"
        );
        assert_eq!(metrics.requests, 1, "the work itself still executed");
    }

    fn tiny_mlp(hidden: usize) -> Mlp {
        let mut r = Rng::seed_from_u64(5);
        let din = 8;
        let classes = 3;
        Mlp::from_flat(
            din,
            hidden,
            classes,
            r.normal_vec_f32(din * hidden, 0.0, 0.5),
            vec![0.0; hidden],
            vec![0.05; hidden],
            r.normal_vec_f32(hidden * classes, 0.0, 0.5),
            vec![0.0; classes],
        )
    }

    #[test]
    fn infer_items_coalesce_into_one_model_forward_bit_identical_to_quantized() {
        // hidden = 16 -> one 16-wide BWHT block per sample, matching the
        // default tile_n = 16 of the test set.
        let mlp = tiny_mlp(16);
        let set = test_set(2);
        let state = test_state(&set);
        let (tx, rx) = mpsc::channel();
        let mut waiters = Vec::new();
        let mut all_x = Vec::new();
        for i in 0..4u64 {
            let (reply_tx, reply_rx) = mpsc::channel();
            let x: Vec<f32> = (0..8).map(|j| ((i * 8 + j) as f32 * 0.21).cos()).collect();
            all_x.extend_from_slice(&x);
            tx.send(BatchItem {
                payload: BatchPayload::Infer { x, samples: 1 },
                reply: ReplySink::channel(reply_tx),
                enqueued: Instant::now(),
                deadline: None,
                trace: TraceHandle::inactive(),
            })
            .unwrap();
            waiters.push(reply_rx);
        }
        drop(tx);
        let metrics = run(
            rx,
            set,
            Some(mlp.clone()),
            8,
            Duration::from_secs(5),
            Arc::clone(&state),
        );
        // Golden: the legacy in-process quantized backend over the same
        // batch (the digital path never consumes the rng).
        let golden = mlp.forward(
            &all_x,
            4,
            Backend::Quantized { bits: 8 },
            &mut Rng::seed_from_u64(0),
        );
        for (i, reply_rx) in waiters.into_iter().enumerate() {
            let reply = reply_rx.recv().unwrap().unwrap();
            assert_eq!(
                reply.values,
                golden[i * 3..(i + 1) * 3].to_vec(),
                "sample {i}"
            );
        }
        assert_eq!(state.infer_batches_total.load(Ordering::Relaxed), 1);
        assert_eq!(state.infer_samples_total.load(Ordering::Relaxed), 4);
        assert_eq!(state.infer_latency.lock().unwrap().count(), 4);
        assert!(metrics.requests > 0, "transforms must hit the tile pools");
    }

    #[test]
    fn infer_without_a_model_reports_a_clean_error() {
        let set = test_set(1);
        let state = test_state(&set);
        let (tx, rx) = mpsc::channel();
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(BatchItem {
            payload: BatchPayload::Infer {
                x: vec![0.0; 8],
                samples: 1,
            },
            reply: ReplySink::channel(reply_tx),
            enqueued: Instant::now(),
            deadline: None,
            trace: TraceHandle::inactive(),
        })
        .unwrap();
        drop(tx);
        run(rx, set, None, 8, Duration::from_secs(5), state);
        let err = reply_rx.recv().unwrap().unwrap_err();
        assert!(err.contains("no model"), "{err}");
    }

    #[cfg(not(feature = "trace-off"))]
    #[test]
    fn traced_transform_item_records_queue_and_execution_spans() {
        use crate::trace::{TraceConfig, Tracer};
        let set = test_set(1);
        let state = test_state(&set);
        let tracer = Tracer::new(TraceConfig::default());
        let handle = tracer.begin("/v1/transform");
        assert!(handle.is_active(), "sample_every=1 must trace everything");
        let (tx, rx) = mpsc::channel();
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut item = transform_item(vec![0.5; 16], reply_tx);
        item.trace = handle.clone();
        tx.send(item).unwrap();
        drop(tx);
        run(rx, set, None, 8, Duration::from_secs(5), state);
        assert!(reply_rx.recv().unwrap().is_ok());
        tracer.finish(handle);
        let traces = tracer.recent(1);
        assert_eq!(traces.len(), 1);
        let stages: Vec<&str> = traces[0].spans.iter().map(|s| s.stage.as_str()).collect();
        for want in ["queue", "plan", "scatter", "pool_queue", "execute", "drain"] {
            assert!(stages.contains(&want), "missing {want} in {stages:?}");
        }
    }

    #[cfg(not(feature = "monitor-off"))]
    #[test]
    fn drift_flagged_slot_is_recycled_by_the_health_tick() {
        use crate::coordinator::{CoordinatorConfig, TileKind};
        use crate::monitor::{MonitorConfig, ShadowSample};
        let mut set = ShardSet::new(ShardSetConfig {
            shards: 2,
            kinds: Some(vec![
                TileKind::Digital,
                TileKind::Noisy { sigma_ant: 2e-3 },
            ]),
            ..Default::default()
        })
        .unwrap();
        let monitor = Arc::new(Monitor::start(
            MonitorConfig {
                sample_every: 1,
                drift_threshold: 0.5,
                ..Default::default()
            },
            CoordinatorConfig::default(),
            set.non_digital_slots(),
            set.slot_health_handle(),
        ));
        assert!(monitor.is_enabled());
        set.set_monitor(monitor.handle());
        let state = Arc::new(ServerState::new(
            AdmissionConfig::default(),
            set.aggregator(),
            set.health_handle(),
            set.respawns_handle(),
            set.slot_health_handle(),
            EnergyModel::new(16, 0.8),
            Arc::new(trace::Tracer::new(trace::TraceConfig::default())),
            Arc::clone(&monitor),
        ));
        // Deterministic drift: feed the checker one grossly wrong
        // observation for slot 1 (no traffic required).
        monitor.handle().enqueue(ShadowSample {
            shard: 1,
            request: TransformRequest::plain(vec![0.5; 16]),
            blocks: vec![16],
            observed: vec![1e6; 16],
        });
        let t0 = Instant::now();
        while monitor.flagged_slots().is_empty() {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "checker never flagged the drifting slot"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(!set.is_healthy(1) || !state.slot_health[1].load(Ordering::Acquire));

        // An idle batcher's health tick must poison + respawn the slot.
        let (tx, rx) = mpsc::channel::<BatchItem>();
        let batcher_state = Arc::clone(&state);
        let handle = std::thread::spawn(move || {
            run_batcher(
                rx,
                set,
                None,
                8,
                Duration::from_millis(5),
                Duration::from_secs(5),
                Duration::from_millis(20),
                true,
                batcher_state,
            )
        });
        let t0 = Instant::now();
        while monitor.drift_respawns_total() == 0 {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "health tick never recycled the drifting slot"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(state.shard_respawns.load(Ordering::Acquire) >= 1);
        assert!(monitor.flagged_slots().is_empty(), "drift state resets");
        assert!(
            state.slot_health[1].load(Ordering::Acquire),
            "the recycled slot is ready again"
        );
        drop(tx);
        handle.join().unwrap();
        assert_eq!(monitor.drift_respawns_total(), 1);
    }

    #[test]
    fn health_tick_respawns_poisoned_shards_before_dispatch() {
        let mut set = test_set(2);
        let state = test_state(&set);
        // Kill shard 0 up front: the first dispatch re-routes its slices
        // (poisoning it), and a later heal pass respawns it.
        set.coordinator_mut(0).unwrap().abort();
        let (tx, rx) = mpsc::channel();
        let batcher_state = Arc::clone(&state);
        let handle =
            std::thread::spawn(move || run(rx, set, None, 1, Duration::from_secs(5), batcher_state));
        for _ in 0..3 {
            let (reply_tx, reply_rx) = mpsc::channel();
            tx.send(transform_item(vec![0.5; 64], reply_tx)).unwrap();
            assert!(reply_rx.recv().unwrap().is_ok(), "requests keep serving");
            // Give the batcher a beat between batches so poisoning and
            // healing happen across iterations.
            std::thread::sleep(Duration::from_millis(20));
        }
        // While the batcher still owns the set: the kill must have been
        // healed (the shutdown below zeroes the gauge by design).
        assert!(
            state.shard_respawns.load(Ordering::Acquire) >= 1,
            "the dead shard must be respawned by the health loop"
        );
        assert_eq!(
            state.shards_healthy.load(Ordering::Acquire),
            2,
            "the set must be fully healthy again"
        );
        drop(tx);
        handle.join().unwrap();
    }
}

//! Readiness primitives for the event-driven serving core.
//!
//! The build box is offline (no tokio/mio/libc crates), so this module
//! binds the three syscalls the reactor needs — `epoll`, `eventfd` and
//! raw `read`/`write` on the eventfd — directly against the platform
//! libc, and layers the small abstractions the connection state
//! machine composes:
//!
//! * [`Epoll`] — a level-triggered epoll instance.  Level-triggered
//!   keeps the state machine simple (no drain-to-`EAGAIN` obligations
//!   on every wakeup); write interest is registered only while a
//!   response is partially flushed, so the loop never spins on
//!   always-writable sockets.
//! * [`Waker`] — an `eventfd` that other threads (the batcher, via
//!   [`Completions`]) ring to get the reactor out of `epoll_wait`.
//! * [`TimerWheel`] — a coarse hashed wheel for idle/slowloris/request
//!   deadlines.  Entries are *hints* `(slot, gen)`; the reactor
//!   validates them against the connection's live deadline at expiry
//!   and re-arms if the deadline moved, so stale hints are harmless
//!   and cancellation is free.
//! * [`Completions`] — the asynchronous reply path: the batcher pushes
//!   a completion token + result and rings the waker; the reactor
//!   drains the queue and resumes the owning connection.
//!
//! Everything here is `std`-only; the `unsafe` is confined to the
//! syscall shims in [`sys`].

use std::io;
use std::os::fd::{AsRawFd, FromRawFd, OwnedFd, RawFd};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Raw syscall bindings.  Signatures mirror the glibc prototypes; all
/// callers live in this module.
mod sys {
    pub const EPOLL_CLOEXEC: i32 = 0o2000000;

    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;

    pub const EPOLLIN: u32 = 0x1;
    pub const EPOLLOUT: u32 = 0x4;
    pub const EPOLLERR: u32 = 0x8;
    pub const EPOLLHUP: u32 = 0x10;
    pub const EPOLLRDHUP: u32 = 0x2000;
    pub const EPOLLEXCLUSIVE: u32 = 1 << 28;

    pub const EFD_CLOEXEC: i32 = 0o2000000;
    pub const EFD_NONBLOCK: i32 = 0o4000;

    /// Matches the kernel ABI: packed on x86-64, natural elsewhere.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        pub fn eventfd(initval: u32, flags: i32) -> i32;
        pub fn read(fd: i32, buf: *mut core::ffi::c_void, count: usize) -> isize;
        pub fn write(fd: i32, buf: *const core::ffi::c_void, count: usize) -> isize;
    }
}

/// Readiness interest/event bits, re-exported for the event loop.
pub mod interest {
    pub const READ: u32 = super::sys::EPOLLIN | super::sys::EPOLLRDHUP;
    pub const WRITE: u32 = super::sys::EPOLLOUT;
    /// No readiness interest; errors/hangups are still delivered.
    pub const NONE: u32 = 0;
}

/// One decoded readiness event.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Peer shut down its write half (`EPOLLRDHUP`): reads will drain
    /// to EOF, writes may still succeed.
    pub rdhup: bool,
    /// Hard error or full hangup (`EPOLLERR`/`EPOLLHUP`).
    pub error: bool,
}

const MAX_EVENTS: usize = 256;

/// A level-triggered epoll instance.
pub struct Epoll {
    fd: OwnedFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Epoll { fd: unsafe { OwnedFd::from_raw_fd(fd) } })
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = sys::EpollEvent { events, data: token };
        let rc = unsafe { sys::epoll_ctl(self.fd.as_raw_fd(), op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    pub fn add(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, events, token)
    }

    /// Register a listener shared by several reactor threads:
    /// `EPOLLEXCLUSIVE` wakes one waiter per connection burst instead
    /// of thundering every reactor.
    pub fn add_exclusive(&self, fd: RawFd, token: u64) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, sys::EPOLLIN | sys::EPOLLEXCLUSIVE, token)
    }

    pub fn modify(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, events, token)
    }

    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait for readiness, decoding into `out` (cleared first).
    /// `timeout` of `None` blocks indefinitely.  EINTR reads as an
    /// empty wakeup.
    pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        out.clear();
        let timeout_ms: i32 = match timeout {
            // Round up so a 0.4ms deadline doesn't busy-poll at 0ms.
            Some(t) => t.as_millis().saturating_add(1).min(i32::MAX as u128) as i32,
            None => -1,
        };
        let mut buf = [sys::EpollEvent { events: 0, data: 0 }; MAX_EVENTS];
        let n = unsafe {
            sys::epoll_wait(self.fd.as_raw_fd(), buf.as_mut_ptr(), MAX_EVENTS as i32, timeout_ms)
        };
        if n < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(err);
        }
        for ev in buf.iter().take(n as usize) {
            // Copy out of the (possibly packed) struct before use.
            let bits = ev.events;
            let token = ev.data;
            out.push(Event {
                token,
                readable: bits & sys::EPOLLIN != 0,
                writable: bits & sys::EPOLLOUT != 0,
                rdhup: bits & sys::EPOLLRDHUP != 0,
                error: bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0,
            });
        }
        Ok(())
    }
}

/// Cross-thread wakeup for a blocked `epoll_wait`, built on `eventfd`.
pub struct Waker {
    fd: OwnedFd,
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        let fd = unsafe { sys::eventfd(0, sys::EFD_CLOEXEC | sys::EFD_NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Waker { fd: unsafe { OwnedFd::from_raw_fd(fd) } })
    }

    pub fn as_raw_fd(&self) -> RawFd {
        self.fd.as_raw_fd()
    }

    /// Ring the waker.  Idempotent while unread (the eventfd counter
    /// saturates); failure is impossible short of fd exhaustion, and
    /// then the reactor's periodic timeout still delivers progress.
    pub fn wake(&self) {
        let one: u64 = 1;
        unsafe {
            sys::write(self.fd.as_raw_fd(), (&one as *const u64).cast(), 8);
        }
    }

    /// Clear the pending wakeup count.
    pub fn drain(&self) {
        let mut buf: u64 = 0;
        unsafe {
            sys::read(self.fd.as_raw_fd(), (&mut buf as *mut u64).cast(), 8);
        }
    }
}

/// Coarse hashed timer wheel: buckets of `(slot, gen)` hints.
///
/// Insertion rounds deadlines *up* to the next bucket boundary, so a
/// hint never fires before its deadline; deadlines beyond the wheel
/// horizon clamp to the last bucket and simply get revalidated (and
/// re-armed) early.  The reactor re-checks the owning connection's
/// actual deadline when a hint fires, which makes re-arming a deadline
/// (every request on a keep-alive connection) free: the stale hint is
/// ignored when it surfaces.
pub struct TimerWheel {
    buckets: Vec<Vec<(u32, u16)>>,
    granularity: Duration,
    cursor: usize,
    /// Start time of the bucket at `cursor`.
    cursor_time: Instant,
}

impl TimerWheel {
    pub fn new(granularity: Duration, buckets: usize, now: Instant) -> TimerWheel {
        assert!(buckets >= 2 && granularity > Duration::ZERO);
        TimerWheel {
            buckets: (0..buckets).map(|_| Vec::new()).collect(),
            granularity,
            cursor: 0,
            cursor_time: now,
        }
    }

    /// Arm a hint for `deadline`.
    pub fn insert(&mut self, deadline: Instant, slot: u32, gen: u16) {
        let delta = deadline.saturating_duration_since(self.cursor_time);
        let gran = self.granularity.as_nanos().max(1);
        let ticks = (delta.as_nanos().div_ceil(gran)).max(1) as usize;
        let ticks = ticks.min(self.buckets.len() - 1);
        let idx = (self.cursor + ticks) % self.buckets.len();
        self.buckets[idx].push((slot, gen));
    }

    /// Advance the wheel to `now`, draining expired hints into `out`.
    pub fn advance(&mut self, now: Instant, out: &mut Vec<(u32, u16)>) {
        while now.saturating_duration_since(self.cursor_time) >= self.granularity {
            self.cursor_time += self.granularity;
            self.cursor = (self.cursor + 1) % self.buckets.len();
            out.append(&mut self.buckets[self.cursor]);
        }
    }

    /// Time until the nearest armed hint could fire, if any.
    pub fn next_timeout(&self, now: Instant) -> Option<Duration> {
        let n = self.buckets.len();
        (1..n)
            .find(|off| !self.buckets[(self.cursor + off) % n].is_empty())
            .map(|off| {
                let fires = self.cursor_time + self.granularity * off as u32;
                fires.saturating_duration_since(now)
            })
    }
}

/// One asynchronous reply routed back into a reactor.
pub struct Completion {
    /// Packed `(slot, gen, seq)` minted by the dispatching connection.
    pub token: u64,
    /// `None` when the batcher dropped the reply without sending (the
    /// stale-shed path) — surfaced to the client as a 504.
    pub result: Option<crate::server::batcher::ReplyResult>,
}

/// The batcher-to-reactor completion queue: a mutexed vector plus the
/// reactor's waker.  Contention is one short critical section per
/// reply on each side.
pub struct Completions {
    queue: Mutex<Vec<Completion>>,
    waker: Waker,
}

impl Completions {
    pub fn new(waker: Waker) -> Completions {
        Completions { queue: Mutex::new(Vec::new()), waker }
    }

    pub fn waker(&self) -> &Waker {
        &self.waker
    }

    /// Push a completion and ring the reactor (only on the empty→
    /// non-empty edge: one wake covers a whole batch fan-out).
    pub fn push(&self, completion: Completion) {
        let was_empty = {
            let mut queue = self.queue.lock().unwrap();
            let was_empty = queue.is_empty();
            queue.push(completion);
            was_empty
        };
        if was_empty {
            self.waker.wake();
        }
    }

    /// Move all pending completions into `out` (appended).
    pub fn drain_into(&self, out: &mut Vec<Completion>) {
        out.append(&mut self.queue.lock().unwrap());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn waker_rings_epoll() {
        let epoll = Epoll::new().unwrap();
        let waker = Waker::new().unwrap();
        epoll.add(waker.as_raw_fd(), interest::READ, 7).unwrap();
        let mut events = Vec::new();
        // Nothing pending: times out empty.
        epoll.wait(&mut events, Some(Duration::from_millis(1))).unwrap();
        assert!(events.is_empty());
        waker.wake();
        waker.wake(); // coalesces
        epoll.wait(&mut events, Some(Duration::from_millis(500))).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);
        waker.drain();
        epoll.wait(&mut events, Some(Duration::from_millis(1))).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn epoll_reports_socket_readiness_and_interest_changes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let epoll = Epoll::new().unwrap();
        epoll.add(server.as_raw_fd(), interest::READ, 1).unwrap();
        let mut events = Vec::new();
        epoll.wait(&mut events, Some(Duration::from_millis(1))).unwrap();
        assert!(events.is_empty(), "no data yet");

        use std::io::Write as _;
        client.write_all(b"ping").unwrap();
        epoll.wait(&mut events, Some(Duration::from_millis(500))).unwrap();
        assert!(events.iter().any(|e| e.token == 1 && e.readable));

        // Switch to write interest: an idle socket is instantly writable.
        epoll.modify(server.as_raw_fd(), interest::WRITE, 2).unwrap();
        epoll.wait(&mut events, Some(Duration::from_millis(500))).unwrap();
        assert!(events.iter().any(|e| e.token == 2 && e.writable));

        // Peer close surfaces as rdhup once read interest is back.
        epoll.modify(server.as_raw_fd(), interest::READ, 3).unwrap();
        drop(client);
        epoll.wait(&mut events, Some(Duration::from_millis(500))).unwrap();
        assert!(events.iter().any(|e| e.token == 3 && (e.rdhup || e.readable)));

        epoll.delete(server.as_raw_fd()).unwrap();
    }

    #[test]
    fn timer_wheel_fires_hints_no_earlier_than_their_deadline() {
        let t0 = Instant::now();
        let mut wheel = TimerWheel::new(Duration::from_millis(10), 64, t0);
        wheel.insert(t0 + Duration::from_millis(25), 3, 1);
        let mut fired = Vec::new();
        wheel.advance(t0 + Duration::from_millis(20), &mut fired);
        assert!(fired.is_empty(), "hint must not fire before its deadline");
        wheel.advance(t0 + Duration::from_millis(40), &mut fired);
        assert_eq!(fired, vec![(3, 1)]);
        // Beyond-horizon deadlines clamp and fire early (reactor
        // revalidates and re-arms).
        wheel.insert(t0 + Duration::from_secs(3600), 9, 2);
        assert!(wheel.next_timeout(t0 + Duration::from_millis(40)).is_some());
        fired.clear();
        wheel.advance(t0 + Duration::from_millis(700), &mut fired);
        assert_eq!(fired, vec![(9, 2)]);
    }

    #[test]
    fn timer_wheel_next_timeout_tracks_nearest_bucket() {
        let t0 = Instant::now();
        let mut wheel = TimerWheel::new(Duration::from_millis(10), 64, t0);
        assert!(wheel.next_timeout(t0).is_none());
        wheel.insert(t0 + Duration::from_millis(50), 1, 1);
        let next = wheel.next_timeout(t0).unwrap();
        assert!(next >= Duration::from_millis(40) && next <= Duration::from_millis(60));
    }

    #[test]
    fn completions_wake_once_per_batch() {
        let completions = Completions::new(Waker::new().unwrap());
        let epoll = Epoll::new().unwrap();
        epoll
            .add(completions.waker().as_raw_fd(), interest::READ, 0)
            .unwrap();
        completions.push(Completion { token: 1, result: None });
        completions.push(Completion { token: 2, result: None });
        let mut events = Vec::new();
        epoll.wait(&mut events, Some(Duration::from_millis(500))).unwrap();
        assert_eq!(events.len(), 1);
        let mut out = Vec::new();
        completions.drain_into(&mut out);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].token, 1);
    }
}

//! Admission control: bounded in-flight limit + per-client token buckets.
//!
//! Sits in front of the batcher so overload is shed in microseconds with
//! a 429 instead of queueing without bound behind the coordinator's
//! backpressure.  Two independent gates:
//!
//! * a server-wide **in-flight cap** (requests between admission and
//!   reply), the fast-shed layer on top of the pool's bounded queues;
//! * a **per-client token bucket** (keyed by peer IP) for steady-state
//!   rate limiting with a configurable burst allowance.

use std::collections::HashMap;
use std::net::IpAddr;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Admission policy knobs.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Maximum requests between admission and reply; 0 disables the cap.
    pub max_inflight: usize,
    /// Per-client steady-state requests/sec; 0.0 disables rate limiting.
    pub rate_per_sec: f64,
    /// Per-client burst allowance (token bucket capacity).
    pub burst: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_inflight: 256,
            rate_per_sec: 0.0,
            burst: 32.0,
        }
    }
}

/// Cap on tracked client buckets; hitting it sweeps out every bucket
/// that has fully refilled (it carries no rate-limiting state worth
/// keeping), so memory is bounded by *actively limited* clients.
const MAX_TRACKED_CLIENTS: usize = 4096;

/// Why a request was shed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejection {
    /// The server-wide in-flight cap is reached.
    Overloaded,
    /// This client exhausted its token bucket.
    RateLimited,
}

#[derive(Debug, Clone, Copy)]
struct TokenBucket {
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    fn take(&mut self, now: Instant, rate: f64, burst: f64) -> bool {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * rate).min(burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Shared admission state (one per server).
#[derive(Debug)]
pub struct Admission {
    config: AdmissionConfig,
    inflight: AtomicUsize,
    buckets: Mutex<HashMap<IpAddr, TokenBucket>>,
    admitted: AtomicU64,
    shed_overload: AtomicU64,
    shed_rate: AtomicU64,
}

impl Admission {
    pub fn new(config: AdmissionConfig) -> Admission {
        Admission {
            config,
            inflight: AtomicUsize::new(0),
            buckets: Mutex::new(HashMap::new()),
            admitted: AtomicU64::new(0),
            shed_overload: AtomicU64::new(0),
            shed_rate: AtomicU64::new(0),
        }
    }

    /// Try to admit a request from `client`.  On success the returned
    /// permit holds an in-flight slot until dropped.  A rate-limit
    /// rejection after the token was the last gate does not refund — the
    /// bucket models work the client asked the server to consider.
    ///
    /// The permit is **owned** (it keeps the `Arc` alive) so the
    /// event-driven front end can park it in a connection while the
    /// batcher completes the request asynchronously.
    pub fn try_acquire(
        self: &Arc<Self>,
        client: IpAddr,
        now: Instant,
    ) -> Result<InflightPermit, Rejection> {
        if self.config.rate_per_sec > 0.0 {
            let mut buckets = self.buckets.lock().expect("bucket map poisoned");
            if buckets.len() >= MAX_TRACKED_CLIENTS && !buckets.contains_key(&client) {
                let rate = self.config.rate_per_sec;
                let burst = self.config.burst;
                buckets.retain(|_, b| {
                    let dt = now.saturating_duration_since(b.last).as_secs_f64();
                    b.tokens + dt * rate < burst
                });
            }
            let bucket = buckets.entry(client).or_insert_with(|| TokenBucket {
                tokens: self.config.burst,
                last: now,
            });
            if !bucket.take(now, self.config.rate_per_sec, self.config.burst) {
                self.shed_rate.fetch_add(1, Ordering::Relaxed);
                return Err(Rejection::RateLimited);
            }
        }
        let counted = self.config.max_inflight > 0;
        if counted {
            let acquired = self
                .inflight
                .fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| {
                    (v < self.config.max_inflight).then_some(v + 1)
                })
                .is_ok();
            if !acquired {
                self.shed_overload.fetch_add(1, Ordering::Relaxed);
                return Err(Rejection::Overloaded);
            }
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        Ok(InflightPermit {
            admission: Arc::clone(self),
            counted,
        })
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    pub fn admitted_total(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn shed_overload_total(&self) -> u64 {
        self.shed_overload.load(Ordering::Relaxed)
    }

    pub fn shed_ratelimited_total(&self) -> u64 {
        self.shed_rate.load(Ordering::Relaxed)
    }

    /// Client buckets currently tracked by the rate limiter.
    pub fn tracked_clients(&self) -> usize {
        self.buckets.lock().expect("bucket map poisoned").len()
    }
}

/// RAII in-flight slot; dropping it releases the slot.
#[derive(Debug)]
pub struct InflightPermit {
    admission: Arc<Admission>,
    counted: bool,
}

impl Drop for InflightPermit {
    fn drop(&mut self) {
        if self.counted {
            self.admission.inflight.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::Ipv4Addr;
    use std::time::Duration;

    fn ip(last: u8) -> IpAddr {
        IpAddr::V4(Ipv4Addr::new(127, 0, 0, last))
    }

    #[test]
    fn inflight_cap_sheds_and_releases() {
        let adm = Arc::new(Admission::new(AdmissionConfig {
            max_inflight: 2,
            rate_per_sec: 0.0,
            burst: 1.0,
        }));
        let now = Instant::now();
        let p1 = adm.try_acquire(ip(1), now).unwrap();
        let _p2 = adm.try_acquire(ip(1), now).unwrap();
        assert_eq!(adm.inflight(), 2);
        assert_eq!(adm.try_acquire(ip(1), now).unwrap_err(), Rejection::Overloaded);
        assert_eq!(adm.shed_overload_total(), 1);
        drop(p1);
        assert_eq!(adm.inflight(), 1);
        let _p3 = adm.try_acquire(ip(1), now).unwrap();
        assert_eq!(adm.admitted_total(), 3);
    }

    #[test]
    fn token_bucket_limits_burst_then_refills() {
        let adm = Arc::new(Admission::new(AdmissionConfig {
            max_inflight: 0,
            rate_per_sec: 10.0,
            burst: 2.0,
        }));
        let t0 = Instant::now();
        assert!(adm.try_acquire(ip(1), t0).is_ok());
        assert!(adm.try_acquire(ip(1), t0).is_ok());
        assert_eq!(adm.try_acquire(ip(1), t0).unwrap_err(), Rejection::RateLimited);
        assert_eq!(adm.shed_ratelimited_total(), 1);
        // 10 req/s -> one token back after 100 ms.
        let t1 = t0 + Duration::from_millis(150);
        assert!(adm.try_acquire(ip(1), t1).is_ok());
        assert_eq!(adm.try_acquire(ip(1), t1).unwrap_err(), Rejection::RateLimited);
    }

    #[test]
    fn buckets_are_per_client() {
        let adm = Arc::new(Admission::new(AdmissionConfig {
            max_inflight: 0,
            rate_per_sec: 1.0,
            burst: 1.0,
        }));
        let now = Instant::now();
        assert!(adm.try_acquire(ip(1), now).is_ok());
        assert_eq!(adm.try_acquire(ip(1), now).unwrap_err(), Rejection::RateLimited);
        assert!(adm.try_acquire(ip(2), now).is_ok(), "other clients unaffected");
    }

    #[test]
    fn bucket_map_is_swept_at_the_client_cap() {
        let adm = Arc::new(Admission::new(AdmissionConfig {
            max_inflight: 0,
            rate_per_sec: 1.0,
            burst: 1.0,
        }));
        let t0 = Instant::now();
        for i in 0..MAX_TRACKED_CLIENTS as u32 {
            let client = IpAddr::V4(Ipv4Addr::from(0x0a00_0000u32 + i));
            let _ = adm.try_acquire(client, t0);
        }
        assert_eq!(adm.tracked_clients(), MAX_TRACKED_CLIENTS);
        // Two seconds later every bucket has refilled to burst, so a new
        // client triggers a sweep instead of unbounded growth.
        let t1 = t0 + Duration::from_secs(2);
        let fresh = IpAddr::V4(Ipv4Addr::new(192, 168, 0, 1));
        assert!(adm.try_acquire(fresh, t1).is_ok());
        assert_eq!(adm.tracked_clients(), 1, "refilled buckets evicted");
    }

    #[test]
    fn disabled_gates_admit_everything() {
        let adm = Arc::new(Admission::new(AdmissionConfig {
            max_inflight: 0,
            rate_per_sec: 0.0,
            burst: 0.0,
        }));
        let now = Instant::now();
        let permits: Vec<_> = (0..64)
            .map(|_| adm.try_acquire(ip(1), now).unwrap())
            .collect();
        assert_eq!(adm.inflight(), 0, "uncounted when the cap is disabled");
        drop(permits);
        assert_eq!(adm.admitted_total(), 64);
    }
}

//! Minimal `.npy` reader/writer for the build-time data interchange
//! (datasets and init params exported by `python/compile/aot.py`).
//!
//! Supports the subset numpy actually emits for our arrays: format v1.0/
//! v2.0 headers, little-endian `<f4`/`<i4`, C order, no pickles.

use std::fs;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

/// A loaded array: shape + flat data.
#[derive(Debug, Clone, PartialEq)]
pub struct NpyArray<T> {
    pub shape: Vec<usize>,
    pub data: Vec<T>,
}

impl<T> NpyArray<T> {
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row `i` of a 2-D array.
    pub fn row(&self, i: usize) -> &[T] {
        assert_eq!(self.shape.len(), 2, "row() requires a 2-D array");
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }
}

fn parse_header(buf: &[u8]) -> Result<(String, bool, Vec<usize>, usize)> {
    if buf.len() < 10 || &buf[..6] != b"\x93NUMPY" {
        bail!("not a .npy file");
    }
    let major = buf[6];
    let (hlen, start) = match major {
        1 => (u16::from_le_bytes([buf[8], buf[9]]) as usize, 10),
        2 => (
            u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize,
            12,
        ),
        v => bail!("unsupported .npy version {v}"),
    };
    let header = std::str::from_utf8(&buf[start..start + hlen])
        .map_err(|e| anyhow!("bad header utf8: {e}"))?;
    // header is a python dict literal:
    // {'descr': '<f4', 'fortran_order': False, 'shape': (64, 64), }
    let descr = header
        .split("'descr':")
        .nth(1)
        .and_then(|s| s.split('\'').nth(1))
        .ok_or_else(|| anyhow!("missing descr"))?
        .to_string();
    let fortran = header.contains("'fortran_order': True");
    let shape_str = header
        .split("'shape':")
        .nth(1)
        .and_then(|s| s.split('(').nth(1))
        .and_then(|s| s.split(')').next())
        .ok_or_else(|| anyhow!("missing shape"))?;
    let shape: Vec<usize> = shape_str
        .split(',')
        .filter_map(|t| {
            let t = t.trim();
            if t.is_empty() {
                None
            } else {
                Some(t.parse::<usize>())
            }
        })
        .collect::<std::result::Result<_, _>>()
        .map_err(|e| anyhow!("bad shape: {e}"))?;
    Ok((descr, fortran, shape, start + hlen))
}

macro_rules! impl_load {
    ($fn_name:ident, $ty:ty, $descr:literal, $width:literal) => {
        /// Load a `.npy` file of this element type.
        pub fn $fn_name(path: impl AsRef<Path>) -> Result<NpyArray<$ty>> {
            let buf = fs::read(path.as_ref())
                .map_err(|e| anyhow!("read {:?}: {e}", path.as_ref()))?;
            let (descr, fortran, shape, off) = parse_header(&buf)?;
            if descr != $descr {
                bail!("expected dtype {}, got {descr}", $descr);
            }
            if fortran {
                bail!("fortran order unsupported");
            }
            let count: usize = shape.iter().product::<usize>().max(1);
            let body = &buf[off..];
            if body.len() < count * $width {
                bail!("truncated data: {} < {}", body.len(), count * $width);
            }
            let data = body[..count * $width]
                .chunks_exact($width)
                .map(|c| <$ty>::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(NpyArray { shape, data })
        }
    };
}

impl_load!(load_f32, f32, "<f4", 4);
impl_load!(load_i32, i32, "<i4", 4);

/// Write a v1.0 `.npy` file (little-endian f32, C order).
pub fn save_f32(path: impl AsRef<Path>, shape: &[usize], data: &[f32]) -> Result<()> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let shape_str = match shape.len() {
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    let base = 10 + header.len() + 1;
    let pad = (64 - base % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    let mut out = Vec::with_capacity(10 + header.len() + data.len() * 4);
    out.extend_from_slice(b"\x93NUMPY\x01\x00");
    out.extend_from_slice(&(header.len() as u16).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let dir = crate::util::tempdir::TempDir::new("npy").unwrap();
        let path = dir.join("a.npy");
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5 - 3.0).collect();
        save_f32(&path, &[3, 4], &data).unwrap();
        let arr = load_f32(&path).unwrap();
        assert_eq!(arr.shape, vec![3, 4]);
        assert_eq!(arr.data, data);
        assert_eq!(arr.row(1), &data[4..8]);
    }

    #[test]
    fn rejects_wrong_dtype() {
        let dir = crate::util::tempdir::TempDir::new("npy").unwrap();
        let path = dir.join("a.npy");
        save_f32(&path, &[4], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert!(load_i32(&path).is_err());
    }

    #[test]
    fn rejects_garbage() {
        let dir = crate::util::tempdir::TempDir::new("npy").unwrap();
        let path = dir.join("g.npy");
        fs::write(&path, b"not numpy at all").unwrap();
        assert!(load_f32(&path).is_err());
    }

    #[test]
    fn one_dim_shape() {
        let dir = crate::util::tempdir::TempDir::new("npy").unwrap();
        let path = dir.join("v.npy");
        save_f32(&path, &[5], &[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        let arr = load_f32(&path).unwrap();
        assert_eq!(arr.shape, vec![5]);
    }
}

//! The N×N analog crossbar and its 4-step operation (Fig. 4).
//!
//! Step 1  PCH: precharge BL/BLB to VDD, CM high (columns stitched), load
//!         the input bitplane on CL/CLB (sign selects the line).
//! Step 2  RL: columns un-stitched, every cell computes its product into
//!         its *local* nodes O/OB in parallel (the design's key deviation
//!         from bit-line-compute CiM: local nodes are far less capacitive).
//! Step 3  RM: rows stitched; O (resp. OB) voltages charge-average onto
//!         SL (resp. SLB) per row.
//! Step 4  compare SL vs SLB per row ⇒ one output bit per row: ADC-free.
//!
//! The simulator reproduces this with per-cell residual/droop voltages,
//! charge-averaging with a merge-settling error that grows with array size
//! and shrinks with the RM/CM boost, a comparator with offset + thermal
//! noise, and per-cell Vth mismatch supplied by
//! [`variability`](super::variability).

use crate::util::rng::Rng;

use super::cell::{CellParams, CellPolarity};
use crate::wht;

/// Static configuration of one crossbar tile.
#[derive(Debug, Clone)]
pub struct CrossbarConfig {
    /// Array dimension N (the paper evaluates 16 and 32).
    pub n: usize,
    /// Supply voltage (V).
    pub vdd: f64,
    /// Extra boost on the CM/RM merge-switch gates (V); the paper uses
    /// +0.2 V to rescue 32×32 arrays at low VDD.
    pub merge_boost: f64,
    /// Comparator input-referred offset sigma (V).
    pub sigma_comparator: f64,
    /// Thermal noise sigma per comparison (V).
    pub sigma_thermal: f64,
    /// Cell electrical parameters.
    pub cell: CellParams,
    /// Merge-settling coefficient: the charge-share reaches its final
    /// average up to a relative error `exp(-k_merge * drive / sqrt(n))`
    /// where `drive = vdd + merge_boost - vth` (the merge switches' gate
    /// overdrive).  Larger arrays settle worse (longer stitched wire, same
    /// window) and the error explodes as VDD approaches Vth — the
    /// vulnerability of Fig. 11(c) that the +0.2 V boost rescues.
    pub k_merge: f64,
}

impl CrossbarConfig {
    pub fn new(n: usize, vdd: f64) -> Self {
        assert!(n.is_power_of_two(), "crossbar dimension must be 2^k");
        CrossbarConfig {
            n,
            vdd,
            merge_boost: 0.0,
            sigma_comparator: 0.004,
            sigma_thermal: 0.001,
            cell: CellParams::default(),
            k_merge: 80.0,
        }
    }

    pub fn with_boost(mut self, boost: f64) -> Self {
        self.merge_boost = boost;
        self
    }
}

/// An instantiated tile: configuration + one sample of process variability
/// (per-cell Vth, per-row comparator offsets).  Create via
/// [`variability::sample_instance`](super::variability::sample_instance)
/// or [`Crossbar::ideal`] for a mismatch-free tile.
#[derive(Debug, Clone)]
pub struct Crossbar {
    pub config: CrossbarConfig,
    /// Hardwired Walsh polarities, row-major N×N.
    polarity: Vec<CellPolarity>,
    /// Per-cell threshold voltages (row-major N×N).
    pub vth: Vec<f64>,
    /// Per-row comparator offsets (V).
    pub comparator_offset: Vec<f64>,
    /// PERF: per-cell discharged-node residual voltage, precomputed at
    /// construction (it depends only on the instance-fixed VDD and Vth,
    /// and the exp() dominated the bitplane hot loop — see
    /// EXPERIMENTS.md §Perf).  Signed by polarity so the inner loop is a
    /// single multiply-free lookup: `signed_drop[c] = polarity * (retained
    /// - discharged)`.
    signed_drop: Vec<f64>,
    /// Retained-node voltage (common to all cells of the instance).
    retained: f64,
    /// PERF: (1 − merge_error)/n, cached (exp() of instance constants).
    merge_scale: f64,
}

impl Crossbar {
    /// Mismatch-free instance (Vth nominal everywhere, zero offsets).
    pub fn ideal(config: CrossbarConfig) -> Self {
        let n = config.n;
        let k = n.trailing_zeros() as usize;
        let w = wht::walsh(k);
        let mut polarity = Vec::with_capacity(n * n);
        for i in 0..n {
            for j in 0..n {
                polarity.push(CellPolarity::from_sign(w.get(i, j)));
            }
        }
        let mut xb = Crossbar {
            polarity,
            vth: vec![config.cell.vth; n * n],
            comparator_offset: vec![0.0; n],
            signed_drop: Vec::new(),
            retained: 0.0,
            merge_scale: 0.0,
            config,
        };
        xb.precompute();
        xb
    }

    /// Replace variability fields (used by the Monte-Carlo harness).
    pub fn with_variability(mut self, vth: Vec<f64>, offsets: Vec<f64>) -> Self {
        assert_eq!(vth.len(), self.config.n * self.config.n);
        assert_eq!(offsets.len(), self.config.n);
        self.vth = vth;
        self.comparator_offset = offsets;
        self.precompute();
        self
    }

    /// Precompute the per-cell differential drop (retained − discharged),
    /// signed by the hardwired polarity.
    fn precompute(&mut self) {
        self.merge_scale = (1.0 - self.merge_error()) / self.config.n as f64;
        let vdd = self.config.vdd;
        let cell = self.config.cell;
        self.retained = vdd * (1.0 - cell.retention_droop);
        self.signed_drop = self
            .polarity
            .iter()
            .zip(&self.vth)
            .map(|(pol, &vth)| {
                let discharged = cell.residual(vdd, vdd, vth);
                pol.sign() as f64 * (self.retained - discharged)
            })
            .collect();
    }

    pub fn n(&self) -> usize {
        self.config.n
    }

    #[inline]
    fn polarity(&self, row: usize, col: usize) -> CellPolarity {
        self.polarity[row * self.config.n + col]
    }

    /// Merge-settling relative error for this configuration.
    fn merge_error(&self) -> f64 {
        let drive =
            (self.config.vdd + self.config.merge_boost - self.config.cell.vth).max(0.01);
        (-self.config.k_merge * drive / (self.config.n as f64).sqrt()).exp()
    }

    /// Execute the 4-step operation on one input bitplane.
    ///
    /// `input[j] ∈ {-1, 0, +1}` is the sign-magnitude bit on column `j`.
    /// Returns one comparator bit per row.  `rng` supplies the thermal
    /// noise of step 4 (offset and Vth mismatch are instance-fixed).
    pub fn execute_bitplane(&self, input: &[i8], rng: &mut Rng) -> Vec<i8> {
        let mut diffs = Vec::with_capacity(self.config.n);
        let mut out = vec![0i8; self.config.n];
        self.execute_bitplane_into(input, rng, &mut diffs, &mut out);
        out
    }

    /// [`Self::execute_bitplane`] through caller scratch: `diffs` holds
    /// the per-row differentials (capacity retained across planes), `out`
    /// receives one comparator bit per row.  Thermal-noise draws happen
    /// in the same row order under the same ±6σ skip rule, so the RNG
    /// stream is byte-identical to the allocating variant.
    pub fn execute_bitplane_into(
        &self,
        input: &[i8],
        rng: &mut Rng,
        diffs: &mut Vec<f64>,
        out: &mut [i8],
    ) {
        self.differential_into(input, diffs);
        assert_eq!(out.len(), self.config.n, "readout must cover every row");
        let sigma = self.config.sigma_thermal;
        // PERF: thermal noise can only flip a decision within ~6σ of the
        // trip point; beyond that the comparator outcome is deterministic
        // (flip probability < 1e-9), so skip the Box–Muller draw.
        let det_margin = 6.0 * sigma;
        for (i, (o, &d)) in out.iter_mut().zip(diffs.iter()).enumerate() {
            let v0 = d + self.comparator_offset[i];
            let v = if v0.abs() > det_margin {
                v0
            } else {
                v0 + rng.normal(0.0, sigma)
            };
            *o = if v > 0.0 {
                1
            } else if v < 0.0 {
                -1
            } else {
                0
            };
        }
    }

    /// Steps 1-3: per-row differential voltage SL − SLB before comparison.
    ///
    /// Derivation of the fast form: per cell, product p = input*polarity.
    /// p=+1 keeps O at `retained` and drops OB to the cell residual; p=−1
    /// mirrors; p=0 leaves both retained (zero differential).  So
    /// `O − OB = p * (retained − discharged)`, and with the polarity
    /// folded into `signed_drop` the row sum is a 3-way-select accumulate
    /// over precomputed constants — no exp() in the hot loop.
    pub fn differential(&self, input: &[i8]) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.config.n);
        self.differential_into(input, &mut out);
        out
    }

    /// [`Self::differential`] into a caller buffer (cleared, then filled).
    pub fn differential_into(&self, input: &[i8], out: &mut Vec<f64>) {
        let n = self.config.n;
        assert_eq!(input.len(), n, "input length must equal array dim");
        let scale = self.merge_scale;
        out.clear();
        out.reserve(n);
        for i in 0..n {
            let row = &self.signed_drop[i * n..(i + 1) * n];
            let mut diff = 0.0f64;
            for (&drop, &x) in row.iter().zip(input) {
                // x ∈ {-1, 0, +1}
                diff += x as f64 * drop;
            }
            out.push(diff * scale);
        }
    }

    /// Ideal (mismatch-free, noise-free) integer PSUM for reference.
    ///
    /// PERF: the hardwired polarities ARE the sequency-ordered Walsh
    /// matrix, so the O(n²) sign loop is the fast O(n log n) butterfly.
    pub fn ideal_psums(&self, input: &[i8]) -> Vec<i64> {
        let mut x: Vec<i64> = input.iter().map(|&v| v as i64).collect();
        crate::wht::fast::wht_sequency_i64(&mut x);
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::seed_from_u64(7)
    }

    #[test]
    fn ideal_crossbar_matches_digital_psums() {
        let xb = Crossbar::ideal(CrossbarConfig::new(16, 0.9));
        let mut r = rng();
        for trial in 0..50 {
            let input: Vec<i8> = (0..16).map(|j| (((trial * 31 + j * 7) % 3) as i8) - 1).collect();
            let bits = xb.execute_bitplane(&input, &mut r);
            let psums = xb.ideal_psums(&input);
            for (b, p) in bits.iter().zip(&psums) {
                if *p != 0 {
                    assert_eq!(
                        *b as i64,
                        p.signum(),
                        "ideal crossbar must reproduce sign(PSUM)"
                    );
                }
            }
        }
    }

    #[test]
    fn differential_scales_with_psum() {
        let xb = Crossbar::ideal(CrossbarConfig::new(16, 0.9));
        // all-ones input: row 0 of the Walsh matrix is all +1 => PSUM=16
        let input = vec![1i8; 16];
        let d = xb.differential(&input);
        let psums = xb.ideal_psums(&input);
        assert_eq!(psums[0], 16);
        assert!(d[0] > 0.8, "full-scale PSUM should give ~VDD differential");
        // rows with PSUM 0 give ~0 differential
        for (i, &p) in psums.iter().enumerate() {
            if p == 0 {
                assert!(d[i].abs() < 1e-6, "row {i}: {}", d[i]);
            }
        }
    }

    #[test]
    fn zero_input_gives_zero_bits_mostly() {
        let xb = Crossbar::ideal(CrossbarConfig::new(16, 0.9));
        let d = xb.differential(&vec![0i8; 16]);
        assert!(d.iter().all(|v| v.abs() < 1e-9));
    }

    #[test]
    fn merge_error_grows_with_array_size() {
        let e16 = Crossbar::ideal(CrossbarConfig::new(16, 0.7)).merge_error();
        let e32 = Crossbar::ideal(CrossbarConfig::new(32, 0.7)).merge_error();
        assert!(e32 > e16);
    }

    #[test]
    fn boost_reduces_merge_error() {
        let plain = Crossbar::ideal(CrossbarConfig::new(32, 0.7)).merge_error();
        let boosted =
            Crossbar::ideal(CrossbarConfig::new(32, 0.7).with_boost(0.2)).merge_error();
        assert!(boosted < plain);
    }

    #[test]
    #[should_panic(expected = "input length")]
    fn wrong_input_length_panics() {
        let xb = Crossbar::ideal(CrossbarConfig::new(16, 0.9));
        xb.differential(&[1i8; 8]);
    }

    #[test]
    fn comparator_offset_biases_decisions() {
        let cfg = CrossbarConfig::new(16, 0.9);
        let n = cfg.n;
        let xb = Crossbar::ideal(cfg).with_variability(
            vec![super::super::VTH_NOMINAL; 16 * 16],
            vec![0.5; n], // huge positive offset
        );
        let mut r = rng();
        let bits = xb.execute_bitplane(&vec![0i8; 16], &mut r);
        assert!(bits.iter().all(|&b| b == 1));
    }
}

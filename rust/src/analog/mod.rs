//! Analog crossbar behavioral simulator — the HSPICE/16nm-PTM substitute
//! (DESIGN.md §1).
//!
//! The paper evaluates its 6T-NMOS crossbar with HSPICE and predictive
//! technology models.  We reproduce the *statistics* those simulations
//! produce (Figs. 5, 11b-d, 12) with a charge-domain behavioral model:
//!
//! * [`cell`] — one ±1 cell: precharged local nodes O/OB, conditional
//!   discharge with a residual-voltage model whose completeness depends on
//!   gate overdrive (VDD − Vth), per-cell Vth mismatch included;
//! * [`crossbar`] — the N×N array and the 4-step / 2-clock operation
//!   (precharge+input, local compute, row-merge charge share, compare);
//! * [`variability`] — Pelgrom-scaled Vth sampling and the Monte-Carlo
//!   failure harness behind Fig. 11(b)/(c);
//! * [`timing`] — the Fig. 5 signal schedule as a checked state machine;
//! * [`noise`] — the algorithmic-noise-tolerance (ANT) injection of
//!   Fig. 11(a).
//!
//! Absolute voltages/capacitances are calibrated to the paper's operating
//! point (16×16 @ 0.8 V ⇒ 1602 TOPS/W, see [`crate::energy`]); the claims
//! we reproduce are the *relative* trends.

pub mod cell;
pub mod crossbar;
pub mod noise;
pub mod timing;
pub mod variability;

pub use cell::{CellParams, CellPolarity};
pub use crossbar::{Crossbar, CrossbarConfig};

/// Nominal NMOS threshold voltage, 16 nm LSTP-class (V).
pub const VTH_NOMINAL: f64 = 0.48;

/// Vth mismatch sigma for a minimum-sized transistor (paper: 24 mV).
pub const SIGMA_VTH_MIN: f64 = 0.024;

/// Nominal supply voltage used by the paper's Fig. 11(b) evaluation.
pub const VDD_NOMINAL: f64 = 0.90;

/// RM/CM boost used to rescue 32×32 arrays at low VDD (paper: +0.2 V).
pub const MERGE_BOOST: f64 = 0.20;

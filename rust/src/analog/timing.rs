//! The Fig. 5 signal schedule as a checked state machine.
//!
//! The 4-step compute-in-memory operation completes in two clock cycles:
//!
//! | step | phase        | PCH | CM | CL/CLB | RL | RM | action                    |
//! |------|--------------|-----|----|--------|----|----|---------------------------|
//! | 1    | clk0 (high)  |  1  | 1  | input  | 0  | 0  | precharge + load input    |
//! | 2    | clk0 (low)   |  0  | 0  | hold   | 1  | 0  | local compute in O/OB     |
//! | 3    | clk1 (high)  |  0  | 0  | 0      | 0  | 1  | row-merge charge share    |
//! | 4    | clk1 (low)   |  0  | 0  | 0      | 0  | 0  | compare SL/SLB, latch out |
//!
//! Step transitions assert the signal invariants (e.g. CM and RM are never
//! simultaneously high — that would short columns to rows), so any
//! scheduler bug in the coordinator surfaces as a panic in tests rather
//! than silently wrong charge math.  `waveform()` dumps the trace that
//! regenerates Fig. 5.

/// One step of the CIM operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Step {
    PrechargeLoad,
    LocalCompute,
    RowMerge,
    Compare,
}

impl Step {
    pub const ALL: [Step; 4] = [
        Step::PrechargeLoad,
        Step::LocalCompute,
        Step::RowMerge,
        Step::Compare,
    ];

    /// (clock cycle index, high-phase?) of this step.
    pub fn clock_phase(&self) -> (u32, bool) {
        match self {
            Step::PrechargeLoad => (0, true),
            Step::LocalCompute => (0, false),
            Step::RowMerge => (1, true),
            Step::Compare => (1, false),
        }
    }
}

/// Control-signal levels during one step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Signals {
    pub pch: bool,
    pub cm: bool,
    pub cl_active: bool,
    pub rl: bool,
    pub rm: bool,
}

impl Signals {
    pub fn for_step(step: Step) -> Signals {
        match step {
            Step::PrechargeLoad => Signals {
                pch: true,
                cm: true,
                cl_active: true,
                rl: false,
                rm: false,
            },
            Step::LocalCompute => Signals {
                pch: false,
                cm: false,
                cl_active: true,
                rl: true,
                rm: false,
            },
            Step::RowMerge => Signals {
                pch: false,
                cm: false,
                cl_active: false,
                rl: false,
                rm: true,
            },
            Step::Compare => Signals {
                pch: false,
                cm: false,
                cl_active: false,
                rl: false,
                rm: false,
            },
        }
    }

    /// Electrical invariants that must hold in *every* step.
    pub fn check_invariants(&self) {
        assert!(
            !(self.cm && self.rm),
            "CM and RM high together shorts columns to rows"
        );
        assert!(
            !(self.pch && self.rl),
            "precharging while RL is high fights the pull-downs"
        );
        assert!(
            !(self.rm && self.rl),
            "row merge during local compute corrupts the charge share"
        );
    }
}

/// Sequencer that walks the 4 steps and accounts clock cycles.
#[derive(Debug, Default)]
pub struct Sequencer {
    ops_completed: u64,
    step_index: usize,
}

impl Sequencer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance one step; returns the signals for the new step.
    pub fn advance(&mut self) -> (Step, Signals) {
        let step = Step::ALL[self.step_index];
        let sig = Signals::for_step(step);
        sig.check_invariants();
        self.step_index = (self.step_index + 1) % 4;
        if self.step_index == 0 {
            self.ops_completed += 1;
        }
        (step, sig)
    }

    /// Total completed bitplane operations.
    pub fn ops_completed(&self) -> u64 {
        self.ops_completed
    }

    /// Clock cycles consumed so far (2 per completed op).
    pub fn clock_cycles(&self) -> u64 {
        self.ops_completed * 2 + (self.step_index as u64).div_ceil(2)
    }
}

/// One waveform sample for the Fig. 5 dump.
#[derive(Debug, Clone)]
pub struct WaveformSample {
    pub time_step: usize,
    pub step: Step,
    pub clk: bool,
    pub signals: Signals,
}

/// Generate the waveform trace for `ops` back-to-back bitplane operations.
pub fn waveform(ops: usize) -> Vec<WaveformSample> {
    let mut seq = Sequencer::new();
    (0..ops * 4)
        .map(|t| {
            let (step, signals) = seq.advance();
            let (_, high) = step.clock_phase();
            WaveformSample {
                time_step: t,
                step,
                clk: high,
                signals,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_steps_two_cycles() {
        let mut seq = Sequencer::new();
        for _ in 0..4 {
            seq.advance();
        }
        assert_eq!(seq.ops_completed(), 1);
        assert_eq!(seq.clock_cycles(), 2);
    }

    #[test]
    fn all_steps_satisfy_invariants() {
        for step in Step::ALL {
            Signals::for_step(step).check_invariants();
        }
    }

    #[test]
    fn step_order_matches_paper() {
        let wf = waveform(1);
        assert_eq!(
            wf.iter().map(|s| s.step).collect::<Vec<_>>(),
            vec![
                Step::PrechargeLoad,
                Step::LocalCompute,
                Step::RowMerge,
                Step::Compare
            ]
        );
    }

    #[test]
    fn precharge_only_in_step_one() {
        let wf = waveform(3);
        for s in &wf {
            assert_eq!(s.signals.pch, s.step == Step::PrechargeLoad);
        }
    }

    #[test]
    fn merge_signals_mutually_exclusive() {
        for s in waveform(2) {
            assert!(!(s.signals.cm && s.signals.rm));
        }
    }

    #[test]
    fn clock_phases() {
        assert_eq!(Step::PrechargeLoad.clock_phase(), (0, true));
        assert_eq!(Step::Compare.clock_phase(), (1, false));
    }
}

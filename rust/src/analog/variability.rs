//! Process variability: Pelgrom-scaled Vth mismatch and the Monte-Carlo
//! processing-failure harness (Fig. 11(b)/(c)).
//!
//! The paper simulates local Vth mismatch with σ_TH = 24 mV for minimum-
//! sized transistors, scaled by Pelgrom's law (σ ∝ 1/√(WL)) for larger
//! devices; cell transistors are minimum-sized and peripherals are scaled
//! with array size for drive strength.

use crate::util::rng::Rng;

use super::crossbar::{Crossbar, CrossbarConfig};
use super::SIGMA_VTH_MIN;

/// Pelgrom scaling: mismatch sigma of a device `area_ratio`× the minimum
/// size: `σ = σ_min / sqrt(area_ratio)`.
pub fn pelgrom_sigma(sigma_min: f64, area_ratio: f64) -> f64 {
    assert!(area_ratio > 0.0);
    sigma_min / area_ratio.sqrt()
}

/// Sample one crossbar instance with process variability.
///
/// * cell transistors: minimum-sized ⇒ full σ_TH;
/// * row comparators: input pair sized `n/4`× minimum (peripherals scale
///   with the array for drive strength) ⇒ Pelgrom-reduced offset.
pub fn sample_instance(config: CrossbarConfig, rng: &mut Rng) -> Crossbar {
    let n = config.n;
    let vth: Vec<f64> = (0..n * n)
        .map(|_| rng.normal(config.cell.vth, SIGMA_VTH_MIN))
        .collect();
    let cmp_sigma = pelgrom_sigma(config.sigma_comparator, (n as f64 / 16.0).max(0.25));
    let offsets: Vec<f64> = (0..n).map(|_| rng.normal(0.0, cmp_sigma)).collect();
    Crossbar::ideal(config).with_variability(vth, offsets)
}

/// Result of the Fig. 11(b)/(c) Monte-Carlo: fraction of output bits whose
/// comparator decision disagrees with the true `sign(PSUM)` *outside* the
/// safety margin.
#[derive(Debug, Clone, Copy)]
pub struct FailureStats {
    pub failures: u64,
    pub checked: u64,
}

impl FailureStats {
    pub fn rate(&self) -> f64 {
        if self.checked == 0 {
            0.0
        } else {
            self.failures as f64 / self.checked as f64
        }
    }
}

/// Measure processing failure at the given safety margin (fraction of the
/// full-scale PSUM range): bits with `|PSUM| < L_I * sm` are excused
/// (BWHT's algorithmic noise tolerance, Fig. 11(a)); any other comparator
/// mismatch counts as a failure.
pub fn measure_failure(
    config: &CrossbarConfig,
    safety_margin: f64,
    vectors: usize,
    instances: usize,
    rng: &mut Rng,
) -> FailureStats {
    let n = config.n;
    let mut stats = FailureStats {
        failures: 0,
        checked: 0,
    };
    for _ in 0..instances {
        let xb = sample_instance(config.clone(), rng);
        for _ in 0..vectors {
            let input: Vec<i8> = (0..n).map(|_| rng.ternary()).collect();
            let bits = xb.execute_bitplane(&input, rng);
            let psums = xb.ideal_psums(&input);
            for (b, p) in bits.iter().zip(&psums) {
                if (p.unsigned_abs() as f64) < n as f64 * safety_margin {
                    continue; // inside the ANT margin: excused
                }
                stats.checked += 1;
                if *p != 0 && (*b as i64) != p.signum() {
                    stats.failures += 1;
                }
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng(seed: u64) -> Rng {
        Rng::seed_from_u64(seed)
    }

    #[test]
    fn pelgrom_scaling() {
        assert!((pelgrom_sigma(0.024, 1.0) - 0.024).abs() < 1e-12);
        assert!((pelgrom_sigma(0.024, 4.0) - 0.012).abs() < 1e-12);
    }

    #[test]
    fn sampled_instance_has_spread() {
        let mut r = rng(1);
        let xb = sample_instance(CrossbarConfig::new(16, 0.9), &mut r);
        let mean: f64 = xb.vth.iter().sum::<f64>() / xb.vth.len() as f64;
        let var: f64 =
            xb.vth.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / xb.vth.len() as f64;
        assert!((mean - super::super::VTH_NOMINAL).abs() < 0.01);
        let sd = var.sqrt();
        assert!(
            (sd - SIGMA_VTH_MIN).abs() < 0.01,
            "vth sd {sd} should be ~{SIGMA_VTH_MIN}"
        );
    }

    #[test]
    fn failure_rate_low_at_nominal_conditions() {
        // Paper: >95% accuracy at SM ~ 2e-3-equivalent at 0.90 V.
        let mut r = rng(2);
        let stats = measure_failure(&CrossbarConfig::new(16, 0.9), 0.05, 50, 4, &mut r);
        assert!(
            stats.rate() < 0.05,
            "16x16 @ 0.9V should be >95% accurate, failure={}",
            stats.rate()
        );
    }

    #[test]
    fn failure_increases_at_low_vdd() {
        let mut r = rng(3);
        let hi = measure_failure(&CrossbarConfig::new(32, 0.9), 0.03, 40, 3, &mut r);
        let lo = measure_failure(&CrossbarConfig::new(32, 0.6), 0.03, 40, 3, &mut r);
        assert!(
            lo.rate() >= hi.rate(),
            "low VDD must not improve failures: {} vs {}",
            lo.rate(),
            hi.rate()
        );
    }

    #[test]
    fn bigger_array_worse_at_low_vdd() {
        let mut r = rng(4);
        let s16 = measure_failure(&CrossbarConfig::new(16, 0.65), 0.03, 40, 3, &mut r);
        let s32 = measure_failure(&CrossbarConfig::new(32, 0.65), 0.03, 40, 3, &mut r);
        assert!(
            s32.rate() >= s16.rate(),
            "32x32 must fail at least as often at low VDD: {} vs {}",
            s32.rate(),
            s16.rate()
        );
    }

    #[test]
    fn boost_rescues_large_array() {
        let mut r = rng(5);
        let plain = measure_failure(&CrossbarConfig::new(32, 0.65), 0.03, 60, 4, &mut r);
        let boosted = measure_failure(
            &CrossbarConfig::new(32, 0.65).with_boost(0.2),
            0.03,
            60,
            4,
            &mut r,
        );
        assert!(
            boosted.rate() <= plain.rate(),
            "merge boost must not hurt: {} vs {}",
            boosted.rate(),
            plain.rate()
        );
    }

    #[test]
    fn wider_safety_margin_reduces_failures() {
        let mut r = rng(6);
        let tight = measure_failure(&CrossbarConfig::new(16, 0.7), 0.0, 60, 4, &mut r);
        let wide = measure_failure(&CrossbarConfig::new(16, 0.7), 0.1, 60, 4, &mut r);
        assert!(wide.rate() <= tight.rate());
    }
}

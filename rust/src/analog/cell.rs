//! Behavioral model of one 6T NMOS compute cell (Fig. 4, right).
//!
//! Each cell hardwires one Walsh-matrix entry (+1 or −1) in its wiring:
//! the '+1' and '−1' variants swap which local node (O vs OB) each column
//! line discharges.  During the local-compute step the cell output nodes
//! either retain the precharge voltage or discharge toward ground through
//! the NMOS pull-down; how *completely* they discharge depends on the gate
//! overdrive `VDD − Vth`, which is where per-cell threshold mismatch
//! enters the computation.

/// Hardwired cell polarity: the sign of the Walsh-matrix entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellPolarity {
    Plus,
    Minus,
}

impl CellPolarity {
    pub fn from_sign(sign: i8) -> Self {
        match sign {
            1 => CellPolarity::Plus,
            -1 => CellPolarity::Minus,
            _ => panic!("walsh entries are ±1, got {sign}"),
        }
    }

    pub fn sign(&self) -> i8 {
        match self {
            CellPolarity::Plus => 1,
            CellPolarity::Minus => -1,
        }
    }
}

/// Electrical parameters of the discharge path (behavioral).
#[derive(Debug, Clone, Copy)]
pub struct CellParams {
    /// Nominal threshold voltage (V).
    pub vth: f64,
    /// Discharge time-constant factor: residual voltage after the compute
    /// window is `VDD * exp(-k_discharge * max(Vgs - vth, 0.01))`.
    /// Larger ⇒ more complete discharge.  Calibrated so the residual is
    /// <2% at nominal overdrive and degrades sharply as VDD -> Vth
    /// (reproducing Fig. 11(c)'s low-VDD failure wall).
    pub k_discharge: f64,
    /// Droop on a *retained* node during the compute window (fraction of
    /// VDD lost to leakage/charge injection).
    pub retention_droop: f64,
}

impl Default for CellParams {
    fn default() -> Self {
        CellParams {
            vth: super::VTH_NOMINAL,
            k_discharge: 10.0,
            retention_droop: 0.01,
        }
    }
}

/// Voltages on a cell's local nodes after the local-compute step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeState {
    pub o: f64,
    pub ob: f64,
}

impl CellParams {
    /// Residual voltage of a *discharging* node (V).
    ///
    /// `vgs` is the effective gate drive of the pull-down path and
    /// `vth_actual` the mismatched threshold of this cell's transistor.
    pub fn residual(&self, vdd: f64, vgs: f64, vth_actual: f64) -> f64 {
        let overdrive = (vgs - vth_actual).max(0.01);
        vdd * (-self.k_discharge * overdrive).exp()
    }

    /// Evaluate the cell for one bitplane input.
    ///
    /// * `input` ∈ {-1, 0, +1}: the sign-magnitude bit on CL/CLB,
    /// * `polarity`: the hardwired Walsh entry,
    /// * `vth_actual`: this cell's mismatched threshold,
    /// * `vdd`: supply (also the gate drive of the pull-down; the paper
    ///   boosts merge signals, not the cell gates).
    ///
    /// Product `p = input * polarity`: `p = +1` discharges OB (O retains),
    /// `p = -1` discharges O, `p = 0` (magnitude bit 0) retains both —
    /// contributing zero differential charge, exactly Kirchhoff-summed
    /// "multiplication by zero without a multiplier".
    pub fn evaluate(
        &self,
        input: i8,
        polarity: CellPolarity,
        vth_actual: f64,
        vdd: f64,
    ) -> NodeState {
        debug_assert!((-1..=1).contains(&input));
        let retained = vdd * (1.0 - self.retention_droop);
        let discharged = self.residual(vdd, vdd, vth_actual);
        match input * polarity.sign() {
            1 => NodeState {
                o: retained,
                ob: discharged,
            },
            -1 => NodeState {
                o: discharged,
                ob: retained,
            },
            _ => NodeState {
                o: retained,
                ob: retained,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn polarity_roundtrip() {
        assert_eq!(CellPolarity::from_sign(1).sign(), 1);
        assert_eq!(CellPolarity::from_sign(-1).sign(), -1);
    }

    #[test]
    #[should_panic(expected = "±1")]
    fn zero_polarity_panics() {
        CellPolarity::from_sign(0);
    }

    #[test]
    fn discharge_nearly_complete_at_nominal() {
        let p = CellParams::default();
        let res = p.residual(0.9, 0.9, super::super::VTH_NOMINAL);
        assert!(res < 0.02 * 0.9, "residual {res} too high at nominal VDD");
    }

    #[test]
    fn discharge_degrades_toward_vth() {
        let p = CellParams::default();
        let hi = p.residual(0.9, 0.9, 0.48);
        let lo = p.residual(0.55, 0.55, 0.48);
        assert!(lo > hi * 5.0, "low-VDD residual must blow up: {hi} vs {lo}");
    }

    #[test]
    fn vth_mismatch_shifts_residual() {
        let p = CellParams::default();
        let slow = p.residual(0.9, 0.9, 0.48 + 0.05); // slow transistor
        let fast = p.residual(0.9, 0.9, 0.48 - 0.05);
        assert!(slow > fast);
    }

    #[test]
    fn product_sign_selects_node() {
        let p = CellParams::default();
        let vdd = 0.9;
        let plus_one = p.evaluate(1, CellPolarity::Plus, 0.48, vdd);
        assert!(plus_one.o > plus_one.ob, "p=+1 keeps O high");
        let minus_one = p.evaluate(1, CellPolarity::Minus, 0.48, vdd);
        assert!(minus_one.ob > minus_one.o, "p=-1 keeps OB high");
        let zero = p.evaluate(0, CellPolarity::Plus, 0.48, vdd);
        assert!((zero.o - zero.ob).abs() < 1e-12, "p=0 is differential-neutral");
    }

    #[test]
    fn negative_input_flips() {
        let p = CellParams::default();
        let a = p.evaluate(-1, CellPolarity::Plus, 0.48, 0.9);
        let b = p.evaluate(1, CellPolarity::Minus, 0.48, 0.9);
        assert_eq!(a, b);
    }
}

//! Algorithmic noise tolerance (ANT) injection — Fig. 11(a).
//!
//! The paper probes how much PSUM noise BWHT processing absorbs by adding
//! `N(0, L_I * σ_ANT)` to each product sum *before* digitization and
//! measuring end accuracy.  The same injection is reused by the nn engine
//! (`nn::bwht_layer` with a [`NoiseModel`]) to regenerate the accuracy
//! curve, and by the coordinator to emulate non-ideal tiles without paying
//! for the full electrical simulation.

use crate::util::rng::Rng;

/// Gaussian PSUM noise model: `psum <- psum + N(0, l_i * sigma_ant)`.
#[derive(Debug, Clone, Copy)]
pub struct NoiseModel {
    /// Standard-deviation knob σ_ANT (the paper sweeps 1e-4 .. 1e-1;
    /// < 2e-3 is inconsequential for accuracy).
    pub sigma_ant: f64,
    /// Input vector length L_I that the PSUM accumulates over.
    pub l_i: usize,
}

impl NoiseModel {
    pub fn new(sigma_ant: f64, l_i: usize) -> Self {
        assert!(sigma_ant >= 0.0);
        assert!(l_i > 0);
        NoiseModel { sigma_ant, l_i }
    }

    /// Noise sigma in PSUM units.
    pub fn sigma_psum(&self) -> f64 {
        self.l_i as f64 * self.sigma_ant
    }

    /// Inject noise into one PSUM value.
    pub fn perturb(&self, psum: f64, rng: &mut Rng) -> f64 {
        if self.sigma_ant == 0.0 {
            return psum;
        }
        psum + rng.normal(0.0, self.sigma_psum())
    }

    /// Inject into a whole PSUM vector, then re-quantize with the
    /// comparator (`sign`), exactly as the hardware digitizes (Fig. 6).
    pub fn perturb_and_compare(&self, psums: &[i64], rng: &mut Rng) -> Vec<i8> {
        let mut out = vec![0i8; psums.len()];
        self.perturb_and_compare_into(psums, rng, &mut out);
        out
    }

    /// [`Self::perturb_and_compare`] into a caller scratch slice.  Draws
    /// one noise sample per PSUM in input order, so the RNG stream is
    /// byte-identical to the allocating variant.
    pub fn perturb_and_compare_into(&self, psums: &[i64], rng: &mut Rng, out: &mut [i8]) {
        assert_eq!(psums.len(), out.len(), "readout buffer must match PSUMs");
        for (o, &p) in out.iter_mut().zip(psums) {
            let v = self.perturb(p as f64, rng);
            *o = if v > 0.0 {
                1
            } else if v < 0.0 {
                -1
            } else {
                0
            };
        }
    }

    /// Probability that a PSUM of magnitude `m` flips sign under this
    /// noise (analytic check for the Monte-Carlo paths).
    pub fn flip_probability(&self, m: f64) -> f64 {
        if self.sigma_ant == 0.0 {
            return 0.0;
        }
        // P(N(0,σ) < -m) = Φ(-m/σ)
        normal_cdf(-m.abs() / self.sigma_psum())
    }
}

/// Standard normal CDF via erf approximation (Abramowitz-Stegun 7.1.26).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng(seed: u64) -> Rng {
        Rng::seed_from_u64(seed)
    }

    #[test]
    fn zero_sigma_is_identity() {
        let nm = NoiseModel::new(0.0, 16);
        let mut r = rng(0);
        assert_eq!(nm.perturb(3.5, &mut r), 3.5);
        assert_eq!(nm.perturb_and_compare(&[5, -5, 0], &mut r), vec![1, -1, 0]);
    }

    #[test]
    fn sigma_scales_with_input_length() {
        assert_eq!(NoiseModel::new(0.01, 16).sigma_psum(), 0.16);
        assert_eq!(NoiseModel::new(0.01, 32).sigma_psum(), 0.32);
    }

    #[test]
    fn empirical_flip_rate_matches_analytic() {
        let nm = NoiseModel::new(0.02, 16); // σ_psum = 0.32
        let m = 0.4f64;
        let mut r = rng(1);
        let trials = 20000;
        let flips = (0..trials)
            .filter(|_| nm.perturb(m, &mut r) < 0.0)
            .count();
        let emp = flips as f64 / trials as f64;
        let ana = nm.flip_probability(m);
        assert!(
            (emp - ana).abs() < 0.01,
            "empirical {emp} vs analytic {ana}"
        );
    }

    #[test]
    fn small_sigma_rarely_flips_large_psums() {
        // The paper's knee: σ_ANT < 2e-3 is inconsequential.
        let nm = NoiseModel::new(2e-3, 16);
        assert!(nm.flip_probability(1.0) < 1e-10);
    }

    #[test]
    fn large_sigma_randomizes() {
        let nm = NoiseModel::new(0.5, 16);
        assert!(nm.flip_probability(1.0) > 0.4);
    }

    #[test]
    fn erf_sane() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!(normal_cdf(3.0) > 0.998);
        assert!(normal_cdf(-3.0) < 0.002);
    }
}

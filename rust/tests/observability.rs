//! Observability integration: end-to-end request tracing served over
//! `GET /debug/traces` (JSON + Chrome `trace_event`), the shard-aware
//! readiness probe, the fidelity monitor's closed drift loop
//! (`GET /debug/fidelity` → degraded `/readyz` → drift respawn), and the
//! Prometheus text-format invariants of the extended `/metrics`
//! exposition.

use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use repro::nn::Mlp;
use repro::server::{Server, ServerConfig};
use repro::util::json::{self, Json};
use repro::util::rng::Rng;

fn send_request(addr: SocketAddr, raw: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    stream.write_all(raw.as_bytes()).unwrap();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).expect("read response");
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (status, body)
}

fn post_json(addr: SocketAddr, path: &str, body: &str) -> (u16, String) {
    send_request(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn get(addr: SocketAddr, path: &str) -> (u16, String) {
    send_request(
        addr,
        &format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"),
    )
}

fn test_mlp() -> Mlp {
    let mut r = Rng::seed_from_u64(77);
    let (din, hidden, classes) = (8usize, 16usize, 3usize);
    Mlp::from_flat(
        din,
        hidden,
        classes,
        r.normal_vec_f32(din * hidden, 0.0, 0.5),
        vec![0.0; hidden],
        vec![0.06; hidden],
        r.normal_vec_f32(hidden * classes, 0.0, 0.5),
        vec![0.0; classes],
    )
}

fn infer_body(x: &[f32]) -> String {
    let vals: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    format!("{{\"x\":[{}]}}", vals.join(","))
}

/// Value of an unlabeled series in a Prometheus text exposition.
fn metric_value(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|line| {
            let rest = line.strip_prefix(name)?;
            let rest = rest.strip_prefix(' ')?;
            rest.trim().parse::<f64>().ok()
        })
        .unwrap_or(f64::NAN)
}

/// ISSUE-6 acceptance: a served `/v1/infer` request must appear in
/// `GET /debug/traces` with at least 6 distinct stage spans, and its
/// execute spans must carry the plane-count / ET-depth payloads.
#[cfg(not(feature = "trace-off"))]
#[test]
fn served_infer_request_appears_in_debug_traces_with_full_stage_coverage() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        shards: 2,
        model: Some(test_mlp()),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;

    let mut rng = Rng::seed_from_u64(6000);
    let x: Vec<f32> = (0..8).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
    let (status, body) = post_json(addr, "/v1/infer", &infer_body(&x));
    assert_eq!(status, 200, "{body}");

    let (status, body) = get(addr, "/debug/traces?n=8");
    assert_eq!(status, 200, "{body}");
    let parsed = json::parse(&body).expect("traces json");
    let traces = parsed.get("traces").and_then(Json::as_arr).expect("traces");
    let infer = traces
        .iter()
        .find(|t| t.get("endpoint").and_then(Json::as_str) == Some("/v1/infer"))
        .expect("the served infer request must have been traced");

    let spans = infer.get("spans").and_then(Json::as_arr).expect("spans");
    let stages: HashSet<&str> = spans
        .iter()
        .filter_map(|s| s.get("stage").and_then(Json::as_str))
        .collect();
    assert!(
        stages.len() >= 6,
        "want >= 6 distinct stages, got {stages:?}"
    );
    for want in ["admission", "queue", "plan", "scatter", "execute", "respond"] {
        assert!(stages.contains(want), "missing {want} in {stages:?}");
    }

    let begin = infer.get("begin_us").and_then(Json::as_f64).unwrap();
    let end = infer.get("end_us").and_then(Json::as_f64).unwrap();
    assert!(end >= begin);
    let mut execute_spans = 0usize;
    for span in spans {
        let start = span.get("start_us").and_then(Json::as_f64).unwrap();
        let dur = span.get("dur_us").and_then(Json::as_f64).unwrap();
        assert!(start >= begin && start + dur <= end + 1.0, "span in window");
        if span.get("stage").and_then(Json::as_str) == Some("execute") {
            execute_spans += 1;
            assert!(
                span.get("planes").and_then(Json::as_f64).unwrap() > 0.0,
                "execute span must carry a plane count"
            );
            assert!(span.get("elements").and_then(Json::as_f64).unwrap() > 0.0);
            assert!(span.get("avg_cycles").and_then(Json::as_f64).unwrap() > 0.0);
            assert!(
                span.get("shard").and_then(Json::as_f64).is_some(),
                "execute span must be shard-attributed"
            );
        }
    }
    assert!(execute_spans >= 1, "at least one execute span");
    server.shutdown();
}

/// The Chrome `trace_event` export must parse as valid JSON and frame
/// every span as a complete ("X") event with the shared timebase.
#[test]
fn chrome_trace_export_parses_as_valid_trace_event_json() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;
    let (status, body) = post_json(addr, "/v1/transform", "{\"x\":[0.5,-0.25,0.75,1.0]}");
    assert_eq!(status, 200, "{body}");

    let (status, body) = get(addr, "/debug/traces?n=4&format=chrome");
    assert_eq!(status, 200, "{body}");
    let parsed = json::parse(&body).expect("chrome export must be valid JSON");
    let events = parsed
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents");
    // With tracing compiled out the export is a valid empty document.
    if cfg!(feature = "trace-off") {
        assert!(events.is_empty());
    } else {
        assert!(!events.is_empty(), "{body}");
        for ev in events {
            assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
            assert!(ev.get("name").and_then(Json::as_str).is_some());
            assert!(ev.get("ts").and_then(Json::as_f64).is_some());
            assert!(ev.get("dur").and_then(Json::as_f64).is_some());
            assert_eq!(ev.get("pid").and_then(Json::as_f64), Some(1.0));
            assert!(ev.get("tid").and_then(Json::as_f64).is_some());
            assert!(
                ev.path(&["args", "trace_id"]).and_then(Json::as_f64).is_some(),
                "{ev:?}"
            );
        }
    }
    server.shutdown();
}

/// `--trace-sample 0` disables tracing entirely: the store stays empty
/// and the endpoint serves an empty (but well-formed) document.
#[test]
fn trace_sampling_zero_disables_the_trace_store() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        trace_sample: 0,
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;
    let (status, body) = post_json(addr, "/v1/transform", "{\"x\":[1.0,0.5]}");
    assert_eq!(status, 200, "{body}");
    let (status, body) = get(addr, "/debug/traces");
    assert_eq!(status, 200);
    let parsed = json::parse(&body).unwrap();
    assert_eq!(
        parsed.get("traces").and_then(Json::as_arr).map(Vec::len),
        Some(0),
        "{body}"
    );
    server.shutdown();
}

/// `/readyz` answers 200 with a per-shard breakdown when the set is
/// fully healthy (the degraded 503 path is unit-tested in the server
/// module; a live server heals itself via auto-respawn).
#[test]
fn readyz_reports_per_shard_health() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        shards: 3,
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;
    let (status, body) = get(addr, "/readyz");
    assert_eq!(status, 200, "{body}");
    let parsed = json::parse(&body).unwrap();
    assert!(matches!(parsed.get("ready"), Some(Json::Bool(true))), "{body}");
    let shards = parsed.get("shards").and_then(Json::as_arr).expect("shards");
    assert_eq!(shards.len(), 3);
    for (i, shard) in shards.iter().enumerate() {
        assert_eq!(shard.get("shard").and_then(Json::as_f64), Some(i as f64));
        assert!(
            matches!(shard.get("healthy"), Some(Json::Bool(true))),
            "{body}"
        );
    }
    let (status, _) = post_json(addr, "/readyz", "");
    assert_eq!(status, 405);
    server.shutdown();
}

/// Strip the `le="..."` label from a label block, returning the group
/// key (remaining labels) and the parsed bound.
fn split_le(labels: &str) -> Option<(String, f64)> {
    let start = labels.find("le=\"")?;
    let rest = &labels[start + 4..];
    let end = rest.find('"')?;
    let bound = match &rest[..end] {
        "+Inf" => f64::INFINITY,
        v => v.parse().ok()?,
    };
    let mut key = String::new();
    key.push_str(&labels[..start]);
    key.push_str(&rest[end + 1..]);
    Some((key.trim_matches(',').to_string(), bound))
}

/// Prometheus text-format invariants over the whole exposition:
/// HELP/TYPE precede every series of their family, histogram `le`
/// bounds are strictly increasing with non-decreasing cumulative
/// counts, the `+Inf` bucket equals `_count`, and no series repeats.
#[test]
fn metrics_exposition_satisfies_prometheus_text_format_invariants() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        shards: 2,
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;
    // Serve real traffic first so the histograms hold live counts.
    for i in 0..4 {
        let (status, body) = post_json(
            addr,
            "/v1/transform",
            &format!("{{\"x\":[0.5,{}.25,-0.75,1.0]}}", i),
        );
        assert_eq!(status, 200, "{body}");
    }
    let (status, text) = get(addr, "/metrics");
    assert_eq!(status, 200);

    let mut helped: HashSet<String> = HashSet::new();
    let mut typed: HashMap<String, String> = HashMap::new();
    let mut seen_series: HashSet<String> = HashSet::new();
    // (family, labels-sans-le) -> (last le, last cumulative, inf count)
    let mut buckets: HashMap<(String, String), (f64, f64, Option<f64>)> = HashMap::new();

    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().expect("HELP name");
            helped.insert(name.to_string());
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split_whitespace();
            let name = parts.next().expect("TYPE name");
            let kind = parts.next().expect("TYPE kind");
            typed.insert(name.to_string(), kind.to_string());
            continue;
        }
        assert!(!line.starts_with('#'), "unknown comment form: {line}");

        // Series line: `name{labels} value` or `name value`.
        let name_end = line.find(['{', ' ']).expect("series name terminator");
        let name = &line[..name_end];
        let (labels, value_str) = match line[name_end..].strip_prefix('{') {
            Some(rest) => {
                let close = rest.find('}').expect("label block close");
                (&rest[..close], rest[close + 1..].trim())
            }
            None => ("", line[name_end..].trim()),
        };
        let value: f64 = value_str.parse().unwrap_or_else(|_| {
            panic!("unparseable sample value in {line:?}")
        });

        // The family a suffixed histogram series belongs to.
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|suffix| {
                let base = name.strip_suffix(suffix)?;
                (typed.get(base).map(String::as_str) == Some("histogram"))
                    .then(|| base.to_string())
            })
            .unwrap_or_else(|| name.to_string());
        assert!(
            typed.contains_key(&family),
            "series {name} before its # TYPE"
        );
        assert!(
            helped.contains(&family),
            "series {name} before its # HELP"
        );
        assert!(
            seen_series.insert(format!("{name}{{{labels}}}")),
            "duplicate series {name}{{{labels}}}"
        );

        if name.ends_with("_bucket") && typed.get(&family).map(String::as_str) == Some("histogram")
        {
            let (group, le) = split_le(labels).expect("bucket without le");
            let entry = buckets
                .entry((family.clone(), group))
                .or_insert((f64::NEG_INFINITY, 0.0, None));
            assert!(le > entry.0, "le must increase: {line}");
            assert!(value >= entry.1, "cumulative count must not drop: {line}");
            entry.0 = le;
            entry.1 = value;
            if le.is_infinite() {
                entry.2 = Some(value);
            }
        }
        if name.ends_with("_count") && typed.get(&family).map(String::as_str) == Some("histogram")
        {
            let key = (family.clone(), labels.to_string());
            let inf = buckets
                .get(&key)
                .and_then(|(_, _, inf)| *inf)
                .unwrap_or_else(|| panic!("_count before +Inf bucket: {line}"));
            assert_eq!(inf, value, "+Inf bucket must equal _count: {line}");
        }
    }

    // Every histogram family ends in +Inf, and the new families exist.
    for ((family, group), (last_le, _, inf)) in &buckets {
        assert!(
            last_le.is_infinite() && inf.is_some(),
            "{family}{{{group}}} must close with a +Inf bucket"
        );
    }
    assert_eq!(typed.get("repro_stage_seconds").map(String::as_str), Some("histogram"));
    assert!(seen_series
        .iter()
        .any(|s| s.starts_with("repro_stage_seconds_bucket{stage=\"execute\"")));
    assert!(typed.contains_key("repro_build_info"));
    assert!(seen_series.iter().any(|s| s.starts_with("repro_build_info{")));
    assert!(typed.contains_key("repro_process_start_time_seconds"));
    assert!(typed.contains_key("repro_traces_sampled_total"));
    // PR-7 families: per-shard energy telemetry and the fidelity
    // monitor render under the same invariants (even with an all-digital
    // set, where the monitor is a disabled stub).
    assert!(seen_series
        .iter()
        .any(|s| s.starts_with("repro_shard_energy_femtojoules_total{shard=")));
    assert!(seen_series
        .iter()
        .any(|s| s.starts_with("repro_shard_tops_per_watt{shard=")));
    assert!(typed.contains_key("repro_fidelity_enabled"));
    assert!(typed.contains_key("repro_fidelity_checked_total"));
    assert!(typed.contains_key("repro_shard_drift_respawns_total"));
    assert_eq!(
        typed.get("repro_fidelity_mean_abs_dq").map(String::as_str),
        Some("histogram")
    );
    assert_eq!(
        typed
            .get("repro_fidelity_block_mismatch_fraction")
            .map(String::as_str),
        Some("histogram")
    );
    server.shutdown();
}

/// ISSUE-7 acceptance: the closed drift loop end to end. A server with
/// one digital and one grossly noisy analog-path shard must (1) record
/// rising divergence for the noisy slot in `GET /debug/fidelity`,
/// (2) flag the slot so `/readyz` degrades to 503 naming it, and
/// (3) recycle the slot on the next heal pass, incrementing
/// `repro_shard_drift_respawns_total` and restoring readiness.
#[cfg(not(feature = "monitor-off"))]
#[test]
fn drifting_shard_degrades_readyz_and_is_recycled_by_the_heal_pass() {
    use repro::coordinator::TileKind;
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        shards: 2,
        shard_kinds: Some(vec![
            TileKind::Digital,
            TileKind::Noisy { sigma_ant: 0.5 },
        ]),
        fidelity_sample: 1,
        drift_threshold: 0.05,
        // A long idle tick keeps the batcher from recycling the slot on
        // its own schedule: the degraded-/readyz window stays observable
        // until we deliberately trigger the next heal pass with traffic.
        health_tick: Duration::from_secs(60),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;

    // A burst of wide transforms spreads blocks over both shards; every
    // slice served by the noisy shard is shadow-checked (1-in-1).
    let mut rng = Rng::seed_from_u64(41);
    for _ in 0..8 {
        let x: Vec<f32> = (0..64).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
        let (status, body) = post_json(addr, "/v1/transform", &infer_body(&x));
        assert_eq!(status, 200, "{body}");
    }

    // Poll the monitor until the EWMA crosses the threshold and flags
    // slot 1. No traffic while polling: a POST would run the heal pass
    // and recycle the slot before we can observe the degraded state.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    let snapshot = loop {
        let (status, body) = get(addr, "/debug/fidelity?n=4");
        assert_eq!(status, 200, "{body}");
        let parsed = json::parse(&body).expect("fidelity json");
        assert!(matches!(parsed.get("enabled"), Some(Json::Bool(true))), "{body}");
        let slots = parsed.get("slots").and_then(Json::as_arr).expect("slots");
        if matches!(slots[1].get("flagged"), Some(Json::Bool(true))) {
            break parsed;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "slot 1 never flagged as drifting: {body}"
        );
        std::thread::sleep(Duration::from_millis(10));
    };
    let slots = snapshot.get("slots").and_then(Json::as_arr).unwrap();
    assert!(slots[1].get("ewma").and_then(Json::as_f64).unwrap() > 0.05);
    assert!(matches!(slots[0].get("flagged"), Some(Json::Bool(false))));
    assert!(snapshot.get("checked").and_then(Json::as_f64).unwrap() >= 1.0);
    let recent = snapshot.get("recent").and_then(Json::as_arr).expect("recent");
    assert!(!recent.is_empty());
    for rec in recent {
        assert_eq!(rec.get("shard").and_then(Json::as_f64), Some(1.0));
    }

    // Let the checker drain the rest of the burst's samples (two stable
    // reads of the checked counter) so no stale sample re-flags the slot
    // after the heal pass resets it.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    let mut last_checked = -1.0f64;
    loop {
        let (_, body) = get(addr, "/debug/fidelity?n=0");
        let parsed = json::parse(&body).unwrap();
        let checked = parsed.get("checked").and_then(Json::as_f64).unwrap();
        if checked == last_checked {
            break;
        }
        last_checked = checked;
        assert!(
            std::time::Instant::now() < deadline,
            "shadow queue never drained: {body}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    // The flagged slot degrades readiness immediately.
    let (status, body) = get(addr, "/readyz");
    assert_eq!(status, 503, "{body}");
    let parsed = json::parse(&body).unwrap();
    let shards = parsed.get("shards").and_then(Json::as_arr).expect("shards");
    assert!(matches!(shards[0].get("healthy"), Some(Json::Bool(true))), "{body}");
    assert!(matches!(shards[1].get("healthy"), Some(Json::Bool(false))), "{body}");

    // Traffic triggers the heal pass before dispatch: the drifting slot
    // is poisoned, respawned as a fresh pool, and its state resets.
    let (status, body) = post_json(addr, "/v1/transform", "{\"x\":[0.5,-0.25,0.75,1.0]}");
    assert_eq!(status, 200, "{body}");
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let (_, text) = get(addr, "/metrics");
        if metric_value(&text, "repro_shard_drift_respawns_total") >= 1.0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "drifting slot never recycled: {text}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    let (status, body) = get(addr, "/readyz");
    assert_eq!(status, 200, "{body}");
    let (status, body) = get(addr, "/debug/fidelity");
    assert_eq!(status, 200, "{body}");
    let parsed = json::parse(&body).unwrap();
    assert!(parsed.get("drift_respawns").and_then(Json::as_f64).unwrap() >= 1.0);
    let slots = parsed.get("slots").and_then(Json::as_arr).unwrap();
    assert!(
        matches!(slots[1].get("flagged"), Some(Json::Bool(false))),
        "slot state must reset after the respawn: {body}"
    );
    server.shutdown();
}

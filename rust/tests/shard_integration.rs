//! Sharding correctness: scatter–gather across N coordinator pools must
//! be bit-identical to a single coordinator on the digital backend —
//! across random widths (including ones that don't divide evenly into
//! tiles or shards), shard counts, and early-termination thresholds —
//! and must survive shard poisoning by shedding load to siblings.
//! Planned (mixed-partition) routing must additionally match the
//! whole-width golden model bit-for-bit when scales are pinned.

use repro::bitplane::QuantBwht;
use repro::coordinator::{Coordinator, CoordinatorConfig, TransformRequest};
use repro::quant::Quantizer;
use repro::shard::{router, ShardSet, ShardSetConfig};
use repro::util::rng::Rng;
use repro::wht;

fn sample_request(width: usize, rng: &mut Rng, threshold_mode: usize) -> TransformRequest {
    let x: Vec<f32> = (0..width)
        .map(|_| rng.uniform_range(-1.5, 1.5) as f32)
        .collect();
    let thresholds_units: Vec<f64> = (0..width)
        .map(|_| match threshold_mode {
            0 => 0.0,                                // lossless, full precision
            1 => rng.uniform_range(0.0, 60.0),       // mixed early termination
            _ => 1e9,                                // saturating: everything zeroes
        })
        .collect();
    TransformRequest {
        x,
        thresholds_units,
        scale: None,
        deadline: None,
        deadline: None,
    }
}

fn single_pool(req: &TransformRequest) -> Vec<f32> {
    let mut c = Coordinator::new(CoordinatorConfig::default());
    let out = c.transform(req).unwrap();
    c.shutdown();
    out
}

/// Property-style sweep: sharded output is bit-identical to the single
/// coordinator across widths x shard counts x threshold regimes.
#[test]
fn sharded_is_bit_identical_to_single_pool_across_the_grid() {
    let mut rng = Rng::seed_from_u64(2024);
    // Widths exercise: sub-tile, exact tiles, non-multiples, prime-ish,
    // and wider-than-shard-count-times-tile.
    let widths = [4usize, 16, 20, 48, 100, 256, 333, 512];
    for (wi, &width) in widths.iter().enumerate() {
        for shards in [1usize, 2, 3, 4, 5] {
            let threshold_mode = (wi + shards) % 3;
            let req = sample_request(width, &mut rng, threshold_mode);
            let golden = single_pool(&req);
            let mut set = ShardSet::new(ShardSetConfig {
                shards,
                ..Default::default()
            })
            .unwrap();
            let out = router::transform(&mut set, &req).unwrap();
            assert_eq!(
                out, golden,
                "width={width} shards={shards} mode={threshold_mode}"
            );
            set.shutdown();
        }
    }
}

/// The acceptance-criteria configuration: a 1024-wide request on 16x16
/// tiles, 4 shards, bit-identical to one coordinator.
#[test]
fn wide_1024_request_on_4_shards_matches_single_coordinator() {
    let mut rng = Rng::seed_from_u64(7);
    let req = sample_request(1024, &mut rng, 0);
    let golden = single_pool(&req);
    assert_eq!(golden.len(), 1024);
    let mut set = ShardSet::new(ShardSetConfig {
        shards: 4,
        coordinator: CoordinatorConfig {
            tile_n: 16,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let out = router::transform(&mut set, &req).unwrap();
    assert_eq!(out, golden);
    // All four shards took part.
    let per_shard = set.aggregator().per_shard();
    assert!(
        per_shard.iter().all(|m| m.requests > 0),
        "every shard should serve a slice of a 64-block request: {:?}",
        per_shard.iter().map(|m| m.requests).collect::<Vec<_>>()
    );
    let merged = set.metrics();
    assert_eq!(merged.cycles.total_elements, 1024);
    set.shutdown();
}

/// Batches keep request order and correctness under sharding.
#[test]
fn sharded_batches_match_singles_with_mixed_widths() {
    let mut rng = Rng::seed_from_u64(99);
    let reqs: Vec<TransformRequest> = [33usize, 64, 128, 17, 256]
        .iter()
        .enumerate()
        .map(|(i, &w)| sample_request(w, &mut rng, i % 3))
        .collect();
    let goldens: Vec<Vec<f32>> = reqs.iter().map(single_pool).collect();
    let mut set = ShardSet::new(ShardSetConfig {
        shards: 3,
        ..Default::default()
    })
    .unwrap();
    let outs = router::transform_batch(&mut set, &reqs).unwrap();
    assert_eq!(outs, goldens);
    set.shutdown();
}

/// Planned routing over mixed BWHT partitions (ISSUE-4 acceptance):
/// non-power-of-two widths scatter their heterogeneous blocks across
/// shards, the narrow blocks run under sub-tile masking, and the result
/// is bit-identical to the whole-width golden model when the global
/// quantization scale is pinned.
#[test]
fn planned_mixed_partitions_are_bit_identical_across_shard_counts() {
    let mut rng = Rng::seed_from_u64(600);
    for &width in &[20usize, 68, 300, 1040] {
        let blocks = wht::bwht_blocks(width, 128);
        assert!(
            blocks.windows(2).any(|w| w[0] != w[1]) || blocks.len() == 1,
            "width {width} should exercise a mixed partition: {blocks:?}"
        );
        let tile = *blocks.iter().max().unwrap();
        let x: Vec<f32> = (0..width)
            .map(|_| rng.uniform_range(-1.5, 1.5) as f32)
            .collect();
        let req = TransformRequest {
            thresholds_units: vec![0.0; width],
            scale: Some(Quantizer::new(8).scale_for(&x)),
            deadline: None,
            x,
        };
        let golden = QuantBwht::new(width, 128, 8).transform(&req.x);
        for shards in [1usize, 2, 4] {
            let mut set = ShardSet::new(ShardSetConfig {
                shards,
                coordinator: CoordinatorConfig {
                    tile_n: tile,
                    ..Default::default()
                },
                ..Default::default()
            })
            .unwrap();
            let outs =
                router::transform_batch_planned(&mut set, &blocks, std::slice::from_ref(&req))
                    .unwrap();
            assert_eq!(outs[0], golden, "width={width} shards={shards}");
            assert_eq!(outs[0].len(), width, "planned outputs are unpadded");
            let m = set.metrics();
            assert_eq!(
                m.cycles.total_elements, width as u64,
                "masked rows must not be billed (width {width})"
            );
            set.shutdown();
        }
    }
}

/// Early termination accounting survives the scatter: merged row-cycles
/// show savings when thresholds saturate.
#[test]
fn merged_metrics_report_early_termination_savings() {
    let mut rng = Rng::seed_from_u64(5);
    let req = sample_request(256, &mut rng, 2); // saturating thresholds
    let mut set = ShardSet::new(ShardSetConfig {
        shards: 4,
        ..Default::default()
    })
    .unwrap();
    let out = router::transform(&mut set, &req).unwrap();
    assert!(out.iter().all(|&v| v == 0.0), "saturating T zeroes everything");
    let m = set.metrics();
    assert_eq!(m.cycles.total_elements, 256);
    assert!(m.row_cycles < 256 * 8, "ET must cut row-cycles");
    assert!(m.row_cycles_saved() > 0);
    set.shutdown();
}

/// Fusion property sweep (PR-8 acceptance): fused multi-sample routing
/// is bit-identical to per-sample single-slice execution across shard
/// counts x block partitions x batch sizes, with mixed early-termination
/// thresholds and pinned scales.  Shard counts that divide the batch
/// unevenly, a queue_depth-1 config (forcing the backpressure
/// drain-batch path), and batch sizes straddling the worker count are
/// all in the grid.
#[test]
fn fused_routing_is_bit_identical_across_shards_partitions_and_batch_sizes() {
    let mut rng = Rng::seed_from_u64(4242);
    let partitions: [&[usize]; 4] = [&[16, 4], &[16, 16, 8], &[8, 8, 2], &[16, 16, 16, 16, 1]];
    for (pi, &blocks) in partitions.iter().enumerate() {
        let width: usize = blocks.iter().sum();
        for shards in [1usize, 2, 3] {
            // Deterministic pseudo-random batch sizes in 1..=9, varying
            // with partition and shard count so chunking hits 1-sample,
            // sub-worker and above-worker group shapes.
            let batch = 1 + (pi * 7 + shards * 5) % 9;
            let reqs: Vec<TransformRequest> = (0..batch)
                .map(|_| {
                    let x: Vec<f32> = (0..width)
                        .map(|_| rng.uniform_range(-1.5, 1.5) as f32)
                        .collect();
                    let thresholds_units: Vec<f64> =
                        (0..width).map(|_| rng.uniform_range(0.0, 40.0)).collect();
                    TransformRequest {
                        scale: Some(Quantizer::new(8).scale_for(&x)),
                        deadline: None,
                        x,
                        thresholds_units,
                    }
                })
                .collect();
            // Golden: the same pool geometry serving every request as
            // its own single-sample planned job.
            let mut single = Coordinator::new(CoordinatorConfig::default());
            let goldens: Vec<Vec<f32>> = reqs
                .iter()
                .map(|r| single.transform_planned(r, blocks).unwrap())
                .collect();
            single.shutdown();

            let mut set = ShardSet::new(ShardSetConfig {
                shards,
                coordinator: CoordinatorConfig {
                    // Exercise the backpressure drain on the widest grid
                    // point; default depth elsewhere.
                    queue_depth: if shards == 3 { 1 } else { 256 },
                    ..Default::default()
                },
                ..Default::default()
            })
            .unwrap();
            let outs = router::transform_batch_planned(&mut set, blocks, &reqs);
            assert_eq!(
                outs.unwrap(),
                goldens,
                "fused != per-sample: partition={blocks:?} shards={shards} batch={batch}"
            );
            // `requests` bills sample-slices (one per request per shard
            // touched), so it floors at the batch size; fused jobs can
            // only ever undercut the slice count.
            let m = set.metrics();
            assert!(m.requests >= batch as u64, "every sample billed");
            assert!(m.jobs <= m.requests, "jobs never exceed slices");
            set.shutdown();
        }
    }
}

/// Fusion must not perturb the noisy backend's RNG streams: a fused
/// multi-sample job draws noise in the same per-sample order as N
/// separate jobs on the same worker.  A 1-shard/1-worker set (shard 0,
/// generation 0 reuses the coordinator seed verbatim) therefore
/// reproduces the sequential per-sample coordinator float-for-float —
/// fusion stays termination- and batching-invariant off the digital
/// golden path too.
#[test]
fn fused_noisy_batches_keep_rng_stream_alignment() {
    use repro::coordinator::TileKind;
    let coord = CoordinatorConfig {
        workers: 1,
        kind: TileKind::Noisy { sigma_ant: 0.02 },
        ..Default::default()
    };
    let blocks = [16usize, 16, 4];
    let width: usize = blocks.iter().sum();
    let mut rng = Rng::seed_from_u64(9001);
    let reqs: Vec<TransformRequest> = (0..6)
        .map(|_| {
            let x: Vec<f32> = (0..width)
                .map(|_| rng.uniform_range(-1.0, 1.0) as f32)
                .collect();
            TransformRequest {
                scale: Some(Quantizer::new(8).scale_for(&x)),
                deadline: None,
                x,
                thresholds_units: vec![0.0; width],
            }
        })
        .collect();
    let mut single = Coordinator::new(coord.clone());
    let goldens: Vec<Vec<f32>> = reqs
        .iter()
        .map(|r| single.transform_planned(r, &blocks).unwrap())
        .collect();
    single.shutdown();

    let mut set = ShardSet::new(ShardSetConfig {
        shards: 1,
        coordinator: coord,
        ..Default::default()
    })
    .unwrap();
    let outs = router::transform_batch_planned(&mut set, &blocks, &reqs);
    assert_eq!(
        outs.unwrap(),
        goldens,
        "fused noisy jobs must replay the RNG streams"
    );
    let m = set.metrics();
    assert!(m.jobs < m.requests, "batch must fuse: {} jobs", m.jobs);
    set.shutdown();
}

/// A shard lost under a fused batch refuses cleanly and re-routes: the
/// constituent slices come back per-request from the survivors, and a
/// follow-up fused batch on the reduced set stays bit-identical.
#[test]
fn fused_batches_survive_shard_loss_with_per_slice_reroute() {
    let mut rng = Rng::seed_from_u64(808);
    let blocks = [16usize, 16, 16, 8];
    let width: usize = blocks.iter().sum();
    let reqs: Vec<TransformRequest> = (0..12)
        .map(|_| {
            let x: Vec<f32> = (0..width)
                .map(|_| rng.uniform_range(-1.5, 1.5) as f32)
                .collect();
            TransformRequest {
                scale: Some(Quantizer::new(8).scale_for(&x)),
                deadline: None,
                x,
                thresholds_units: vec![0.0; width],
            }
        })
        .collect();
    let mut single = Coordinator::new(CoordinatorConfig::default());
    let goldens: Vec<Vec<f32>> = reqs
        .iter()
        .map(|r| single.transform_planned(r, &blocks).unwrap())
        .collect();
    single.shutdown();

    let mut set = ShardSet::new(ShardSetConfig {
        shards: 3,
        ..Default::default()
    })
    .unwrap();
    // Kill a shard before the fused batch: every fused job routed to it
    // is refused at submit, split per request, and re-planned onto the
    // survivors.
    set.coordinator_mut(1).unwrap().abort();
    let outs = router::transform_batch_planned(&mut set, &blocks, &reqs);
    assert_eq!(outs.unwrap(), goldens);
    assert_eq!(set.healthy(), vec![0, 2]);
    // Steady state on the survivors: still fused, still identical.
    let outs = router::transform_batch_planned(&mut set, &blocks, &reqs);
    assert_eq!(outs.unwrap(), goldens);
    let m = set.metrics();
    assert!(m.jobs < m.requests, "survivor batches keep fusing");
    set.shutdown();
}

/// Failure isolation: poisoning shards mid-stream sheds their load to
/// siblings; the request still completes bit-identically.
#[test]
fn poisoned_shards_shed_load_without_failing_requests() {
    let mut rng = Rng::seed_from_u64(41);
    let req = sample_request(320, &mut rng, 0);
    let golden = single_pool(&req);

    let mut set = ShardSet::new(ShardSetConfig {
        shards: 4,
        ..Default::default()
    })
    .unwrap();
    // First request with all shards alive.
    assert_eq!(router::transform(&mut set, &req).unwrap(), golden);
    // Kill two pools; the next request must still come back identical.
    set.coordinator_mut(0).unwrap().abort();
    set.coordinator_mut(2).unwrap().abort();
    assert_eq!(router::transform(&mut set, &req).unwrap(), golden);
    assert_eq!(set.healthy(), vec![1, 3]);
    assert_eq!(set.health_handle().load(std::sync::atomic::Ordering::Acquire), 2);
    // And again, steady-state on the survivors.
    assert_eq!(router::transform(&mut set, &req).unwrap(), golden);
    let m = set.shutdown();
    assert!(m.requests > 0);
}

//! End-to-end serving integration: the HTTP subsystem on an ephemeral
//! port, driven by concurrent std-thread clients speaking hand-rolled
//! HTTP/1.1 over `TcpStream` — including persistent (keep-alive)
//! connections and multi-shard scatter–gather serving.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use repro::bitplane::QuantBwht;
use repro::coordinator::{Coordinator, CoordinatorConfig, TransformRequest};
use repro::nn::{Backend, Mlp};
use repro::server::{AdmissionConfig, Server, ServerConfig};
use repro::util::json::{self, Json};
use repro::util::rng::Rng;

fn send_request(addr: SocketAddr, raw: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    stream.write_all(raw.as_bytes()).unwrap();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).expect("read response");
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (status, body)
}

fn post_json(addr: SocketAddr, path: &str, body: &str) -> (u16, String) {
    send_request(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn get(addr: SocketAddr, path: &str) -> (u16, String) {
    send_request(
        addr,
        &format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"),
    )
}

fn transform_body(x: &[f32], threshold: Option<f64>) -> String {
    let xs: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    match threshold {
        None => format!("{{\"x\":[{}]}}", xs.join(",")),
        Some(t) => {
            let th: Vec<String> = x.iter().map(|_| format!("{t}")).collect();
            format!(
                "{{\"x\":[{}],\"thresholds\":[{}]}}",
                xs.join(","),
                th.join(",")
            )
        }
    }
}

/// Read one framed HTTP response off a persistent connection.
/// Returns `(status, headers, body)`; headers are lower-cased names.
fn read_response(
    reader: &mut BufReader<TcpStream>,
) -> (u16, Vec<(String, String)>, String) {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).expect("status line");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).expect("header line");
        let trimmed = line.trim_end();
        if trimmed.is_empty() {
            break;
        }
        let (name, value) = trimmed.split_once(':').expect("header colon");
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim().to_string();
        if name == "content-length" {
            content_length = value.parse().expect("content length");
        }
        headers.push((name, value));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).expect("body");
    (status, headers, String::from_utf8(body).expect("utf-8 body"))
}

fn header_value<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers
        .iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v.as_str())
}

fn metric_value(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|line| {
            let rest = line.strip_prefix(name)?;
            let rest = rest.strip_prefix(' ')?;
            rest.trim().parse::<f64>().ok()
        })
        .unwrap_or(f64::NAN)
}

#[test]
fn serves_concurrent_clients_with_correct_outputs_and_metrics() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .expect("server start");
    let addr = server.addr;

    let (status, body) = get(addr, "/healthz");
    assert_eq!(status, 200);
    assert_eq!(body, "ok\n");

    // 8 parallel clients x 5 requests each, exact WHT correctness (T=0).
    let mut clients = Vec::new();
    for client in 0..8u64 {
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(100 + client);
            for _ in 0..5 {
                let x: Vec<f32> = (0..16)
                    .map(|_| rng.uniform_range(-1.0, 1.0) as f32)
                    .collect();
                let (status, body) =
                    post_json(addr, "/v1/transform", &transform_body(&x, None));
                assert_eq!(status, 200, "body: {body}");
                let parsed = json::parse(&body).expect("response json");
                let y: Vec<f32> = parsed
                    .get("y")
                    .and_then(Json::as_arr)
                    .expect("y array")
                    .iter()
                    .map(|v| v.as_f64().expect("numeric y") as f32)
                    .collect();
                let golden = QuantBwht::new(16, 16, 8).transform(&x);
                assert_eq!(y.len(), golden.len());
                for (i, (a, b)) in y.iter().zip(&golden).enumerate() {
                    assert!((a - b).abs() < 1e-4, "elem {i}: {a} vs {b}");
                }
                assert!(parsed.get("latency_us").and_then(Json::as_f64).is_some());
            }
        }));
    }
    for handle in clients {
        handle.join().expect("client thread");
    }

    // A saturating-threshold request: provably-zero outputs that
    // terminate after one bitplane, so /metrics shows row-cycle savings.
    let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
    let (status, body) = post_json(addr, "/v1/transform", &transform_body(&x, Some(1e9)));
    assert_eq!(status, 200, "body: {body}");
    let parsed = json::parse(&body).unwrap();
    assert!(parsed
        .get("y")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .all(|v| v.as_f64() == Some(0.0)));

    let (status, metrics) = get(addr, "/metrics");
    assert_eq!(status, 200);
    assert!(
        metric_value(&metrics, "repro_requests_total") >= 41.0,
        "{metrics}"
    );
    assert!(
        metric_value(&metrics, "repro_row_cycles_saved_total") > 0.0,
        "{metrics}"
    );
    assert!(metric_value(&metrics, "repro_request_latency_seconds_p50") > 0.0);
    assert!(metric_value(&metrics, "repro_request_latency_seconds_p99") > 0.0);
    assert!(metric_value(&metrics, "repro_batches_total") >= 1.0);
    assert!(metric_value(&metrics, "repro_http_requests_ok_total") >= 41.0);
    assert!(metric_value(&metrics, "repro_tops_per_watt") > 0.0);
    assert!(metrics.contains("# TYPE repro_request_latency_seconds histogram"));

    let m = server.shutdown();
    assert_eq!(m.requests, 41);
}

#[test]
fn keep_alive_serves_multiple_requests_on_one_connection() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;

    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // Three sequential requests on the same connection (HTTP/1.1
    // defaults to keep-alive; no Connection header sent).
    let mut rng = Rng::seed_from_u64(700);
    for i in 0..3 {
        let x: Vec<f32> = (0..16)
            .map(|_| rng.uniform_range(-1.0, 1.0) as f32)
            .collect();
        let body = transform_body(&x, None);
        write!(
            writer,
            "POST /v1/transform HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        writer.flush().unwrap();
        let (status, headers, body) = read_response(&mut reader);
        assert_eq!(status, 200, "request {i}: {body}");
        assert_eq!(
            header_value(&headers, "connection"),
            Some("keep-alive"),
            "request {i} must keep the connection open"
        );
        let parsed = json::parse(&body).unwrap();
        let y: Vec<f32> = parsed
            .get("y")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        assert_eq!(y, QuantBwht::new(16, 16, 8).transform(&x), "request {i}");
    }

    // An explicit Connection: close is honored and the socket drains.
    let body = transform_body(&[0.5; 16], None);
    write!(
        writer,
        "POST /v1/transform HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    writer.flush().unwrap();
    let (status, headers, _) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert_eq!(header_value(&headers, "connection"), Some("close"));
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "server must close after Connection: close");

    let m = server.shutdown();
    assert_eq!(m.requests, 4);
}

#[test]
fn keep_alive_request_cap_closes_the_connection() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        keepalive_max_requests: 2,
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;

    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let body = transform_body(&[0.25; 16], None);
    let raw = format!(
        "POST /v1/transform HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );

    write!(writer, "{raw}").unwrap();
    writer.flush().unwrap();
    let (status, headers, _) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert_eq!(header_value(&headers, "connection"), Some("keep-alive"));

    write!(writer, "{raw}").unwrap();
    writer.flush().unwrap();
    let (status, headers, _) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert_eq!(
        header_value(&headers, "connection"),
        Some("close"),
        "the per-connection cap must close the second response"
    );
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "no more requests after the cap");
    server.shutdown();
}

#[test]
fn keep_alive_idle_timeout_closes_quiet_connections() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        keepalive_idle: Duration::from_millis(100),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;

    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let body = transform_body(&[0.75; 16], None);
    write!(
        writer,
        "POST /v1/transform HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    writer.flush().unwrap();
    let (status, _, _) = read_response(&mut reader);
    assert_eq!(status, 200);

    // Go quiet past the idle deadline: the server hangs up (EOF), and
    // does so silently (no 400 for the non-request).
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "idle timeout must close without a response");
    server.shutdown();
}

#[test]
fn sharded_server_is_bit_identical_to_a_single_pool() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        shards: 3,
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;

    // A wide request that spans many tile blocks across the 3 shards.
    let mut rng = Rng::seed_from_u64(900);
    let x: Vec<f32> = (0..200)
        .map(|_| rng.uniform_range(-1.0, 1.0) as f32)
        .collect();
    let (status, body) = post_json(addr, "/v1/transform", &transform_body(&x, None));
    assert_eq!(status, 200, "{body}");
    let parsed = json::parse(&body).unwrap();
    assert_eq!(parsed.get("padded_dim").and_then(Json::as_f64), Some(208.0));
    let y: Vec<f32> = parsed
        .get("y")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    let mut single = Coordinator::new(CoordinatorConfig::default());
    let golden = single
        .transform(&TransformRequest {
            x,
            thresholds_units: vec![0.0; 200],
            scale: None,
            deadline: None,
        })
        .unwrap();
    single.shutdown();
    assert_eq!(y, golden, "sharded serving must match a single pool");

    let (status, metrics) = get(addr, "/metrics");
    assert_eq!(status, 200);
    assert_eq!(metric_value(&metrics, "repro_shards_healthy"), 3.0, "{metrics}");
    assert_eq!(metric_value(&metrics, "repro_shards_total"), 3.0);
    assert!(metrics.contains("repro_shard_requests_total{shard=\"2\"}"));
    assert!(metric_value(&metrics, "repro_elements_total") >= 208.0);
    server.shutdown();
}

fn test_mlp() -> Mlp {
    let mut r = Rng::seed_from_u64(77);
    let (din, hidden, classes) = (8usize, 16usize, 3usize);
    Mlp::from_flat(
        din,
        hidden,
        classes,
        r.normal_vec_f32(din * hidden, 0.0, 0.5),
        vec![0.0; hidden],
        vec![0.06; hidden],
        r.normal_vec_f32(hidden * classes, 0.0, 0.5),
        vec![0.0; classes],
    )
}

fn json_row(x: &[f32]) -> String {
    let vals: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    format!("[{}]", vals.join(","))
}

fn parse_f32s(v: &Json) -> Vec<f32> {
    v.as_arr()
        .expect("array")
        .iter()
        .map(|v| v.as_f64().expect("number") as f32)
        .collect()
}

#[test]
fn infer_endpoint_serves_logits_bit_identical_to_quantized_backend() {
    // The ISSUE-3 acceptance path: POST /v1/infer against a 2-shard
    // server hosting the model must return logits bit-identical to
    // Mlp::forward with Backend::Quantized.
    let mlp = test_mlp();
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        shards: 2,
        model: Some(mlp.clone()),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;

    // Single sample: flat x in, flat logits out.
    let mut rng = Rng::seed_from_u64(1000);
    let x: Vec<f32> = (0..8).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
    let (status, body) = post_json(addr, "/v1/infer", &format!("{{\"x\":{}}}", json_row(&x)));
    assert_eq!(status, 200, "{body}");
    let parsed = json::parse(&body).unwrap();
    let logits = parse_f32s(parsed.get("logits").expect("logits"));
    let want = mlp.forward(
        &x,
        1,
        Backend::Quantized { bits: 8 },
        &mut Rng::seed_from_u64(0),
    );
    assert_eq!(logits, want, "single-sample logits must be bit-identical");
    assert_eq!(parsed.get("classes").and_then(Json::as_f64), Some(3.0));
    assert_eq!(parsed.get("samples").and_then(Json::as_f64), Some(1.0));

    // Batch: nested rows in, nested logits out, same bit-identity.
    let xs: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..8).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect())
        .collect();
    let rows: Vec<String> = xs.iter().map(|r| json_row(r)).collect();
    let (status, body) = post_json(
        addr,
        "/v1/infer",
        &format!("{{\"x\":[{}]}}", rows.join(",")),
    );
    assert_eq!(status, 200, "{body}");
    let parsed = json::parse(&body).unwrap();
    let rows_out = parsed.get("logits").and_then(Json::as_arr).expect("rows");
    assert_eq!(rows_out.len(), 3);
    let flat: Vec<f32> = xs.iter().flatten().copied().collect();
    let want = mlp.forward(
        &flat,
        3,
        Backend::Quantized { bits: 8 },
        &mut Rng::seed_from_u64(0),
    );
    for (i, row) in rows_out.iter().enumerate() {
        assert_eq!(
            parse_f32s(row),
            want[i * 3..(i + 1) * 3].to_vec(),
            "batch row {i}"
        );
    }

    // Malformed inputs are clean 400s.
    let (status, _) = post_json(addr, "/v1/infer", "{\"x\":[1,2]}");
    assert_eq!(status, 400, "wrong feature count");
    let (status, _) = post_json(addr, "/v1/infer", "{\"y\":[1]}");
    assert_eq!(status, 400, "missing x");
    let (status, _) = get(addr, "/v1/infer");
    assert_eq!(status, 405);

    // The infer series show up on /metrics.
    let (status, metrics) = get(addr, "/metrics");
    assert_eq!(status, 200);
    assert_eq!(metric_value(&metrics, "repro_infer_requests_total"), 2.0, "{metrics}");
    assert_eq!(metric_value(&metrics, "repro_infer_samples_total"), 4.0);
    assert!(metric_value(&metrics, "repro_infer_batches_total") >= 2.0);
    assert!(metrics.contains("# TYPE repro_infer_latency_seconds histogram"));
    assert_eq!(metric_value(&metrics, "repro_shard_respawns_total"), 0.0);
    server.shutdown();
}

#[test]
fn infer_serves_non_power_of_two_hidden_width_through_shards() {
    // ISSUE-4 acceptance: an MLP with hidden = 300 (BWHT partition
    // [128, 128, 32, 8, 4] — nothing uniform about it) must serve on a
    // 2-shard server with no tile/width alignment rejection, and the
    // logits must be bit-identical to Backend::Quantized.
    let mut r = Rng::seed_from_u64(4242);
    let (din, hidden, classes) = (8usize, 300usize, 3usize);
    let mlp = Mlp::from_flat(
        din,
        hidden,
        classes,
        r.normal_vec_f32(din * hidden, 0.0, 0.4),
        vec![0.0; hidden],
        vec![0.05; hidden],
        r.normal_vec_f32(hidden * classes, 0.0, 0.4),
        vec![0.0; classes],
    );
    assert_eq!(
        mlp.bwht.transform_blocks().to_vec(),
        vec![128usize, 128, 32, 8, 4]
    );
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        shards: 2,
        model: Some(mlp.clone()),
        ..Default::default()
    })
    .expect("a mixed-partition model must start cleanly");
    let addr = server.addr;

    // Single sample.
    let mut rng = Rng::seed_from_u64(4300);
    let x: Vec<f32> = (0..din).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
    let (status, body) = post_json(addr, "/v1/infer", &format!("{{\"x\":{}}}", json_row(&x)));
    assert_eq!(status, 200, "{body}");
    let parsed = json::parse(&body).unwrap();
    let logits = parse_f32s(parsed.get("logits").expect("logits"));
    let want = mlp.forward(
        &x,
        1,
        Backend::Quantized { bits: 8 },
        &mut Rng::seed_from_u64(0),
    );
    assert_eq!(logits, want, "hidden-300 logits must be bit-identical");

    // A batch of three rows.
    let xs: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..din).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect())
        .collect();
    let rows: Vec<String> = xs.iter().map(|r| json_row(r)).collect();
    let (status, body) = post_json(addr, "/v1/infer", &format!("{{\"x\":[{}]}}", rows.join(",")));
    assert_eq!(status, 200, "{body}");
    let parsed = json::parse(&body).unwrap();
    let rows_out = parsed.get("logits").and_then(Json::as_arr).expect("rows");
    let flat: Vec<f32> = xs.iter().flatten().copied().collect();
    let want = mlp.forward(
        &flat,
        3,
        Backend::Quantized { bits: 8 },
        &mut Rng::seed_from_u64(0),
    );
    for (i, row) in rows_out.iter().enumerate() {
        assert_eq!(
            parse_f32s(row),
            want[i * classes..(i + 1) * classes].to_vec(),
            "batch row {i}"
        );
    }

    let (status, metrics) = get(addr, "/metrics");
    assert_eq!(status, 200);
    assert_eq!(metric_value(&metrics, "repro_shards_healthy"), 2.0, "{metrics}");
    assert!(metric_value(&metrics, "repro_infer_samples_total") >= 4.0);
    server.shutdown();
}

#[test]
fn infer_without_a_model_is_503() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .unwrap();
    let (status, body) = post_json(server.addr, "/v1/infer", "{\"x\":[1,2,3]}");
    assert_eq!(status, 503, "{body}");
    assert!(body.contains("--weights"), "{body}");
    server.shutdown();
}

#[test]
fn rate_limiting_sheds_with_429() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        admission: AdmissionConfig {
            max_inflight: 16,
            // Effectively no refill within the test's lifetime.
            rate_per_sec: 1e-6,
            burst: 2.0,
        },
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;
    let body = transform_body(&[0.5; 16], None);
    let (s1, _) = post_json(addr, "/v1/transform", &body);
    let (s2, _) = post_json(addr, "/v1/transform", &body);
    let (s3, b3) = post_json(addr, "/v1/transform", &body);
    assert_eq!((s1, s2), (200, 200));
    assert_eq!(s3, 429, "{b3}");
    assert!(b3.contains("rate"), "{b3}");
    let (_, metrics) = get(addr, "/metrics");
    assert_eq!(
        metric_value(&metrics, "repro_http_shed_total{reason=\"rate_limited\"}"),
        1.0,
        "{metrics}"
    );
    server.shutdown();
}

#[test]
fn rejects_malformed_requests_cleanly_and_stays_up() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;

    let (status, body) = post_json(addr, "/v1/transform", "{\"x\": []}");
    assert_eq!(status, 400, "{body}");
    let (status, _) = post_json(addr, "/v1/transform", "this is not json");
    assert_eq!(status, 400);
    let (status, body) = post_json(addr, "/v1/transform", "{\"x\":[1,2],\"thresholds\":[0]}");
    assert_eq!(status, 400, "{body}");
    let (status, _) = post_json(addr, "/v1/transform", "{\"y\":[1,2]}");
    assert_eq!(status, 400);
    let (status, _) = get(addr, "/no-such-endpoint");
    assert_eq!(status, 404);
    let (status, _) = get(addr, "/v1/transform");
    assert_eq!(status, 405);

    // Still healthy afterwards; short inputs are padded to the tile.
    let (status, body) = post_json(
        addr,
        "/v1/transform",
        &transform_body(&[1.0, -1.0, 0.5, 0.25], None),
    );
    assert_eq!(status, 200, "{body}");
    let parsed = json::parse(&body).unwrap();
    assert_eq!(
        parsed.get("padded_dim").and_then(Json::as_f64),
        Some(16.0),
        "dim-4 input pads to one 16-wide tile"
    );
    let (_, metrics) = get(addr, "/metrics");
    assert!(metric_value(&metrics, "repro_http_bad_requests_total") >= 4.0);
    server.shutdown();
}

#[test]
fn graceful_drain_serves_every_inflight_request_then_closes() {
    // A wide batch window parks the 8 requests inside the batcher, so
    // the drain begins while they are genuinely in flight — a drain
    // that dropped parked work would fail the 200 assertions below.
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        max_batch: 9,
        max_wait_us: 300_000,
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;

    let (sent_tx, sent_rx) = std::sync::mpsc::channel::<()>();
    let mut clients = Vec::new();
    for client in 0..8u64 {
        let sent = sent_tx.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(3100 + client);
            let x: Vec<f32> = (0..16)
                .map(|_| rng.uniform_range(-1.0, 1.0) as f32)
                .collect();
            let stream = TcpStream::connect(addr).unwrap();
            stream
                .set_read_timeout(Some(Duration::from_secs(30)))
                .unwrap();
            let mut writer = stream.try_clone().unwrap();
            let body = transform_body(&x, None);
            // A keep-alive request: the drain must still deliver the
            // real reply, then close the stream instead of re-arming.
            write!(
                writer,
                "POST /v1/transform HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .unwrap();
            writer.flush().unwrap();
            sent.send(()).unwrap();
            let mut reader = BufReader::new(stream);
            let (status, _, body) = read_response(&mut reader);
            assert_eq!(status, 200, "drain must not drop in-flight work: {body}");
            let parsed = json::parse(&body).expect("response json");
            let y: Vec<f32> = parsed
                .get("y")
                .and_then(Json::as_arr)
                .expect("y array")
                .iter()
                .map(|v| v.as_f64().expect("numeric y") as f32)
                .collect();
            assert_eq!(y, QuantBwht::new(16, 16, 8).transform(&x));
            let mut rest = Vec::new();
            reader.read_to_end(&mut rest).unwrap();
            assert!(rest.is_empty(), "drain must close the keep-alive stream");
        }));
    }

    // Wait until every request is written, give the reactors a beat to
    // consume them into the batcher's accumulation window, then start
    // the drain underneath the parked work.
    for _ in 0..8 {
        sent_rx.recv().unwrap();
    }
    std::thread::sleep(Duration::from_millis(100));
    server.begin_drain();

    for handle in clients {
        handle.join().expect("client thread");
    }

    let started = std::time::Instant::now();
    let m = server.drain(Duration::from_secs(10));
    assert!(
        started.elapsed() < Duration::from_secs(9),
        "drain must converge well before its timeout"
    );
    assert_eq!(m.requests, 8, "every in-flight request must be served");
}

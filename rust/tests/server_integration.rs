//! End-to-end serving integration: the HTTP subsystem on an ephemeral
//! port, driven by concurrent std-thread clients speaking hand-rolled
//! HTTP/1.1 over `TcpStream`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use repro::bitplane::QuantBwht;
use repro::server::{AdmissionConfig, Server, ServerConfig};
use repro::util::json::{self, Json};
use repro::util::rng::Rng;

fn send_request(addr: SocketAddr, raw: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    stream.write_all(raw.as_bytes()).unwrap();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).expect("read response");
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (status, body)
}

fn post_json(addr: SocketAddr, path: &str, body: &str) -> (u16, String) {
    send_request(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn get(addr: SocketAddr, path: &str) -> (u16, String) {
    send_request(
        addr,
        &format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"),
    )
}

fn transform_body(x: &[f32], threshold: Option<f64>) -> String {
    let xs: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    match threshold {
        None => format!("{{\"x\":[{}]}}", xs.join(",")),
        Some(t) => {
            let th: Vec<String> = x.iter().map(|_| format!("{t}")).collect();
            format!(
                "{{\"x\":[{}],\"thresholds\":[{}]}}",
                xs.join(","),
                th.join(",")
            )
        }
    }
}

fn metric_value(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|line| {
            let rest = line.strip_prefix(name)?;
            let rest = rest.strip_prefix(' ')?;
            rest.trim().parse::<f64>().ok()
        })
        .unwrap_or(f64::NAN)
}

#[test]
fn serves_concurrent_clients_with_correct_outputs_and_metrics() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .expect("server start");
    let addr = server.addr;

    let (status, body) = get(addr, "/healthz");
    assert_eq!(status, 200);
    assert_eq!(body, "ok\n");

    // 8 parallel clients x 5 requests each, exact WHT correctness (T=0).
    let mut clients = Vec::new();
    for client in 0..8u64 {
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(100 + client);
            for _ in 0..5 {
                let x: Vec<f32> = (0..16)
                    .map(|_| rng.uniform_range(-1.0, 1.0) as f32)
                    .collect();
                let (status, body) =
                    post_json(addr, "/v1/transform", &transform_body(&x, None));
                assert_eq!(status, 200, "body: {body}");
                let parsed = json::parse(&body).expect("response json");
                let y: Vec<f32> = parsed
                    .get("y")
                    .and_then(Json::as_arr)
                    .expect("y array")
                    .iter()
                    .map(|v| v.as_f64().expect("numeric y") as f32)
                    .collect();
                let golden = QuantBwht::new(16, 16, 8).transform(&x);
                assert_eq!(y.len(), golden.len());
                for (i, (a, b)) in y.iter().zip(&golden).enumerate() {
                    assert!((a - b).abs() < 1e-4, "elem {i}: {a} vs {b}");
                }
                assert!(parsed.get("latency_us").and_then(Json::as_f64).is_some());
            }
        }));
    }
    for handle in clients {
        handle.join().expect("client thread");
    }

    // A saturating-threshold request: provably-zero outputs that
    // terminate after one bitplane, so /metrics shows row-cycle savings.
    let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
    let (status, body) = post_json(addr, "/v1/transform", &transform_body(&x, Some(1e9)));
    assert_eq!(status, 200, "body: {body}");
    let parsed = json::parse(&body).unwrap();
    assert!(parsed
        .get("y")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .all(|v| v.as_f64() == Some(0.0)));

    let (status, metrics) = get(addr, "/metrics");
    assert_eq!(status, 200);
    assert!(
        metric_value(&metrics, "repro_requests_total") >= 41.0,
        "{metrics}"
    );
    assert!(
        metric_value(&metrics, "repro_row_cycles_saved_total") > 0.0,
        "{metrics}"
    );
    assert!(metric_value(&metrics, "repro_request_latency_seconds_p50") > 0.0);
    assert!(metric_value(&metrics, "repro_request_latency_seconds_p99") > 0.0);
    assert!(metric_value(&metrics, "repro_batches_total") >= 1.0);
    assert!(metric_value(&metrics, "repro_http_requests_ok_total") >= 41.0);
    assert!(metric_value(&metrics, "repro_tops_per_watt") > 0.0);
    assert!(metrics.contains("# TYPE repro_request_latency_seconds histogram"));

    let m = server.shutdown();
    assert_eq!(m.requests, 41);
}

#[test]
fn rate_limiting_sheds_with_429() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        admission: AdmissionConfig {
            max_inflight: 16,
            // Effectively no refill within the test's lifetime.
            rate_per_sec: 1e-6,
            burst: 2.0,
        },
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;
    let body = transform_body(&[0.5; 16], None);
    let (s1, _) = post_json(addr, "/v1/transform", &body);
    let (s2, _) = post_json(addr, "/v1/transform", &body);
    let (s3, b3) = post_json(addr, "/v1/transform", &body);
    assert_eq!((s1, s2), (200, 200));
    assert_eq!(s3, 429, "{b3}");
    assert!(b3.contains("rate"), "{b3}");
    let (_, metrics) = get(addr, "/metrics");
    assert_eq!(
        metric_value(&metrics, "repro_http_shed_total{reason=\"rate_limited\"}"),
        1.0,
        "{metrics}"
    );
    server.shutdown();
}

#[test]
fn rejects_malformed_requests_cleanly_and_stays_up() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;

    let (status, body) = post_json(addr, "/v1/transform", "{\"x\": []}");
    assert_eq!(status, 400, "{body}");
    let (status, _) = post_json(addr, "/v1/transform", "this is not json");
    assert_eq!(status, 400);
    let (status, body) = post_json(addr, "/v1/transform", "{\"x\":[1,2],\"thresholds\":[0]}");
    assert_eq!(status, 400, "{body}");
    let (status, _) = post_json(addr, "/v1/transform", "{\"y\":[1,2]}");
    assert_eq!(status, 400);
    let (status, _) = get(addr, "/no-such-endpoint");
    assert_eq!(status, 404);
    let (status, _) = get(addr, "/v1/transform");
    assert_eq!(status, 405);

    // Still healthy afterwards; short inputs are padded to the tile.
    let (status, body) = post_json(
        addr,
        "/v1/transform",
        &transform_body(&[1.0, -1.0, 0.5, 0.25], None),
    );
    assert_eq!(status, 200, "{body}");
    let parsed = json::parse(&body).unwrap();
    assert_eq!(
        parsed.get("padded_dim").and_then(Json::as_f64),
        Some(16.0),
        "dim-4 input pads to one 16-wide tile"
    );
    let (_, metrics) = get(addr, "/metrics");
    assert!(metric_value(&metrics, "repro_http_bad_requests_total") >= 4.0);
    server.shutdown();
}

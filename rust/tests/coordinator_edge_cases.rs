//! Coordinator backpressure and scheduler edge cases: the failure modes
//! a serving front-end leans on (clean rejection instead of deadlock or
//! panic) plus the zero-vector fast path.

use std::collections::HashSet;

use repro::coordinator::{Coordinator, CoordinatorConfig, TransformRequest};

#[test]
fn full_queue_rejects_instead_of_deadlocking() {
    let mut c = Coordinator::new(CoordinatorConfig {
        workers: 1,
        queue_depth: 2,
        ..Default::default()
    });
    // One large request keeps the single worker busy for milliseconds
    // while nanosecond-scale try_submits fill the depth-2 queue.
    let big_dim = 16 * 8192;
    let big = TransformRequest {
        x: vec![0.25; big_dim],
        thresholds_units: vec![0.0; big_dim],
        scale: None,
        deadline: None,
    };
    let small = TransformRequest {
        x: vec![0.5; 16],
        thresholds_units: vec![0.0; 16],
        scale: None,
        deadline: None,
    };
    let mut submitted = vec![c.submit(&big).unwrap()];
    let mut rejected = false;
    for _ in 0..100_000 {
        match c.try_submit(&small).unwrap() {
            Some(id) => submitted.push(id),
            None => {
                rejected = true;
                break;
            }
        }
    }
    assert!(rejected, "bounded queue must reject when full");
    // Everything accepted still completes — no deadlock, no loss.
    let mut seen = HashSet::new();
    for _ in 0..submitted.len() {
        seen.insert(c.drain_one().unwrap().request_id);
    }
    assert_eq!(seen.len(), submitted.len());
    for id in &submitted {
        assert!(seen.contains(id), "request {id} lost");
    }
    let m = c.metrics();
    assert_eq!(m.requests as usize, submitted.len());
    c.shutdown();
}

#[test]
fn zero_vector_terminates_on_the_first_plane() {
    let mut c = Coordinator::new(CoordinatorConfig::default());
    let out = c
        .transform(&TransformRequest {
            x: vec![0.0; 16],
            thresholds_units: vec![0.0; 16],
            scale: None,
            deadline: None,
        })
        .unwrap();
    assert!(out.iter().all(|&v| v == 0.0));
    let m = c.metrics();
    assert_eq!(m.planes_issued, 1, "zero input must retire after one plane");
    assert_eq!(m.row_cycles, 16);
    assert_eq!(m.cycles.terminated_early, 16);
    assert!((m.average_cycles() - 1.0).abs() < 1e-12);
    assert!(m.row_cycles_saved() > 0);
    c.shutdown();
}

#[test]
fn threshold_length_mismatch_is_a_clean_error() {
    let mut c = Coordinator::new(CoordinatorConfig::default());
    let err = c
        .transform(&TransformRequest {
            x: vec![0.1; 16],
            thresholds_units: vec![0.0; 8],
            scale: None,
            deadline: None,
        })
        .unwrap_err();
    assert!(
        err.to_string().contains("thresholds_units length"),
        "unexpected error: {err}"
    );
    // The pool survives the rejection and keeps serving.
    let ok = c
        .transform(&TransformRequest {
            x: vec![0.1; 16],
            thresholds_units: vec![0.0; 16],
            scale: None,
            deadline: None,
        })
        .unwrap();
    assert_eq!(ok.len(), 16);
    c.shutdown();
}

#[test]
fn empty_input_is_a_clean_error() {
    let mut c = Coordinator::new(CoordinatorConfig::default());
    assert!(c
        .transform(&TransformRequest {
            x: Vec::new(),
            thresholds_units: Vec::new(),
            scale: None,
            deadline: None,
        })
        .is_err());
    assert!(c.submit(&TransformRequest {
        x: Vec::new(),
        thresholds_units: Vec::new(),
        scale: None,
        deadline: None,
    })
    .is_err());
    c.shutdown();
}

#[test]
fn batch_with_one_bad_request_fails_before_dispatch() {
    let mut c = Coordinator::new(CoordinatorConfig::default());
    let good = TransformRequest {
        x: vec![0.3; 16],
        thresholds_units: vec![0.0; 16],
        scale: None,
        deadline: None,
    };
    let bad = TransformRequest {
        x: vec![0.3; 16],
        thresholds_units: vec![0.0; 4],
        scale: None,
        deadline: None,
    };
    assert!(c.transform_batch(&[good.clone(), bad]).is_err());
    // A clean batch afterwards still works.
    let outs = c.transform_batch(&[good.clone(), good]).unwrap();
    assert_eq!(outs.len(), 2);
    assert_eq!(outs[0], outs[1]);
    c.shutdown();
}

#[test]
fn sync_apis_refuse_to_run_with_undrained_submissions() {
    let mut c = Coordinator::new(CoordinatorConfig::default());
    let req = TransformRequest {
        x: vec![0.5; 16],
        thresholds_units: vec![0.0; 16],
        scale: None,
        deadline: None,
    };
    let id = c.submit(&req).unwrap();
    // transform() would steal the submitted result off the shared
    // channel; it must refuse cleanly instead.
    let err = c.transform(&req).unwrap_err();
    assert!(err.to_string().contains("drain_one"), "{err}");
    assert!(c.transform_batch(&[req.clone()]).is_err());
    let done = c.drain_one().unwrap();
    assert_eq!(done.request_id, id);
    // Drained: the synchronous path works again.
    assert_eq!(c.transform(&req).unwrap().len(), 16);
    c.shutdown();
}

#[test]
fn submit_drain_matches_synchronous_transform() {
    let x: Vec<f32> = (0..32).map(|i| ((i as f32) * 0.17).sin()).collect();
    let req = TransformRequest {
        x,
        thresholds_units: vec![0.0; 32],
        scale: None,
        deadline: None,
    };
    let mut sync = Coordinator::new(CoordinatorConfig::default());
    let want = sync.transform(&req).unwrap();
    sync.shutdown();

    let mut c = Coordinator::new(CoordinatorConfig::default());
    let id = c.submit(&req).unwrap();
    let done = c.drain_one().unwrap();
    assert_eq!(done.request_id, id);
    assert_eq!(done.values, want);
    c.shutdown();
}

//! Property-based tests over the substrate invariants (util::prop loops —
//! proptest is unavailable offline; failures report a reproducing seed).

use repro::quant::Quantizer;
use repro::util::prop::{self, forall};
use repro::wht;

#[test]
fn prop_quantizer_roundtrip_error_bounded() {
    forall(
        120,
        1,
        |r| {
            let bits = r.int_range(1, 10) as u32;
            let len = r.int_range(1, 100) as usize;
            let x = prop::vec_f32(r, len, 5.0);
            (bits, x)
        },
        |(bits, x)| {
            let q = Quantizer::new(*bits).quantize(x);
            for (orig, deq) in x.iter().zip(q.dequantize()) {
                if (orig - deq).abs() > q.scale / 2.0 + 1e-5 {
                    return Err(format!("roundtrip error: {orig} vs {deq}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bitplanes_reconstruct_exactly() {
    forall(
        120,
        2,
        |r| {
            let bits = r.int_range(1, 12) as u32;
            let x = prop::vec_f32(r, 32, 3.0);
            (bits, x)
        },
        |(bits, x)| {
            let q = Quantizer::new(*bits).quantize(x);
            if q.reconstruct_from_planes() != q.q {
                return Err("bitplane reconstruction mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_wht_involution_and_parseval() {
    forall(
        80,
        3,
        |r| {
            let k = r.int_range(1, 8) as usize;
            prop::vec_f32(r, 1 << k, 2.0)
        },
        |x| {
            let n = x.len() as f32;
            let mut y = x.clone();
            wht::wht_sequency(&mut y);
            // Parseval: ||Wx||^2 = n * ||x||^2
            let ex: f32 = x.iter().map(|v| v * v).sum();
            let ey: f32 = y.iter().map(|v| v * v).sum();
            if (ey - n * ex).abs() > 1e-2 * (n * ex).max(1.0) {
                return Err(format!("Parseval violated: {ey} vs {}", n * ex));
            }
            // Involution: W(Wx) = n x
            wht::wht_sequency(&mut y);
            for (a, b) in y.iter().zip(x) {
                if (a - n * b).abs() > 1e-2 * n.max(1.0) {
                    return Err(format!("involution violated: {a} vs {}", n * b));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bwht_blocks_always_cover() {
    forall(
        200,
        4,
        |r| {
            let dim = r.int_range(1, 5000) as usize;
            let cap = 1usize << r.int_range(2, 10);
            (dim, cap)
        },
        |(dim, cap)| {
            let blocks = wht::bwht_blocks(*dim, *cap);
            let total: usize = blocks.iter().sum();
            if total < *dim || total >= dim + wht::MIN_BLOCK {
                return Err(format!("bad cover: dim {dim} -> {total}"));
            }
            for &b in &blocks {
                if !b.is_power_of_two() || b > *cap || b < wht::MIN_BLOCK {
                    return Err(format!("bad block {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quant_transform_is_odd_function() {
    // Eq. 4 is odd: F0(-x) = -F0(x) (sign-magnitude symmetry end to end).
    forall(
        60,
        5,
        |r| {
            let bits = r.int_range(1, 8) as u32;
            (bits, prop::vec_f32(r, 32, 2.0))
        },
        |(bits, x)| {
            let eng = repro::bitplane::QuantBwht::new(32, 16, *bits);
            let pos = eng.transform(x);
            let neg: Vec<f32> = x.iter().map(|v| -v).collect();
            let neg_out = eng.transform(&neg);
            for (a, b) in pos.iter().zip(&neg_out) {
                if (a + b).abs() > 1e-5 {
                    return Err(format!("odd symmetry violated: {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_energy_model_monotone_in_vdd_and_positive() {
    forall(
        60,
        6,
        |r| {
            let n = 1usize << r.int_range(3, 6);
            let v1 = r.uniform_range(0.5, 0.9);
            let v2 = v1 + r.uniform_range(0.01, 0.2);
            (n, v1, v2)
        },
        |(n, v1, v2)| {
            let e1 = repro::energy::EnergyModel::new(*n, *v1).bitplane_energy_fj();
            let e2 = repro::energy::EnergyModel::new(*n, *v2).bitplane_energy_fj();
            if e1 <= 0.0 || e2 <= e1 {
                return Err(format!("energy not monotone: {e1} vs {e2}"));
            }
            Ok(())
        },
    );
}

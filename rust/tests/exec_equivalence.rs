//! Executor equivalence (ISSUE 3 satellite): the pooled/sharded digital
//! executors must be bit-identical to the legacy in-process
//! `Backend::Quantized` path across widths × bits × shard counts, and
//! the refactored in-process executors must reproduce the pre-refactor
//! algorithms exactly.

use repro::bitplane::QuantBwht;
use repro::coordinator::{Coordinator, CoordinatorConfig};
use repro::exec::{self, InProcess, Pooled, Sharded, TransformExecutor};
use repro::nn::{Backend, BwhtLayer, Mlp};
use repro::shard::{ShardSet, ShardSetConfig};
use repro::util::prop;
use repro::util::rng::Rng;
use repro::wht;

fn sample(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::seed_from_u64(seed);
    (0..n).map(|_| r.uniform_range(-1.5, 1.5) as f32).collect()
}

/// A layer whose per-channel thresholds are random (nonzero), so the
/// soft-threshold → early-termination fusion is actually exercised.
fn layer(width: usize, tseed: u64) -> BwhtLayer {
    let mut r = Rng::seed_from_u64(tseed);
    let t: Vec<f32> = (0..width)
        .map(|_| r.uniform_range(0.0, 0.15) as f32)
        .collect();
    BwhtLayer::new(width, width, t, 128)
}

#[test]
fn pooled_digital_is_bit_identical_across_widths_and_bits() {
    for &width in &[64usize, 128, 256] {
        for &bits in &[2u32, 4, 8] {
            let l = layer(width, 100 + width as u64);
            let tile = exec::uniform_tile(l.transform_blocks()).unwrap();
            let mut coord = Coordinator::new(CoordinatorConfig {
                tile_n: tile,
                bits,
                ..Default::default()
            });
            let batch = 3usize;
            let x = sample(batch * width, 200 + width as u64 + bits as u64);
            let want = l.forward(
                &x,
                batch,
                width,
                width,
                Backend::Quantized { bits },
                &mut Rng::seed_from_u64(0),
            );
            let got = {
                let mut executor = Pooled::new(&mut coord);
                l.forward_with(&mut executor, &x, batch, width, width, 0)
                    .unwrap()
            };
            assert_eq!(got, want, "width {width} bits {bits}");
            coord.shutdown();
        }
    }
}

#[test]
fn sharded_digital_is_bit_identical_across_shard_counts() {
    let width = 256usize;
    let l = layer(width, 11);
    let tile = exec::uniform_tile(l.transform_blocks()).unwrap();
    let batch = 4usize;
    let x = sample(batch * width, 12);
    let want = l.forward(
        &x,
        batch,
        width,
        width,
        Backend::Quantized { bits: 8 },
        &mut Rng::seed_from_u64(0),
    );
    for shards in 1..=3usize {
        let mut set = ShardSet::new(ShardSetConfig {
            shards,
            coordinator: CoordinatorConfig {
                tile_n: tile,
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        let got = {
            let mut executor = Sharded::new(&mut set);
            l.forward_with(&mut executor, &x, batch, width, width, 0)
                .unwrap()
        };
        assert_eq!(got, want, "shards {shards}");
        set.shutdown();
    }
}

#[test]
fn mlp_logits_match_quantized_backend_on_pooled_and_sharded_executors() {
    let mut r = Rng::seed_from_u64(21);
    let (din, hidden, classes, batch) = (16usize, 64usize, 4usize, 5usize);
    let mlp = Mlp::from_flat(
        din,
        hidden,
        classes,
        r.normal_vec_f32(din * hidden, 0.0, 0.4),
        vec![0.0; hidden],
        vec![0.08; hidden],
        r.normal_vec_f32(hidden * classes, 0.0, 0.4),
        vec![0.0; classes],
    );
    let tile = exec::uniform_tile(mlp.bwht.transform_blocks()).unwrap();
    assert_eq!(tile, 64);
    let x = sample(batch * din, 22);
    let want = mlp.forward(
        &x,
        batch,
        Backend::Quantized { bits: 8 },
        &mut Rng::seed_from_u64(0),
    );

    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: tile,
        ..Default::default()
    });
    let pooled = {
        let mut executor = Pooled::new(&mut coord);
        mlp.forward_with(&mut executor, &x, batch, 0).unwrap()
    };
    assert_eq!(pooled, want, "pooled logits");
    coord.shutdown();

    let mut set = ShardSet::new(ShardSetConfig {
        shards: 2,
        coordinator: CoordinatorConfig {
            tile_n: tile,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let sharded = {
        let mut executor = Sharded::new(&mut set);
        mlp.forward_with(&mut executor, &x, batch, 0).unwrap()
    };
    assert_eq!(sharded, want, "sharded logits");
    set.shutdown();
}

/// The pre-refactor float algorithm, restated inline: per sample,
/// transform → norm → soft-threshold → transform → norm.
fn legacy_float_forward(l: &BwhtLayer, x: &[f32], batch: usize, width: usize) -> Vec<f32> {
    let norm = 1.0f32 / (width.min(128) as f32).sqrt();
    let mut out = vec![0f32; batch * width];
    for bi in 0..batch {
        let xi = &x[bi * width..(bi + 1) * width];
        let mut freq = wht::bwht_apply(xi, width, 128);
        for f in freq.iter_mut() {
            *f *= norm;
        }
        for (f, t) in freq.iter_mut().zip(&l.t) {
            let a = f.abs() - t.abs();
            *f = if a > 0.0 { f.signum() * a } else { 0.0 };
        }
        let mut spatial = wht::bwht_apply(&freq, width, 128);
        for s in spatial.iter_mut() {
            *s *= norm;
        }
        out[bi * width..(bi + 1) * width].copy_from_slice(&spatial);
    }
    out
}

#[test]
fn in_process_float_matches_the_legacy_algorithm() {
    for &width in &[64usize, 128] {
        let l = layer(width, 31);
        let batch = 2usize;
        let x = sample(batch * width, 32);
        let want = legacy_float_forward(&l, &x, batch, width);
        let got = l.forward(&x, batch, width, width, Backend::Float, &mut Rng::seed_from_u64(0));
        assert_eq!(got, want, "width {width}");
    }
}

/// The pre-refactor quantized algorithm, restated inline against
/// `QuantBwht` (the digital golden model).
fn legacy_quantized_forward(l: &BwhtLayer, x: &[f32], batch: usize, width: usize, bits: u32) -> Vec<f32> {
    let eng = QuantBwht::new(width, 128, bits);
    let norm = 1.0f32 / (width.min(128) as f32).sqrt();
    let mut out = vec![0f32; batch * width];
    for bi in 0..batch {
        let xi = &x[bi * width..(bi + 1) * width];
        let mut freq = eng.transform(xi);
        for f in freq.iter_mut() {
            *f *= norm;
        }
        for (f, t) in freq.iter_mut().zip(&l.t) {
            let a = f.abs() - t.abs();
            *f = if a > 0.0 { f.signum() * a } else { 0.0 };
        }
        let mut spatial = eng.transform(&freq);
        for s in spatial.iter_mut() {
            *s *= norm;
        }
        out[bi * width..(bi + 1) * width].copy_from_slice(&spatial);
    }
    out
}

#[test]
fn in_process_quantized_matches_the_legacy_algorithm() {
    for &width in &[64usize, 128] {
        for &bits in &[4u32, 8] {
            let l = layer(width, 41);
            let batch = 2usize;
            let x = sample(batch * width, 42 + bits as u64);
            let want = legacy_quantized_forward(&l, &x, batch, width, bits);
            let got = l.forward(
                &x,
                batch,
                width,
                width,
                Backend::Quantized { bits },
                &mut Rng::seed_from_u64(0),
            );
            assert_eq!(got, want, "width {width} bits {bits}");
        }
    }
}

#[test]
fn property_pooled_matches_quantized_for_random_inputs_and_thresholds() {
    // One long-lived pool; every case must agree bit-for-bit with the
    // in-process quantized layer, whatever the input and thresholds —
    // including thresholds near the dead-zone boundary.
    let width = 64usize;
    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: 64,
        ..Default::default()
    });
    prop::forall(
        40,
        55,
        |r| {
            let x = prop::vec_f32(r, width, 2.0);
            let t: Vec<f32> = (0..width)
                .map(|_| r.uniform_range(0.0, 0.4) as f32)
                .collect();
            (x, t)
        },
        |(x, t)| {
            let l = BwhtLayer::new(width, width, t.clone(), 128);
            let want = l.forward(
                x,
                1,
                width,
                width,
                Backend::Quantized { bits: 8 },
                &mut Rng::seed_from_u64(0),
            );
            let got = {
                let mut executor = Pooled::new(&mut coord);
                l.forward_with(&mut executor, x, 1, width, width, 0)
                    .map_err(|e| e.to_string())?
            };
            if got != want {
                return Err(format!("pooled {got:?} != quantized {want:?}"));
            }
            Ok(())
        },
    );
    coord.shutdown();
}

#[test]
fn in_process_executor_exposes_backend_bits() {
    assert_eq!(InProcess::new(Backend::Float, 0).quant_bits(), None);
    assert_eq!(
        InProcess::new(Backend::Quantized { bits: 6 }, 0).quant_bits(),
        Some(6)
    );
    assert_eq!(
        InProcess::new(
            Backend::Noisy {
                bits: 3,
                sigma_ant: 0.1
            },
            0
        )
        .quant_bits(),
        Some(3)
    );
}

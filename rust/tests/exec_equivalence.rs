//! Executor equivalence (ISSUE 3 satellite, extended by ISSUE 4): the
//! pooled/sharded digital executors must be bit-identical to the legacy
//! in-process `Backend::Quantized` path across widths × bits × shard
//! counts — including non-power-of-two widths whose BWHT partitions mix
//! block sizes (20 → `[16, 4]`, 300 → `[128, 128, 32, 8, 4]`), served
//! via sub-tile masking — and the refactored in-process executors must
//! reproduce the pre-refactor algorithms exactly.

use repro::bitplane::QuantBwht;
use repro::coordinator::{required_tile, Coordinator, CoordinatorConfig};
use repro::exec::{InProcess, Pooled, Sharded, TransformExecutor};
use repro::nn::{Backend, BwhtLayer, Mlp};
use repro::shard::{ShardSet, ShardSetConfig};
use repro::util::prop;
use repro::util::rng::Rng;
use repro::wht;

fn sample(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::seed_from_u64(seed);
    (0..n).map(|_| r.uniform_range(-1.5, 1.5) as f32).collect()
}

/// A layer whose per-channel thresholds are random (nonzero), so the
/// soft-threshold → early-termination fusion is actually exercised.
fn layer(width: usize, tseed: u64) -> BwhtLayer {
    let mut r = Rng::seed_from_u64(tseed);
    let t: Vec<f32> = (0..width)
        .map(|_| r.uniform_range(0.0, 0.15) as f32)
        .collect();
    BwhtLayer::new(width, width, t, 128)
}

#[test]
fn pooled_digital_is_bit_identical_across_widths_and_bits() {
    // Power-of-two widths partition into uniform tiles; 20, 68, 300 and
    // 1040 produce mixed partitions ([16, 4], [64, 4],
    // [128, 128, 32, 8, 4], [128×8, 16]) whose narrow blocks run under
    // sub-tile masking.
    for &width in &[64usize, 128, 256, 20, 68, 300, 1040] {
        for &bits in &[2u32, 4, 8] {
            let l = layer(width, 100 + width as u64);
            let tile = required_tile(l.transform_blocks()).unwrap();
            let mut coord = Coordinator::new(CoordinatorConfig {
                tile_n: tile,
                bits,
                ..Default::default()
            });
            let batch = 3usize;
            let x = sample(batch * width, 200 + width as u64 + bits as u64);
            let want = l.forward(
                &x,
                batch,
                width,
                width,
                Backend::Quantized { bits },
                &mut Rng::seed_from_u64(0),
            );
            let got = {
                let mut executor = Pooled::new(&mut coord);
                l.forward_with(&mut executor, &x, batch, width, width, 0)
                    .unwrap()
            };
            assert_eq!(got, want, "width {width} bits {bits}");
            coord.shutdown();
        }
    }
}

#[test]
fn sharded_digital_is_bit_identical_across_shard_counts() {
    // 300 partitions as [128, 128, 32, 8, 4]: every shard count must
    // reproduce the in-process quantized layer exactly, wherever the
    // planner places the sub-tile blocks.
    for &width in &[256usize, 300] {
        let l = layer(width, 11 + width as u64);
        let tile = required_tile(l.transform_blocks()).unwrap();
        let batch = 4usize;
        let x = sample(batch * width, 12 + width as u64);
        let want = l.forward(
            &x,
            batch,
            width,
            width,
            Backend::Quantized { bits: 8 },
            &mut Rng::seed_from_u64(0),
        );
        for shards in 1..=3usize {
            let mut set = ShardSet::new(ShardSetConfig {
                shards,
                coordinator: CoordinatorConfig {
                    tile_n: tile,
                    ..Default::default()
                },
                ..Default::default()
            })
            .unwrap();
            let got = {
                let mut executor = Sharded::new(&mut set);
                l.forward_with(&mut executor, &x, batch, width, width, 0)
                    .unwrap()
            };
            assert_eq!(got, want, "width {width} shards {shards}");
            set.shutdown();
        }
    }
}

#[test]
fn mlp_logits_match_quantized_backend_on_pooled_and_sharded_executors() {
    let mut r = Rng::seed_from_u64(21);
    let (din, hidden, classes, batch) = (16usize, 64usize, 4usize, 5usize);
    let mlp = Mlp::from_flat(
        din,
        hidden,
        classes,
        r.normal_vec_f32(din * hidden, 0.0, 0.4),
        vec![0.0; hidden],
        vec![0.08; hidden],
        r.normal_vec_f32(hidden * classes, 0.0, 0.4),
        vec![0.0; classes],
    );
    let tile = required_tile(mlp.bwht.transform_blocks()).unwrap();
    assert_eq!(tile, 64);
    let x = sample(batch * din, 22);
    let want = mlp.forward(
        &x,
        batch,
        Backend::Quantized { bits: 8 },
        &mut Rng::seed_from_u64(0),
    );

    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: tile,
        ..Default::default()
    });
    let pooled = {
        let mut executor = Pooled::new(&mut coord);
        mlp.forward_with(&mut executor, &x, batch, 0).unwrap()
    };
    assert_eq!(pooled, want, "pooled logits");
    coord.shutdown();

    let mut set = ShardSet::new(ShardSetConfig {
        shards: 2,
        coordinator: CoordinatorConfig {
            tile_n: tile,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let sharded = {
        let mut executor = Sharded::new(&mut set);
        mlp.forward_with(&mut executor, &x, batch, 0).unwrap()
    };
    assert_eq!(sharded, want, "sharded logits");
    set.shutdown();
}

/// The pre-refactor float algorithm, restated inline: per sample,
/// transform → norm → soft-threshold → transform → norm.
fn legacy_float_forward(l: &BwhtLayer, x: &[f32], batch: usize, width: usize) -> Vec<f32> {
    let norm = 1.0f32 / (width.min(128) as f32).sqrt();
    let mut out = vec![0f32; batch * width];
    for bi in 0..batch {
        let xi = &x[bi * width..(bi + 1) * width];
        let mut freq = wht::bwht_apply(xi, width, 128);
        for f in freq.iter_mut() {
            *f *= norm;
        }
        for (f, t) in freq.iter_mut().zip(&l.t) {
            let a = f.abs() - t.abs();
            *f = if a > 0.0 { f.signum() * a } else { 0.0 };
        }
        let mut spatial = wht::bwht_apply(&freq, width, 128);
        for s in spatial.iter_mut() {
            *s *= norm;
        }
        out[bi * width..(bi + 1) * width].copy_from_slice(&spatial);
    }
    out
}

#[test]
fn in_process_float_matches_the_legacy_algorithm() {
    for &width in &[64usize, 128] {
        let l = layer(width, 31);
        let batch = 2usize;
        let x = sample(batch * width, 32);
        let want = legacy_float_forward(&l, &x, batch, width);
        let got = l.forward(&x, batch, width, width, Backend::Float, &mut Rng::seed_from_u64(0));
        assert_eq!(got, want, "width {width}");
    }
}

/// The pre-refactor quantized algorithm, restated inline against
/// `QuantBwht` (the digital golden model).
fn legacy_quantized_forward(l: &BwhtLayer, x: &[f32], batch: usize, width: usize, bits: u32) -> Vec<f32> {
    let eng = QuantBwht::new(width, 128, bits);
    let norm = 1.0f32 / (width.min(128) as f32).sqrt();
    let mut out = vec![0f32; batch * width];
    for bi in 0..batch {
        let xi = &x[bi * width..(bi + 1) * width];
        let mut freq = eng.transform(xi);
        for f in freq.iter_mut() {
            *f *= norm;
        }
        for (f, t) in freq.iter_mut().zip(&l.t) {
            let a = f.abs() - t.abs();
            *f = if a > 0.0 { f.signum() * a } else { 0.0 };
        }
        let mut spatial = eng.transform(&freq);
        for s in spatial.iter_mut() {
            *s *= norm;
        }
        out[bi * width..(bi + 1) * width].copy_from_slice(&spatial);
    }
    out
}

#[test]
fn in_process_quantized_matches_the_legacy_algorithm() {
    for &width in &[64usize, 128] {
        for &bits in &[4u32, 8] {
            let l = layer(width, 41);
            let batch = 2usize;
            let x = sample(batch * width, 42 + bits as u64);
            let want = legacy_quantized_forward(&l, &x, batch, width, bits);
            let got = l.forward(
                &x,
                batch,
                width,
                width,
                Backend::Quantized { bits },
                &mut Rng::seed_from_u64(0),
            );
            assert_eq!(got, want, "width {width} bits {bits}");
        }
    }
}

#[test]
fn property_pooled_matches_quantized_for_random_inputs_and_thresholds() {
    // One long-lived pool; every case must agree bit-for-bit with the
    // in-process quantized layer, whatever the input and thresholds —
    // including thresholds near the dead-zone boundary.
    let width = 64usize;
    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: 64,
        ..Default::default()
    });
    prop::forall(
        40,
        55,
        |r| {
            let x = prop::vec_f32(r, width, 2.0);
            let t: Vec<f32> = (0..width)
                .map(|_| r.uniform_range(0.0, 0.4) as f32)
                .collect();
            (x, t)
        },
        |(x, t)| {
            let l = BwhtLayer::new(width, width, t.clone(), 128);
            let want = l.forward(
                x,
                1,
                width,
                width,
                Backend::Quantized { bits: 8 },
                &mut Rng::seed_from_u64(0),
            );
            let got = {
                let mut executor = Pooled::new(&mut coord);
                l.forward_with(&mut executor, x, 1, width, width, 0)
                    .map_err(|e| e.to_string())?
            };
            if got != want {
                return Err(format!("pooled {got:?} != quantized {want:?}"));
            }
            Ok(())
        },
    );
    coord.shutdown();
}

#[test]
fn property_plan_layer_random_widths_pooled_and_sharded_match_quantized() {
    // ISSUE-4 satellite: draw random widths in [MIN_BLOCK, 2048], build
    // the natural `bwht_blocks` partition (mixed block sizes for most
    // draws), and assert pooled and sharded digital execution is
    // bit-identical to the in-process quantized backend — including the
    // fused early-termination thresholds and pinned per-sample scales
    // that `BwhtLayer::forward_with` plumbs through the seam.
    let mut set = ShardSet::new(ShardSetConfig {
        shards: 2,
        coordinator: CoordinatorConfig {
            tile_n: 128,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: 128,
        ..Default::default()
    });
    prop::forall(
        12,
        2024,
        |r| {
            let width = r.int_range(wht::MIN_BLOCK as i64, 2048) as usize;
            let padded = wht::bwht_padded_dim(width, 128);
            let t: Vec<f32> = (0..padded)
                .map(|_| r.uniform_range(0.0, 0.2) as f32)
                .collect();
            let x = prop::vec_f32(r, padded, 1.5);
            (padded, t, x)
        },
        |(padded, t, x)| {
            let l = BwhtLayer::new(*padded, *padded, t.clone(), 128);
            assert_eq!(
                l.transform_blocks().to_vec(),
                wht::bwht_blocks(*padded, 128),
                "layer must emit its natural partition"
            );
            let want = l.forward(
                x,
                1,
                *padded,
                *padded,
                Backend::Quantized { bits: 8 },
                &mut Rng::seed_from_u64(0),
            );
            let pooled = {
                let mut executor = Pooled::new(&mut coord);
                l.forward_with(&mut executor, x, 1, *padded, *padded, 0)
                    .map_err(|e| e.to_string())?
            };
            if pooled != want {
                return Err(format!("pooled diverged at width {padded}"));
            }
            let sharded = {
                let mut executor = Sharded::new(&mut set);
                l.forward_with(&mut executor, x, 1, *padded, *padded, 0)
                    .map_err(|e| e.to_string())?
            };
            if sharded != want {
                return Err(format!("sharded diverged at width {padded}"));
            }
            Ok(())
        },
    );
    coord.shutdown();
    set.shutdown();
}

#[test]
fn mlp_hidden_300_logits_match_quantized_backend_when_sharded() {
    // The acceptance-criteria model shape: hidden = 300 partitions as
    // [128, 128, 32, 8, 4] — nothing about it is uniform, and it must
    // still serve bit-identically through the shard set.
    let mut r = Rng::seed_from_u64(51);
    let (din, hidden, classes, batch) = (12usize, 300usize, 4usize, 3usize);
    let mlp = Mlp::from_flat(
        din,
        hidden,
        classes,
        r.normal_vec_f32(din * hidden, 0.0, 0.3),
        vec![0.0; hidden],
        vec![0.06; hidden],
        r.normal_vec_f32(hidden * classes, 0.0, 0.3),
        vec![0.0; classes],
    );
    assert_eq!(
        mlp.bwht.transform_blocks().to_vec(),
        vec![128usize, 128, 32, 8, 4]
    );
    let tile = required_tile(mlp.bwht.transform_blocks()).unwrap();
    assert_eq!(tile, 128);
    let x = sample(batch * din, 52);
    let want = mlp.forward(
        &x,
        batch,
        Backend::Quantized { bits: 8 },
        &mut Rng::seed_from_u64(0),
    );
    let mut set = ShardSet::new(ShardSetConfig {
        shards: 2,
        coordinator: CoordinatorConfig {
            tile_n: tile,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let got = {
        let mut executor = Sharded::new(&mut set);
        mlp.forward_with(&mut executor, &x, batch, 0).unwrap()
    };
    assert_eq!(got, want, "hidden-300 sharded logits");
    set.shutdown();
}

#[test]
fn in_process_executor_exposes_backend_bits() {
    assert_eq!(InProcess::new(Backend::Float, 0).quant_bits(), None);
    assert_eq!(
        InProcess::new(Backend::Quantized { bits: 6 }, 0).quant_bits(),
        Some(6)
    );
    assert_eq!(
        InProcess::new(
            Backend::Noisy {
                bits: 3,
                sigma_ant: 0.1
            },
            0
        )
        .quant_bits(),
        Some(3)
    );
}

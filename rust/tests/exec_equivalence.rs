//! Executor equivalence (ISSUE 3 satellite, extended by ISSUE 4): the
//! pooled/sharded digital executors must be bit-identical to the legacy
//! in-process `Backend::Quantized` path across widths × bits × shard
//! counts — including non-power-of-two widths whose BWHT partitions mix
//! block sizes (20 → `[16, 4]`, 300 → `[128, 128, 32, 8, 4]`), served
//! via sub-tile masking — and the refactored in-process executors must
//! reproduce the pre-refactor algorithms exactly.

use repro::bitplane::QuantBwht;
use repro::coordinator::{
    required_tile, schedule_batch, schedule_block, Coordinator, CoordinatorConfig, ScratchArena,
    Tile, TileKind, TilePlan, TransformRequest,
};
use repro::exec::{InProcess, Pooled, Sharded, TransformExecutor};
use repro::nn::{Backend, BwhtLayer, Mlp};
use repro::shard::{ShardSet, ShardSetConfig};
use repro::util::prop;
use repro::util::rng::Rng;
use repro::wht;

fn sample(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::seed_from_u64(seed);
    (0..n).map(|_| r.uniform_range(-1.5, 1.5) as f32).collect()
}

/// A layer whose per-channel thresholds are random (nonzero), so the
/// soft-threshold → early-termination fusion is actually exercised.
fn layer(width: usize, tseed: u64) -> BwhtLayer {
    let mut r = Rng::seed_from_u64(tseed);
    let t: Vec<f32> = (0..width)
        .map(|_| r.uniform_range(0.0, 0.15) as f32)
        .collect();
    BwhtLayer::new(width, width, t, 128)
}

#[test]
fn pooled_digital_is_bit_identical_across_widths_and_bits() {
    // Power-of-two widths partition into uniform tiles; 20, 68, 300 and
    // 1040 produce mixed partitions ([16, 4], [64, 4],
    // [128, 128, 32, 8, 4], [128×8, 16]) whose narrow blocks run under
    // sub-tile masking.
    for &width in &[64usize, 128, 256, 20, 68, 300, 1040] {
        for &bits in &[2u32, 4, 8] {
            let l = layer(width, 100 + width as u64);
            let tile = required_tile(l.transform_blocks()).unwrap();
            let mut coord = Coordinator::new(CoordinatorConfig {
                tile_n: tile,
                bits,
                ..Default::default()
            });
            let batch = 3usize;
            let x = sample(batch * width, 200 + width as u64 + bits as u64);
            let want = l.forward(
                &x,
                batch,
                width,
                width,
                Backend::Quantized { bits },
                &mut Rng::seed_from_u64(0),
            );
            let got = {
                let mut executor = Pooled::new(&mut coord);
                l.forward_with(&mut executor, &x, batch, width, width, 0)
                    .unwrap()
            };
            assert_eq!(got, want, "width {width} bits {bits}");
            coord.shutdown();
        }
    }
}

#[test]
fn sharded_digital_is_bit_identical_across_shard_counts() {
    // 300 partitions as [128, 128, 32, 8, 4]: every shard count must
    // reproduce the in-process quantized layer exactly, wherever the
    // planner places the sub-tile blocks.
    for &width in &[256usize, 300] {
        let l = layer(width, 11 + width as u64);
        let tile = required_tile(l.transform_blocks()).unwrap();
        let batch = 4usize;
        let x = sample(batch * width, 12 + width as u64);
        let want = l.forward(
            &x,
            batch,
            width,
            width,
            Backend::Quantized { bits: 8 },
            &mut Rng::seed_from_u64(0),
        );
        for shards in 1..=3usize {
            let mut set = ShardSet::new(ShardSetConfig {
                shards,
                coordinator: CoordinatorConfig {
                    tile_n: tile,
                    ..Default::default()
                },
                ..Default::default()
            })
            .unwrap();
            let got = {
                let mut executor = Sharded::new(&mut set);
                l.forward_with(&mut executor, &x, batch, width, width, 0)
                    .unwrap()
            };
            assert_eq!(got, want, "width {width} shards {shards}");
            set.shutdown();
        }
    }
}

#[test]
fn mlp_logits_match_quantized_backend_on_pooled_and_sharded_executors() {
    let mut r = Rng::seed_from_u64(21);
    let (din, hidden, classes, batch) = (16usize, 64usize, 4usize, 5usize);
    let mlp = Mlp::from_flat(
        din,
        hidden,
        classes,
        r.normal_vec_f32(din * hidden, 0.0, 0.4),
        vec![0.0; hidden],
        vec![0.08; hidden],
        r.normal_vec_f32(hidden * classes, 0.0, 0.4),
        vec![0.0; classes],
    );
    let tile = required_tile(mlp.bwht.transform_blocks()).unwrap();
    assert_eq!(tile, 64);
    let x = sample(batch * din, 22);
    let want = mlp.forward(
        &x,
        batch,
        Backend::Quantized { bits: 8 },
        &mut Rng::seed_from_u64(0),
    );

    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: tile,
        ..Default::default()
    });
    let pooled = {
        let mut executor = Pooled::new(&mut coord);
        mlp.forward_with(&mut executor, &x, batch, 0).unwrap()
    };
    assert_eq!(pooled, want, "pooled logits");
    coord.shutdown();

    let mut set = ShardSet::new(ShardSetConfig {
        shards: 2,
        coordinator: CoordinatorConfig {
            tile_n: tile,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let sharded = {
        let mut executor = Sharded::new(&mut set);
        mlp.forward_with(&mut executor, &x, batch, 0).unwrap()
    };
    assert_eq!(sharded, want, "sharded logits");
    set.shutdown();
}

/// The pre-refactor float algorithm, restated inline: per sample,
/// transform → norm → soft-threshold → transform → norm.
fn legacy_float_forward(l: &BwhtLayer, x: &[f32], batch: usize, width: usize) -> Vec<f32> {
    let norm = 1.0f32 / (width.min(128) as f32).sqrt();
    let mut out = vec![0f32; batch * width];
    for bi in 0..batch {
        let xi = &x[bi * width..(bi + 1) * width];
        let mut freq = wht::bwht_apply(xi, width, 128);
        for f in freq.iter_mut() {
            *f *= norm;
        }
        for (f, t) in freq.iter_mut().zip(&l.t) {
            let a = f.abs() - t.abs();
            *f = if a > 0.0 { f.signum() * a } else { 0.0 };
        }
        let mut spatial = wht::bwht_apply(&freq, width, 128);
        for s in spatial.iter_mut() {
            *s *= norm;
        }
        out[bi * width..(bi + 1) * width].copy_from_slice(&spatial);
    }
    out
}

#[test]
fn in_process_float_matches_the_legacy_algorithm() {
    for &width in &[64usize, 128] {
        let l = layer(width, 31);
        let batch = 2usize;
        let x = sample(batch * width, 32);
        let want = legacy_float_forward(&l, &x, batch, width);
        let got = l.forward(&x, batch, width, width, Backend::Float, &mut Rng::seed_from_u64(0));
        assert_eq!(got, want, "width {width}");
    }
}

/// The pre-refactor quantized algorithm, restated inline against
/// `QuantBwht` (the digital golden model).
fn legacy_quantized_forward(l: &BwhtLayer, x: &[f32], batch: usize, width: usize, bits: u32) -> Vec<f32> {
    let eng = QuantBwht::new(width, 128, bits);
    let norm = 1.0f32 / (width.min(128) as f32).sqrt();
    let mut out = vec![0f32; batch * width];
    for bi in 0..batch {
        let xi = &x[bi * width..(bi + 1) * width];
        let mut freq = eng.transform(xi);
        for f in freq.iter_mut() {
            *f *= norm;
        }
        for (f, t) in freq.iter_mut().zip(&l.t) {
            let a = f.abs() - t.abs();
            *f = if a > 0.0 { f.signum() * a } else { 0.0 };
        }
        let mut spatial = eng.transform(&freq);
        for s in spatial.iter_mut() {
            *s *= norm;
        }
        out[bi * width..(bi + 1) * width].copy_from_slice(&spatial);
    }
    out
}

#[test]
fn in_process_quantized_matches_the_legacy_algorithm() {
    for &width in &[64usize, 128] {
        for &bits in &[4u32, 8] {
            let l = layer(width, 41);
            let batch = 2usize;
            let x = sample(batch * width, 42 + bits as u64);
            let want = legacy_quantized_forward(&l, &x, batch, width, bits);
            let got = l.forward(
                &x,
                batch,
                width,
                width,
                Backend::Quantized { bits },
                &mut Rng::seed_from_u64(0),
            );
            assert_eq!(got, want, "width {width} bits {bits}");
        }
    }
}

#[test]
fn property_pooled_matches_quantized_for_random_inputs_and_thresholds() {
    // One long-lived pool; every case must agree bit-for-bit with the
    // in-process quantized layer, whatever the input and thresholds —
    // including thresholds near the dead-zone boundary.
    let width = 64usize;
    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: 64,
        ..Default::default()
    });
    prop::forall(
        40,
        55,
        |r| {
            let x = prop::vec_f32(r, width, 2.0);
            let t: Vec<f32> = (0..width)
                .map(|_| r.uniform_range(0.0, 0.4) as f32)
                .collect();
            (x, t)
        },
        |(x, t)| {
            let l = BwhtLayer::new(width, width, t.clone(), 128);
            let want = l.forward(
                x,
                1,
                width,
                width,
                Backend::Quantized { bits: 8 },
                &mut Rng::seed_from_u64(0),
            );
            let got = {
                let mut executor = Pooled::new(&mut coord);
                l.forward_with(&mut executor, x, 1, width, width, 0)
                    .map_err(|e| e.to_string())?
            };
            if got != want {
                return Err(format!("pooled {got:?} != quantized {want:?}"));
            }
            Ok(())
        },
    );
    coord.shutdown();
}

#[test]
fn property_plan_layer_random_widths_pooled_and_sharded_match_quantized() {
    // ISSUE-4 satellite: draw random widths in [MIN_BLOCK, 2048], build
    // the natural `bwht_blocks` partition (mixed block sizes for most
    // draws), and assert pooled and sharded digital execution is
    // bit-identical to the in-process quantized backend — including the
    // fused early-termination thresholds and pinned per-sample scales
    // that `BwhtLayer::forward_with` plumbs through the seam.
    let mut set = ShardSet::new(ShardSetConfig {
        shards: 2,
        coordinator: CoordinatorConfig {
            tile_n: 128,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let mut coord = Coordinator::new(CoordinatorConfig {
        tile_n: 128,
        ..Default::default()
    });
    prop::forall(
        12,
        2024,
        |r| {
            let width = r.int_range(wht::MIN_BLOCK as i64, 2048) as usize;
            let padded = wht::bwht_padded_dim(width, 128);
            let t: Vec<f32> = (0..padded)
                .map(|_| r.uniform_range(0.0, 0.2) as f32)
                .collect();
            let x = prop::vec_f32(r, padded, 1.5);
            (padded, t, x)
        },
        |(padded, t, x)| {
            let l = BwhtLayer::new(*padded, *padded, t.clone(), 128);
            assert_eq!(
                l.transform_blocks().to_vec(),
                wht::bwht_blocks(*padded, 128),
                "layer must emit its natural partition"
            );
            let want = l.forward(
                x,
                1,
                *padded,
                *padded,
                Backend::Quantized { bits: 8 },
                &mut Rng::seed_from_u64(0),
            );
            let pooled = {
                let mut executor = Pooled::new(&mut coord);
                l.forward_with(&mut executor, x, 1, *padded, *padded, 0)
                    .map_err(|e| e.to_string())?
            };
            if pooled != want {
                return Err(format!("pooled diverged at width {padded}"));
            }
            let sharded = {
                let mut executor = Sharded::new(&mut set);
                l.forward_with(&mut executor, x, 1, *padded, *padded, 0)
                    .map_err(|e| e.to_string())?
            };
            if sharded != want {
                return Err(format!("sharded diverged at width {padded}"));
            }
            Ok(())
        },
    );
    coord.shutdown();
    set.shutdown();
}

#[test]
fn mlp_hidden_300_logits_match_quantized_backend_when_sharded() {
    // The acceptance-criteria model shape: hidden = 300 partitions as
    // [128, 128, 32, 8, 4] — nothing about it is uniform, and it must
    // still serve bit-identically through the shard set.
    let mut r = Rng::seed_from_u64(51);
    let (din, hidden, classes, batch) = (12usize, 300usize, 4usize, 3usize);
    let mlp = Mlp::from_flat(
        din,
        hidden,
        classes,
        r.normal_vec_f32(din * hidden, 0.0, 0.3),
        vec![0.0; hidden],
        vec![0.06; hidden],
        r.normal_vec_f32(hidden * classes, 0.0, 0.3),
        vec![0.0; classes],
    );
    assert_eq!(
        mlp.bwht.transform_blocks().to_vec(),
        vec![128usize, 128, 32, 8, 4]
    );
    let tile = required_tile(mlp.bwht.transform_blocks()).unwrap();
    assert_eq!(tile, 128);
    let x = sample(batch * din, 52);
    let want = mlp.forward(
        &x,
        batch,
        Backend::Quantized { bits: 8 },
        &mut Rng::seed_from_u64(0),
    );
    let mut set = ShardSet::new(ShardSetConfig {
        shards: 2,
        coordinator: CoordinatorConfig {
            tile_n: tile,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let got = {
        let mut executor = Sharded::new(&mut set);
        mlp.forward_with(&mut executor, &x, batch, 0).unwrap()
    };
    assert_eq!(got, want, "hidden-300 sharded logits");
    set.shutdown();
}

/// The per-sample reference for `schedule_batch`: every (sample, block)
/// scheduled as its own `schedule_block` call on the same tile, in
/// sample-major order — the exact execution a stream of individual jobs
/// would produce.
fn per_sample_reference(
    tile: &mut Tile,
    plan: &TilePlan,
    reqs: &[TransformRequest],
) -> Vec<Vec<f32>> {
    let mut outs = Vec::with_capacity(reqs.len());
    for req in reqs {
        let mut v = vec![0.0f32; plan.width()];
        for slot in plan.slots() {
            let lo = slot.offset;
            let hi = lo + slot.width;
            let out = schedule_block(
                tile,
                &req.x[lo..hi],
                8,
                &req.thresholds_units[lo..hi],
                req.scale,
                &slot.rows,
            );
            v[lo..hi].copy_from_slice(&out.values);
        }
        outs.push(v);
    }
    outs
}

/// Draw a random batch: a random power-of-two partition on a random
/// tile, random inputs (zero vectors included), random thresholds and an
/// optionally pinned scale.
fn random_batch(r: &mut Rng) -> (usize, Vec<usize>, Vec<TransformRequest>) {
    let tile_n = [16usize, 32][r.int_range(0, 1) as usize];
    let nblocks = r.int_range(1, 3) as usize;
    let mut blocks = Vec::with_capacity(nblocks);
    for _ in 0..nblocks {
        loop {
            let b = [4usize, 8, 16, 32][r.int_range(0, 3) as usize];
            if b <= tile_n {
                blocks.push(b);
                break;
            }
        }
    }
    let width: usize = blocks.iter().sum();
    let samples = r.int_range(1, 4) as usize;
    let mut reqs = Vec::with_capacity(samples);
    for s in 0..samples {
        let x = if s == 1 && samples > 1 {
            vec![0.0; width] // exercise the zero fast path mid-batch
        } else {
            prop::vec_f32(r, width, 1.5)
        };
        let mut thresholds_units = Vec::with_capacity(width);
        for _ in 0..width {
            thresholds_units.push(r.uniform_range(0.0, 200.0));
        }
        let scale = if r.coin() {
            Some(repro::quant::Quantizer::new(8).scale_for(&x))
        } else {
            None
        };
        reqs.push(TransformRequest {
            x,
            thresholds_units,
            scale,
            deadline: None,
        });
    }
    (tile_n, blocks, reqs)
}

#[test]
fn property_schedule_batch_is_bit_identical_to_per_sample_on_digital() {
    // ISSUE-5 satellite: random batches (width, bits via thresholds
    // range, partition) — the batch-fused plane-major engine must be
    // bit-identical to the per-sample scheduling loop on the digital
    // golden model, arena reuse across cases included.
    let mut arena = ScratchArena::new();
    prop::forall(30, 5150, random_batch, |(tile_n, blocks, reqs)| {
        let plan = TilePlan::new(*tile_n, blocks).map_err(|e| e.to_string())?;
        let mut t1 = Tile::new(*tile_n, &TileKind::Digital, 0);
        let want = per_sample_reference(&mut t1, &plan, reqs);
        let mut t2 = Tile::new(*tile_n, &TileKind::Digital, 0);
        let got = schedule_batch(&mut t2, &plan, reqs, 8, &mut arena);
        if got.values != want {
            return Err(format!("batch diverged on tile {tile_n} blocks {blocks:?}"));
        }
        Ok(())
    });
}

#[test]
fn property_noisy_tile_rng_stream_is_batching_invariant() {
    // ISSUE-5 satellite: a noisy tile's RNG stream after a batched job
    // must equal the stream after the equivalent per-sample jobs —
    // outputs agree and the tiles stay in lockstep afterwards.
    let mut arena = ScratchArena::new();
    prop::forall(15, 6270, random_batch, |(tile_n, blocks, reqs)| {
        let kind = TileKind::Noisy { sigma_ant: 0.4 };
        let plan = TilePlan::new(*tile_n, blocks).map_err(|e| e.to_string())?;
        let mut batched_tile = Tile::new(*tile_n, &kind, 13);
        let mut per_sample_tile = Tile::new(*tile_n, &kind, 13);
        let got = schedule_batch(&mut batched_tile, &plan, reqs, 8, &mut arena);
        let want = per_sample_reference(&mut per_sample_tile, &plan, reqs);
        if got.values != want {
            return Err("noisy batched outputs diverged".to_string());
        }
        let probe = vec![1i8; *tile_n];
        if batched_tile.execute_bitplane(&probe) != per_sample_tile.execute_bitplane(&probe) {
            return Err("RNG streams diverged after the batch".to_string());
        }
        Ok(())
    });
}

#[test]
fn analog_tile_rng_stream_is_batching_invariant() {
    // Same contract as the noisy sweep, on the full analog behavioral
    // model: batched execution must consume the tile's thermal-noise
    // stream byte-identically to per-sample jobs (the analog backend
    // executes every physical row per plane; only the gather is masked).
    let kind = TileKind::Analog {
        config: repro::analog::crossbar::CrossbarConfig::new(16, 0.9),
    };
    let plan = TilePlan::new(16, &[16, 4]).unwrap();
    let mut r = Rng::seed_from_u64(808);
    let mut reqs = Vec::new();
    for _ in 0..3 {
        let x = prop::vec_f32(&mut r, 20, 1.5);
        let mut thresholds_units = Vec::with_capacity(20);
        for _ in 0..20 {
            thresholds_units.push(r.uniform_range(0.0, 100.0));
        }
        reqs.push(TransformRequest {
            x,
            thresholds_units,
            scale: None,
            deadline: None,
        });
    }
    let mut batched_tile = Tile::new(16, &kind, 31);
    let mut per_sample_tile = Tile::new(16, &kind, 31);
    let mut arena = ScratchArena::new();
    let got = schedule_batch(&mut batched_tile, &plan, &reqs, 8, &mut arena);
    let want = per_sample_reference(&mut per_sample_tile, &plan, &reqs);
    assert_eq!(got.values, want, "analog batched outputs");
    let probe = vec![1i8; 16];
    assert_eq!(
        batched_tile.execute_bitplane(&probe),
        per_sample_tile.execute_bitplane(&probe),
        "analog RNG streams diverged after the batch"
    );
}

#[test]
fn in_process_executor_exposes_backend_bits() {
    assert_eq!(InProcess::new(Backend::Float, 0).quant_bits(), None);
    assert_eq!(
        InProcess::new(Backend::Quantized { bits: 6 }, 0).quant_bits(),
        Some(6)
    );
    assert_eq!(
        InProcess::new(
            Backend::Noisy {
                bits: 3,
                sigma_ant: 0.1
            },
            0
        )
        .quant_bits(),
        Some(3)
    );
}

//! Malformed-framing and connection-lifecycle coverage for the epoll
//! front end: oversized header blocks, bad/absent `Content-Length`,
//! partial-header stalls against the first-byte timeout, pipelined
//! back-to-back requests, and HTTP/1.0 close-by-default semantics.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use repro::server::{Server, ServerConfig};

fn start_default() -> Server {
    Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        ..Default::default()
    })
    .expect("server start")
}

/// Write raw bytes, then read until the server closes the connection.
fn raw_roundtrip(addr: SocketAddr, raw: &[u8]) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    stream.write_all(raw).unwrap();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).expect("read response");
    buf
}

fn status_of(response: &str) -> u16 {
    response
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status")
}

/// Read one framed HTTP response off a persistent connection.
fn read_response(reader: &mut BufReader<TcpStream>) -> (u16, String) {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).expect("status line");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).expect("header line");
        let trimmed = line.trim_end();
        if trimmed.is_empty() {
            break;
        }
        if let Some((name, value)) = trimmed.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().expect("content length");
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).expect("body");
    (status, String::from_utf8(body).expect("utf-8 body"))
}

fn metric_value(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|line| {
            let rest = line.strip_prefix(name)?;
            let rest = rest.strip_prefix(' ')?;
            rest.trim().parse::<f64>().ok()
        })
        .unwrap_or(f64::NAN)
}

fn scrape_metrics(addr: SocketAddr) -> String {
    let response = raw_roundtrip(
        addr,
        b"GET /metrics HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(status_of(&response), 200);
    response.split("\r\n\r\n").nth(1).unwrap_or("").to_string()
}

/// Write `first` (which must keep the server below its framing caps),
/// give the reactor time to consume it, then write `second` (which
/// crosses a cap) and read the rejection.  The pause guarantees the
/// server has drained everything it was sent before it errors, so the
/// 400 arrives on a clean close instead of being lost to a reset.
fn paced_rejection(addr: SocketAddr, first: &[u8], second: &[u8]) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    stream.write_all(first).unwrap();
    stream.flush().unwrap();
    std::thread::sleep(Duration::from_millis(50));
    let _ = stream.write_all(second);
    let mut buf = String::new();
    stream.read_to_string(&mut buf).expect("read response");
    buf
}

#[test]
fn oversized_header_block_is_rejected_with_400() {
    let server = start_default();
    let addr = server.addr;

    // Well-formed header lines whose total crosses the 16 KiB cap.
    let mut under_cap = b"GET /healthz HTTP/1.1\r\n".to_vec();
    for i in 0..170 {
        under_cap.extend_from_slice(format!("X-Pad-{i}: {}\r\n", "a".repeat(80)).as_bytes());
    }
    let mut over_cap = Vec::new();
    for i in 170..220 {
        over_cap.extend_from_slice(format!("X-Pad-{i}: {}\r\n", "a".repeat(80)).as_bytes());
    }
    over_cap.extend_from_slice(b"\r\n");
    let response = paced_rejection(addr, &under_cap, &over_cap);
    assert_eq!(status_of(&response), 400, "{response}");
    assert!(response.contains("bad request"), "{response}");

    // A newline-free flood must also error at the cap instead of
    // buffering without bound.
    let flood = vec![b'A'; 15 << 10];
    let tail = vec![b'A'; 4 << 10];
    let response = paced_rejection(addr, &flood, &tail);
    assert_eq!(status_of(&response), 400, "{response}");

    let metrics = scrape_metrics(addr);
    assert!(
        metric_value(&metrics, "repro_http_bad_requests_total") >= 2.0,
        "{metrics}"
    );
    server.shutdown();
}

#[test]
fn bad_and_oversized_content_length_are_rejected() {
    let server = start_default();
    let addr = server.addr;

    let response = raw_roundtrip(
        addr,
        b"POST /v1/transform HTTP/1.1\r\nHost: t\r\nContent-Length: banana\r\n\r\n",
    );
    assert_eq!(status_of(&response), 400, "{response}");
    assert!(response.contains("Content-Length"), "{response}");

    let huge = format!(
        "POST /v1/transform HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
        1u64 << 30
    );
    let response = raw_roundtrip(addr, huge.as_bytes());
    assert_eq!(status_of(&response), 400, "{response}");
    server.shutdown();
}

#[test]
fn post_without_content_length_reads_as_empty_body() {
    let server = start_default();
    let addr = server.addr;
    // No Content-Length: the framed body is empty, which fails JSON
    // parsing in the handler — a clean 400, not a hang or a 500.
    let response = raw_roundtrip(
        addr,
        b"POST /v1/transform HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(status_of(&response), 400, "{response}");
    server.shutdown();
}

#[test]
fn partial_header_stall_hits_the_first_byte_timeout_silently() {
    let server = Server::start(ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        first_byte_timeout: Duration::from_millis(150),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr;

    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    // A slowloris-style stall: half a request line, then silence.
    stream.write_all(b"GET /healthz HT").unwrap();
    let start = Instant::now();
    let mut rest = Vec::new();
    stream.read_to_end(&mut rest).expect("server closes");
    assert!(rest.is_empty(), "stalled connection must close silently");
    assert!(
        start.elapsed() < Duration::from_secs(10),
        "close must come from the timeout wheel, not the read deadline"
    );

    let metrics = scrape_metrics(addr);
    assert!(
        metric_value(&metrics, "repro_connections_timed_out_total") >= 1.0,
        "{metrics}"
    );
    assert!(
        metric_value(&metrics, "repro_connections_accepted_total") >= 2.0,
        "{metrics}"
    );
    server.shutdown();
}

#[test]
fn pipelined_requests_are_served_back_to_back() {
    let server = start_default();
    let addr = server.addr;

    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // Two POSTs and a GET in one write: the state machine must frame
    // and serve them in order off the same buffered bytes.
    let body = "{\"x\":[1,-1,0.5,0.25]}";
    let post = format!(
        "POST /v1/transform HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    let mut raw = Vec::new();
    raw.extend_from_slice(post.as_bytes());
    raw.extend_from_slice(post.as_bytes());
    raw.extend_from_slice(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    writer.write_all(&raw).unwrap();
    writer.flush().unwrap();

    for i in 0..2 {
        let (status, body) = read_response(&mut reader);
        assert_eq!(status, 200, "pipelined request {i}: {body}");
        assert!(body.contains("\"y\""), "pipelined request {i}: {body}");
    }
    let (status, body) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert_eq!(body, "ok\n");

    // The connection is still usable for a framed follow-up.
    writer
        .write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .unwrap();
    writer.flush().unwrap();
    let (status, body) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert_eq!(body, "ok\n");
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "server must close after Connection: close");
    server.shutdown();
}

#[test]
fn http10_without_keep_alive_closes_after_one_response() {
    let server = start_default();
    let addr = server.addr;
    let response = raw_roundtrip(addr, b"GET /healthz HTTP/1.0\r\nHost: t\r\n\r\n");
    assert_eq!(status_of(&response), 200, "{response}");
    assert!(response.contains("Connection: close"), "{response}");
    assert!(response.ends_with("ok\n"), "{response}");
    server.shutdown();
}

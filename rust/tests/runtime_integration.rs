//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These need `artifacts/` built by `make artifacts` (the Makefile runs
//! python once, build-time only).  Every test cross-checks the HLO
//! round-trip against the pure-rust golden models — the strongest signal
//! that L1 (pallas), L2 (jax) and L3 (rust) agree numerically.
//!
//! The PJRT runtime needs the XLA toolchain, so this whole test crate is
//! gated behind the non-default `pjrt` feature.

#![cfg(feature = "pjrt")]

use repro::bitplane::QuantBwht;
use repro::nn::{Backend, Mlp};
use repro::npy;
use repro::runtime::{HostTensor, Runtime};
use repro::util::rng::Rng;
use repro::wht;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

fn load_params(dir: &std::path::Path) -> Vec<HostTensor> {
    ["fc1_w", "fc1_b", "bwht_t", "fc2_w", "fc2_b"]
        .iter()
        .map(|n| {
            let a = npy::load_f32(dir.join(format!("init_{n}.npy"))).unwrap();
            HostTensor::f32(&a.shape, a.data)
        })
        .collect()
}

#[test]
fn wht16_artifact_matches_rust_fast_wht() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut rt = Runtime::new(artifacts_dir()).unwrap();
    let mut rng = Rng::seed_from_u64(1);
    let x: Vec<f32> = (0..16 * 16)
        .map(|_| rng.uniform_range(-2.0, 2.0) as f32)
        .collect();
    let out = rt
        .run("wht16", &[HostTensor::f32(&[16, 16], x.clone())])
        .unwrap();
    let y = out[0].as_f32().unwrap();
    // rust golden: per-row sequency WHT
    for r in 0..16 {
        let mut row = x[r * 16..(r + 1) * 16].to_vec();
        wht::wht_sequency(&mut row);
        for c in 0..16 {
            assert!(
                (y[r * 16 + c] - row[c]).abs() < 1e-3,
                "row {r} col {c}: pallas {} vs rust {}",
                y[r * 16 + c],
                row[c]
            );
        }
    }
}

#[test]
fn quant_bwht_artifact_matches_rust_golden_model() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::new(artifacts_dir()).unwrap();
    let mut rng = Rng::seed_from_u64(2);
    let x: Vec<f32> = (0..32 * 64)
        .map(|_| rng.uniform_range(-1.5, 1.5) as f32)
        .collect();
    let out = rt
        .run("quant_bwht64", &[HostTensor::f32(&[32, 64], x.clone())])
        .unwrap();
    let y = out[0].as_f32().unwrap();
    // rust golden model per row: the whole point of the stack — the
    // pallas kernel (Eq. 4) and the rust bit-serial engine must agree
    // bit-for-bit BUT quantization scale: the kernel quantizes per-tensor
    // over the full (32,64) batch, the rust engine per row. Compare
    // against an engine fed the kernel's global scale.
    let amax = x.iter().fold(0f32, |m, v| m.max(v.abs()));
    let qmax = 255.0f32;
    let scale = amax / qmax;
    for r in 0..32 {
        let row = &x[r * 64..(r + 1) * 64];
        // quantize with the global scale, then run the plane pipeline
        let q: Vec<i32> = row
            .iter()
            .map(|v| (v / scale).round().clamp(-qmax, qmax) as i32)
            .collect();
        let quantized = repro::quant::Quantized {
            q,
            scale,
            bits: 8,
        };
        let eng = QuantBwht::new(64, 128, 8);
        let mut acc = vec![0f32; 64];
        let mut plane = vec![0i8; 64];
        let mut planes = quantized.planes_msb_first();
        while let Some(b) = planes.next_into(&mut plane) {
            let psums = eng.plane_psums(&plane);
            let w = (1i64 << b) as f32;
            for (a, &ps) in acc.iter_mut().zip(&psums) {
                *a += repro::bitplane::comparator(ps) as f32 * w;
            }
        }
        for c in 0..64 {
            let want = acc[c] * scale;
            assert!(
                (y[r * 64 + c] - want).abs() < 1e-4,
                "row {r} col {c}: pallas {} vs rust {}",
                y[r * 64 + c],
                want
            );
        }
    }
}

#[test]
fn mlp_fwd_artifact_matches_rust_nn() {
    if !have_artifacts() {
        return;
    }
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir).unwrap();
    let params = load_params(&dir);
    let xte = npy::load_f32(dir.join("test_x.npy")).unwrap();
    let xb: Vec<f32> = xte.data[..64 * 64].to_vec();
    let mut inputs = params.clone();
    inputs.push(HostTensor::f32(&[64, 64], xb.clone()));
    let out = rt.run("mlp_fwd", &inputs).unwrap();
    let pjrt = out[0].as_f32().unwrap();

    let flat: Vec<Vec<f32>> = params
        .iter()
        .map(|t| t.as_f32().unwrap().to_vec())
        .collect();
    let mlp = Mlp::from_flat(
        64,
        64,
        10,
        flat[0].clone(),
        flat[1].clone(),
        flat[2].clone(),
        flat[3].clone(),
        flat[4].clone(),
    );
    let mut rng = Rng::seed_from_u64(0);
    let rust = mlp.forward(&xb, 64, Backend::Float, &mut rng);
    for (i, (a, b)) in pjrt.iter().zip(&rust).enumerate() {
        assert!((a - b).abs() < 1e-3, "logit {i}: pjrt {a} vs rust {b}");
    }
}

#[test]
fn train_step_artifact_reduces_loss_and_transfers() {
    if !have_artifacts() {
        return;
    }
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir).unwrap();
    let mut params = load_params(&dir);
    let xtr = npy::load_f32(dir.join("train_x.npy")).unwrap();
    let ytr = npy::load_i32(dir.join("train_y.npy")).unwrap();
    let batch = 64usize;
    let din = xtr.shape[1];
    let mut rng = Rng::seed_from_u64(3);
    let mut losses = Vec::new();
    for _ in 0..40 {
        let mut bx = Vec::with_capacity(batch * din);
        let mut by = Vec::with_capacity(batch);
        for _ in 0..batch {
            let i = rng.int_range(0, xtr.shape[0] as i64 - 1) as usize;
            bx.extend_from_slice(xtr.row(i));
            by.push(ytr.data[i]);
        }
        let mut inputs = params.clone();
        inputs.push(HostTensor::f32(&[batch, din], bx));
        inputs.push(HostTensor::i32(&[batch], by));
        let mut outputs = rt.run("train_step", &inputs).unwrap();
        let loss = outputs.pop().unwrap().scalar_f32().unwrap();
        losses.push(loss);
        params = outputs;
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.5),
        "training must reduce loss: {losses:?}"
    );

    // Trained params must transfer to the rust engine above chance.
    let xte = npy::load_f32(dir.join("test_x.npy")).unwrap();
    let yte = npy::load_i32(dir.join("test_y.npy")).unwrap();
    let flat: Vec<Vec<f32>> = params
        .iter()
        .map(|t| t.as_f32().unwrap().to_vec())
        .collect();
    let mlp = Mlp::from_flat(
        din,
        64,
        10,
        flat[0].clone(),
        flat[1].clone(),
        flat[2].clone(),
        flat[3].clone(),
        flat[4].clone(),
    );
    let mut r2 = Rng::seed_from_u64(4);
    let acc = mlp.evaluate(
        &xte.data,
        &yte.data,
        Backend::Quantized { bits: 8 },
        &mut r2,
        256,
    );
    assert!(acc > 0.5, "transferred accuracy too low: {acc}");
}

#[test]
fn runtime_rejects_bad_shapes() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::new(artifacts_dir()).unwrap();
    let bad = rt.run("wht16", &[HostTensor::f32(&[8, 8], vec![0.0; 64])]);
    assert!(bad.is_err());
    let missing = rt.run("nonexistent", &[]);
    assert!(missing.is_err());
}
